//! Fig. 4 reproduction: detection delay & accuracy vs. the maximum HV
//! density after thinning (the sparse-HDC hyperparameter).
//!
//! ```bash
//! cargo run --release --example density_sweep
//! ```
//!
//! Thin wrapper over `repro fig4` semantics: sweeps the density grid for
//! the optimized sparse design over several synthetic patients (lines in
//! Fig. 4), finds each patient's optimum (the stars), and prints the
//! dense-HDC reference point. See EXPERIMENTS.md §FIG4 for the
//! paper-vs-measured discussion.

use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::evalpool;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::pipeline;

fn main() -> sparse_hdc_ieeg::Result<()> {
    let densities = [0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.40, 0.50];
    let synth = SynthConfig {
        records_per_patient: 4,
        pre_s: 30.0,
        ictal_s: 20.0,
        post_s: 10.0,
        ..Default::default()
    };
    let patients: Vec<SynthPatient> = (1..=6).map(|p| SynthPatient::generate(&synth, p)).collect();
    let policy = AlarmPolicy { consecutive: 1 };

    println!("max-density   mean-delay-s   detection-acc   FA/h   (sparse-optimized)");
    // Shard all (density × patient) cells over the evaluation pool;
    // results come back in input order, so the aggregation below is
    // identical to the serial sweep.
    let jobs: Vec<(f64, usize)> = densities
        .iter()
        .flat_map(|&d| (0..patients.len()).map(move |i| (d, i)))
        .collect();
    let evals = evalpool::map(&jobs, |&(d, i)| {
        pipeline::evaluate_patient(
            Variant::Optimized,
            &ClassifierConfig::optimized(),
            &patients[i],
            Some(d),
            policy,
        )
    });

    let mut best: Vec<(f64, f64)> = vec![(f64::INFINITY, 0.0); patients.len()];
    for (di, &d) in densities.iter().enumerate() {
        let mut delays = Vec::new();
        let mut acc = 0.0;
        let mut fa = 0.0;
        let row = &evals[di * patients.len()..(di + 1) * patients.len()];
        for (i, eval) in row.iter().enumerate() {
            if eval.summary.mean_delay_s().is_finite() {
                delays.push(eval.summary.mean_delay_s());
            }
            acc += eval.summary.detection_accuracy();
            fa += eval.summary.false_alarms_per_hour();
            let (bd, ba) = best[i];
            let (ed, ea) = (eval.summary.mean_delay_s(), eval.summary.detection_accuracy());
            if ea > ba || (ea == ba && ed < bd) {
                best[i] = (ed, ea);
            }
        }
        println!(
            "{:>9.0}% {:>13.2} {:>14.1}% {:>6.2}",
            d * 100.0,
            delays.iter().sum::<f64>() / delays.len().max(1) as f64,
            acc / patients.len() as f64 * 100.0,
            fa / patients.len() as f64
        );
    }

    let star_d: f64 = best.iter().filter(|(d, _)| d.is_finite()).map(|(d, _)| d).sum::<f64>()
        / best.iter().filter(|(d, _)| d.is_finite()).count().max(1) as f64;
    let star_a: f64 = best.iter().map(|(_, a)| a).sum::<f64>() / best.len() as f64;
    println!("\nper-patient tuned (stars): delay {star_d:.2} s, accuracy {:.1}%", star_a * 100.0);

    let dense_evals = evalpool::map(&patients, |p| {
        pipeline::evaluate_patient(
            Variant::DenseBaseline,
            &ClassifierConfig::default(),
            p,
            None,
            policy,
        )
    });
    let mut delays = Vec::new();
    let mut acc = 0.0;
    for e in &dense_evals {
        if e.summary.mean_delay_s().is_finite() {
            delays.push(e.summary.mean_delay_s());
        }
        acc += e.summary.detection_accuracy();
    }
    println!(
        "dense HDC baseline:        delay {:.2} s, accuracy {:.1}%",
        delays.iter().sum::<f64>() / delays.len().max(1) as f64,
        acc / patients.len() as f64 * 100.0
    );
    Ok(())
}
