//! Fig. 1(c) + Fig. 5 reproduction: gate-level area/energy breakdowns of
//! all four design points under the patient-11 stimulus.
//!
//! ```bash
//! cargo run --release --example hw_breakdown
//! ```

use sparse_hdc_ieeg::hdc::classifier::ClassifierConfig;
use sparse_hdc_ieeg::hwmodel::breakdown::{format_breakdown, format_comparison};
use sparse_hdc_ieeg::hwmodel::designs::analyze_all;

fn main() -> sparse_hdc_ieeg::Result<()> {
    let reports = analyze_all(&ClassifierConfig::default(), 4);

    println!("=== Fig. 1(c): naive sparse HDC breakdown ===\n");
    print!("{}", format_breakdown(&reports[1]));
    let bind = ["binding", "one-hot-decoder"];
    println!(
        "\nbinding+decoder: {:.1}% energy / {:.1}% area (paper 51.3% / 38%); \
         spatial bundling {:.1}% area (paper 44.9%)\n",
        reports[1].group_energy_nj(&bind) / reports[1].energy_nj_per_pred() * 100.0,
        reports[1].group_area_mm2(&bind) / reports[1].area_mm2() * 100.0,
        reports[1].group_area_mm2(&["spatial-bundling"]) / reports[1].area_mm2() * 100.0,
    );

    println!("=== Fig. 5: four design points ===\n");
    print!("{}", format_comparison(&reports));

    let opt = &reports[3];
    let base = &reports[1];
    let dense = &reports[0];
    println!(
        "\nheadline ratios: vs sparse baseline {:.2}×E {:.2}×A (paper 1.72/2.20); \
         vs dense {:.2}×E {:.2}×A (paper 7.50/3.24)",
        base.energy_nj_per_pred() / opt.energy_nj_per_pred(),
        base.area_mm2() / opt.area_mm2(),
        dense.energy_nj_per_pred() / opt.energy_nj_per_pred(),
        dense.area_mm2() / opt.area_mm2(),
    );
    println!(
        "optimized point: {:.4} mm², {:.2} nJ/predict (paper 0.059 mm², 12.5 nJ)",
        opt.area_mm2(),
        opt.energy_nj_per_pred()
    );
    Ok(())
}
