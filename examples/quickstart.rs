//! Quickstart: one patient, one-shot training, seizure detection.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core library API end to end on synthetic iEEG:
//! generate a patient, train on their first seizure (one-shot protocol,
//! paper §II-D), run the optimized sparse classifier over the remaining
//! seizures and report detection delay + accuracy (paper §IV-A metrics).

use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::pipeline;

fn main() -> sparse_hdc_ieeg::Result<()> {
    // 1. Synthetic patient: 4 records, one seizure each (record 0 trains).
    let synth = SynthConfig {
        records_per_patient: 4,
        pre_s: 30.0,
        ictal_s: 20.0,
        post_s: 10.0,
        ..Default::default()
    };
    let patient = SynthPatient::generate(&synth, 11);
    println!(
        "patient 11: {} records, ictal rhythm {:.1} Hz, focus electrodes {:?}",
        patient.records.len(),
        patient.profile.rhythm_hz,
        patient.profile.focus
    );

    // 2. The paper's optimized design point (CompIM + OR bundling,
    //    temporal threshold tuned for max query density 25%).
    let cfg = ClassifierConfig::optimized();
    let eval = pipeline::evaluate_patient(
        Variant::Optimized,
        &cfg,
        &patient,
        Some(0.25), // max HV density after thinning (Fig. 4 hyperparameter)
        AlarmPolicy { consecutive: 1 },
    );

    println!(
        "\none-shot training on record 0, testing on {} seizures:",
        eval.summary.seizures
    );
    println!(
        "  detected          : {}/{} ({:.0}%)",
        eval.summary.detected,
        eval.summary.seizures,
        eval.summary.detection_accuracy() * 100.0
    );
    println!("  mean delay        : {:.2} s", eval.summary.mean_delay_s());
    println!(
        "  false alarms      : {:.2} /h",
        eval.summary.false_alarms_per_hour()
    );
    println!(
        "  window accuracy   : {:.1}%",
        eval.summary.mean_window_accuracy() * 100.0
    );
    println!(
        "  temporal threshold: {} (query density {:.1}%)",
        eval.temporal_threshold,
        eval.mean_query_density * 100.0
    );

    // 3. Compare against the dense HDC baseline (Burrello'18).
    let dense = pipeline::evaluate_patient(
        Variant::DenseBaseline,
        &ClassifierConfig::default(),
        &patient,
        None,
        AlarmPolicy { consecutive: 1 },
    );
    println!(
        "\ndense HDC baseline: {}/{} detected, mean delay {:.2} s",
        dense.summary.detected,
        dense.summary.seizures,
        dense.summary.mean_delay_s()
    );
    Ok(())
}
