//! Table I reproduction: comparison with state-of-the-art seizure /
//! biosignal classification chips.
//!
//! ```bash
//! cargo run --release --example sota_table
//! ```
//!
//! Our row is measured from the gate-level cost model under the
//! patient-11 stimulus; the other rows are the published numbers the
//! paper tabulates ([10] SVM, [11] decision tree, [3] dense HDC).

use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hwmodel::breakdown::{format_table1, literature_rows, ours_row};
use sparse_hdc_ieeg::hwmodel::designs::{analyze, patient11_stimulus};

fn main() -> sparse_hdc_ieeg::Result<()> {
    let frames = patient11_stimulus(4);
    let cfg = ClassifierConfig {
        spatial_threshold: 1,
        ..ClassifierConfig::optimized()
    };
    let rep = analyze(Variant::Optimized, &cfg, &frames);

    println!("=== Table I: comparison to SotA ===\n");
    print!("{}", format_table1(&rep));

    // The paper's two Table-I claims, checked programmatically:
    let ours = ours_row(&rep);
    let most_efficient = literature_rows()
        .iter()
        .all(|r| ours.energy_per_predict_nj < r.energy_per_predict_nj
            && ours.area_mm2 < r.area_mm2.max(0.0601));
    println!(
        "\nclaim 1 (most energy-efficient per prediction): {}",
        if most_efficient { "HOLDS" } else { "check" }
    );
    let menon = &literature_rows()[2];
    println!(
        "claim 2 (per-channel energy comparable to [3]): ours {:.3} vs [3] {:.3} nJ/ch \
         ({}× — the paper explains the gap closes because [3] runs its temporal encoder \
         once per prediction vs our 256)",
        ours.energy_per_channel_nj(),
        menon.energy_per_channel_nj(),
        (ours.energy_per_channel_nj() / menon.energy_per_channel_nj()).max(
            menon.energy_per_channel_nj() / ours.energy_per_channel_nj()
        ) as i64
    );
    Ok(())
}
