//! End-to-end system driver (DESIGN.md §4, experiment E2E).
//!
//! ```bash
//! make artifacts && cargo run --release --example streaming_detection [-- --pjrt] [--realtime]
//! ```
//!
//! Proves all layers compose on a real (synthetic-patient) workload:
//! 4 patients are one-shot trained, then their test seizures are served
//! *concurrently* through the streaming coordinator — LBP front-end,
//! per-session windowing, bounded-queue engine worker (native golden
//! model or, with `--pjrt`, the AOT-compiled HLO executed through the
//! PJRT runtime — the full Rust+JAX+Pallas stack on the request path),
//! K-consecutive alarm detector — and scored against the expert
//! annotations. Reports detection quality AND serving latency/throughput.

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec};
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};
use sparse_hdc_ieeg::pipeline;

fn main() -> sparse_hdc_ieeg::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let use_pjrt = args.iter().any(|a| a == "--pjrt");
    let realtime = args.iter().any(|a| a == "--realtime");

    let synth = SynthConfig {
        records_per_patient: 2,
        pre_s: 20.0,
        ictal_s: 15.0,
        post_s: 5.0,
        ..Default::default()
    };

    // One-shot training per patient, streaming spec per test record.
    let cfg = ClassifierConfig::optimized();
    let mut streams = Vec::new();
    for pid in 1..=4u32 {
        let patient = SynthPatient::generate(&synth, pid);
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let bundle = pipeline::train_on_record(&mut enc, patient.train_record(), &cfg);
        println!(
            "patient {pid}: trained one-shot, model v{} (class densities {:.1}% / {:.1}%)",
            bundle.version,
            bundle.am.classes[0].density() * 100.0,
            bundle.am.classes[1].density() * 100.0
        );
        streams.push(StreamSpec {
            session_id: pid as u64,
            patient_id: pid,
            record: patient.records[1].clone(),
            bundle,
        });
    }

    let backend = if use_pjrt {
        Backend::Pjrt {
            artifacts_dir: "artifacts".into(),
        }
    } else {
        Backend::Native
    };
    let mut system = SystemConfig::default();
    system.alarm_consecutive = 1;
    let mut coordinator = Coordinator::new(system, backend);
    coordinator.realtime = realtime;

    println!(
        "\nstreaming {} sessions concurrently ({} backend, {})…",
        streams.len(),
        if use_pjrt { "PJRT/HLO" } else { "native" },
        if realtime { "realtime 512 Hz pacing" } else { "max speed" }
    );
    let t0 = std::time::Instant::now();
    let report = coordinator.run(streams)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== detection ===");
    for s in &report.sessions {
        println!(
            "  patient {}: windows {}, alarms {:?}, detected {:?}, delay {:?} s",
            s.patient_id,
            s.windows,
            s.alarms.iter().map(|a| a.time_s).collect::<Vec<_>>(),
            s.eval.detected,
            s.eval.delay_s
        );
    }
    println!(
        "  total: {}/{} seizures detected, mean delay {:.2} s",
        report.summary.detected,
        report.summary.seizures,
        report.summary.mean_delay_s()
    );

    println!("\n=== serving ===");
    println!("  {}", report.metrics.summary());
    println!(
        "  wall time {wall:.2} s for {:.1} s of 4-patient iEEG ({:.1}× realtime)",
        report.metrics.samples_in as f64 / 4.0 / 512.0,
        report.metrics.samples_in as f64 / 4.0 / 512.0 / wall
    );
    sparse_hdc_ieeg::ensure!(
        report.metrics.windows_failed == 0,
        "windows failed during serving"
    );
    sparse_hdc_ieeg::ensure!(
        report.summary.detected > 0,
        "end-to-end run detected no seizures"
    );
    println!("\nOK: all layers compose (LBP → encode → detect → score).");
    Ok(())
}
