"""AOT lowering: JAX graphs → HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids that the xla_extension 0.5.1 inside the published ``xla`` crate
rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts (written to ``--out-dir``, default ``../artifacts``):

* ``sparse_window.hlo.txt`` — optimized sparse design, full window:
  (codes i32[256,64], im_pos i32[64,64,8], elec_pos i32[64,8],
   am i32[2,1024], thr i32[1]) → (scores i32[2], query i32[1024])
* ``dense_window.hlo.txt``  — dense baseline:
  (codes, im_bits i32[64,1024], elec_bits i32[64,1024], tie_s i32[1024],
   tie_t i32[1024], am) → (scores, query)
* ``manifest.txt``          — shapes, seeds and the cross-language IM digest.

The item-memory tables are runtime *inputs*, not baked constants: the HLO
text printer elides large constants (``constant({...})``), so the tables
must cross the interchange boundary as parameters. The Rust runtime
regenerates them (digest-checked) and binds them at engine load.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import hdc_params as P
from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_sparse(t_frames: int, graph: str = "pallas") -> str:
    """Lower the sparse window. `graph`:

    * ``pallas`` (default) — the L1 Pallas kernel (interpret-mode) inlined
      into the L2 graph: the prescribed three-layer artifact.
    * ``ref`` — the pure-jnp reference graph (bit-identical; measured ~30%
      faster through the CPU PJRT path of the old xla_extension — see
      EXPERIMENTS.md §Perf L2-3).
    """
    codes, am, thr = model.example_inputs(t_frames)
    im_pos, elec_pos = model.sparse_table_specs()

    def fn(codes, im_pos, elec_pos, am, thr):
        return model.sparse_window_core(
            codes, im_pos, elec_pos, am, thr, use_pallas=(graph == "pallas")
        )

    return to_hlo_text(jax.jit(fn).lower(codes, im_pos, elec_pos, am, thr))


def lower_dense(t_frames: int) -> str:
    codes, am, _ = model.example_inputs(t_frames)
    im_bits, elec_bits, tie_s, tie_t = model.dense_table_specs()

    def fn(codes, im_bits, elec_bits, tie_s, tie_t, am):
        return model.dense_window_core(codes, im_bits, elec_bits, tie_s, tie_t, am)

    return to_hlo_text(
        jax.jit(fn).lower(codes, im_bits, elec_bits, tie_s, tie_t, am)
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--frames", type=int, default=P.FRAMES_PER_PREDICTION)
    ap.add_argument("--graph", choices=["pallas", "ref"], default="pallas",
                    help="sparse-window graph flavour (see lower_sparse)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)

    jobs = [
        ("sparse_window.hlo.txt", lambda: lower_sparse(args.frames, args.graph)),
        ("dense_window.hlo.txt", lambda: lower_dense(args.frames)),
    ]
    for name, build in jobs:
        path = os.path.join(args.out_dir, name)
        text = build()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}", file=sys.stderr)

    digest = P.im_digest()
    manifest = "\n".join(
        [
            "# sparse-hdc-ieeg AOT manifest",
            f"frames = {args.frames}",
            f"channels = {P.CHANNELS}",
            f"dim = {P.DIM}",
            f"segments = {P.SEGMENTS}",
            f"num_classes = {P.NUM_CLASSES}",
            f"im_seed = {P.IM_SEED:#018x}",
            f"im_digest = {digest:#018x}",
            "sparse_window = sparse_window.hlo.txt",
            "dense_window = dense_window.hlo.txt",
            "",
        ]
    )
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(manifest)
    print(f"im_digest = {digest:#018x}", file=sys.stderr)


if __name__ == "__main__":
    main()
