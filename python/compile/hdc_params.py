"""Architecture parameters + deterministic item-memory generation.

This file is the Python mirror of ``rust/src/params.rs`` and
``rust/src/rng.rs`` / ``rust/src/hdc/im.rs``. Every layer of the stack —
the Rust golden model, these JAX/Pallas kernels and therefore the AOT HLO
artifacts — must contain *bit-identical* item memories; the generator is
pinned to SplitMix64 chained hashing (see the Rust doc comments). Change
one side only ever together with the other; ``im_digest()`` is compared
against the Rust side by an integration test.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1

# --- architecture constants (rust/src/params.rs) ---
DIM = 1024
SEGMENTS = 8
SEG_LEN = DIM // SEGMENTS  # 128
SEG_POS_BITS = 7
CHANNELS = 64
LBP_BITS = 6
LBP_CODES = 1 << LBP_BITS
FRAMES_PER_PREDICTION = 256
TEMPORAL_THRESHOLD_DEFAULT = 130
TEMPORAL_COUNTER_MAX = 255
NUM_CLASSES = 2
IM_SEED = 0x5EED_1EE6_0000_0001

# --- domain-separation tags (rust/src/hdc/im.rs) ---
TAG_SPARSE_IM = 1
TAG_SPARSE_ELECTRODE = 2
TAG_DENSE_IM = 3
TAG_DENSE_ELECTRODE = 4
TAG_DENSE_TIEBREAK = 5


def splitmix64_mix(z: int) -> int:
    """The SplitMix64 finalizer (rust/src/rng.rs::splitmix64_mix)."""
    z = (z + 0x9E37_79B9_7F4A_7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
    return z ^ (z >> 31)


def hash_chain(seed: int, words) -> int:
    """Domain-separated chained hash (rust/src/rng.rs::hash_chain)."""
    h = splitmix64_mix(seed)
    for w in words:
        h = splitmix64_mix(h ^ w)
    return h


def sparse_im_positions(seed: int = IM_SEED) -> np.ndarray:
    """[CHANNELS, LBP_CODES, SEGMENTS] uint8 — data-HV 1-bit positions."""
    out = np.empty((CHANNELS, LBP_CODES, SEGMENTS), dtype=np.uint8)
    for c in range(CHANNELS):
        for k in range(LBP_CODES):
            for s in range(SEGMENTS):
                out[c, k, s] = hash_chain(seed, (TAG_SPARSE_IM, c, k, s)) % SEG_LEN
    return out


def sparse_electrode_positions(seed: int = IM_SEED) -> np.ndarray:
    """[CHANNELS, SEGMENTS] uint8 — electrode-HV 1-bit positions."""
    out = np.empty((CHANNELS, SEGMENTS), dtype=np.uint8)
    for c in range(CHANNELS):
        for s in range(SEGMENTS):
            out[c, s] = hash_chain(seed, (TAG_SPARSE_ELECTRODE, c, s)) % SEG_LEN
    return out


def _words_to_bits(words) -> np.ndarray:
    """16 u64 words (LSB-first) → [DIM] int32 0/1 array."""
    bits = np.empty(DIM, dtype=np.int32)
    for wi, w in enumerate(words):
        for b in range(64):
            bits[wi * 64 + b] = (w >> b) & 1
    return bits


def dense_im_bits(seed: int = IM_SEED) -> np.ndarray:
    """[LBP_CODES, DIM] int32 — dense code HVs."""
    out = np.empty((LBP_CODES, DIM), dtype=np.int32)
    for k in range(LBP_CODES):
        words = [hash_chain(seed, (TAG_DENSE_IM, k, w)) for w in range(DIM // 64)]
        out[k] = _words_to_bits(words)
    return out


def dense_electrode_bits(seed: int = IM_SEED) -> np.ndarray:
    """[CHANNELS, DIM] int32 — dense electrode HVs."""
    out = np.empty((CHANNELS, DIM), dtype=np.int32)
    for c in range(CHANNELS):
        words = [hash_chain(seed, (TAG_DENSE_ELECTRODE, c, w)) for w in range(DIM // 64)]
        out[c] = _words_to_bits(words)
    return out


def dense_tiebreak_bits(seed: int = IM_SEED, stage: int = 0) -> np.ndarray:
    """[DIM] int32 — tie-break HV for bundling stage (0 spatial, 1 temporal)."""
    words = [hash_chain(seed, (TAG_DENSE_TIEBREAK, stage, w)) for w in range(DIM // 64)]
    return _words_to_bits(words)


def im_digest(seed: int = IM_SEED) -> int:
    """Order-sensitive digest over the sparse IM + electrode tables.

    The Rust integration test (rust/tests/cross_language.rs) recomputes
    this digest from its own tables; equality proves the two languages
    generate identical item memories.
    """
    h = splitmix64_mix(seed)
    im = sparse_im_positions(seed)
    el = sparse_electrode_positions(seed)
    for v in im.reshape(-1):
        h = splitmix64_mix(h ^ int(v))
    for v in el.reshape(-1):
        h = splitmix64_mix(h ^ int(v))
    return h


if __name__ == "__main__":
    print(f"im_digest(IM_SEED) = {im_digest():#018x}")
