"""Layer-1 Pallas kernel: the dense-HDC baseline encoder (Burrello'18).

Same window-grid structure as ``sparse_encode.py`` but with the dense
operations: XOR binding against the electrode HVs, bit-wise majority
across channels (+ tie-break HV for the even fan-in), and a plain
(non-saturating) temporal count. Used by the dense design point of the
Fig. 4 / Fig. 5 reproductions and as the baseline for the ablation
benches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


FRAME_TILE = 16


def _pick_tile(t_frames: int) -> int:
    for tile in range(min(FRAME_TILE, t_frames), 0, -1):
        if t_frames % tile == 0:
            return tile
    return 1


def _dense_kernel(codes_ref, im_ref, elec_ref, tie_ref, counts_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    codes = codes_ref[...]  # [TILE, CHANNELS]
    im = im_ref[...]  # [LBP_CODES, DIM]
    elec = elec_ref[...]  # [CHANNELS, DIM]
    tie = tie_ref[...]  # [DIM]

    tile, channels = codes.shape
    # One-hot contraction instead of a gather (old-XLA HLO-text path, see
    # ref.py): data = onehot(codes) @ im — an MXU matmul on real TPUs.
    lbp_codes = im.shape[0]
    onehot_codes = (codes[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tile, channels, lbp_codes), 2
    )).astype(jnp.int32)
    data = jnp.einsum("tck,kd->tcd", onehot_codes, im)
    bound = jnp.bitwise_xor(data, elec[None, :, :])
    counts = bound.sum(axis=1) + tie[None, :]  # implicit (n+1)-th input
    half = (channels + 1) // 2
    spatial = (counts > half).astype(jnp.int32)  # [TILE, DIM]
    counts_ref[...] = counts_ref[...] + spatial.sum(axis=0)


def dense_encode_window(codes, im_bits, elec_bits, tie_bits, *, interpret: bool = True):
    """codes: [T, CHANNELS] int32 → [DIM] int32 temporal counts."""
    t_frames, channels = codes.shape
    dim = im_bits.shape[1]
    tile = _pick_tile(t_frames)
    return pl.pallas_call(
        _dense_kernel,
        grid=(t_frames // tile,),
        in_specs=[
            pl.BlockSpec((tile, channels), lambda t: (t, 0)),
            pl.BlockSpec(im_bits.shape, lambda t: (0, 0)),
            pl.BlockSpec(elec_bits.shape, lambda t: (0, 0)),
            pl.BlockSpec((dim,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((dim,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((dim,), jnp.int32),
        interpret=interpret,
    )(
        codes.astype(jnp.int32),
        im_bits.astype(jnp.int32),
        elec_bits.astype(jnp.int32),
        tie_bits.astype(jnp.int32),
    )


def dense_thin_and_search(counts, am, tie_temporal, n_frames: int, *, interpret: bool = True):
    """Temporal majority + Hamming search, scores as DIM - hamming."""

    def _kernel(counts_ref, am_ref, tie_ref, scores_ref, query_ref):
        counts = counts_ref[...]
        am = am_ref[...]
        tie = tie_ref[...]
        half = (n_frames + 1) // 2
        query = ((counts + tie) > half).astype(jnp.int32)
        query_ref[...] = query
        dim = counts.shape[0]
        hamming = jnp.abs(query[None, :] - am).sum(axis=1)
        scores_ref[...] = (dim - hamming).astype(jnp.int32)

    dim = counts.shape[0]
    classes = am.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec(am.shape, lambda i: (0, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((classes,), lambda i: (0,)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((classes,), jnp.int32),
            jax.ShapeDtypeStruct((dim,), jnp.int32),
        ],
        interpret=interpret,
    )(counts.astype(jnp.int32), am.astype(jnp.int32), tie_temporal.astype(jnp.int32))
