"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness references: the Pallas kernels
(sparse_encode.py, dense_encode.py, similarity.py) must agree with these
functions *exactly* (integer semantics, no tolerance), and these in turn
mirror the Rust golden model (rust/src/hdc/), which the cross-language
digest test ties to the same item memory.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import hdc_params as P


# ---------------------------------------------------------------------
# Sparse pipeline (position space, CompIM semantics)
# ---------------------------------------------------------------------

def bind_positions(elec_pos, data_pos):
    """Segmented-shift binding: (e + d) mod SEG_LEN.

    elec_pos: [..., SEGMENTS] int32, data_pos: [..., SEGMENTS] int32.
    """
    return (elec_pos + data_pos) % P.SEG_LEN


def positions_to_hv(pos):
    """[..., SEGMENTS] positions → [..., DIM] one-hot-per-segment 0/1.

    Segment s occupies elements [s*SEG_LEN, (s+1)*SEG_LEN); the one-hot
    compare is segment-local ([..., SEG, SEG_LEN] instead of [..., SEG,
    DIM] — 8× less work) and the row-major reshape lands each segment in
    its slice.
    """
    iota = jnp.arange(P.SEG_LEN, dtype=jnp.int32)
    onehot = (pos.astype(jnp.int32)[..., :, None] == iota).astype(jnp.int32)
    return onehot.reshape(*pos.shape[:-1], P.DIM)


def sparse_spatial_frame(codes, im_pos, elec_pos, threshold=1):
    """One frame of the sparse spatial encoder.

    codes: [CHANNELS] int32 LBP codes;
    im_pos: [CHANNELS, LBP_CODES, SEGMENTS]; elec_pos: [CHANNELS, SEGMENTS].
    Returns [DIM] int32 0/1 — the bundled + thinned spatial HV.
    threshold=1 is the OR tree (optimized design §III-B).
    """
    # Table lookup as a one-hot contraction rather than a gather: this is
    # literally what the IM ROM does in hardware, and it sidesteps a
    # gather-semantics mismatch between jax≥0.5's StableHLO and the
    # xla_extension 0.5.1 compiler behind the Rust runtime (jax's newer
    # gather lowering miscompiles through the HLO-text round-trip; one-hot
    # contractions round-trip exactly).
    onehot_codes = (codes[:, None] == jnp.arange(P.LBP_CODES, dtype=jnp.int32)).astype(
        jnp.int32
    )  # [CHANNELS, LBP_CODES]
    data = (onehot_codes[:, :, None] * im_pos).sum(axis=1)  # [CHANNELS, SEGMENTS]
    bound = bind_positions(elec_pos.astype(jnp.int32), data)
    hvs = positions_to_hv(bound)  # [CHANNELS, DIM]
    counts = hvs.sum(axis=0)
    return (counts >= threshold).astype(jnp.int32)


def sparse_window_counts(codes, im_pos, elec_pos, threshold=1):
    """Temporal counter plane over a full prediction window.

    codes: [T, CHANNELS]; returns [DIM] int32 counts (saturating at 255,
    like the 8-bit hardware counters).
    """
    def frame_fn(carry, frame_codes):
        spatial = sparse_spatial_frame(frame_codes, im_pos, elec_pos, threshold)
        carry = jnp.minimum(carry + spatial, P.TEMPORAL_COUNTER_MAX)
        return carry, None

    import jax
    init = jnp.zeros(P.DIM, dtype=jnp.int32)
    counts, _ = jax.lax.scan(frame_fn, init, codes)
    return counts


def thin(counts, threshold):
    """Temporal thinning: counts >= threshold → binary query HV."""
    return (counts >= threshold).astype(jnp.int32)


def similarity_scores(query, am):
    """AND-popcount similarity (paper §II-D).

    query: [DIM] 0/1; am: [NUM_CLASSES, DIM] 0/1 → [NUM_CLASSES] int32.
    """
    return (query[None, :] * am).sum(axis=1).astype(jnp.int32)


def sparse_window(codes, am, threshold, im_pos, elec_pos, spatial_threshold=1):
    """Full sparse pipeline: codes → (scores[2], query[DIM])."""
    counts = sparse_window_counts(codes, im_pos, elec_pos, spatial_threshold)
    query = thin(counts, threshold)
    return similarity_scores(query, am), query


# ---------------------------------------------------------------------
# Dense pipeline (Burrello'18 baseline)
# ---------------------------------------------------------------------

def dense_spatial_frame(codes, im_bits, elec_bits, tie_bits):
    """One frame of the dense spatial encoder: XOR bind + majority(+tie).

    codes: [CHANNELS]; im_bits: [LBP_CODES, DIM]; elec_bits: [CHANNELS, DIM];
    tie_bits: [DIM]. Returns [DIM] 0/1.
    """
    # One-hot contraction instead of a gather (see sparse_spatial_frame).
    onehot_codes = (codes[:, None] == jnp.arange(im_bits.shape[0], dtype=jnp.int32)).astype(
        jnp.int32
    )
    data = onehot_codes @ im_bits  # [CHANNELS, DIM]
    bound = jnp.bitwise_xor(data, elec_bits)
    counts = bound.sum(axis=0) + tie_bits  # implicit 65th input
    half = (P.CHANNELS + 1) // 2
    return (counts > half).astype(jnp.int32)


def dense_window(codes, am, im_bits, elec_bits, tie_spatial, tie_temporal):
    """Full dense pipeline: codes[T, CHANNELS] → (scores[2], query[DIM]).

    Scores are `DIM - hamming` so that "bigger = more similar" matches the
    sparse contract (rust/src/hdc/classifier.rs::Classifier::search).
    """
    import jax

    def frame_fn(carry, frame_codes):
        spatial = dense_spatial_frame(frame_codes, im_bits, elec_bits, tie_spatial)
        return carry + spatial, None

    init = jnp.zeros(P.DIM, dtype=jnp.int32)
    counts, _ = jax.lax.scan(frame_fn, init, codes)
    n = codes.shape[0]
    half = (n + 1) // 2
    query = ((counts + tie_temporal) > half).astype(jnp.int32)
    hamming = jnp.abs(query[None, :] - am).sum(axis=1)
    scores = (P.DIM - hamming).astype(jnp.int32)
    return scores, query
