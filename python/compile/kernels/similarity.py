"""Layer-1 Pallas kernel: temporal thinning + AND-popcount similarity.

Implements the back half of the accelerator (paper §II-C/D): the counter
plane is thinned with the (patient-tuned) temporal threshold and the
resulting query HV is compared against the two class HVs of the
associative memory. The threshold arrives as a runtime input — it is the
paper's max-density hyperparameter knob — so one compiled artifact serves
every operating point.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(counts_ref, am_ref, thr_ref, scores_ref, query_ref):
    counts = counts_ref[...]  # [DIM]
    am = am_ref[...]  # [NUM_CLASSES, DIM]
    thr = thr_ref[0]
    query = (counts >= thr).astype(jnp.int32)
    query_ref[...] = query
    # AND + popcount per class (only 1-bits carry information, §II-D).
    scores_ref[...] = (query[None, :] * am).sum(axis=1).astype(jnp.int32)


def thin_and_search(counts, am, threshold, *, interpret: bool = True):
    """counts: [DIM] int32, am: [C, DIM] int32, threshold: [1] int32
    → (scores [C] int32, query [DIM] int32)."""
    dim = counts.shape[0]
    classes = am.shape[0]
    return pl.pallas_call(
        _sim_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec(am.shape, lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((classes,), lambda i: (0,)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((classes,), jnp.int32),
            jax.ShapeDtypeStruct((dim,), jnp.int32),
        ],
        interpret=interpret,
    )(counts.astype(jnp.int32), am.astype(jnp.int32), threshold.astype(jnp.int32))
