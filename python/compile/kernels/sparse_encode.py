"""Layer-1 Pallas kernel: the sparse HDC spatial + temporal encoder.

This is the compute hot-spot of the paper's accelerator, expressed for a
TPU-shaped machine (DESIGN.md §3 Hardware-Adaptation):

* HVs stay in **position space** (the CompIM insight, §III-A) until the
  bundling boundary — binding is a vectorised mod-128 add on an
  ``[TILE, CHANNELS, SEGMENTS]`` int32 block, not a 1024-bit shift
  network;
* the one-hot expansion compares positions only **within their segment**
  (``[..., SEGMENTS, SEG_LEN]`` iota-compare, 8× less work than a naive
  ``[..., DIM]`` compare) and reshapes to the 1024-element layout —
  segment-locality is exactly what the segmented representation buys;
* the grid walks the prediction window in **frame tiles** (16 frames per
  program): per-element temporal increments are non-negative and the
  8-bit saturation is an absorbing clamp, so
  ``min(c + Σ_tile spatial, 255)`` is bit-exact equal to 256 sequential
  saturating adds — one clamp per tile instead of per cycle (§Perf L1-2);
* the temporal counter plane lives in the output block across the whole
  window (the hardware's "large 8192-bit register" in VMEM).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom calls; numerics are validated against ``ref.py`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import hdc_params as P

#: Frames processed per grid step (divisor of FRAMES_PER_PREDICTION).
FRAME_TILE = 16


def _encode_kernel(codes_ref, impos_ref, elec_ref, counts_ref, *, spatial_threshold: int):
    """One grid step = one tile of frames."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    codes = codes_ref[...]  # [TILE, CHANNELS] int32
    impos = impos_ref[...]  # [CHANNELS, LBP_CODES, SEGMENTS]
    elec = elec_ref[...]  # [CHANNELS, SEGMENTS]

    tile, channels = codes.shape
    lbp_codes = impos.shape[1]
    segments = impos.shape[2]
    dim = counts_ref.shape[0]
    seg_len = dim // segments

    # CompIM lookup as a one-hot contraction (the ROM read itself; gathers
    # miscompile through the old-XLA HLO-text path — see ref.py).
    onehot_codes = (
        codes[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (tile, channels, lbp_codes), 2)
    ).astype(jnp.int32)
    # [TILE, CH, SEG]
    data = jnp.einsum("tck,cks->tcs", onehot_codes, impos.astype(jnp.int32))

    # Binding: eight 7-bit modular adds per channel (§III-A).
    bound = (elec[None, :, :] + data) % seg_len  # [TILE, CH, SEG]

    # Per-segment one-hot expansion + channel bundling (VPU-friendly,
    # segment-local: positions only ever compare against their own
    # segment's 128 slots).
    onehot_pos = (
        bound[:, :, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (tile, channels, segments, seg_len), 3)
    )
    element_counts = onehot_pos.astype(jnp.int32).sum(axis=1)  # [TILE, SEG, SEG_LEN]

    # Spatial thinning (threshold 1 == OR tree, the optimized design).
    spatial = (element_counts >= spatial_threshold).astype(jnp.int32)
    spatial = spatial.reshape(tile, dim)

    # Temporal accumulation; one absorbing clamp per tile is exact.
    counts_ref[...] = jnp.minimum(
        counts_ref[...] + spatial.sum(axis=0), P.TEMPORAL_COUNTER_MAX
    )


def _pick_tile(t_frames: int) -> int:
    """Largest divisor of t_frames not exceeding FRAME_TILE."""
    for tile in range(min(FRAME_TILE, t_frames), 0, -1):
        if t_frames % tile == 0:
            return tile
    return 1


def sparse_encode_window(codes, im_pos, elec_pos, *, spatial_threshold: int = 1,
                         interpret: bool = True):
    """Temporal counter plane for one prediction window.

    codes: [T, CHANNELS] int32; im_pos: [CHANNELS, LBP_CODES, SEGMENTS]
    int32; elec_pos: [CHANNELS, SEGMENTS] int32 → [DIM] int32 counts.
    """
    t_frames, channels = codes.shape
    assert im_pos.shape[0] == channels and elec_pos.shape[0] == channels
    segments = im_pos.shape[2]
    dim = segments * P.SEG_LEN
    tile = _pick_tile(t_frames)

    kernel = functools.partial(_encode_kernel, spatial_threshold=spatial_threshold)
    return pl.pallas_call(
        kernel,
        grid=(t_frames // tile,),
        in_specs=[
            # One tile of frames per grid step.
            pl.BlockSpec((tile, channels), lambda t: (t, 0)),
            # The CompIM tables stay resident in VMEM across the window.
            pl.BlockSpec(im_pos.shape, lambda t: (0, 0, 0)),
            pl.BlockSpec(elec_pos.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((dim,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((dim,), jnp.int32),
        interpret=interpret,
    )(codes.astype(jnp.int32), im_pos.astype(jnp.int32), elec_pos.astype(jnp.int32))
