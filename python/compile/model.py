"""Layer-2 JAX model: the full classifier compute graph per design point.

Each ``*_window_fn`` consumes one prediction window of LBP codes plus the
runtime state (trained AM, temporal threshold) and emits the class scores
and the query HV. The item-memory tables are baked in as constants —
exactly like the ROMs of the accelerator — so the HLO artifact is
self-contained and the Rust hot path only ships codes + AM + threshold.

Lowered once by ``aot.py`` to HLO text; loaded by ``rust/src/runtime``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import hdc_params as P
from .kernels import dense_encode, ref, similarity, sparse_encode


@functools.lru_cache(maxsize=None)
def _sparse_tables_np(seed: int):
    # Cache as numpy (never cache tracers/jnp values created under a jit).
    import numpy as np
    return (
        np.asarray(P.sparse_im_positions(seed), dtype=np.int32),
        np.asarray(P.sparse_electrode_positions(seed), dtype=np.int32),
    )


def sparse_tables(seed: int = P.IM_SEED):
    """CompIM contents as jnp constants."""
    im_pos, elec_pos = _sparse_tables_np(seed)
    return jnp.asarray(im_pos), jnp.asarray(elec_pos)


@functools.lru_cache(maxsize=None)
def _dense_tables_np(seed: int):
    import numpy as np
    return (
        np.asarray(P.dense_im_bits(seed), dtype=np.int32),
        np.asarray(P.dense_electrode_bits(seed), dtype=np.int32),
        np.asarray(P.dense_tiebreak_bits(seed, 0), dtype=np.int32),
        np.asarray(P.dense_tiebreak_bits(seed, 1), dtype=np.int32),
    )


def dense_tables(seed: int = P.IM_SEED):
    im_bits, elec_bits, tie_s, tie_t = _dense_tables_np(seed)
    return jnp.asarray(im_bits), jnp.asarray(elec_bits), jnp.asarray(tie_s), jnp.asarray(tie_t)


def sparse_window_core(codes, im_pos, elec_pos, am, threshold, *,
                       spatial_threshold: int = 1, use_pallas: bool = True):
    """Optimized sparse design (CompIM + OR bundling), tables as inputs.

    The item-memory tables arrive as *runtime inputs*, not baked
    constants: the HLO text printer elides large constants
    (``constant({...})``), so anything bigger than a scalar must cross the
    AOT boundary as a parameter. The Rust runtime regenerates the tables
    bit-identically (digest-checked) and feeds them at engine load.

    codes: [T, CHANNELS] int32; im_pos: [CHANNELS, LBP_CODES, SEGMENTS];
    elec_pos: [CHANNELS, SEGMENTS]; am: [NUM_CLASSES, DIM] int32;
    threshold: [1] int32 → (scores [NUM_CLASSES] int32, query [DIM] int32).
    """
    if use_pallas:
        counts = sparse_encode.sparse_encode_window(
            codes, im_pos, elec_pos, spatial_threshold=spatial_threshold
        )
        scores, query = similarity.thin_and_search(counts, am, threshold)
    else:
        counts = ref.sparse_window_counts(codes, im_pos, elec_pos, spatial_threshold)
        query = ref.thin(counts, threshold[0])
        scores = ref.similarity_scores(query, am)
    return scores, query


def sparse_window_fn(codes, am, threshold, *, seed: int = P.IM_SEED,
                     spatial_threshold: int = 1, use_pallas: bool = True):
    """Convenience wrapper with the default tables (tests / exploration)."""
    im_pos, elec_pos = sparse_tables(seed)
    return sparse_window_core(codes, im_pos, elec_pos, am, threshold,
                              spatial_threshold=spatial_threshold,
                              use_pallas=use_pallas)


def dense_window_core(codes, im_bits, elec_bits, tie_s, tie_t, am, *,
                      use_pallas: bool = True):
    """Dense baseline design (Burrello'18), tables as inputs."""
    if use_pallas:
        counts = dense_encode.dense_encode_window(codes, im_bits, elec_bits, tie_s)
        scores, query = dense_encode.dense_thin_and_search(
            counts, am, tie_t, n_frames=codes.shape[0]
        )
        return scores, query
    return ref.dense_window(codes, am, im_bits, elec_bits, tie_s, tie_t)


def dense_window_fn(codes, am, *, seed: int = P.IM_SEED, use_pallas: bool = True):
    """Convenience wrapper with the default tables (tests / exploration)."""
    im_bits, elec_bits, tie_s, tie_t = dense_tables(seed)
    return dense_window_core(codes, im_bits, elec_bits, tie_s, tie_t, am,
                             use_pallas=use_pallas)


def example_inputs(t_frames: int = P.FRAMES_PER_PREDICTION):
    """Shape specs used by the AOT lowering."""
    codes = jax.ShapeDtypeStruct((t_frames, P.CHANNELS), jnp.int32)
    am = jax.ShapeDtypeStruct((P.NUM_CLASSES, P.DIM), jnp.int32)
    threshold = jax.ShapeDtypeStruct((1,), jnp.int32)
    return codes, am, threshold


def sparse_table_specs():
    return (
        jax.ShapeDtypeStruct((P.CHANNELS, P.LBP_CODES, P.SEGMENTS), jnp.int32),
        jax.ShapeDtypeStruct((P.CHANNELS, P.SEGMENTS), jnp.int32),
    )


def dense_table_specs():
    return (
        jax.ShapeDtypeStruct((P.LBP_CODES, P.DIM), jnp.int32),
        jax.ShapeDtypeStruct((P.CHANNELS, P.DIM), jnp.int32),
        jax.ShapeDtypeStruct((P.DIM,), jnp.int32),
        jax.ShapeDtypeStruct((P.DIM,), jnp.int32),
    )
