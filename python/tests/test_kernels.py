"""Pallas kernels vs pure-jnp oracles — exact integer equality.

Hypothesis drives the input space (window lengths, code patterns,
thresholds, AM densities); the kernels run under ``interpret=True`` so
these tests are the numerics gate for the AOT artifacts.
"""

import numpy as np
import pytest

jnp = pytest.importorskip(
    "jax.numpy", reason="JAX unavailable - kernel tests need jax", exc_type=ImportError
)

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Offline fallback (no network, no hypothesis wheel): the property
    # tests skip individually; the deterministic kernel tests still run.
    class _Strategy:
        def flatmap(self, _f):
            return self

        def map(self, _f):
            return self

        def filter(self, _f):
            return self

    class _Strategies:
        def __getattr__(self, _name):
            def _make(*_args, **_kwargs):
                return _Strategy()

            return _make

    st = _Strategies()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis unavailable offline")

    def settings(*_args, **_kwargs):
        def _identity(f):
            return f

        return _identity


from compile import hdc_params as P
from compile import model
from compile.kernels import dense_encode, ref, similarity, sparse_encode

IM_POS = jnp.asarray(P.sparse_im_positions(), dtype=jnp.int32)
ELEC_POS = jnp.asarray(P.sparse_electrode_positions(), dtype=jnp.int32)
DENSE_IM = jnp.asarray(P.dense_im_bits(), dtype=jnp.int32)
DENSE_ELEC = jnp.asarray(P.dense_electrode_bits(), dtype=jnp.int32)
TIE_S = jnp.asarray(P.dense_tiebreak_bits(stage=0), dtype=jnp.int32)
TIE_T = jnp.asarray(P.dense_tiebreak_bits(stage=1), dtype=jnp.int32)

HYP = dict(deadline=None, max_examples=12)


def codes_strategy(max_t=10):
    return st.integers(1, max_t).flatmap(
        lambda t: st.lists(
            st.lists(st.integers(0, P.LBP_CODES - 1), min_size=P.CHANNELS, max_size=P.CHANNELS),
            min_size=t,
            max_size=t,
        )
    )


@settings(**HYP)
@given(codes=codes_strategy(), spatial_threshold=st.integers(1, 4))
def test_sparse_encode_matches_ref(codes, spatial_threshold):
    codes = jnp.asarray(np.array(codes, dtype=np.int32))
    got = sparse_encode.sparse_encode_window(
        codes, IM_POS, ELEC_POS, spatial_threshold=spatial_threshold
    )
    want = ref.sparse_window_counts(codes, IM_POS, ELEC_POS, spatial_threshold)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**HYP)
@given(
    counts=st.lists(st.integers(0, 255), min_size=P.DIM, max_size=P.DIM),
    threshold=st.integers(1, 256),
    am_seed=st.integers(0, 2**31 - 1),
)
def test_similarity_matches_ref(counts, threshold, am_seed):
    rng = np.random.default_rng(am_seed)
    am = jnp.asarray(rng.integers(0, 2, size=(P.NUM_CLASSES, P.DIM)), dtype=jnp.int32)
    counts = jnp.asarray(np.array(counts, dtype=np.int32))
    thr = jnp.asarray(np.array([threshold], dtype=np.int32))
    scores, query = similarity.thin_and_search(counts, am, thr)
    want_query = ref.thin(counts, threshold)
    want_scores = ref.similarity_scores(want_query, am)
    np.testing.assert_array_equal(np.asarray(query), np.asarray(want_query))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(want_scores))


@settings(**HYP)
@given(codes=codes_strategy(max_t=6))
def test_dense_encode_matches_ref(codes):
    codes = jnp.asarray(np.array(codes, dtype=np.int32))
    got = dense_encode.dense_encode_window(codes, DENSE_IM, DENSE_ELEC, TIE_S)
    # Reference: scan of dense_spatial_frame sums.
    import jax

    def frame_fn(carry, fc):
        return carry + ref.dense_spatial_frame(fc, DENSE_IM, DENSE_ELEC, TIE_S), None

    want, _ = jax.lax.scan(frame_fn, jnp.zeros(P.DIM, dtype=jnp.int32), codes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_temporal_counters_saturate_at_255():
    # Constant codes → the same spatial HV every frame → counters must
    # clamp at 255 even over 300 frames (8-bit hardware registers).
    codes = jnp.zeros((300, P.CHANNELS), dtype=jnp.int32)
    counts = np.asarray(
        sparse_encode.sparse_encode_window(codes, IM_POS, ELEC_POS, spatial_threshold=1)
    )
    assert counts.max() == 255
    on = counts[counts > 0]
    assert (on == 255).all(), "every active element hits the clamp"


def test_sparse_full_window_pipeline():
    rng = np.random.default_rng(7)
    codes = jnp.asarray(
        rng.integers(0, P.LBP_CODES, size=(P.FRAMES_PER_PREDICTION, P.CHANNELS)),
        dtype=jnp.int32,
    )
    am = jnp.asarray(rng.integers(0, 2, size=(P.NUM_CLASSES, P.DIM)), dtype=jnp.int32)
    thr = jnp.asarray(np.array([P.TEMPORAL_THRESHOLD_DEFAULT], dtype=np.int32))
    s_pallas, q_pallas = model.sparse_window_fn(codes, am, thr)
    s_ref, q_ref = model.sparse_window_fn(codes, am, thr, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(s_pallas), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(q_pallas), np.asarray(q_ref))
    # Query density must respect the 50% cap of the OR bundling.
    assert 0.0 <= np.asarray(q_pallas).mean() <= 0.5


def test_dense_full_window_pipeline():
    rng = np.random.default_rng(8)
    codes = jnp.asarray(
        rng.integers(0, P.LBP_CODES, size=(64, P.CHANNELS)), dtype=jnp.int32
    )
    am = jnp.asarray(rng.integers(0, 2, size=(P.NUM_CLASSES, P.DIM)), dtype=jnp.int32)
    s_pallas, q_pallas = model.dense_window_fn(codes, am)
    s_ref, q_ref = model.dense_window_fn(codes, am, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(s_pallas), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(q_pallas), np.asarray(q_ref))


def test_bound_positions_preserve_sparsity():
    # Every bound HV has exactly SEGMENTS ones (one per segment).
    codes = jnp.asarray(np.arange(P.CHANNELS, dtype=np.int32) % P.LBP_CODES)
    spatial = ref.sparse_spatial_frame(codes, IM_POS, ELEC_POS, threshold=1)
    total = int(np.asarray(spatial).sum())
    assert total <= P.CHANNELS * P.SEGMENTS
    assert total >= P.SEGMENTS  # at least one channel's worth survives ORing


@pytest.mark.parametrize("threshold,expect_subset", [(2, True), (3, True)])
def test_thinning_is_subset_of_or(threshold, expect_subset):
    rng = np.random.default_rng(9)
    codes = jnp.asarray(rng.integers(0, P.LBP_CODES, size=(P.CHANNELS,)), dtype=jnp.int32)
    or_out = np.asarray(ref.sparse_spatial_frame(codes, IM_POS, ELEC_POS, 1))
    thin_out = np.asarray(ref.sparse_spatial_frame(codes, IM_POS, ELEC_POS, threshold))
    assert ((thin_out <= or_out).all()) == expect_subset
