"""L2 model + AOT lowering tests."""

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="JAX unavailable - model tests need jax", exc_type=ImportError
)
import jax.numpy as jnp

from compile import aot, hdc_params as P, model


def _inputs(t=16, seed=0):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, P.LBP_CODES, size=(t, P.CHANNELS)), dtype=jnp.int32)
    am = jnp.asarray(rng.integers(0, 2, size=(P.NUM_CLASSES, P.DIM)), dtype=jnp.int32)
    thr = jnp.asarray(np.array([5], dtype=np.int32))
    return codes, am, thr


def test_output_shapes_and_dtypes():
    codes, am, thr = _inputs()
    scores, query = model.sparse_window_fn(codes, am, thr)
    assert scores.shape == (P.NUM_CLASSES,)
    assert query.shape == (P.DIM,)
    assert scores.dtype == jnp.int32
    assert query.dtype == jnp.int32


def test_scores_bounded_by_query_ones():
    codes, am, thr = _inputs(seed=1)
    scores, query = model.sparse_window_fn(codes, am, thr)
    ones = int(np.asarray(query).sum())
    assert int(np.asarray(scores).max()) <= ones


def test_threshold_monotonicity():
    # Higher temporal threshold → sparser query → scores cannot grow.
    codes, am, _ = _inputs(seed=2)
    prev = None
    for t in [1, 4, 8, 16]:
        thr = jnp.asarray(np.array([t], dtype=np.int32))
        scores, query = model.sparse_window_fn(codes, am, thr)
        total = int(np.asarray(query).sum())
        if prev is not None:
            assert total <= prev
        prev = total


def test_am_identity_scores_full_overlap():
    # Querying with a class HV as both query source and AM row: the class
    # whose HV *is* the query scores its own popcount.
    codes, _, thr = _inputs(seed=3)
    _, query = model.sparse_window_fn(
        codes, jnp.zeros((P.NUM_CLASSES, P.DIM), dtype=jnp.int32), thr
    )
    am = jnp.stack([query, jnp.zeros(P.DIM, dtype=jnp.int32)])
    scores, _ = model.sparse_window_fn(codes, am, thr)
    assert int(scores[0]) == int(np.asarray(query).sum())
    assert int(scores[1]) == 0


def test_hlo_text_emission():
    text = aot.lower_sparse(t_frames=8)
    assert "ENTRY" in text
    assert "HloModule" in text
    # Signature sanity: the three parameters appear with expected shapes.
    assert "s32[8,64]" in text.replace(" ", "")
    text_d = aot.lower_dense(t_frames=8)
    assert "ENTRY" in text_d


def test_lowering_is_deterministic():
    a = aot.lower_sparse(t_frames=4)
    b = aot.lower_sparse(t_frames=4)
    assert a == b


def test_pallas_and_ref_agree_after_jit():
    # The exact path the artifact takes: jit(fn) with pallas inside.
    codes, am, thr = _inputs(seed=4)

    f = jax.jit(lambda c, a, t: model.sparse_window_fn(c, a, t))
    scores_j, query_j = f(codes, am, thr)
    scores_r, query_r = model.sparse_window_fn(codes, am, thr, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(scores_j), np.asarray(scores_r))
    np.testing.assert_array_equal(np.asarray(query_j), np.asarray(query_r))
