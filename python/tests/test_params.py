"""Cross-language contract tests for the parameter/RNG mirror."""

import numpy as np
import pytest

from compile import hdc_params as P


def test_splitmix_reference_vectors():
    # Same pinned vectors as rust/src/rng.rs::tests::mix_known_value.
    assert P.splitmix64_mix(0) == 0xE220_A839_7B1D_CDAF
    assert P.splitmix64_mix(1) == 0x910A_2DEC_8902_5CC1


def test_hash_chain_order_sensitive():
    assert P.hash_chain(42, (2, 0)) != P.hash_chain(42, (0, 2))


def test_architecture_constants():
    assert P.DIM == 1024
    assert P.SEGMENTS == 8
    assert P.SEG_LEN == 128
    assert P.CHANNELS == 64
    assert P.LBP_CODES == 64
    assert P.FRAMES_PER_PREDICTION == 256


def test_sparse_tables_shape_and_range():
    im = P.sparse_im_positions()
    el = P.sparse_electrode_positions()
    assert im.shape == (P.CHANNELS, P.LBP_CODES, P.SEGMENTS)
    assert el.shape == (P.CHANNELS, P.SEGMENTS)
    assert im.max() < P.SEG_LEN
    assert el.max() < P.SEG_LEN


def test_tables_deterministic():
    a = P.sparse_im_positions(123)
    b = P.sparse_im_positions(123)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, P.sparse_im_positions(124))


def test_positions_roughly_uniform():
    im = P.sparse_im_positions()
    hist = np.bincount(im.reshape(-1), minlength=P.SEG_LEN)
    expected = im.size / P.SEG_LEN
    assert hist.min() > expected * 0.5
    assert hist.max() < expected * 1.5


def test_dense_tables_density():
    im = P.dense_im_bits()
    assert im.shape == (P.LBP_CODES, P.DIM)
    dens = im.mean(axis=1)
    assert (dens > 0.38).all() and (dens < 0.62).all()
    el = P.dense_electrode_bits()
    assert el.shape == (P.CHANNELS, P.DIM)
    tie0 = P.dense_tiebreak_bits(stage=0)
    tie1 = P.dense_tiebreak_bits(stage=1)
    assert not np.array_equal(tie0, tie1)


def test_im_digest_pinned():
    # The frozen cross-language digest. rust/tests/cross_language.rs and
    # artifacts/manifest.txt carry the same value; a mismatch means the
    # generator diverged between languages.
    assert P.im_digest() == 0xF7CD_F969_F2B3_3A13


@pytest.mark.parametrize("seed", [1, 2, 0xDEADBEEF])
def test_digest_varies_with_seed(seed):
    assert P.im_digest(seed) != P.im_digest(seed + 1)
