//! Associative-memory search benchmarks: serial `search` vs the fused
//! `search_batch` at batch sizes 1 / 16 / 256, plus the batched native
//! engine path the coalescing pool exercises.
//!
//! ```bash
//! cargo bench --bench bench_am
//! BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_am.json cargo bench --bench bench_am
//! ```
//!
//! The second form is what CI runs (alongside `bench_encoder`); the JSON
//! feeds the `repro bench-diff` trajectory gate. `search_batch` holds the
//! class HVs once and fuses both class scores into one pass per query —
//! the win over `search` grows with the batch size.

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::hdc::am::{AmPlane, AssociativeMemory, Metric};
use sparse_hdc_ieeg::hdc::classifier::ClassifierConfig;
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::simd::KernelSet;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, LBP_CODES};
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::runtime::native::NativeWindowEngine;
use sparse_hdc_ieeg::runtime::EngineKind;

fn main() {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(11);

    let am = AssociativeMemory::new(Hv::random(&mut rng, 0.5), Hv::random(&mut rng, 0.5));

    // --- AM search: serial vs batched, sparse + dense metrics ----------
    for &n in &[1usize, 16, 256] {
        let queries: Vec<Hv> = (0..n).map(|_| Hv::random(&mut rng, 0.25)).collect();
        b.bench_throughput(&format!("am/search-serial/batch-{n}"), n as f64, || {
            queries.iter().map(|q| am.search(black_box(q))).collect::<Vec<_>>()
        });
        b.bench_throughput(&format!("am/search-batch/batch-{n}"), n as f64, || {
            am.search_batch(black_box(&queries), Metric::Overlap)
        });
    }
    let queries: Vec<Hv> = (0..256).map(|_| Hv::random_half(&mut rng)).collect();
    b.bench_throughput("am/search-dense-serial/batch-256", 256.0, || {
        queries
            .iter()
            .map(|q| am.search_dense(black_box(q)))
            .collect::<Vec<_>>()
    });
    b.bench_throughput("am/search-dense-batch/batch-256", 256.0, || {
        am.search_batch(black_box(&queries), Metric::Hamming)
    });

    // --- dispatch pairs: fused two-class scoring, scalar vs SIMD --------
    // `/simd` records are emitted only when runtime dispatch resolved to
    // a non-scalar set (see bench_encoder.rs for the rationale).
    let mut sets = vec![("scalar", KernelSet::scalar())];
    let auto = KernelSet::auto();
    if auto.name != "scalar" {
        sets.push(("simd", auto));
    }
    let sparse_queries: Vec<Hv> = (0..256).map(|_| Hv::random(&mut rng, 0.25)).collect();
    for &(tag, ks) in &sets {
        b.bench_throughput(&format!("kernel/search-batch-256/{tag}"), 256.0, || {
            am.search_batch_with(black_box(&sparse_queries), Metric::Overlap, ks)
        });
        b.bench_throughput(&format!("kernel/search-batch-dense-256/{tag}"), 256.0, || {
            am.search_batch_with(black_box(&queries), Metric::Hamming, ks)
        });
    }

    // --- native engine: per-window run vs run_batch ---------------------
    // (encode dominates; the batch win here is the shared AM decode +
    // one search pass — the shape the engine pool submits.)
    let plane = AmPlane::from_memory(&am);
    let batch_windows = 8usize;
    let codes: Vec<u8> = (0..batch_windows * FRAMES_PER_PREDICTION * CHANNELS)
        .map(|_| rng.next_below(LBP_CODES as u64) as u8)
        .collect();
    let thresholds = vec![130i32; batch_windows];
    let window = FRAMES_PER_PREDICTION * CHANNELS;
    let mut engine =
        NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
    b.bench_throughput("engine/native-run-serial/batch-8", batch_windows as f64, || {
        (0..batch_windows)
            .map(|w| {
                engine
                    .run(black_box(&codes[w * window..(w + 1) * window]), plane.i32s(), 130)
                    .unwrap()
            })
            .collect::<Vec<_>>()
    });
    let mut engine =
        NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
    b.bench_throughput("engine/native-run-batch/batch-8", batch_windows as f64, || {
        engine.run_batch(black_box(&codes), &plane, &thresholds).unwrap()
    });

    b.finish();
}
