//! Coordinator benchmarks: end-to-end streaming throughput (native
//! backend), router dispatch, session assembly, detector.
//!
//! `cargo bench --bench bench_coordinator`

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::detector::Detector;
use sparse_hdc_ieeg::coordinator::registry::PublishedModel;
use sparse_hdc_ieeg::coordinator::router::{Router, SampleChunk};
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec};
use sparse_hdc_ieeg::coordinator::session::Session;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::model::ModelBundle;
use sparse_hdc_ieeg::params::CHANNELS;
use sparse_hdc_ieeg::pipeline;
use sparse_hdc_ieeg::rng::Xoshiro256;

fn main() {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(5);

    // --- session sample path (LBP + window assembly) ---
    let mut session = Session::new(1, 1, PublishedModel::placeholder(), 1);
    let mut sample = [0f32; CHANNELS];
    b.bench_throughput("session/push-sample", 1.0, || {
        for (i, s) in sample.iter_mut().enumerate() {
            *s = ((rng.next_u64() >> 40) as f32) * (i as f32 * 1e-6 + 1e-4);
        }
        session.push_sample(black_box(&sample)).is_some()
    });

    // --- router dispatch ---
    let mut router = Router::new();
    for id in 1..=8u64 {
        router.add_session(Session::new(id, id as u32, PublishedModel::placeholder(), 1));
    }
    let chunk = SampleChunk {
        session_id: 4,
        samples: vec![0.25; 64 * CHANNELS],
    };
    let mut out = Vec::new();
    b.bench_throughput("router/route-64-sample-chunk", 64.0, || {
        out.clear();
        router.route(black_box(&chunk), &mut out).unwrap();
        out.len()
    });

    // --- detector ---
    let mut det = Detector::new(2);
    let mut w = 0u64;
    b.bench("detector/push", || {
        w += 1;
        det.push(w, w % 7 < 3, 1)
    });

    // --- end-to-end streaming (native backend, 2 patients) ---
    let synth = SynthConfig {
        records_per_patient: 2,
        pre_s: 3.0,
        ictal_s: 2.0,
        post_s: 1.0,
        ..Default::default()
    };
    let cfg = ClassifierConfig::optimized();
    let specs: Vec<(u32, ModelBundle, sparse_hdc_ieeg::data::synth::Record)> = (1..=2u32)
        .map(|pid| {
            let p = SynthPatient::generate(&synth, pid);
            let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
            let bundle = pipeline::train_on_record(&mut enc, p.train_record(), &cfg);
            (pid, bundle, p.records[1].clone())
        })
        .collect();
    let samples_per_run: f64 = specs.iter().map(|(_, _, r)| r.num_samples() as f64).sum();
    b.bench_throughput("coordinator/stream-2-patients (samples/s)", samples_per_run, || {
        let streams: Vec<StreamSpec> = specs
            .iter()
            .map(|(pid, bundle, rec)| StreamSpec {
                session_id: *pid as u64,
                patient_id: *pid,
                record: rec.clone(),
                bundle: bundle.clone(),
            })
            .collect();
        let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
        coordinator.run(streams).unwrap().metrics.windows_completed
    });

    b.finish();
}
