//! Encoder hot-path benchmarks: the word-parallel kernels against their
//! retained scalar `*_reference` implementations, plus end-to-end
//! frames/s for every design variant and the item-memory cache.
//!
//! ```bash
//! cargo bench --bench bench_encoder
//! BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_encoder.json cargo bench --bench bench_encoder
//! ```
//!
//! (`BENCH_JSON` wants an absolute path — cargo runs bench binaries
//! with the package root `rust/` as working directory.)
//!
//! The second form is what CI runs; the JSON lands at the repo root and
//! is uploaded as a workflow artifact (perf trajectory tracking). The
//! acceptance bar for the word-parallel rewrite is ≥ 2x on the
//! `kernel/*` new-vs-reference pairs and it should carry through to the
//! `window-encode/*` end-to-end numbers.

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::hdc::bundling::{
    self, bundle_adder_thin_pos, bundle_or_pos, bundle_or_pos_reference,
};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Encoder, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::imcache;
use sparse_hdc_ieeg::hdc::simd::KernelSet;
use sparse_hdc_ieeg::hdc::sparse::SparseHv;
use sparse_hdc_ieeg::hdc::temporal::{TemporalAccumulator, TemporalAccumulatorReference};
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, IM_SEED, LBP_CODES};
use sparse_hdc_ieeg::rng::Xoshiro256;

fn random_frames(n: usize, seed: u64) -> Vec<[u8; CHANNELS]> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let mut f = [0u8; CHANNELS];
            for c in f.iter_mut() {
                *c = rng.next_below(LBP_CODES as u64) as u8;
            }
            f
        })
        .collect()
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(1);

    // --- kernel pairs: word-parallel vs scalar reference ---------------
    let bound_pos: Vec<SparseHv> = (0..CHANNELS).map(|_| SparseHv::random(&mut rng)).collect();

    b.bench("kernel/or-tree/word-parallel", || bundle_or_pos(black_box(&bound_pos)));
    b.bench("kernel/or-tree/reference", || bundle_or_pos_reference(black_box(&bound_pos)));

    b.bench("kernel/adder+thin/word-parallel", || bundle_adder_thin_pos(black_box(&bound_pos), 2));
    b.bench("kernel/adder+thin/reference", || {
        let counts = bundling::element_counts_pos_reference(black_box(&bound_pos));
        bundling::thin_reference(&counts, 2)
    });

    let spatial = bundle_or_pos(&bound_pos);
    b.bench("kernel/temporal-add/word-parallel", || {
        let mut acc = TemporalAccumulator::new();
        for _ in 0..16 {
            acc.add(black_box(&spatial));
        }
        acc.frames()
    });
    b.bench("kernel/temporal-add/reference", || {
        let mut acc = TemporalAccumulatorReference::new();
        for _ in 0..16 {
            acc.add(black_box(&spatial));
        }
        acc.frames()
    });

    let mut full = TemporalAccumulator::new();
    let mut full_ref = TemporalAccumulatorReference::new();
    let mut frame_rng = Xoshiro256::new(3);
    for _ in 0..FRAMES_PER_PREDICTION {
        let f = Hv::random(&mut frame_rng, 0.4);
        full.add(&f);
        full_ref.add(&f);
    }
    b.bench("kernel/temporal-thin/word-parallel", || full.peek(black_box(130)));
    b.bench("kernel/temporal-thin/reference", || full_ref.peek(black_box(130)));

    // --- dispatch pairs: scalar vs the runtime-selected SIMD set --------
    // The `/simd` records exist only when runtime dispatch resolved to a
    // non-scalar set, so `repro bench-speedup` never sees a bogus
    // scalar-vs-scalar 1.0x pair on machines without AVX2/NEON, and
    // `repro bench-diff` never loses a baseline name across machines.
    let mut sets = vec![("scalar", KernelSet::scalar())];
    let auto = KernelSet::auto();
    if auto.name != "scalar" {
        sets.push(("simd", auto));
    }
    let dense_inputs: Vec<Hv> = (0..64).map(|_| Hv::random(&mut rng, 0.1)).collect();
    for &(tag, ks) in &sets {
        b.bench(&format!("kernel/spatial-bundle/{tag}"), || {
            let mut acc = bundling::SpatialCounts::new();
            for hv in &dense_inputs {
                acc.add_hv_with(black_box(hv), ks);
            }
            acc.thin_with(2, ks)
        });
        b.bench(&format!("kernel/temporal-add16/{tag}"), || {
            let mut acc = TemporalAccumulator::new();
            for _ in 0..16 {
                acc.add_with(black_box(&spatial), ks);
            }
            acc.frames()
        });
        b.bench(&format!("kernel/temporal-thin/{tag}"), || {
            full.peek_with(black_box(130), ks)
        });
        b.bench(&format!("kernel/transpose-counts/{tag}"), || full.counts_with(ks));
    }

    // --- item-memory cache vs regeneration -----------------------------
    // Touch the cache once so the cached bench measures the steady state.
    let _ = imcache::sparse(IM_SEED);
    b.bench("imcache/encoder-construct (cached)", || {
        SparseEncoder::new(Variant::Optimized, ClassifierConfig::optimized())
    });
    b.bench("imcache/generate-sparse (uncached)", || {
        sparse_hdc_ieeg::hdc::im::ItemMemory::generate(black_box(7))
    });

    // --- end-to-end window encode, frames/s per variant -----------------
    let frames = random_frames(FRAMES_PER_PREDICTION, 2);
    for variant in Variant::ALL {
        let cfg = if variant.is_sparse() {
            ClassifierConfig {
                spatial_threshold: 1,
                ..ClassifierConfig::optimized()
            }
        } else {
            ClassifierConfig::default()
        };
        let mut enc = sparse_hdc_ieeg::hdc::classifier::make_encoder(variant, cfg);
        b.bench_throughput(
            &format!("window-encode/{}", variant.name()),
            FRAMES_PER_PREDICTION as f64,
            || {
                let mut q = None;
                for f in &frames {
                    q = q.or(enc.push_frame(f));
                }
                q
            },
        );
    }

    // Reference-kernel window for the optimized variant: same CompIM
    // binds, but scalar OR-tree + scalar temporal accumulate/thin. The
    // word-parallel `window-encode/sparse-optimized` above must beat this
    // by ≥ 2x (the PR's acceptance bar).
    let ims = imcache::sparse(IM_SEED);
    b.bench_throughput(
        "window-encode/sparse-optimized (reference kernels)",
        FRAMES_PER_PREDICTION as f64,
        || {
            let mut acc = TemporalAccumulatorReference::new();
            let mut bound = Vec::with_capacity(CHANNELS);
            let mut q = None;
            for f in &frames {
                bound.clear();
                for (c, &code) in f.iter().enumerate() {
                    bound.push(ims.compim.bind(c, code));
                }
                acc.add(&bundle_or_pos_reference(&bound));
                if acc.frames() >= FRAMES_PER_PREDICTION {
                    q = Some(acc.finish(130));
                }
            }
            q
        },
    );

    b.finish();
}
