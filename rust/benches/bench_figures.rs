//! Figure/table regeneration benches — one bench per paper artifact
//! (DESIGN.md §4 experiment index). Each bench times the full regeneration
//! AND prints the regenerated numbers, so `cargo bench --bench
//! bench_figures` doubles as the reproduction harness.

use sparse_hdc_ieeg::benchkit::Bench;
use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hwmodel::breakdown::{format_comparison, format_table1};
use sparse_hdc_ieeg::hwmodel::designs::{analyze, analyze_all, patient11_stimulus};
use sparse_hdc_ieeg::pipeline;

fn main() {
    let mut b = Bench::new();

    // FIG1C + FIG5: the four-design analysis under patient-11 stimulus.
    b.bench("fig1c+fig5/analyze-all-designs", || {
        analyze_all(&ClassifierConfig::default(), 2).len()
    });
    let reports = analyze_all(&ClassifierConfig::default(), 4);
    println!("\n{}", format_comparison(&reports));

    // TAB1: ours row from the optimized design.
    b.bench("table1/analyze-optimized", || {
        let frames = patient11_stimulus(2);
        analyze(
            Variant::Optimized,
            &ClassifierConfig {
                spatial_threshold: 1,
                ..ClassifierConfig::optimized()
            },
            &frames,
        )
        .energy_nj_per_pred()
    });
    println!("\n{}", format_table1(&reports[3]));

    // FIG4 (reduced grid so the bench stays minutes-scale): delay/accuracy
    // at three densities over two patients.
    let synth = SynthConfig {
        records_per_patient: 3,
        pre_s: 12.0,
        ictal_s: 8.0,
        post_s: 4.0,
        ..Default::default()
    };
    let patients: Vec<SynthPatient> = (1..=2).map(|p| SynthPatient::generate(&synth, p)).collect();
    b.bench("fig4/one-density-point (2 patients)", || {
        let mut acc = 0.0;
        for p in &patients {
            acc += pipeline::evaluate_patient(
                Variant::Optimized,
                &ClassifierConfig::optimized(),
                p,
                Some(0.25),
                AlarmPolicy::default(),
            )
            .summary
            .detection_accuracy();
        }
        acc
    });
    println!("\nfig4 sample points (full grid: `repro fig4` / examples/density_sweep):");
    println!("{:>9} {:>10} {:>9}", "max-dens", "delay s", "acc %");
    for d in [0.1, 0.25, 0.5] {
        let mut delays = Vec::new();
        let mut acc = 0.0;
        for p in &patients {
            let e = pipeline::evaluate_patient(
                Variant::Optimized,
                &ClassifierConfig::optimized(),
                p,
                Some(d),
                AlarmPolicy::default(),
            );
            if e.summary.mean_delay_s().is_finite() {
                delays.push(e.summary.mean_delay_s());
            }
            acc += e.summary.detection_accuracy();
        }
        println!(
            "{:>8.0}% {:>10.2} {:>8.1}%",
            d * 100.0,
            delays.iter().sum::<f64>() / delays.len().max(1) as f64,
            acc / patients.len() as f64 * 100.0
        );
    }

    b.finish();
}
