//! Core HDC operation benchmarks + the paper's design-choice ablations in
//! software terms (CompIM vs decode+shift binding, OR vs adder bundling).
//!
//! `cargo bench --bench bench_hdc` (filter: `cargo bench --bench bench_hdc -- bind`)

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::bundling;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Encoder, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::compim::CompIm;
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::im::{DenseItemMemory, ItemMemory};
use sparse_hdc_ieeg::hdc::sparse::{bind_bitdomain, SparseHv};
use sparse_hdc_ieeg::hdc::temporal::TemporalAccumulator;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, LBP_CODES};
use sparse_hdc_ieeg::rng::Xoshiro256;

fn random_frames(n: usize, seed: u64) -> Vec<[u8; CHANNELS]> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let mut f = [0u8; CHANNELS];
            for c in f.iter_mut() {
                *c = rng.next_below(LBP_CODES as u64) as u8;
            }
            f
        })
        .collect()
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(1);

    // --- binding: the paper's §III-A ablation in software ---
    let im = ItemMemory::default_im();
    let compim = CompIm::default_im();
    let mut code = 0u8;
    b.bench("bind/baseline-decode+shift (64ch)", || {
        code = code.wrapping_add(1) % LBP_CODES as u8;
        let mut acc = 0u32;
        for c in 0..CHANNELS {
            let bound = bind_bitdomain(&im.electrode_hv(c), &im.lookup_hv(c, code)).unwrap();
            acc ^= bound.popcount();
        }
        acc
    });
    b.bench("bind/compim-7bit-add (64ch)", || {
        code = code.wrapping_add(1) % LBP_CODES as u8;
        let mut acc = 0u32;
        for c in 0..CHANNELS {
            acc ^= compim.bind(c, code).pos[0] as u32;
        }
        acc
    });

    // --- spatial bundling: §III-B ablation ---
    let bound_pos: Vec<SparseHv> = (0..CHANNELS).map(|_| SparseHv::random(&mut rng)).collect();
    let bound_bits: Vec<Hv> = bound_pos.iter().map(|p| p.to_hv()).collect();
    b.bench("bundle/adder-tree+thin (bit domain)", || {
        bundling::bundle_adder_thin(black_box(&bound_bits), 2)
    });
    b.bench("bundle/or-tree (bit domain)", || {
        bundling::bundle_or(black_box(&bound_bits))
    });
    b.bench("bundle/or-tree (position domain)", || {
        bundling::bundle_or_pos(black_box(&bound_pos))
    });

    // --- temporal + AM ---
    let spatial = bundling::bundle_or_pos(&bound_pos);
    b.bench("temporal/accumulate-frame", || {
        let mut acc = TemporalAccumulator::new();
        acc.add(black_box(&spatial));
        acc.frames()
    });
    let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
    let query = Hv::random(&mut rng, 0.25);
    b.bench("am/search (2 classes)", || am.search(black_box(&query)));

    // --- full-frame spatial encode per sparse variant ---
    let frames = random_frames(FRAMES_PER_PREDICTION, 2);
    for variant in [
        Variant::SparseBaseline,
        Variant::SparseCompIm,
        Variant::Optimized,
    ] {
        let cfg = ClassifierConfig {
            spatial_threshold: 1,
            ..ClassifierConfig::optimized()
        };
        let mut enc = SparseEncoder::new(variant, cfg);
        let mut i = 0;
        b.bench(&format!("frame-encode/{}", variant.name()), || {
            i = (i + 1) % frames.len();
            enc.spatial_encode(black_box(&frames[i]))
        });
    }

    // --- full window (256 frames) per variant, throughput in frames/s ---
    for variant in Variant::ALL {
        let cfg = if variant.is_sparse() {
            ClassifierConfig {
                spatial_threshold: 1,
                ..ClassifierConfig::optimized()
            }
        } else {
            ClassifierConfig::default()
        };
        let mut enc = sparse_hdc_ieeg::hdc::classifier::make_encoder(variant, cfg);
        b.bench_throughput(
            &format!("window-encode/{}", variant.name()),
            FRAMES_PER_PREDICTION as f64,
            || {
                let mut q = None;
                for f in &frames {
                    q = q.or(enc.push_frame(f));
                }
                q
            },
        );
    }

    // IM generation cost (one-time, for context).
    b.bench("im/generate-sparse", || ItemMemory::generate(black_box(7)));
    b.bench("im/generate-dense", || DenseItemMemory::generate(black_box(7)));

    b.finish();
}
