//! Model-memory benchmarks: the fleet-scale registry paths behind
//! `--cache-planes` / `[model] cache_planes`.
//!
//! ```bash
//! cargo bench --bench bench_registry
//! BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_registry.json cargo bench --bench bench_registry
//! ```
//!
//! The second form is what CI runs; the JSON feeds the `repro bench-diff`
//! trajectory gate (`registry/*` records gate alongside `kernel/*`).
//! Three paths, matching the serve lifecycle:
//!
//! - `registry/cold_open` — open + lazily index a fleet store (META/PROV
//!   reads only, no plane decodes).
//! - `registry/warm_hit`  — `plane()` on resident cache entries, the
//!   steady-state serving path.
//! - `registry/evict_redecode` — alternating two patients through a
//!   budget-of-1 cache, the worst-case thrash (every touch evicts and
//!   re-decodes).

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::coordinator::registry::{ModelRegistry, ModelStore};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::model::{ModelBundle, Provenance};
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::testkit;

const PATIENTS: u32 = 8;
const VERSIONS: u64 = 4;

fn patient_bundle(rng: &mut Xoshiro256, pid: u32, version: u64) -> ModelBundle {
    let mut b = ModelBundle::new(
        Variant::Optimized,
        ClassifierConfig::optimized(),
        AssociativeMemory::new(Hv::random(rng, 0.25), Hv::random(rng, 0.25)),
        Provenance::default(),
    );
    b.version = version;
    b.provenance.patient_id = pid;
    b.provenance.parent_version = version - 1;
    b
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(23);

    // --- cold open: index a fleet store without decoding planes --------
    let dir = testkit::scratch_dir("bench_registry_store");
    {
        let store = ModelStore::open(&dir).unwrap();
        for pid in 1..=PATIENTS {
            for v in 1..=VERSIONS {
                store.save(&patient_bundle(&mut rng, pid, v)).unwrap();
            }
        }
    }
    b.bench_throughput(
        "registry/cold_open",
        (PATIENTS as u64 * VERSIONS) as f64,
        || {
            let store = ModelStore::open(black_box(&dir)).unwrap();
            let peek = store.peek().unwrap();
            assert_eq!(peek.recovered.len(), PATIENTS as usize);
            peek.recovered.len()
        },
    );

    // --- warm hit: steady-state serving on a resident cache ------------
    let registry = ModelRegistry::with_cache_planes(PATIENTS as usize);
    for pid in 1..=PATIENTS {
        registry.publish(pid, patient_bundle(&mut rng, pid, 1)).unwrap();
    }
    // Prime: the timed loop below measures pure hits, not first decodes.
    for pid in 1..=PATIENTS {
        black_box(registry.current(pid).unwrap().plane());
    }
    b.bench_throughput("registry/warm_hit", PATIENTS as f64, || {
        (1..=PATIENTS)
            .map(|pid| registry.current(pid).unwrap().plane().i32s()[0])
            .sum::<i32>()
    });

    // --- evict + re-decode: thrash a budget-of-1 cache ------------------
    let thrash = ModelRegistry::with_cache_planes(1);
    thrash.publish(1, patient_bundle(&mut rng, 1, 1)).unwrap();
    thrash.publish(2, patient_bundle(&mut rng, 2, 1)).unwrap();
    let first = thrash.current(1).unwrap();
    let second = thrash.current(2).unwrap();
    b.bench_throughput("registry/evict_redecode", 2.0, || {
        // Each call misses, decodes, and evicts the other's plane.
        black_box(first.plane().i32s()[0]) ^ black_box(second.plane().i32s()[0])
    });
    let stats = thrash.plane_cache().stats();
    assert!(stats.evictions > 0, "thrash loop must actually evict");
    assert!(stats.redecodes > 0, "thrash loop must actually re-decode");

    std::fs::remove_dir_all(&dir).ok();
    b.finish();
}
