//! Window-engine benchmarks: per-window execute latency of the native
//! golden-model engine, and — with `--features pjrt` plus `make
//! artifacts` — artifact compile time and the PJRT engines for
//! comparison.
//!
//! `cargo bench --bench bench_runtime`

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::ClassifierConfig;
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, LBP_CODES};
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::runtime::native::NativeWindowEngine;
use sparse_hdc_ieeg::runtime::EngineKind;

fn main() {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(3);

    let codes: Vec<u8> = (0..FRAMES_PER_PREDICTION * CHANNELS)
        .map(|_| rng.next_below(LBP_CODES as u64) as u8)
        .collect();
    let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
    let am_i32 = am.to_i32s();

    // Native golden-model engines (always available, no artifacts).
    let mut sparse = NativeWindowEngine::new(
        EngineKind::SparseWindow,
        ClassifierConfig::optimized(),
    );
    b.bench_throughput(
        "runtime/native-sparse-window-execute",
        FRAMES_PER_PREDICTION as f64,
        || sparse.run(black_box(&codes), &am_i32, 130).unwrap(),
    );
    let mut dense = NativeWindowEngine::new(EngineKind::DenseWindow, ClassifierConfig::default());
    b.bench_throughput(
        "runtime/native-dense-window-execute",
        FRAMES_PER_PREDICTION as f64,
        || dense.run(black_box(&codes), &am_i32, 0).unwrap(),
    );

    #[cfg(feature = "pjrt")]
    pjrt_benches(&mut b, &codes, &am_i32);
    #[cfg(not(feature = "pjrt"))]
    eprintln!("bench_runtime: PJRT engines not built (enable with --features pjrt); native only");

    b.finish();
}

/// PJRT engine benchmarks — need `--features pjrt` and `make artifacts`.
#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &mut Bench, codes: &[u8], am_i32: &[i32]) {
    use sparse_hdc_ieeg::runtime::Runtime;
    use std::path::PathBuf;

    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("bench_runtime: artifacts/ missing — run `make artifacts`; skipping PJRT");
        return;
    }

    b.bench("runtime/client+manifest", || {
        Runtime::new(black_box(&artifacts)).unwrap().platform()
    });

    let rt = Runtime::new(&artifacts).unwrap();
    let engine = rt.load_sparse().unwrap();
    let dense_engine = rt.load_dense().unwrap();

    b.bench_throughput(
        "runtime/pjrt-sparse-window-execute",
        FRAMES_PER_PREDICTION as f64,
        || engine.run(black_box(codes), am_i32, 130).unwrap(),
    );
    b.bench_throughput(
        "runtime/pjrt-dense-window-execute",
        FRAMES_PER_PREDICTION as f64,
        || dense_engine.run(black_box(codes), am_i32, 0).unwrap(),
    );
}
