//! PJRT runtime benchmarks: artifact compile time and per-window execute
//! latency of the AOT-compiled HLO, vs. the native golden model.
//!
//! Requires `make artifacts`. `cargo bench --bench bench_runtime`

use std::path::PathBuf;

use sparse_hdc_ieeg::benchkit::{black_box, Bench};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Encoder, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, LBP_CODES};
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::runtime::Runtime;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("bench_runtime: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(3);

    b.bench("runtime/client+manifest", || {
        Runtime::new(black_box(&artifacts)).unwrap().platform()
    });

    let rt = Runtime::new(&artifacts).unwrap();
    let engine = rt.load_sparse().unwrap();
    let dense_engine = rt.load_dense().unwrap();

    let codes: Vec<u8> = (0..FRAMES_PER_PREDICTION * CHANNELS)
        .map(|_| rng.next_below(LBP_CODES as u64) as u8)
        .collect();
    let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
    let am_i32 = am.to_i32s();

    b.bench_throughput(
        "runtime/sparse-window-execute",
        FRAMES_PER_PREDICTION as f64,
        || engine.run(black_box(&codes), &am_i32, 130).unwrap(),
    );
    b.bench_throughput(
        "runtime/dense-window-execute",
        FRAMES_PER_PREDICTION as f64,
        || dense_engine.run(black_box(&codes), &am_i32, 0).unwrap(),
    );

    // Native golden model for comparison (same window semantics).
    let cfg = ClassifierConfig::optimized();
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg);
    b.bench_throughput(
        "runtime/native-window (reference)",
        FRAMES_PER_PREDICTION as f64,
        || {
            let mut frame = [0u8; CHANNELS];
            let mut q = None;
            for chunk in codes.chunks_exact(CHANNELS) {
                frame.copy_from_slice(chunk);
                q = q.or(enc.push_frame(&frame));
            }
            q
        },
    );

    b.finish();
}
