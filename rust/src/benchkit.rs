//! Micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §2). Used by the `cargo bench` targets via `harness = false`.
//!
//! Methodology (criterion-like, simplified):
//! * warm-up phase to stabilise caches/branch predictors,
//! * timed batches sized so one batch ≥ ~1 ms (amortises timer overhead),
//! * reports min / median / mean / p95 per-iteration time and derived
//!   throughput,
//! * a [`black_box`] to defeat constant folding.
//!
//! ## Machine-readable output
//!
//! Set `BENCH_JSON=<path>` to additionally write the collected stats as a
//! JSON document on [`Bench::finish`]:
//!
//! ```json
//! {"schema": "benchkit/v1", "fast": false, "records": [
//!   {"name": "...", "iters": 1234, "min_s": ..., "median_s": ...,
//!    "mean_s": ..., "p95_s": ..., "throughput": ...}
//! ]}
//! ```
//!
//! `throughput` is items/s for benches registered through
//! [`Bench::bench_throughput`] and `null` otherwise. CI runs
//! `bench_encoder` with `BENCH_JSON` enabled and uploads the file, so the
//! perf trajectory is tracked per commit (see `BENCH_encoder.json` at the
//! repo root for the committed trajectory point).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of the standard black box for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's collected statistics (per-iteration seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
    /// Items per iteration for throughput benches (`None` for plain ones).
    pub items_per_iter: Option<f64>,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    /// Items/s for throughput benches, `None` otherwise.
    pub fn throughput_per_s(&self) -> Option<f64> {
        self.items_per_iter.map(|n| self.throughput(n))
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{:8.3} s ", s)
    }
}

/// Minimal JSON string escaping (bench names are plain ASCII, but keep
/// the output well-formed for any input).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A finite f64 as a JSON number (JSON has no NaN/Infinity).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    fast: bool,
    results: Vec<Stats>,
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // honour `cargo bench -- <filter>` and a fast mode for CI
        let args: Vec<String> = std::env::args().skip(1).collect();
        let filter = args.iter().find(|a| !a.starts_with('-')).cloned();
        let fast = std::env::var("BENCH_FAST").is_ok() || args.iter().any(|a| a == "--test");
        Bench {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure: if fast {
                Duration::from_millis(80)
            } else {
                Duration::from_millis(1500)
            },
            fast,
            results: Vec::new(),
            filter,
        }
    }

    /// Run one benchmark. `f` is called once per iteration.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Option<&Stats> {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return None;
            }
        }
        // Warm-up + estimate batch size.
        let mut iters_done: u64 = 0;
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            std_black_box(f());
            iters_done += 1;
        }
        let est_per_iter = warm_start.elapsed().as_secs_f64() / iters_done.max(1) as f64;
        let batch = ((1e-3 / est_per_iter).ceil() as u64).clamp(1, 1_000_000);

        // Measured batches.
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let meas_start = Instant::now();
        while meas_start.elapsed() < self.measure || samples.len() < 10 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std_black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / batch as f64;
            samples.push(dt);
            total_iters += batch;
            if samples.len() >= 2000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min_s = samples[0];
        let median_s = samples[samples.len() / 2];
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        // Clamp the p95 index to the last sample (never wrap to the min).
        let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
        let p95_s = samples[p95_idx];
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            min_s,
            median_s,
            mean_s,
            p95_s,
            items_per_iter: None,
        };
        println!(
            "{:<48} min {} med {} mean {} p95 {}",
            stats.name,
            fmt_time(min_s),
            fmt_time(median_s),
            fmt_time(mean_s),
            fmt_time(p95_s)
        );
        self.results.push(stats);
        self.results.last()
    }

    /// Like [`Self::bench`] but annotates throughput in items/s.
    pub fn bench_throughput<R>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: impl FnMut() -> R,
    ) -> Option<&Stats> {
        let before = self.results.len();
        self.bench(name, f)?;
        self.results[before].items_per_iter = Some(items_per_iter);
        let s = &self.results[before];
        println!(
            "{:<48} throughput {:>12.0} items/s",
            format!("  ({name})"),
            s.throughput(items_per_iter)
        );
        Some(&self.results[before])
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Serialize the collected stats as the `benchkit/v1` JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"schema\": \"benchkit/v1\", \"fast\": {},\n \"records\": [",
            self.fast
        ));
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let throughput = r
                .throughput_per_s()
                .map(json_num)
                .unwrap_or_else(|| "null".to_string());
            out.push_str(&format!(
                "\n  {{\"name\": \"{}\", \"iters\": {}, \"min_s\": {}, \"median_s\": {}, \
                 \"mean_s\": {}, \"p95_s\": {}, \"throughput\": {}}}",
                json_escape(&r.name),
                r.iters,
                json_num(r.min_s),
                json_num(r.median_s),
                json_num(r.mean_s),
                json_num(r.p95_s),
                throughput
            ));
        }
        out.push_str("\n]}\n");
        out
    }

    /// Write the `benchkit/v1` JSON document to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Final summary table (call at the end of a bench binary). When
    /// `BENCH_JSON=<path>` is set, also writes the machine-readable
    /// record file.
    pub fn finish(&self) {
        println!("\n=== {} benchmarks run ===", self.results.len());
        if let Ok(path) = std::env::var("BENCH_JSON") {
            if !path.is_empty() {
                match self.write_json(&path) {
                    Ok(()) => println!("wrote {} records to {path}", self.results.len()),
                    Err(e) => eprintln!("BENCH_JSON: failed to write {path}: {e}"),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Perf-trajectory records: read benchkit/v1 documents back and diff two
// runs pairwise (`repro bench-diff`). serde is unavailable offline, so a
// minimal JSON reader lives here next to the writer it mirrors.
// ---------------------------------------------------------------------

/// One record read back from a benchkit/v1 JSON document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub median_s: f64,
    pub mean_s: f64,
    /// Items/s, when the bench was registered with a throughput.
    pub throughput: Option<f64>,
}

/// Minimal JSON scanner: just enough of the grammar for the documents
/// [`Bench::to_json`] emits (objects, arrays, strings with escapes,
/// numbers incl. exponents, `true`/`false`/`null`). Crate-visible so the
/// load generator's `loadgen/v1` reader
/// ([`crate::transport::loadgen::parse_loadgen_json`]) reuses it instead
/// of growing a second hand-rolled parser.
pub(crate) struct JsonScanner<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonScanner<'a> {
    pub(crate) fn new(text: &'a str) -> Self {
        JsonScanner {
            bytes: text.as_bytes(),
            pos: 0,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> crate::Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| crate::err!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        let got = self.peek()?;
        crate::ensure!(
            got == b,
            "expected {:?}, got {:?} at byte {}",
            b as char,
            got as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    pub(crate) fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        // Collect raw bytes and validate UTF-8 once at the end — pushing
        // `b as char` would decode multi-byte sequences as Latin-1.
        let mut out = Vec::new();
        loop {
            let b = *self
                .bytes
                .get(self.pos)
                .ok_or_else(|| crate::err!("unterminated JSON string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(String::from_utf8(out)?),
                b'\\' => {
                    let esc = *self
                        .bytes
                        .get(self.pos)
                        .ok_or_else(|| crate::err!("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'u' => {
                            crate::ensure!(
                                self.pos + 4 <= self.bytes.len(),
                                "truncated \\u escape"
                            );
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            let ch = char::from_u32(code).unwrap_or('\u{FFFD}');
                            out.extend_from_slice(ch.encode_utf8(&mut [0u8; 4]).as_bytes());
                            self.pos += 4;
                        }
                        other => crate::bail!("unsupported escape \\{}", other as char),
                    }
                }
                _ => out.push(b),
            }
        }
    }

    /// Parse any value; returns `Some(f64)` for numbers, `None` for
    /// everything else (nested containers are consumed and discarded).
    pub(crate) fn value(&mut self) -> crate::Result<Option<f64>> {
        match self.peek()? {
            b'"' => {
                self.string()?;
                Ok(None)
            }
            b'{' => {
                self.object(|s, _| s.value().map(|_| ()))?;
                Ok(None)
            }
            b'[' => {
                self.array(|s| s.value().map(|_| ()))?;
                Ok(None)
            }
            b't' | b'f' | b'n' => {
                let start = self.pos;
                while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_alphabetic() {
                    self.pos += 1;
                }
                let word = std::str::from_utf8(&self.bytes[start..self.pos])?;
                crate::ensure!(
                    matches!(word, "true" | "false" | "null"),
                    "bad JSON literal {word:?}"
                );
                Ok(None)
            }
            _ => {
                let start = self.pos;
                let is_num = |b: u8| matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E');
                while self.pos < self.bytes.len() && is_num(self.bytes[self.pos]) {
                    self.pos += 1;
                }
                let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
                let parsed: f64 = text
                    .parse()
                    .map_err(|e| crate::err!("bad JSON number {text:?}: {e}"))?;
                Ok(Some(parsed))
            }
        }
    }

    /// Consume an object, calling `field(self, key)` for every value.
    pub(crate) fn object(
        &mut self,
        mut field: impl FnMut(&mut Self, &str) -> crate::Result<()>,
    ) -> crate::Result<()> {
        self.expect(b'{')?;
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(());
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            field(self, &key)?;
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(());
                }
                other => crate::bail!("expected ',' or '}}', got {:?}", other as char),
            }
        }
    }

    /// Consume an array, calling `elem` for every element.
    pub(crate) fn array(
        &mut self,
        mut elem: impl FnMut(&mut Self) -> crate::Result<()>,
    ) -> crate::Result<()> {
        self.expect(b'[')?;
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(());
        }
        loop {
            elem(self)?;
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(());
                }
                other => crate::bail!("expected ',' or ']', got {:?}", other as char),
            }
        }
    }
}

/// Parse a benchkit/v1 JSON document into its records.
pub fn parse_benchkit_json(text: &str) -> crate::Result<Vec<BenchRecord>> {
    let mut scanner = JsonScanner::new(text);
    let mut schema = None;
    let mut records = Vec::new();
    scanner.object(|s, key| {
        match key {
            "schema" => schema = Some(s.string()?),
            "records" => {
                s.array(|s| {
                    let mut rec = BenchRecord {
                        name: String::new(),
                        median_s: f64::NAN,
                        mean_s: f64::NAN,
                        throughput: None,
                    };
                    s.object(|s, field| {
                        match field {
                            "name" => rec.name = s.string()?,
                            "median_s" => rec.median_s = s.value()?.unwrap_or(f64::NAN),
                            "mean_s" => rec.mean_s = s.value()?.unwrap_or(f64::NAN),
                            "throughput" => rec.throughput = s.value()?,
                            _ => {
                                s.value()?;
                            }
                        }
                        Ok(())
                    })?;
                    crate::ensure!(!rec.name.is_empty(), "record without a name");
                    // The writer always emits finite medians; a missing or
                    // null median would otherwise become NaN and slip
                    // through the regression gate unflagged.
                    crate::ensure!(
                        rec.median_s.is_finite(),
                        "record {:?} has no finite median_s",
                        rec.name
                    );
                    records.push(rec);
                    Ok(())
                })?;
            }
            _ => {
                s.value()?;
            }
        }
        Ok(())
    })?;
    let schema = schema.ok_or_else(|| crate::err!("not a benchkit document (no schema key)"))?;
    crate::ensure!(
        schema == "benchkit/v1",
        "unsupported benchkit schema {schema:?} (expected benchkit/v1)"
    );
    Ok(records)
}

/// One name present in both runs, compared on the median.
#[derive(Clone, Debug)]
pub struct BenchDiff {
    pub name: String,
    pub baseline_median_s: f64,
    pub current_median_s: f64,
    /// `current / baseline` (> 1 = slower than baseline).
    pub ratio: f64,
}

/// Record families that block the CI bench-diff gate. `kernel/*` covers
/// the SIMD/scalar hot loops, `registry/*` the model-memory paths (cold
/// open, warm cache hit, evict + re-decode). End-to-end names are
/// tracked but too machine-noisy to fail on.
pub fn gated_name(name: &str) -> bool {
    name.starts_with("kernel/") || name.starts_with("registry/")
}

impl BenchDiff {
    /// Regression = a gated pair ([`gated_name`]) whose median slowed
    /// down by more than `threshold` (0.20 = 20%). Fail-closed: a
    /// non-finite ratio (zero/NaN baseline — `> threshold` catches +inf,
    /// the NaN check the rest) on a gated pair counts as a regression
    /// rather than slipping through.
    pub fn is_regression(&self, threshold: f64) -> bool {
        gated_name(&self.name) && (self.ratio > 1.0 + threshold || self.ratio.is_nan())
    }
}

/// Pair two runs' records by name (baseline order), comparing medians.
pub fn diff_benchkit_records(current: &[BenchRecord], baseline: &[BenchRecord]) -> Vec<BenchDiff> {
    baseline
        .iter()
        .filter_map(|b| {
            let c = current.iter().find(|c| c.name == b.name)?;
            Some(BenchDiff {
                name: b.name.clone(),
                baseline_median_s: b.median_s,
                current_median_s: c.median_s,
                ratio: c.median_s / b.median_s,
            })
        })
        .collect()
}

/// One scalar/SIMD dispatch pair measured within a single run
/// (`kernel/<op>/scalar` matched with `kernel/<op>/simd`).
#[derive(Clone, Debug)]
pub struct SpeedupPair {
    /// The shared prefix, e.g. `kernel/transpose-counts`.
    pub name: String,
    pub scalar_median_s: f64,
    pub simd_median_s: f64,
    /// `scalar / simd` medians (> 1 = SIMD faster).
    pub speedup: f64,
}

/// Collect every `<prefix>/scalar` record with a `<prefix>/simd` sibling
/// in the same record set (scalar order). The benches emit the `/simd`
/// record only when runtime dispatch resolved to a non-scalar kernel set,
/// so an empty result means the SIMD tier was inactive on this machine —
/// `repro bench-speedup` treats that as an error, not a pass.
pub fn speedup_pairs(records: &[BenchRecord]) -> Vec<SpeedupPair> {
    records
        .iter()
        .filter_map(|s| {
            let prefix = s.name.strip_suffix("/scalar")?;
            let simd_name = format!("{prefix}/simd");
            let v = records.iter().find(|r| r.name == simd_name)?;
            Some(SpeedupPair {
                name: prefix.to_string(),
                scalar_median_s: s.median_s,
                simd_median_s: v.median_s,
                speedup: s.median_s / v.median_s,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = None;
        let mut acc = 0u64;
        let s = b
            .bench("noop-ish", || {
                acc = acc.wrapping_add(black_box(1));
                acc
            })
            .unwrap()
            .clone();
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.p95_s * 1.0001);
        assert!(s.iters > 0);
        assert!(s.mean_s > 0.0);
        assert!(s.items_per_iter.is_none());
        assert!(s.throughput_per_s().is_none());
    }

    #[test]
    fn filter_skips() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = Some("match-me".to_string());
        assert!(b.bench("other", || 1).is_none());
        assert!(b.bench("match-me-please", || 1).is_some());
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            min_s: 1.0,
            median_s: 1.0,
            mean_s: 0.5,
            p95_s: 1.0,
            items_per_iter: Some(100.0),
        };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
        assert!((s.throughput_per_s().unwrap() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn json_document_shape() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = None;
        b.bench("plain \"quoted\"", || 1);
        b.bench_throughput("with-throughput", 256.0, || 2);
        let json = b.to_json();
        assert!(json.starts_with("{\"schema\": \"benchkit/v1\""), "{json}");
        assert!(json.contains("\"name\": \"plain \\\"quoted\\\"\""), "{json}");
        assert!(json.contains("\"name\": \"with-throughput\""), "{json}");
        // Plain bench has a null throughput, the throughput bench a number.
        assert!(json.contains("\"throughput\": null"), "{json}");
        assert_eq!(json.matches("\"throughput\": null").count(), 1, "{json}");
        // Every record carries the full stat set.
        for key in ["\"iters\"", "\"min_s\"", "\"median_s\"", "\"mean_s\"", "\"p95_s\""] {
            assert_eq!(json.matches(key).count(), 2, "{key} in {json}");
        }
        // Balanced braces/brackets (cheap well-formedness check without a
        // JSON parser in the dependency-free build).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn json_escape_and_numbers() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("tab\there"), "tab\\there");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert!(json_num(1.5e-7).contains('e'));
    }

    #[test]
    fn parse_reads_back_what_to_json_writes() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = None;
        b.bench("kernel/thing \"quoted\"", || 1);
        b.bench_throughput("window/e2e", 64.0, || 2);
        b.bench("kernel/µs — utf-8 name", || 3);
        let records = parse_benchkit_json(&b.to_json()).unwrap();
        assert_eq!(records.len(), 3);
        assert_eq!(records[0].name, "kernel/thing \"quoted\"");
        assert!(records[0].throughput.is_none());
        assert!(records[0].median_s > 0.0);
        assert_eq!(records[1].name, "window/e2e");
        assert!(records[1].throughput.unwrap() > 0.0);
        // Non-ASCII names must round-trip byte-exact (diff pairs by name).
        assert_eq!(records[2].name, "kernel/µs — utf-8 name");
        // The committed-baseline shape: extra keys + empty records.
        let empty = parse_benchkit_json(
            "{\"schema\": \"benchkit/v1\", \"fast\": true,\n \
             \"note\": \"placeholder\", \"records\": []}",
        )
        .unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn parse_rejects_non_benchkit_documents() {
        assert!(parse_benchkit_json("{}").is_err(), "no schema key");
        assert!(parse_benchkit_json("{\"schema\": \"other/v2\", \"records\": []}").is_err());
        assert!(parse_benchkit_json("not json at all").is_err());
        assert!(parse_benchkit_json("{\"schema\": \"benchkit/v1\", \"records\": [{}]}").is_err());
        // A record without a finite median would bypass the gate as NaN.
        let no_median = "{\"schema\": \"benchkit/v1\", \"records\": [{\"name\": \"kernel/x\"}]}";
        assert!(parse_benchkit_json(no_median).is_err());
        let null_median =
            "{\"schema\": \"benchkit/v1\", \"records\": [{\"name\": \"k\", \"median_s\": null}]}";
        assert!(parse_benchkit_json(null_median).is_err());
    }

    #[test]
    fn diff_pairs_by_name_and_flags_kernel_regressions() {
        let rec = |name: &str, median: f64| BenchRecord {
            name: name.to_string(),
            median_s: median,
            mean_s: median,
            throughput: None,
        };
        let baseline = vec![
            rec("kernel/a", 1.0e-6),
            rec("kernel/b", 1.0e-6),
            rec("window/c", 1.0e-3),
            rec("kernel/gone", 1.0e-6),
            rec("registry/warm_hit", 1.0e-6),
        ];
        let current = vec![
            rec("kernel/a", 1.1e-6),  // +10% — under the 20% gate
            rec("kernel/b", 1.5e-6),  // +50% — regression
            rec("window/c", 900.0),   // huge, but not gated — tracked only
            rec("kernel/new", 1.0e-6), // unmatched — ignored
            rec("registry/warm_hit", 2.0e-6), // +100% — registry/* gates too
        ];
        let diffs = diff_benchkit_records(&current, &baseline);
        assert_eq!(diffs.len(), 4, "only names present in both runs pair up");
        let by_name = |n: &str| diffs.iter().find(|d| d.name == n).unwrap();
        assert!(!by_name("kernel/a").is_regression(0.20));
        assert!(by_name("kernel/a").is_regression(0.05));
        assert!(by_name("kernel/b").is_regression(0.20));
        assert!(!by_name("window/c").is_regression(0.20), "non-gated never gates");
        assert!(by_name("registry/warm_hit").is_regression(0.20));
        assert!(gated_name("registry/cold_open") && gated_name("kernel/a"));
        assert!(!gated_name("window/c"));
        // Fail-closed: a pathological zero baseline (infinite ratio) on a
        // kernel pair flags rather than slipping through.
        let weird = diff_benchkit_records(&[rec("kernel/z", 1.0e-6)], &[rec("kernel/z", 0.0)]);
        assert!(weird[0].is_regression(0.20));
    }

    #[test]
    fn speedup_pairs_match_scalar_with_simd_sibling() {
        let rec = |name: &str, median: f64| BenchRecord {
            name: name.to_string(),
            median_s: median,
            mean_s: median,
            throughput: None,
        };
        let records = vec![
            rec("kernel/transpose-counts/scalar", 4.0e-6),
            rec("kernel/transpose-counts/simd", 1.0e-6), // 4.0x
            rec("kernel/temporal-add16/scalar", 2.0e-6), // no simd sibling
            rec("kernel/search-batch-256/simd", 1.0e-6), // no scalar sibling
            rec("window/e2e", 1.0e-3),                   // not a dispatch pair
        ];
        let pairs = speedup_pairs(&records);
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].name, "kernel/transpose-counts");
        assert!((pairs[0].speedup - 4.0).abs() < 1e-9);
        assert!((pairs[0].scalar_median_s - 4.0e-6).abs() < 1e-15);
        assert!((pairs[0].simd_median_s - 1.0e-6).abs() < 1e-15);
        // No pairs at all on a scalar-only run.
        assert!(speedup_pairs(&records[2..4]).is_empty());
        assert!(speedup_pairs(&[]).is_empty());
    }

    #[test]
    fn write_json_roundtrips_through_file() {
        // Exercises the writer `finish` delegates to, without routing the
        // output path through the BENCH_JSON env var (tests run
        // multithreaded; BENCH_FAST below is the suite's existing idiom).
        std::env::set_var("BENCH_FAST", "1");
        let name = format!("benchkit-test-{}.json", std::process::id());
        let path = std::env::temp_dir().join(name);
        let mut b = Bench::new();
        b.filter = None;
        b.bench("file-write-smoke", || 1);
        b.write_json(path.to_str().unwrap()).expect("writable");
        let body = std::fs::read_to_string(&path).expect("JSON file written");
        let _ = std::fs::remove_file(&path);
        assert!(body.contains("\"file-write-smoke\""), "{body}");
        assert!(body.ends_with("]}\n"), "{body}");
    }
}
