//! Micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §2). Used by the `cargo bench` targets via `harness = false`.
//!
//! Methodology (criterion-like, simplified):
//! * warm-up phase to stabilise caches/branch predictors,
//! * timed batches sized so one batch ≥ ~1 ms (amortises timer overhead),
//! * reports min / median / mean / p95 per-iteration time and derived
//!   throughput,
//! * a [`black_box`] to defeat constant folding.
//!
//! ## Machine-readable output
//!
//! Set `BENCH_JSON=<path>` to additionally write the collected stats as a
//! JSON document on [`Bench::finish`]:
//!
//! ```json
//! {"schema": "benchkit/v1", "fast": false, "records": [
//!   {"name": "...", "iters": 1234, "min_s": ..., "median_s": ...,
//!    "mean_s": ..., "p95_s": ..., "throughput": ...}
//! ]}
//! ```
//!
//! `throughput` is items/s for benches registered through
//! [`Bench::bench_throughput`] and `null` otherwise. CI runs
//! `bench_encoder` with `BENCH_JSON` enabled and uploads the file, so the
//! perf trajectory is tracked per commit (see `BENCH_encoder.json` at the
//! repo root for the committed trajectory point).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of the standard black box for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's collected statistics (per-iteration seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
    /// Items per iteration for throughput benches (`None` for plain ones).
    pub items_per_iter: Option<f64>,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    /// Items/s for throughput benches, `None` otherwise.
    pub fn throughput_per_s(&self) -> Option<f64> {
        self.items_per_iter.map(|n| self.throughput(n))
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{:8.3} s ", s)
    }
}

/// Minimal JSON string escaping (bench names are plain ASCII, but keep
/// the output well-formed for any input).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A finite f64 as a JSON number (JSON has no NaN/Infinity).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    fast: bool,
    results: Vec<Stats>,
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // honour `cargo bench -- <filter>` and a fast mode for CI
        let args: Vec<String> = std::env::args().skip(1).collect();
        let filter = args.iter().find(|a| !a.starts_with('-')).cloned();
        let fast = std::env::var("BENCH_FAST").is_ok() || args.iter().any(|a| a == "--test");
        Bench {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure: if fast {
                Duration::from_millis(80)
            } else {
                Duration::from_millis(1500)
            },
            fast,
            results: Vec::new(),
            filter,
        }
    }

    /// Run one benchmark. `f` is called once per iteration.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Option<&Stats> {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return None;
            }
        }
        // Warm-up + estimate batch size.
        let mut iters_done: u64 = 0;
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            std_black_box(f());
            iters_done += 1;
        }
        let est_per_iter = warm_start.elapsed().as_secs_f64() / iters_done.max(1) as f64;
        let batch = ((1e-3 / est_per_iter).ceil() as u64).clamp(1, 1_000_000);

        // Measured batches.
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let meas_start = Instant::now();
        while meas_start.elapsed() < self.measure || samples.len() < 10 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std_black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / batch as f64;
            samples.push(dt);
            total_iters += batch;
            if samples.len() >= 2000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min_s = samples[0];
        let median_s = samples[samples.len() / 2];
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        // Clamp the p95 index to the last sample (never wrap to the min).
        let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
        let p95_s = samples[p95_idx];
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            min_s,
            median_s,
            mean_s,
            p95_s,
            items_per_iter: None,
        };
        println!(
            "{:<48} min {} med {} mean {} p95 {}",
            stats.name,
            fmt_time(min_s),
            fmt_time(median_s),
            fmt_time(mean_s),
            fmt_time(p95_s)
        );
        self.results.push(stats);
        self.results.last()
    }

    /// Like [`Self::bench`] but annotates throughput in items/s.
    pub fn bench_throughput<R>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: impl FnMut() -> R,
    ) -> Option<&Stats> {
        let before = self.results.len();
        self.bench(name, f)?;
        self.results[before].items_per_iter = Some(items_per_iter);
        let s = &self.results[before];
        println!(
            "{:<48} throughput {:>12.0} items/s",
            format!("  ({name})"),
            s.throughput(items_per_iter)
        );
        Some(&self.results[before])
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Serialize the collected stats as the `benchkit/v1` JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"schema\": \"benchkit/v1\", \"fast\": {},\n \"records\": [",
            self.fast
        ));
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let throughput = r
                .throughput_per_s()
                .map(json_num)
                .unwrap_or_else(|| "null".to_string());
            out.push_str(&format!(
                "\n  {{\"name\": \"{}\", \"iters\": {}, \"min_s\": {}, \"median_s\": {}, \
                 \"mean_s\": {}, \"p95_s\": {}, \"throughput\": {}}}",
                json_escape(&r.name),
                r.iters,
                json_num(r.min_s),
                json_num(r.median_s),
                json_num(r.mean_s),
                json_num(r.p95_s),
                throughput
            ));
        }
        out.push_str("\n]}\n");
        out
    }

    /// Write the `benchkit/v1` JSON document to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Final summary table (call at the end of a bench binary). When
    /// `BENCH_JSON=<path>` is set, also writes the machine-readable
    /// record file.
    pub fn finish(&self) {
        println!("\n=== {} benchmarks run ===", self.results.len());
        if let Ok(path) = std::env::var("BENCH_JSON") {
            if !path.is_empty() {
                match self.write_json(&path) {
                    Ok(()) => println!("wrote {} records to {path}", self.results.len()),
                    Err(e) => eprintln!("BENCH_JSON: failed to write {path}: {e}"),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = None;
        let mut acc = 0u64;
        let s = b
            .bench("noop-ish", || {
                acc = acc.wrapping_add(black_box(1));
                acc
            })
            .unwrap()
            .clone();
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.p95_s * 1.0001);
        assert!(s.iters > 0);
        assert!(s.mean_s > 0.0);
        assert!(s.items_per_iter.is_none());
        assert!(s.throughput_per_s().is_none());
    }

    #[test]
    fn filter_skips() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = Some("match-me".to_string());
        assert!(b.bench("other", || 1).is_none());
        assert!(b.bench("match-me-please", || 1).is_some());
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            min_s: 1.0,
            median_s: 1.0,
            mean_s: 0.5,
            p95_s: 1.0,
            items_per_iter: Some(100.0),
        };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
        assert!((s.throughput_per_s().unwrap() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn json_document_shape() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = None;
        b.bench("plain \"quoted\"", || 1);
        b.bench_throughput("with-throughput", 256.0, || 2);
        let json = b.to_json();
        assert!(json.starts_with("{\"schema\": \"benchkit/v1\""), "{json}");
        assert!(json.contains("\"name\": \"plain \\\"quoted\\\"\""), "{json}");
        assert!(json.contains("\"name\": \"with-throughput\""), "{json}");
        // Plain bench has a null throughput, the throughput bench a number.
        assert!(json.contains("\"throughput\": null"), "{json}");
        assert_eq!(json.matches("\"throughput\": null").count(), 1, "{json}");
        // Every record carries the full stat set.
        for key in ["\"iters\"", "\"min_s\"", "\"median_s\"", "\"mean_s\"", "\"p95_s\""] {
            assert_eq!(json.matches(key).count(), 2, "{key} in {json}");
        }
        // Balanced braces/brackets (cheap well-formedness check without a
        // JSON parser in the dependency-free build).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn json_escape_and_numbers() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("tab\there"), "tab\\there");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert!(json_num(1.5e-7).contains('e'));
    }

    #[test]
    fn write_json_roundtrips_through_file() {
        // Exercises the writer `finish` delegates to, without routing the
        // output path through the BENCH_JSON env var (tests run
        // multithreaded; BENCH_FAST below is the suite's existing idiom).
        std::env::set_var("BENCH_FAST", "1");
        let name = format!("benchkit-test-{}.json", std::process::id());
        let path = std::env::temp_dir().join(name);
        let mut b = Bench::new();
        b.filter = None;
        b.bench("file-write-smoke", || 1);
        b.write_json(path.to_str().unwrap()).expect("writable");
        let body = std::fs::read_to_string(&path).expect("JSON file written");
        let _ = std::fs::remove_file(&path);
        assert!(body.contains("\"file-write-smoke\""), "{body}");
        assert!(body.ends_with("]}\n"), "{body}");
    }
}
