//! Micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §2). Used by the `cargo bench` targets via `harness = false`.
//!
//! Methodology (criterion-like, simplified):
//! * warm-up phase to stabilise caches/branch predictors,
//! * timed batches sized so one batch ≥ ~1 ms (amortises timer overhead),
//! * reports min / median / mean / p95 per-iteration time and derived
//!   throughput,
//! * a [`black_box`] to defeat constant folding.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of the standard black box for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's collected statistics (per-iteration seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{:8.3} s ", s)
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    results: Vec<Stats>,
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // honour `cargo bench -- <filter>` and a fast mode for CI
        let args: Vec<String> = std::env::args().skip(1).collect();
        let filter = args.iter().find(|a| !a.starts_with('-')).cloned();
        let fast = std::env::var("BENCH_FAST").is_ok() || args.iter().any(|a| a == "--test");
        Bench {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure: if fast {
                Duration::from_millis(80)
            } else {
                Duration::from_millis(1500)
            },
            results: Vec::new(),
            filter,
        }
    }

    /// Run one benchmark. `f` is called once per iteration.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Option<&Stats> {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return None;
            }
        }
        // Warm-up + estimate batch size.
        let mut iters_done: u64 = 0;
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            std_black_box(f());
            iters_done += 1;
        }
        let est_per_iter = warm_start.elapsed().as_secs_f64() / iters_done.max(1) as f64;
        let batch = ((1e-3 / est_per_iter).ceil() as u64).clamp(1, 1_000_000);

        // Measured batches.
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let meas_start = Instant::now();
        while meas_start.elapsed() < self.measure || samples.len() < 10 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std_black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / batch as f64;
            samples.push(dt);
            total_iters += batch;
            if samples.len() >= 2000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min_s = samples[0];
        let median_s = samples[samples.len() / 2];
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        let p95_s = samples[(samples.len() as f64 * 0.95) as usize % samples.len()];
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            min_s,
            median_s,
            mean_s,
            p95_s,
        };
        println!(
            "{:<48} min {} med {} mean {} p95 {}",
            stats.name,
            fmt_time(min_s),
            fmt_time(median_s),
            fmt_time(mean_s),
            fmt_time(p95_s)
        );
        self.results.push(stats);
        self.results.last()
    }

    /// Like [`Self::bench`] but annotates throughput in items/s.
    pub fn bench_throughput<R>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: impl FnMut() -> R,
    ) -> Option<&Stats> {
        let before = self.results.len();
        self.bench(name, f)?;
        let s = &self.results[before];
        println!(
            "{:<48} throughput {:>12.0} items/s",
            format!("  ({name})"),
            s.throughput(items_per_iter)
        );
        Some(&self.results[before])
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Final summary table (call at the end of a bench binary).
    pub fn finish(&self) {
        println!("\n=== {} benchmarks run ===", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = None;
        let mut acc = 0u64;
        let s = b
            .bench("noop-ish", || {
                acc = acc.wrapping_add(black_box(1));
                acc
            })
            .unwrap()
            .clone();
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.p95_s * 1.0001);
        assert!(s.iters > 0);
        assert!(s.mean_s > 0.0);
    }

    #[test]
    fn filter_skips() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        b.filter = Some("match-me".to_string());
        assert!(b.bench("other", || 1).is_none());
        assert!(b.bench("match-me-please", || 1).is_some());
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            min_s: 1.0,
            median_s: 1.0,
            mean_s: 0.5,
            p95_s: 1.0,
        };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
    }
}
