//! Dependency-free command-line parsing (clap is unavailable in the
//! offline build environment — DESIGN.md §2).
//!
//! Supports the subset the `repro` binary needs: subcommands, `--flag`,
//! `--key value` / `--key=value`, positional arguments, typed getters with
//! defaults, and generated usage text.

use std::collections::BTreeMap;

use crate::error::Context;
use crate::{bail, err};

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-option token becomes the
    /// subcommand; later non-option tokens are positional.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> crate::Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminator: everything after is positional.
                    out.positional.extend(iter.by_ref());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` or boolean `--flag` (next token missing
                    // or looks like another option).
                    let takes_value = iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        let v = iter.next().unwrap();
                        out.options.insert(rest.to_string(), v);
                    } else {
                        out.flags.push(rest.to_string());
                    }
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short options are not supported: {tok}");
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> crate::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn require(&self, name: &str) -> crate::Result<&str> {
        self.get(name)
            .ok_or_else(|| err!("missing required option --{name}"))
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .with_context(|| format!("invalid value for --{name}: {s:?}")),
        }
    }

    /// Typed optional option: `None` when absent, `Some(parsed)` when
    /// present, an error when present but unparseable — for options with
    /// no meaningful default (`--max-density`, `--models-dir`-style
    /// opt-ins), where `get_parse`'s mandatory default would invent one.
    pub fn get_parse_opt<T: std::str::FromStr>(&self, name: &str) -> crate::Result<Option<T>>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        self.get(name)
            .map(|s| {
                s.parse::<T>()
                    .with_context(|| format!("invalid value for --{name}: {s:?}"))
            })
            .transpose()
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|s| {
                s.split(',')
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Unknown-option guard: error out if an option is not in `known`
    /// (catches typos early — clap would do this for us).
    pub fn check_known(&self, known: &[&str]) -> crate::Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig4", "--patients", "8", "--out=/tmp/x", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert_eq!(a.get("patients"), Some("8"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "42", "--f", "2.5"]);
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 42);
        assert!((a.get_parse("f", 0.0f64).unwrap() - 2.5).abs() < 1e-12);
        assert_eq!(a.get_parse("missing", 7u32).unwrap(), 7);
        assert!(a.get_parse::<usize>("f", 0).is_err() || a.get("f") == Some("2.5"));
    }

    #[test]
    fn optional_typed_getter() {
        let a = parse(&["x", "--d", "0.25", "--bad", "nope"]);
        assert_eq!(a.get_parse_opt::<f64>("d").unwrap(), Some(0.25));
        assert_eq!(a.get_parse_opt::<f64>("missing").unwrap(), None);
        let err = a.get_parse_opt::<f64>("bad").unwrap_err();
        assert!(format!("{err:#}").contains("--bad"), "{err:#}");
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["detect", "input.ieeg", "more.ieeg"]);
        assert_eq!(a.positional, vec!["input.ieeg", "more.ieeg"]);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["run", "--", "--not-an-option"]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse(&["x", "--oops", "1"]);
        assert!(a.check_known(&["n"]).is_err());
        assert!(a.check_known(&["oops"]).is_ok());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["x", "--dry-run", "--n", "3"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("n"), Some("3"));
    }

    #[test]
    fn require_errors_when_missing() {
        let a = parse(&["x"]);
        assert!(a.require("out").is_err());
    }
}
