//! Subcommand implementations for the `repro` binary.

use std::path::PathBuf;

use sparse_hdc_ieeg::benchkit;
use sparse_hdc_ieeg::cli::Args;
use sparse_hdc_ieeg::ensure;
use sparse_hdc_ieeg::error::Context;
use sparse_hdc_ieeg::data::dataset;
use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::evalpool;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hwmodel::breakdown::{format_breakdown, format_comparison, format_table1};
use sparse_hdc_ieeg::hwmodel::designs::{analyze, analyze_all, patient11_stimulus};
use sparse_hdc_ieeg::pipeline;
use sparse_hdc_ieeg::transport::loadgen;

fn parse_variant(args: &Args) -> sparse_hdc_ieeg::Result<Variant> {
    let name = args.get_str("variant", "sparse-optimized");
    Variant::from_name(&name).with_context(|| format!("unknown variant {name:?}"))
}

fn classifier_config(args: &Args, variant: Variant) -> sparse_hdc_ieeg::Result<ClassifierConfig> {
    let mut cfg = if variant == Variant::Optimized {
        ClassifierConfig::optimized()
    } else {
        ClassifierConfig::default()
    };
    cfg.temporal_threshold = args.get_parse("temporal-threshold", cfg.temporal_threshold)?;
    cfg.spatial_threshold = args.get_parse("spatial-threshold", cfg.spatial_threshold)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    Ok(cfg)
}

/// `repro bench-diff <current.json> <baseline.json> [--threshold FRAC]`
///
/// Compare two benchkit/v1 documents pairwise (matched by record name)
/// and fail when any gated (`kernel/*` or `registry/*`) median regressed
/// by more than `--threshold` (default 0.20 = 20%). The gate is blocking: an empty
/// baseline (the pre-promotion stub) is an **error**, not a pass — CI
/// self-promotes a stub via `scripts/promote-bench-baselines.sh` before
/// running the diff, so there is always something real to gate against.
pub fn bench_diff(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["threshold"])?;
    ensure!(
        args.positional.len() == 2,
        "usage: repro bench-diff <current.json> <baseline.json> [--threshold FRAC]"
    );
    let threshold: f64 = args.get_parse("threshold", 0.20)?;
    let read = |path: &str| -> sparse_hdc_ieeg::Result<Vec<benchkit::BenchRecord>> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        benchkit::parse_benchkit_json(&text).with_context(|| format!("parse {path}"))
    };
    let current = read(&args.positional[0])?;
    let baseline = read(&args.positional[1])?;
    ensure!(
        !baseline.is_empty(),
        "baseline {} has no records (the never-promoted stub) — promote a real run first: \
         scripts/promote-bench-baselines.sh <dir with BENCH_*.current.json>, or commit the \
         CI bench-baselines-promoted artifact",
        args.positional[1]
    );

    let diffs = benchkit::diff_benchkit_records(&current, &baseline);
    // Fail-closed on lost coverage: a baseline gated bench with no
    // counterpart in the current run (renamed, filtered out, crashed)
    // must not make the gate pass vacuously.
    let missing: Vec<&str> = baseline
        .iter()
        .filter(|b| benchkit::gated_name(&b.name))
        .filter(|b| !current.iter().any(|c| c.name == b.name))
        .map(|b| b.name.as_str())
        .collect();
    if diffs.is_empty() && missing.is_empty() {
        println!(
            "bench-diff: no comparable pairs ({} current / {} baseline records) — nothing to gate",
            current.len(),
            baseline.len()
        );
        return Ok(());
    }

    println!(
        "{:<48} {:>14} {:>14} {:>8}",
        "benchmark", "baseline med", "current med", "Δ"
    );
    let mut regressions = 0usize;
    for d in &diffs {
        let delta = (d.ratio - 1.0) * 100.0;
        let flag = if d.is_regression(threshold) {
            regressions += 1;
            "  REGRESSION"
        } else {
            ""
        };
        println!(
            "{:<48} {:>11.3} µs {:>11.3} µs {:>+7.1}%{}",
            d.name,
            d.baseline_median_s * 1e6,
            d.current_median_s * 1e6,
            delta,
            flag
        );
    }
    for name in &missing {
        println!("{name:<48} missing from the current run  LOST-COVERAGE");
    }
    ensure!(
        regressions == 0 && missing.is_empty(),
        "{regressions} gated median(s) regressed more than {:.0}% and {} gated \
         baseline bench(es) are missing from the current run",
        threshold * 100.0,
        missing.len()
    );
    println!(
        "bench-diff: {} pairs compared, no gated regression above {:.0}%",
        diffs.len(),
        threshold * 100.0
    );
    Ok(())
}

/// `repro bench-speedup <run.json>... [--min-speedup X]`
///
/// Within-run SIMD gate: collect every `kernel/<op>/scalar` record with a
/// `kernel/<op>/simd` sibling across the given benchkit/v1 documents and
/// require the **best** pair to show at least `--min-speedup` (default
/// 2.0×, scalar median / SIMD median). The benches emit `/simd` records
/// only when runtime dispatch picked a non-scalar set, so on a machine
/// without AVX2/NEON there are no pairs — that is an error here, not a
/// pass: CI runners are x86_64 with AVX2 and the gate must not vanish
/// silently.
pub fn bench_speedup(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["min-speedup"])?;
    ensure!(
        !args.positional.is_empty(),
        "usage: repro bench-speedup <run.json>... [--min-speedup X]"
    );
    let min_speedup: f64 = args.get_parse("min-speedup", 2.0)?;
    let mut records = Vec::new();
    for path in &args.positional {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let parsed =
            benchkit::parse_benchkit_json(&text).with_context(|| format!("parse {path}"))?;
        records.extend(parsed);
    }
    let pairs = benchkit::speedup_pairs(&records);
    ensure!(
        !pairs.is_empty(),
        "no kernel/*/scalar + kernel/*/simd pairs in {} record(s) — the SIMD tier was \
         inactive (scalar-only machine, or HDC_KERNELS=scalar); this gate needs a SIMD-capable \
         runner",
        records.len()
    );
    println!(
        "{:<40} {:>14} {:>14} {:>9}",
        "kernel", "scalar med", "simd med", "speedup"
    );
    let mut best = 0usize;
    for (i, p) in pairs.iter().enumerate() {
        println!(
            "{:<40} {:>11.3} µs {:>11.3} µs {:>8.2}x",
            p.name,
            p.scalar_median_s * 1e6,
            p.simd_median_s * 1e6,
            p.speedup
        );
        if p.speedup > pairs[best].speedup {
            best = i;
        }
    }
    let best = &pairs[best];
    ensure!(
        best.speedup.is_finite() && best.speedup >= min_speedup,
        "best SIMD speedup is {:.2}x ({}) — below the {min_speedup:.1}x floor",
        best.speedup,
        best.name
    );
    println!(
        "bench-speedup: best pair {} at {:.2}x (floor {min_speedup:.1}x), {} pair(s) measured",
        best.name,
        best.speedup,
        pairs.len()
    );
    Ok(())
}

/// `repro loadgen --addr HOST:PORT --data DIR [--patients LIST]
/// [--sessions N] [--concurrency N] [--record K] [--chunk N]
/// [--retries N] [--report FILE] [--allow-drops]
/// [--hostile SPEC --seed N]`
///
/// `--retries` re-runs sessions a fleet dispatcher cut with a
/// "re-leased" `Shutdown` (shard died mid-stream); only the final
/// attempt counts, so a rebalance under load still reports
/// every-window-answered-exactly-once.
///
/// `--hostile dropout,drift` wraps every session's record in the
/// testkit's fault injectors (see `testkit::hostile` for the
/// vocabulary), each session re-keyed off `--seed` so two same-seed
/// runs stream bit-identical corruption — the CI chaos leg diffs them.
///
/// Replay patient records as concurrent wire sessions against a
/// `repro serve --listen` server and report throughput / latency /
/// drops. Strict by default: any dropped window or failed session is an
/// error (the CI scale smoke relies on this); `--allow-drops` downgrades
/// both to report-only for overload experiments.
pub fn loadgen(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&[
        "addr",
        "data",
        "patients",
        "sessions",
        "concurrency",
        "record",
        "chunk",
        "retries",
        "report",
        "allow-drops",
        "hostile",
        "seed",
    ])?;
    let addr = args.require("addr")?.to_string();
    let data = PathBuf::from(args.require("data")?);
    let patient_ids: Vec<u32> = {
        let list = args.get_list("patients");
        if list.is_empty() {
            vec![1, 2, 3, 4]
        } else {
            list.iter()
                .map(|s| s.parse::<u32>())
                .collect::<Result<_, _>>()?
        }
    };
    let record_idx: usize = args.get_parse("record", 1usize)?;
    let mut cfg = loadgen::LoadgenConfig {
        sessions: args.get_parse("sessions", 64usize)?,
        concurrency: args.get_parse("concurrency", 16usize)?,
        retries: args.get_parse("retries", 0usize)?,
        ..Default::default()
    };
    cfg.client.chunk_samples = args.get_parse("chunk", cfg.client.chunk_samples)?;
    if let Some(spec) = args.get("hostile") {
        let seed: u64 = args.get_parse("seed", 0u64)?;
        cfg.hostile = Some(sparse_hdc_ieeg::testkit::hostile::HostileStream::parse(spec, seed)?);
        println!("loadgen: hostile streams [{spec}], seed {seed}");
    }

    // Same record the server replays in-process mode (`--record`,
    // default 1), so wire results stay comparable run-to-run.
    let mut records = Vec::new();
    for &pid in &patient_ids {
        let mut all = dataset::load_patient(&data, pid)?;
        ensure!(
            record_idx < all.len(),
            "patient {pid} has {} records, --record {record_idx} is out of range",
            all.len()
        );
        records.push((pid, all.swap_remove(record_idx).samples));
    }

    println!(
        "loadgen: {} sessions x {} patients against {addr} ({} in flight)…",
        cfg.sessions,
        records.len(),
        cfg.concurrency.min(cfg.sessions)
    );
    // Bound client writes the same way the client bounds reads: a
    // server that wedges mid-stream errors the session instead of
    // hanging a worker forever.
    let write_timeout = cfg.client.silence_deadline;
    let report = loadgen::run(
        &|| sparse_hdc_ieeg::transport::tcp::TcpTransport::connect(&addr, Some(write_timeout)),
        &records,
        &cfg,
    )?;
    println!("loadgen: {}", report.summary());
    if let Some(path) = args.get("report") {
        std::fs::write(path, report.to_json()).with_context(|| format!("write {path}"))?;
        println!("loadgen: wrote {path}");
    }
    if !args.flag("allow-drops") {
        ensure!(
            report.drops == 0 && report.failures == 0,
            "{} windows dropped, {} sessions failed (pass --allow-drops to downgrade)",
            report.drops,
            report.failures
        );
    }
    Ok(())
}

/// `repro loadgen-diff <current.json> <baseline.json> [--threshold FRAC]`
///
/// Compare two loadgen/v1 reports. The gate is blocking: a baseline
/// stub (`"sessions": 0`, never refreshed from a real run) is an
/// **error**, mirroring the empty-records bench-diff rule — CI promotes
/// the fresh report over a stub before diffing. Against a real
/// baseline, fail when throughput fell (or p95 latency rose) by more
/// than `--threshold` (default 0.50 — shared-runner load numbers are
/// noisy; tighten once the trajectory stabilises).
pub fn loadgen_diff(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["threshold"])?;
    ensure!(
        args.positional.len() == 2,
        "usage: repro loadgen-diff <current.json> <baseline.json> [--threshold FRAC]"
    );
    let threshold: f64 = args.get_parse("threshold", 0.50)?;
    let read = |path: &str| -> sparse_hdc_ieeg::Result<loadgen::LoadgenReport> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        loadgen::parse_loadgen_json(&text).with_context(|| format!("parse {path}"))
    };
    let current = read(&args.positional[0])?;
    let baseline = read(&args.positional[1])?;
    println!("current:  {}", current.summary());
    println!("baseline: {}", baseline.summary());
    ensure!(
        !loadgen::is_stub_report(&baseline),
        "baseline {} is the never-promoted stub (0 sessions) — promote a real report first: \
         scripts/promote-bench-baselines.sh <dir with loadgen.current.json>, or commit the \
         CI loadgen-baseline-promoted artifact",
        args.positional[1]
    );
    let mut regressions = Vec::new();
    if baseline.windows_per_s > 0.0
        && current.windows_per_s < baseline.windows_per_s * (1.0 - threshold)
    {
        regressions.push(format!(
            "throughput fell {:.0}% ({:.0} → {:.0} windows/s)",
            (1.0 - current.windows_per_s / baseline.windows_per_s) * 100.0,
            baseline.windows_per_s,
            current.windows_per_s
        ));
    }
    if let (Some(cur), Some(base)) = (current.p95_latency_s, baseline.p95_latency_s) {
        if base > 0.0 && cur > base * (1.0 + threshold) {
            regressions.push(format!(
                "p95 latency rose {:.0}% ({:.2} ms → {:.2} ms)",
                (cur / base - 1.0) * 100.0,
                base * 1e3,
                cur * 1e3
            ));
        }
    }
    ensure!(
        regressions.is_empty(),
        "loadgen regression beyond {:.0}%: {}",
        threshold * 100.0,
        regressions.join("; ")
    );
    println!(
        "loadgen-diff: within {:.0}% of baseline",
        threshold * 100.0
    );
    Ok(())
}

/// `repro dispatch --shards ADDR,ADDR[,...] [--listen HOST:PORT]
/// [--place "PATIENT=SHARD,..."] [--lease-ms N] [--reap-ms N]
/// [--wait-shards-s N] [--config FILE]`
///
/// Run the fleet dispatcher (`coordinator::fleet`): register the given
/// `serve --listen` shards over control connections, then accept
/// clients, place each `Subscribe` by the deterministic patient hash
/// (plus `--place` overrides), lease the patient to its shard, and
/// proxy the session frames. When a shard dies its patients re-lease to
/// survivors on their next placement. CLI flags override the `[fleet]`
/// config section key-for-key.
pub fn dispatch(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    use sparse_hdc_ieeg::config::{ConfigFile, SystemConfig};
    use sparse_hdc_ieeg::coordinator::fleet;
    use sparse_hdc_ieeg::transport::tcp::TcpTransport;
    use std::sync::Arc;
    use std::time::Duration;

    args.check_known(&[
        "shards",
        "listen",
        "place",
        "lease-ms",
        "reap-ms",
        "wait-shards-s",
        "config",
    ])?;
    let mut system = match args.get("config") {
        Some(path) => SystemConfig::from_file(&ConfigFile::load(std::path::Path::new(path))?)?,
        None => SystemConfig::default(),
    };
    if let Some(place) = args.get("place") {
        system.fleet_overrides = Some(place.to_string());
    }
    system.fleet_lease_ms = args.get_parse("lease-ms", system.fleet_lease_ms)?;
    system.fleet_reap_ms = args.get_parse("reap-ms", system.fleet_reap_ms)?;

    let shards: Vec<String> = {
        let cli = args.get_list("shards");
        if cli.is_empty() {
            system
                .fleet_shards
                .as_deref()
                .unwrap_or("")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        } else {
            cli
        }
    };
    ensure!(
        !shards.is_empty(),
        "dispatch needs shard addresses: --shards HOST:PORT,HOST:PORT or [fleet] shards"
    );
    let listen = args
        .get("listen")
        .or(system.fleet_listen.as_deref())
        .unwrap_or("127.0.0.1:0")
        .to_string();
    let wait_s: u64 = args.get_parse("wait-shards-s", 10u64)?;

    let n_shards = shards.len();
    let cfg = fleet::FleetConfig::from_system(&system, shards)?;
    let transport = TcpTransport::bind(&listen)?;
    // Dialed shard connections (control + proxied data) get a write
    // timeout equal to the staleness deadline, so a wedged shard fails
    // the monitor's heartbeat send instead of blocking it forever.
    let write_timeout = cfg.staleness;
    let connect: fleet::Connector =
        Arc::new(move |addr: &str| TcpTransport::connect(addr, Some(write_timeout)));
    let dispatcher = fleet::FleetDispatcher::start(Box::new(transport), connect, cfg)?;
    dispatcher.wait_live(n_shards, Duration::from_secs(wait_s.max(1)))?;
    println!("dispatch: {n_shards} shards registered and live");
    // The scripted harnesses (CI smoke, tests) scrape this exact line
    // for the bound port — same contract as `serve --listen`.
    println!("listening on {}", dispatcher.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    dispatcher.run()
}

/// `repro gen-data --out DIR [--patients N] [--records N] [--seed S]`
pub fn gen_data(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["out", "patients", "records", "seed"])?;
    let out = PathBuf::from(args.require("out")?);
    let patients: u32 = args.get_parse("patients", 8u32)?;
    let records: usize = args.get_parse("records", 5usize)?;
    let seed: u64 = args.get_parse("seed", SynthConfig::default().seed)?;
    let cfg = SynthConfig {
        records_per_patient: records,
        seed,
        ..Default::default()
    };
    std::fs::create_dir_all(&out)?;
    for pid in 1..=patients {
        let p = SynthPatient::generate(&cfg, pid);
        dataset::save_patient(&p.records, &out, pid)?;
        println!(
            "patient {pid:2}: {} records, rhythm {:.1} Hz, focus {:?}",
            p.records.len(),
            p.profile.rhythm_hz,
            p.profile.focus
        );
    }
    println!("wrote {patients} patients to {}", out.display());
    Ok(())
}

/// `repro train --data DIR --patient ID [--variant V] [--max-density D]
/// [--save FILE] [--retrain-epochs N] [--kernels SET]`
pub fn train(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&[
        "data",
        "patient",
        "variant",
        "max-density",
        "temporal-threshold",
        "spatial-threshold",
        "seed",
        "out",
        "save",
        "retrain-epochs",
        "kernels",
    ])?;
    if let Some(name) = args.get("kernels") {
        sparse_hdc_ieeg::hdc::simd::select(name)?;
        println!("kernels: {}", sparse_hdc_ieeg::hdc::simd::active().name);
    }
    let data = PathBuf::from(args.require("data")?);
    let pid: u32 = args.get_parse("patient", 1u32)?;
    let variant = parse_variant(args)?;
    let mut cfg = classifier_config(args, variant)?;
    let records = dataset::load_patient(&data, pid)?;
    ensure!(!records.is_empty(), "patient {pid} has no records");

    if let Some(d) = args.get_parse_opt::<f64>("max-density")? {
        cfg.temporal_threshold =
            pipeline::tune_temporal_threshold(variant, &cfg, &records[0], d);
        println!("tuned temporal threshold = {} for max density {d}", cfg.temporal_threshold);
    }

    let mut enc = sparse_hdc_ieeg::hdc::classifier::make_encoder(variant, cfg.clone());
    let mut bundle = pipeline::train_on_record(enc.as_mut(), &records[0], &cfg);
    bundle.provenance.patient_id = pid;
    println!(
        "trained {} on patient {pid} record 0: class densities interictal {:.1}% ictal {:.1}%",
        variant.name(),
        bundle.am.classes[0].density() * 100.0,
        bundle.am.classes[1].density() * 100.0
    );

    // Optional iterative refinement before saving (Pale et al.): re-bundle
    // misclassified training windows, keep the better model version.
    let retrain_epochs: usize = args.get_parse("retrain-epochs", 0usize)?;
    if retrain_epochs > 0 {
        ensure!(
            variant.is_sparse(),
            "online retraining targets the sparse design points"
        );
        let opts = pipeline::RetrainOptions {
            max_epochs: retrain_epochs,
            ..Default::default()
        };
        let (next, report) = pipeline::retrain_bundle(&bundle, &records[0], &opts);
        println!(
            "online retrain (≤{retrain_epochs} epochs): training-window errors {} -> {} \
             — saving model v{}",
            report.initial_errors, report.best_errors, next.version
        );
        bundle = next;
    }

    if let Some(path) = args.get("save") {
        let bytes = bundle.to_bytes();
        std::fs::write(path, &bytes)
            .with_context(|| format!("write model bundle {path}"))?;
        println!(
            "model bundle v{} written to {path} ({} bytes)",
            bundle.version,
            bytes.len()
        );
    }
    if let Some(out) = args.get("out") {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&bundle.am.classes[0].to_bytes());
        bytes.extend_from_slice(&bundle.am.classes[1].to_bytes());
        std::fs::write(out, &bytes)?;
        println!("raw AM written to {out} ({} bytes)", bytes.len());
    }
    Ok(())
}

/// `repro model-info <bundle.hdcm | models-dir>` — inspect a saved model
/// bundle, or list a `--models-dir` store (the latest valid version per
/// patient, as a restarted `serve` would recover it).
pub fn model_info(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&[])?;
    ensure!(
        args.positional.len() == 1,
        "usage: repro model-info <bundle.hdcm | models-dir>"
    );
    let path = std::path::Path::new(&args.positional[0]);
    if path.is_dir() {
        // Read-only inspection: `peek` reports corrupt files but never
        // renames them — looking at a store must not change it (the
        // quarantine side effect belongs to `serve`'s recovery scan).
        // Listing goes through lazy bundles: only META/CFGS/PROV are
        // read, so peeking a fleet-sized store never decodes a plane.
        let store = sparse_hdc_ieeg::coordinator::registry::ModelStore::open(path)?;
        let scan = store.peek()?;
        ensure!(
            !scan.recovered.is_empty(),
            "no valid model bundles under {} ({} corrupt, {} ignored)",
            path.display(),
            scan.quarantined.len(),
            scan.ignored.len()
        );
        println!("model store {} — latest valid version per patient:", path.display());
        for (pid, bundle) in &scan.recovered {
            println!(
                "  patient {pid}: latest v{} (format {}, {} online epoch(s), counter planes {})",
                bundle.version(),
                bundle.wire_format(),
                bundle.provenance().epochs,
                if bundle.has_counters() { "present" } else { "absent" },
            );
            debug_assert_eq!(bundle.decode_count(), 0, "listing must stay lazy");
        }
        for q in &scan.quarantined {
            println!("  corrupt: {}", q.display());
        }
        return Ok(());
    }
    let bundle = sparse_hdc_ieeg::hdc::model::ModelBundle::load(path)?;
    println!("{}", bundle.describe());
    Ok(())
}

/// `repro detect --data DIR --patient ID [--variant V] [--max-density D]`
pub fn detect(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&[
        "data",
        "patient",
        "variant",
        "max-density",
        "temporal-threshold",
        "spatial-threshold",
        "seed",
        "consecutive",
    ])?;
    let data = PathBuf::from(args.require("data")?);
    let pid: u32 = args.get_parse("patient", 1u32)?;
    let variant = parse_variant(args)?;
    let cfg = classifier_config(args, variant)?;
    let max_density: Option<f64> = args.get_parse_opt("max-density")?;
    let policy = AlarmPolicy {
        consecutive: args.get_parse("consecutive", 1usize)?,
    };

    let records = dataset::load_patient(&data, pid)?;
    ensure!(records.len() >= 2, "one-shot protocol needs ≥ 2 records");
    let patient = SynthPatient {
        profile: sparse_hdc_ieeg::data::synth::PatientProfile::derive(
            &SynthConfig::default(),
            pid,
        ),
        records,
    };
    let eval = pipeline::evaluate_patient(variant, &cfg, &patient, max_density, policy);
    println!(
        "patient {pid} [{}]: detected {}/{} seizures, mean delay {:.2} s, FA/h {:.2}, \
         window acc {:.1}%, threshold {}, query density {:.1}%",
        variant.name(),
        eval.summary.detected,
        eval.summary.seizures,
        eval.summary.mean_delay_s(),
        eval.summary.false_alarms_per_hour(),
        eval.summary.mean_window_accuracy() * 100.0,
        eval.temporal_threshold,
        eval.mean_query_density * 100.0
    );
    Ok(())
}

/// `repro serve ...` — streaming coordinator (see `coordinator` module).
pub fn serve(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    sparse_hdc_ieeg::coordinator::serve_command(args)
}

/// `repro fig1c [--windows N]` — Fig. 1(c): naive sparse breakdown.
pub fn fig1c(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["windows"])?;
    let windows: usize = args.get_parse("windows", 4usize)?;
    let frames = patient11_stimulus(windows);
    let rep = analyze(
        Variant::SparseBaseline,
        &ClassifierConfig::default(),
        &frames,
    );
    println!("=== Fig. 1(c): naive sparse HDC breakdown (patient-11 stimulus) ===\n");
    print!("{}", format_breakdown(&rep));
    let bind = ["binding", "one-hot-decoder"];
    println!(
        "\nbinding + one-hot decoder: {:.1}% energy, {:.1}% area   (paper: 51.3% / 38%)",
        rep.group_energy_nj(&bind) / rep.energy_nj_per_pred() * 100.0,
        rep.group_area_mm2(&bind) / rep.area_mm2() * 100.0,
    );
    println!(
        "spatial bundling:          {:.1}% area            (paper: 44.9%)",
        rep.group_area_mm2(&["spatial-bundling"]) / rep.area_mm2() * 100.0
    );
    Ok(())
}

/// `repro fig5 [--windows N]` — Fig. 5: four-design comparison.
pub fn fig5(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["windows"])?;
    let windows: usize = args.get_parse("windows", 4usize)?;
    let reports = analyze_all(&ClassifierConfig::default(), windows);
    println!("=== Fig. 5: energy & area, dense vs sparse vs optimized ===\n");
    print!("{}", format_comparison(&reports));
    let opt = &reports[3];
    let base = &reports[1];
    let dense = &reports[0];
    println!(
        "ratios vs sparse baseline: {:.2}× energy, {:.2}× area   (paper: 1.72× / 2.20×)",
        base.energy_nj_per_pred() / opt.energy_nj_per_pred(),
        base.area_mm2() / opt.area_mm2()
    );
    println!(
        "ratios vs dense baseline:  {:.2}× energy, {:.2}× area   (paper: 7.50× / 3.24×)",
        dense.energy_nj_per_pred() / opt.energy_nj_per_pred(),
        dense.area_mm2() / opt.area_mm2()
    );
    Ok(())
}

/// `repro table1 [--windows N]` — Table I: SotA comparison.
pub fn table1(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["windows"])?;
    let windows: usize = args.get_parse("windows", 4usize)?;
    let frames = patient11_stimulus(windows);
    let rep = analyze(Variant::Optimized, &ClassifierConfig::optimized(), &frames);
    println!("=== Table I: comparison to SotA ===\n");
    print!("{}", format_table1(&rep));
    Ok(())
}

/// `repro ablate-thinning` — the §III-B claim: removing the spatial
/// thinning (adder tree + threshold → OR tree) costs no algorithmic
/// performance. Sweeps the spatial threshold on the adder-tree design and
/// compares against the OR-tree design at the same operating point.
pub fn ablate_thinning(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["patients", "records", "max-density"])?;
    let n_patients: u32 = args.get_parse("patients", 4u32)?;
    let records: usize = args.get_parse("records", 3usize)?;
    let max_density: f64 = args.get_parse("max-density", 0.25)?;
    let synth = SynthConfig {
        records_per_patient: records,
        pre_s: 30.0,
        ictal_s: 20.0,
        post_s: 10.0,
        ..Default::default()
    };
    let patients: Vec<SynthPatient> = (1..=n_patients)
        .map(|pid| SynthPatient::generate(&synth, pid))
        .collect();
    let policy = AlarmPolicy { consecutive: 1 };

    println!("=== §III-B ablation: spatial bundling with vs without thinning ===");
    println!("(max query density {max_density}, {n_patients} patients)\n");
    println!(
        "{:<34} {:>12} {:>14} {:>8}",
        "design / spatial threshold", "mean delay s", "detection acc", "FA/h"
    );

    let run = |label: String, variant: Variant, spatial_threshold: u16| {
        let cfg = ClassifierConfig {
            spatial_threshold,
            ..ClassifierConfig::optimized()
        };
        // Shard the patients over the evaluation pool; results come back
        // in patient order, so the aggregation is identical to the old
        // serial loop.
        let evals = evalpool::map(&patients, |p| {
            pipeline::evaluate_patient(variant, &cfg, p, Some(max_density), policy)
        });
        let mut delays = Vec::new();
        let mut acc = 0.0;
        let mut fa = 0.0;
        for e in &evals {
            if e.summary.mean_delay_s().is_finite() {
                delays.push(e.summary.mean_delay_s());
            }
            acc += e.summary.detection_accuracy();
            fa += e.summary.false_alarms_per_hour();
        }
        println!(
            "{:<34} {:>12.2} {:>13.1}% {:>8.2}",
            label,
            delays.iter().sum::<f64>() / delays.len().max(1) as f64,
            acc / patients.len() as f64 * 100.0,
            fa / patients.len() as f64
        );
    };
    run("OR tree (no thinning, §III-B)".into(), Variant::Optimized, 1);
    for t in [1u16, 2, 3, 4] {
        run(
            format!("adder tree + thinning (thr={t})"),
            Variant::SparseCompIm,
            t,
        );
    }
    println!(
        "\nthr=1 must equal the OR tree exactly (same function); the paper's claim is\n         that the deployed baseline threshold can be removed without performance loss."
    );
    Ok(())
}

/// `repro fig4` — Fig. 4: delay & accuracy vs max HV density.
pub fn fig4(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    args.check_known(&["patients", "densities", "variant", "records", "consecutive"])?;
    let n_patients: u32 = args.get_parse("patients", 6u32)?;
    let records: usize = args.get_parse("records", 4usize)?;
    let policy = AlarmPolicy {
        consecutive: args.get_parse("consecutive", 1usize)?,
    };
    let densities: Vec<f64> = {
        let list = args.get_list("densities");
        if list.is_empty() {
            vec![0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.40, 0.50]
        } else {
            list.iter()
                .map(|s| s.parse::<f64>())
                .collect::<Result<_, _>>()?
        }
    };

    let synth = SynthConfig {
        records_per_patient: records,
        pre_s: 30.0,
        ictal_s: 20.0,
        post_s: 10.0,
        ..Default::default()
    };
    let patients: Vec<SynthPatient> = (1..=n_patients)
        .map(|pid| SynthPatient::generate(&synth, pid))
        .collect();

    println!("=== Fig. 4: detection delay & accuracy vs max HV density ===");
    println!(
        "(sparse-optimized, one-shot protocol, {n_patients} patients × {} test seizures)\n",
        records - 1
    );
    println!(
        "{:>10} {:>12} {:>14} {:>10}",
        "max dens", "mean delay s", "detection acc", "FA/h"
    );

    // Stage 1 — threshold tuning, one pass per *patient*: every candidate
    // density's threshold falls out of the same encode of the training
    // record (histogram reuse, `tune_temporal_thresholds`), instead of
    // re-encoding it once per (density × patient) cell.
    let densities_ref = &densities;
    let tuned: Vec<Vec<u16>> = evalpool::map(&patients, |p| {
        pipeline::tune_temporal_thresholds(
            Variant::Optimized,
            &ClassifierConfig::optimized(),
            p.train_record(),
            densities_ref,
        )
    });

    // Stage 2 — evaluation sweep (the lines in Fig. 4): all (density ×
    // patient) cells are independent — shard them over the evaluation
    // pool in one go with their pre-tuned thresholds, then aggregate in
    // input order so the printed table is identical to the serial sweep.
    let jobs: Vec<(usize, usize)> = (0..densities.len())
        .flat_map(|di| (0..patients.len()).map(move |i| (di, i)))
        .collect();
    let tuned_ref = &tuned;
    let evals = evalpool::map(&jobs, |&(di, i)| {
        let mut cfg = ClassifierConfig::optimized();
        cfg.temporal_threshold = tuned_ref[i][di];
        pipeline::evaluate_patient(Variant::Optimized, &cfg, &patients[i], None, policy)
    });

    let mut per_patient_best: Vec<(f64, f64)> = vec![(f64::INFINITY, 0.0); patients.len()];
    for (di, &d) in densities.iter().enumerate() {
        let mut delays = Vec::new();
        let mut acc_sum = 0.0;
        let mut fa = 0.0;
        for (i, eval) in evals[di * patients.len()..(di + 1) * patients.len()]
            .iter()
            .enumerate()
        {
            let delay = eval.summary.mean_delay_s();
            let acc = eval.summary.detection_accuracy();
            if delay.is_finite() {
                delays.push(delay);
            }
            acc_sum += acc;
            fa += eval.summary.false_alarms_per_hour();
            // Track per-patient optimum (stars in Fig. 4): prefer full
            // detection, then min delay.
            let score = if acc >= per_patient_best[i].1 {
                delay
            } else {
                f64::INFINITY
            };
            if acc > per_patient_best[i].1
                || (acc == per_patient_best[i].1 && score < per_patient_best[i].0)
            {
                per_patient_best[i] = (delay, acc);
            }
        }
        let mean_delay = if delays.is_empty() {
            f64::NAN
        } else {
            delays.iter().sum::<f64>() / delays.len() as f64
        };
        println!(
            "{:>9.0}% {:>12.2} {:>13.1}% {:>10.2}",
            d * 100.0,
            mean_delay,
            acc_sum / patients.len() as f64 * 100.0,
            fa / patients.len() as f64
        );
    }

    // The stars: per-patient optimal density.
    let star_delay: f64 = per_patient_best
        .iter()
        .filter(|(d, _)| d.is_finite())
        .map(|(d, _)| *d)
        .sum::<f64>()
        / per_patient_best.iter().filter(|(d, _)| d.is_finite()).count().max(1) as f64;
    let star_acc: f64 =
        per_patient_best.iter().map(|(_, a)| *a).sum::<f64>() / per_patient_best.len() as f64;
    println!(
        "\nper-patient tuned (stars): mean delay {star_delay:.2} s, detection acc {:.1}%",
        star_acc * 100.0
    );

    // Dense baseline reference line.
    let dense_evals = evalpool::map(&patients, |p| {
        pipeline::evaluate_patient(
            Variant::DenseBaseline,
            &ClassifierConfig::default(),
            p,
            None,
            policy,
        )
    });
    let mut delays = Vec::new();
    let mut acc_sum = 0.0;
    for eval in &dense_evals {
        if eval.summary.mean_delay_s().is_finite() {
            delays.push(eval.summary.mean_delay_s());
        }
        acc_sum += eval.summary.detection_accuracy();
    }
    println!(
        "dense HDC baseline:        mean delay {:.2} s, detection acc {:.1}%",
        delays.iter().sum::<f64>() / delays.len().max(1) as f64,
        acc_sum / patients.len() as f64 * 100.0
    );
    Ok(())
}
