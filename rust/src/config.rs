//! Minimal configuration system (serde/toml are unavailable offline —
//! DESIGN.md §2).
//!
//! Parses a TOML subset sufficient for deployment configs: `[section]`
//! headers, `key = value` with string / integer / float / boolean values,
//! `#` comments. Lookup is by `"section.key"`. A typed view
//! ([`SystemConfig`]) maps the file onto the coordinator/classifier
//! options, layered as defaults → file → CLI overrides.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::Context;
use crate::{bail, err};

use crate::hdc::classifier::{ClassifierConfig, Variant};
use crate::params::IM_SEED;

/// A parsed flat config: `"section.key" → raw string value`.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header {raw:?}", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {raw:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            // Strip matching quotes.
            if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
                || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
            {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .with_context(|| format!("config key {key}: invalid value {s:?}")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// Typed system configuration used by the `repro` binary and the
/// coordinator.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Design point to deploy.
    pub variant: Variant,
    pub classifier: ClassifierConfig,
    /// Alarm policy: consecutive ictal windows required.
    pub alarm_consecutive: usize,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Serve the encode hot path through the PJRT runtime (vs. the native
    /// golden model).
    pub use_pjrt: bool,
    /// Worker threads for the coordinator.
    pub workers: usize,
    /// Bounded queue depth per session (backpressure).
    pub queue_depth: usize,
    /// Windows per engine micro-batch submitted by a session (1 = submit
    /// every window immediately; results are bit-identical at any value).
    pub batch_windows: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            variant: Variant::Optimized,
            classifier: ClassifierConfig::optimized(),
            alarm_consecutive: 1,
            artifacts_dir: "artifacts".to_string(),
            use_pjrt: false,
            workers: 2,
            queue_depth: 64,
            batch_windows: 4,
        }
    }
}

impl SystemConfig {
    /// Layer file values over the defaults.
    pub fn from_file(file: &ConfigFile) -> crate::Result<Self> {
        let mut cfg = SystemConfig::default();
        if let Some(v) = file.get("system.variant") {
            cfg.variant = Variant::from_name(v)
                .ok_or_else(|| err!("unknown variant {v:?}"))?;
        }
        cfg.classifier.seed = file.get_parse("classifier.seed", IM_SEED)?;
        cfg.classifier.spatial_threshold =
            file.get_parse("classifier.spatial_threshold", cfg.classifier.spatial_threshold)?;
        cfg.classifier.temporal_threshold = file.get_parse(
            "classifier.temporal_threshold",
            cfg.classifier.temporal_threshold,
        )?;
        cfg.classifier.train_density =
            file.get_parse("classifier.train_density", cfg.classifier.train_density)?;
        cfg.alarm_consecutive = file.get_parse("detector.consecutive", cfg.alarm_consecutive)?;
        cfg.artifacts_dir = file
            .get("runtime.artifacts_dir")
            .unwrap_or(&cfg.artifacts_dir)
            .to_string();
        cfg.use_pjrt = file.get_parse("runtime.use_pjrt", cfg.use_pjrt)?;
        cfg.workers = file.get_parse("coordinator.workers", cfg.workers)?;
        cfg.queue_depth = file.get_parse("coordinator.queue_depth", cfg.queue_depth)?;
        cfg.batch_windows = file.get_parse("coordinator.batch_windows", cfg.batch_windows)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# deployment config
[system]
variant = "sparse-optimized"

[classifier]
temporal_threshold = 120
train_density = 0.4     # inline comment

[coordinator]
workers = 4
queue_depth = 128
batch_windows = 8

[runtime]
use_pjrt = true
artifacts_dir = "artifacts"
"#;

    #[test]
    fn parse_sections_and_types() {
        let f = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(f.get("system.variant"), Some("sparse-optimized"));
        assert_eq!(f.get_parse("classifier.temporal_threshold", 0u16).unwrap(), 120);
        assert!((f.get_parse("classifier.train_density", 0.0).unwrap() - 0.4) < 1e-12);
        assert_eq!(f.get_parse("coordinator.workers", 0usize).unwrap(), 4);
        assert!(f.get_parse("runtime.use_pjrt", false).unwrap());
    }

    #[test]
    fn system_config_layering() {
        let f = ConfigFile::parse(SAMPLE).unwrap();
        let cfg = SystemConfig::from_file(&f).unwrap();
        assert_eq!(cfg.variant, Variant::Optimized);
        assert_eq!(cfg.classifier.temporal_threshold, 120);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_depth, 128);
        assert_eq!(cfg.batch_windows, 8);
        assert!(cfg.use_pjrt);
        // untouched default
        assert_eq!(cfg.alarm_consecutive, 1);
    }

    #[test]
    fn malformed_lines_error() {
        assert!(ConfigFile::parse("[unclosed").is_err());
        assert!(ConfigFile::parse("novalue").is_err());
    }

    #[test]
    fn unknown_variant_errors() {
        let f = ConfigFile::parse("[system]\nvariant = \"bogus\"").unwrap();
        assert!(SystemConfig::from_file(&f).is_err());
    }

    #[test]
    fn empty_config_gives_defaults() {
        let f = ConfigFile::parse("").unwrap();
        let cfg = SystemConfig::from_file(&f).unwrap();
        assert_eq!(cfg.variant, Variant::Optimized);
        assert_eq!(cfg.classifier.temporal_threshold, 130);
    }
}
