//! Minimal configuration system (serde/toml are unavailable offline —
//! DESIGN.md §2).
//!
//! Parses a TOML subset sufficient for deployment configs: `[section]`
//! headers, `key = value` with string / integer / float / boolean values,
//! `#` comments (quote-aware: a `#` inside a quoted value is data, not a
//! comment). Lookup is by `"section.key"`. A typed view
//! ([`SystemConfig`]) maps the file onto the coordinator/classifier
//! options, layered as defaults → file → CLI overrides — and **rejects
//! unrecognized keys**, so a typo like `cordinator.workers` fails with
//! the list of known keys instead of silently deploying defaults.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::error::Context;
use crate::{bail, err};

use crate::hdc::classifier::{ClassifierConfig, Variant};
use crate::params::IM_SEED;

/// Strip a trailing `#` comment, honouring quoted values: a `#` inside
/// a quoted value (`key = "a#b"`) is data, not a comment. Only a quote
/// that *opens the value* (first character after `=`) delimits — an
/// apostrophe inside a bare value or a comment (`dir = /o'brien # x`)
/// stays plain text, so it cannot swallow the comment marker.
fn strip_comment(line: &str) -> &str {
    let hash = line.find('#');
    let eq = line.find('=');
    match (hash, eq) {
        (None, _) => line,
        (Some(h), None) => &line[..h],
        // `#` before any `=`: the assignment (if any) is itself comment.
        (Some(h), Some(e)) if h < e => &line[..h],
        (Some(_), Some(e)) => {
            let value = &line[e + 1..];
            let vstart = e + 1 + (value.len() - value.trim_start().len());
            let rest = &line[vstart..];
            if let Some(q @ ('"' | '\'')) = rest.chars().next() {
                // Quoted value: the comment can only start after the
                // closing quote (both quote chars are 1 byte).
                if let Some(close) = rest[1..].find(q) {
                    let after = vstart + 1 + close + 1;
                    return match line[after..].find('#') {
                        Some(h) => &line[..after + h],
                        None => line,
                    };
                }
                // Unterminated quote: fall through to the bare-value rule.
            }
            match line[vstart..].find('#') {
                Some(h) => &line[..vstart + h],
                None => line,
            }
        }
    }
}

/// A parsed flat config: `"section.key" → raw string value`.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header {raw:?}", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {raw:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            // Strip matching quotes.
            if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
                || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
            {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .with_context(|| format!("config key {key}: invalid value {s:?}")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// A [`ConfigFile`] view that records every key it is asked for, so the
/// typed loader can reject keys nothing consumed (typo detection).
struct TrackedConfig<'a> {
    file: &'a ConfigFile,
    consumed: BTreeSet<&'static str>,
}

impl<'a> TrackedConfig<'a> {
    fn new(file: &'a ConfigFile) -> Self {
        TrackedConfig {
            file,
            consumed: BTreeSet::new(),
        }
    }

    fn get(&mut self, key: &'static str) -> Option<&'a str> {
        self.consumed.insert(key);
        self.file.get(key)
    }

    fn get_parse<T: std::str::FromStr>(&mut self, key: &'static str, default: T) -> crate::Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        self.consumed.insert(key);
        self.file.get_parse(key, default)
    }

    /// Error actionably on any file key no loader consumed.
    fn finish(self) -> crate::Result<()> {
        let unknown: Vec<&str> = self
            .file
            .keys()
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        let known: Vec<&str> = self.consumed.iter().copied().collect();
        bail!(
            "unrecognized config key{} {} — known keys: {}",
            if unknown.len() == 1 { "" } else { "s" },
            unknown.join(", "),
            known.join(", ")
        )
    }
}

/// Typed system configuration used by the `repro` binary and the
/// coordinator.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Design point to deploy.
    pub variant: Variant,
    pub classifier: ClassifierConfig,
    /// Alarm policy: consecutive ictal windows required.
    pub alarm_consecutive: usize,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Serve the encode hot path through the PJRT runtime (vs. the native
    /// golden model).
    pub use_pjrt: bool,
    /// SIMD kernel set to pin (`[runtime] kernels`, CLI `--kernels`):
    /// `scalar`, `avx2`, `neon` or `auto`. `None` = not specified, which
    /// defers to the `HDC_KERNELS` env var / auto-detection.
    pub kernels: Option<String>,
    /// Worker threads for the coordinator.
    pub workers: usize,
    /// Bounded queue depth per session (backpressure).
    pub queue_depth: usize,
    /// Windows per engine micro-batch submitted by a session (1 = submit
    /// every window immediately; results are bit-identical at any value).
    pub batch_windows: usize,
    /// Saved model bundle to deploy (`[model] path`); serving skips
    /// startup retraining when set.
    pub model_path: Option<String>,
    /// Durable per-patient model store (`[model] dir`, CLI
    /// `--models-dir`): published versions persist here and a serve
    /// restart resumes from the highest valid one.
    pub model_dir: Option<String>,
    /// Online-retraining epochs per scheduled retrain
    /// (`[model] retrain_epochs`; 0 = retraining off).
    pub retrain_epochs: usize,
    /// Retrain-trigger threshold on the sliding false-alarm rate
    /// (`[model] fa_rate`; 0.0 = trigger as soon as the window fills).
    pub retrain_fa_rate: f64,
    /// Sliding false-alarm-estimator window, in prediction windows
    /// (`[model] fa_window`).
    pub retrain_fa_window: usize,
    /// Windows to hold off after a triggered retrain
    /// (`[model] retrain_cooldown`).
    pub retrain_cooldown: usize,
    /// Retrains allowed per patient per serve run
    /// (`[model] max_retrains`; 0 = unlimited).
    pub retrain_max: u64,
    /// Labelled serving windows retained per patient for feedback
    /// retraining (`[model] feedback_window`, CLI `--feedback-window`;
    /// 0 disables the feedback loop). A triggered retrain prefers a
    /// full feedback ring over the retained training record.
    pub feedback_window: usize,
    /// Decoded associative-memory planes kept resident at once
    /// (`[model] cache_planes`, CLI `--cache-planes`; 0 = unbounded).
    /// Bounds serve-side model memory: planes past the budget are
    /// evicted LRU and re-decoded from their bundle on the next touch.
    pub cache_planes: usize,
    /// Bundle versions kept on disk per patient (`[model]
    /// max_versions_per_patient`, CLI `--max-model-versions`; 0 = keep
    /// everything). The store GC runs at publish time and never removes
    /// live, newest, or lineage-parent versions.
    pub max_versions_per_patient: usize,
    /// Wire-serve listen address (`[server] listen`, CLI `--listen`);
    /// unset = in-process replay serving.
    pub listen: Option<String>,
    /// Writer-idle heartbeat interval, milliseconds (`[server]
    /// heartbeat_ms`).
    pub heartbeat_ms: u64,
    /// Disconnect a connection sending no frames for this long,
    /// milliseconds (`[server] staleness_ms`).
    pub staleness_ms: u64,
    /// Outbound frames buffered per connection before a slow consumer is
    /// shed (`[server] conn_queue`).
    pub conn_queue: usize,
    /// Dispatcher listen address (`[fleet] listen`, CLI
    /// `dispatch --listen`); unset = bind `127.0.0.1:0`.
    pub fleet_listen: Option<String>,
    /// Comma-separated shard data-plane addresses, slot = position
    /// (`[fleet] shards`, CLI `dispatch --shards`).
    pub fleet_shards: Option<String>,
    /// Explicit placement overrides, `patient=shard` pairs
    /// (`[fleet] place`, CLI `dispatch --place`). Overrides win over the
    /// placement hash.
    pub fleet_overrides: Option<String>,
    /// Lease TTL, milliseconds (`[fleet] lease_ms`): a patient lease not
    /// renewed by session traffic for this long is reaped.
    pub fleet_lease_ms: u64,
    /// Lease reaper scan interval, milliseconds (`[fleet] reap_ms`).
    pub fleet_reap_ms: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            variant: Variant::Optimized,
            classifier: ClassifierConfig::optimized(),
            alarm_consecutive: 1,
            artifacts_dir: "artifacts".to_string(),
            use_pjrt: false,
            kernels: None,
            workers: 2,
            queue_depth: 64,
            batch_windows: 4,
            model_path: None,
            model_dir: None,
            retrain_epochs: 0,
            retrain_fa_rate: 0.0,
            retrain_fa_window: 64,
            retrain_cooldown: 512,
            retrain_max: 1,
            feedback_window: 0,
            cache_planes: 0,
            max_versions_per_patient: 0,
            listen: None,
            heartbeat_ms: 1000,
            staleness_ms: 5000,
            conn_queue: 256,
            fleet_listen: None,
            fleet_shards: None,
            fleet_overrides: None,
            fleet_lease_ms: 3000,
            fleet_reap_ms: 500,
        }
    }
}

impl SystemConfig {
    /// Layer file values over the defaults. Every key the file holds must
    /// be one this loader reads — anything else errors with the list of
    /// known keys.
    pub fn from_file(file: &ConfigFile) -> crate::Result<Self> {
        let mut cfg = SystemConfig::default();
        let mut file = TrackedConfig::new(file);
        if let Some(v) = file.get("system.variant") {
            cfg.variant = Variant::from_name(v).ok_or_else(|| err!("unknown variant {v:?}"))?;
        }
        cfg.classifier.seed = file.get_parse("classifier.seed", IM_SEED)?;
        cfg.classifier.spatial_threshold =
            file.get_parse("classifier.spatial_threshold", cfg.classifier.spatial_threshold)?;
        cfg.classifier.temporal_threshold = file.get_parse(
            "classifier.temporal_threshold",
            cfg.classifier.temporal_threshold,
        )?;
        cfg.classifier.train_density =
            file.get_parse("classifier.train_density", cfg.classifier.train_density)?;
        cfg.alarm_consecutive = file.get_parse("detector.consecutive", cfg.alarm_consecutive)?;
        cfg.artifacts_dir = file
            .get("runtime.artifacts_dir")
            .unwrap_or(&cfg.artifacts_dir)
            .to_string();
        cfg.use_pjrt = file.get_parse("runtime.use_pjrt", cfg.use_pjrt)?;
        if let Some(k) = file.get("runtime.kernels") {
            // Validate the *name* here (typo detection with the file in
            // hand); whether this CPU supports the set is checked when
            // the coordinator pins it via `hdc::simd::select`.
            if !matches!(k, "scalar" | "avx2" | "neon" | "auto") {
                bail!(
                    "runtime.kernels: unknown kernel set {k:?} \
                     (known: scalar, avx2, neon, auto)"
                );
            }
            cfg.kernels = Some(k.to_string());
        }
        cfg.workers = file.get_parse("coordinator.workers", cfg.workers)?;
        cfg.queue_depth = file.get_parse("coordinator.queue_depth", cfg.queue_depth)?;
        cfg.batch_windows = file.get_parse("coordinator.batch_windows", cfg.batch_windows)?;
        cfg.model_path = file.get("model.path").map(str::to_string);
        cfg.model_dir = file.get("model.dir").map(str::to_string);
        cfg.retrain_epochs = file.get_parse("model.retrain_epochs", cfg.retrain_epochs)?;
        cfg.retrain_fa_rate = file.get_parse("model.fa_rate", cfg.retrain_fa_rate)?;
        cfg.retrain_fa_window = file.get_parse("model.fa_window", cfg.retrain_fa_window)?;
        cfg.retrain_cooldown = file.get_parse("model.retrain_cooldown", cfg.retrain_cooldown)?;
        cfg.retrain_max = file.get_parse("model.max_retrains", cfg.retrain_max)?;
        cfg.feedback_window = file.get_parse("model.feedback_window", cfg.feedback_window)?;
        cfg.cache_planes = file.get_parse("model.cache_planes", cfg.cache_planes)?;
        cfg.max_versions_per_patient = file.get_parse(
            "model.max_versions_per_patient",
            cfg.max_versions_per_patient,
        )?;
        cfg.listen = file.get("server.listen").map(str::to_string);
        cfg.heartbeat_ms = file.get_parse("server.heartbeat_ms", cfg.heartbeat_ms)?;
        cfg.staleness_ms = file.get_parse("server.staleness_ms", cfg.staleness_ms)?;
        cfg.conn_queue = file.get_parse("server.conn_queue", cfg.conn_queue)?;
        cfg.fleet_listen = file.get("fleet.listen").map(str::to_string);
        cfg.fleet_shards = file.get("fleet.shards").map(str::to_string);
        cfg.fleet_overrides = file.get("fleet.place").map(str::to_string);
        cfg.fleet_lease_ms = file.get_parse("fleet.lease_ms", cfg.fleet_lease_ms)?;
        cfg.fleet_reap_ms = file.get_parse("fleet.reap_ms", cfg.fleet_reap_ms)?;
        file.finish()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# deployment config
[system]
variant = "sparse-optimized"

[classifier]
temporal_threshold = 120
train_density = 0.4     # inline comment

[coordinator]
workers = 4
queue_depth = 128
batch_windows = 8

[runtime]
use_pjrt = true
artifacts_dir = "artifacts"
kernels = "auto"

[model]
path = "models/p1.hdcm"
dir = "models/fleet"
retrain_epochs = 3
fa_rate = 0.15
fa_window = 32
retrain_cooldown = 128
max_retrains = 4
feedback_window = 48
cache_planes = 2
max_versions_per_patient = 6

[server]
listen = "127.0.0.1:7070"
heartbeat_ms = 500
staleness_ms = 4000
conn_queue = 32

[fleet]
listen = "127.0.0.1:7100"
shards = "127.0.0.1:7101,127.0.0.1:7102"
place = "1=0,2=1"
lease_ms = 2000
reap_ms = 250
"#;

    #[test]
    fn parse_sections_and_types() {
        let f = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(f.get("system.variant"), Some("sparse-optimized"));
        assert_eq!(f.get_parse("classifier.temporal_threshold", 0u16).unwrap(), 120);
        assert!((f.get_parse("classifier.train_density", 0.0).unwrap() - 0.4) < 1e-12);
        assert_eq!(f.get_parse("coordinator.workers", 0usize).unwrap(), 4);
        assert!(f.get_parse("runtime.use_pjrt", false).unwrap());
    }

    #[test]
    fn system_config_layering() {
        let f = ConfigFile::parse(SAMPLE).unwrap();
        let cfg = SystemConfig::from_file(&f).unwrap();
        assert_eq!(cfg.variant, Variant::Optimized);
        assert_eq!(cfg.classifier.temporal_threshold, 120);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_depth, 128);
        assert_eq!(cfg.batch_windows, 8);
        assert!(cfg.use_pjrt);
        assert_eq!(cfg.kernels.as_deref(), Some("auto"));
        assert_eq!(cfg.model_path.as_deref(), Some("models/p1.hdcm"));
        assert_eq!(cfg.model_dir.as_deref(), Some("models/fleet"));
        assert_eq!(cfg.retrain_epochs, 3);
        assert!((cfg.retrain_fa_rate - 0.15).abs() < 1e-12);
        assert_eq!(cfg.retrain_fa_window, 32);
        assert_eq!(cfg.retrain_cooldown, 128);
        assert_eq!(cfg.retrain_max, 4);
        assert_eq!(cfg.feedback_window, 48);
        assert_eq!(cfg.cache_planes, 2);
        assert_eq!(cfg.max_versions_per_patient, 6);
        assert_eq!(cfg.listen.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(cfg.heartbeat_ms, 500);
        assert_eq!(cfg.staleness_ms, 4000);
        assert_eq!(cfg.conn_queue, 32);
        assert_eq!(cfg.fleet_listen.as_deref(), Some("127.0.0.1:7100"));
        assert_eq!(
            cfg.fleet_shards.as_deref(),
            Some("127.0.0.1:7101,127.0.0.1:7102")
        );
        assert_eq!(cfg.fleet_overrides.as_deref(), Some("1=0,2=1"));
        assert_eq!(cfg.fleet_lease_ms, 2000);
        assert_eq!(cfg.fleet_reap_ms, 250);
        // untouched default
        assert_eq!(cfg.alarm_consecutive, 1);
    }

    #[test]
    fn malformed_lines_error() {
        assert!(ConfigFile::parse("[unclosed").is_err());
        assert!(ConfigFile::parse("novalue").is_err());
    }

    #[test]
    fn unknown_variant_errors() {
        let f = ConfigFile::parse("[system]\nvariant = \"bogus\"").unwrap();
        assert!(SystemConfig::from_file(&f).is_err());
    }

    #[test]
    fn unknown_kernel_set_errors() {
        let f = ConfigFile::parse("[runtime]\nkernels = \"avx512\"").unwrap();
        let err = SystemConfig::from_file(&f).unwrap_err();
        assert!(format!("{err:#}").contains("avx512"), "{err:#}");
        for good in ["scalar", "avx2", "neon", "auto"] {
            let f = ConfigFile::parse(&format!("[runtime]\nkernels = \"{good}\"")).unwrap();
            let cfg = SystemConfig::from_file(&f).unwrap();
            assert_eq!(cfg.kernels.as_deref(), Some(good));
        }
    }

    #[test]
    fn empty_config_gives_defaults() {
        let f = ConfigFile::parse("").unwrap();
        let cfg = SystemConfig::from_file(&f).unwrap();
        assert_eq!(cfg.variant, Variant::Optimized);
        assert_eq!(cfg.classifier.temporal_threshold, 130);
        assert_eq!(cfg.kernels, None);
        assert_eq!(cfg.model_path, None);
        assert_eq!(cfg.model_dir, None);
        assert_eq!(cfg.retrain_epochs, 0);
        assert_eq!(cfg.retrain_fa_window, 64);
        assert_eq!(cfg.retrain_max, 1);
        assert_eq!(cfg.feedback_window, 0);
        assert_eq!(cfg.cache_planes, 0);
        assert_eq!(cfg.max_versions_per_patient, 0);
        assert_eq!(cfg.listen, None);
        assert_eq!(cfg.heartbeat_ms, 1000);
        assert_eq!(cfg.staleness_ms, 5000);
        assert_eq!(cfg.conn_queue, 256);
        assert_eq!(cfg.fleet_listen, None);
        assert_eq!(cfg.fleet_shards, None);
        assert_eq!(cfg.fleet_overrides, None);
        assert_eq!(cfg.fleet_lease_ms, 3000);
        assert_eq!(cfg.fleet_reap_ms, 500);
    }

    #[test]
    fn typo_keys_error_actionably() {
        // The motivating bug: a typo'd section silently deployed defaults.
        let f = ConfigFile::parse("[cordinator]\nworkers = 8").unwrap();
        let err = SystemConfig::from_file(&f).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cordinator.workers"), "{msg}");
        assert!(msg.contains("coordinator.workers"), "should list known keys: {msg}");

        // Typo'd key inside a valid section too.
        let f = ConfigFile::parse("[coordinator]\nworker = 8").unwrap();
        let err = SystemConfig::from_file(&f).unwrap_err();
        assert!(format!("{err:#}").contains("coordinator.worker"), "{err:#}");

        // All-known keys still pass.
        let f = ConfigFile::parse(SAMPLE).unwrap();
        assert!(SystemConfig::from_file(&f).is_ok());
    }

    #[test]
    fn hash_inside_quotes_is_data() {
        let f = ConfigFile::parse(
            "[runtime]\nartifacts_dir = \"art#1\"  # and a real comment\n\
             [model]\npath = 'a#b#c'\n",
        )
        .unwrap();
        assert_eq!(f.get("runtime.artifacts_dir"), Some("art#1"));
        assert_eq!(f.get("model.path"), Some("a#b#c"));
        // Unquoted values still treat # as a comment start.
        let f = ConfigFile::parse("[coordinator]\nworkers = 4 # comment").unwrap();
        assert_eq!(f.get("coordinator.workers"), Some("4"));
        // A comment containing an apostrophe must not swallow the line end.
        let f = ConfigFile::parse("[coordinator]\nworkers = 4 # don't trip\nqueue_depth = 9")
            .unwrap();
        assert_eq!(f.get("coordinator.workers"), Some("4"));
        assert_eq!(f.get("coordinator.queue_depth"), Some("9"));
        // An apostrophe *inside* a bare value is data and the trailing
        // comment is still stripped (quotes only delimit when they open
        // the value).
        let f = ConfigFile::parse("[runtime]\nartifacts_dir = /data/o'brien # prod box").unwrap();
        assert_eq!(f.get("runtime.artifacts_dir"), Some("/data/o'brien"));
        // Comment-only line containing an `=` stays a comment.
        let f = ConfigFile::parse("# commented = out\n[coordinator]\nworkers = 2").unwrap();
        assert_eq!(f.get("coordinator.workers"), Some("2"));
    }
}
