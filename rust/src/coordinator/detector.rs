//! Alarm post-processing: K-consecutive smoothing and onset events.
//!
//! The raw classifier emits one ictal/interictal decision per 0.5 s
//! window; an implant alerts only after `consecutive` ictal windows in a
//! row (reducing false alarms at the cost of added delay — the same
//! policy [`crate::data::metrics::AlarmPolicy`] scores offline).

use crate::params::{FRAMES_PER_PREDICTION, SAMPLE_RATE_HZ};

/// A raised alarm.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AlarmEvent {
    /// Window index whose prediction completed the run.
    pub window_idx: u64,
    /// Stream time of the alarm (seconds since stream start).
    pub time_s: f64,
    /// Decision margin of the triggering window.
    pub margin: i64,
}

/// Streaming K-consecutive detector.
#[derive(Clone, Debug)]
pub struct Detector {
    consecutive: usize,
    run: usize,
    /// Alarm latched until a interictal window resets it (prevents one
    /// seizure from raising a flood of events).
    latched: bool,
    pub events: Vec<AlarmEvent>,
}

impl Detector {
    pub fn new(consecutive: usize) -> Self {
        Detector {
            consecutive: consecutive.max(1),
            run: 0,
            latched: false,
            events: Vec::new(),
        }
    }

    /// Feed one window decision; returns an event when an alarm fires.
    pub fn push(&mut self, window_idx: u64, is_ictal: bool, margin: i64) -> Option<AlarmEvent> {
        if !is_ictal {
            self.run = 0;
            self.latched = false;
            return None;
        }
        self.run += 1;
        if self.run >= self.consecutive && !self.latched {
            self.latched = true;
            let event = AlarmEvent {
                window_idx,
                time_s: (window_idx + 1) as f64 * FRAMES_PER_PREDICTION as f64 / SAMPLE_RATE_HZ,
                margin,
            };
            self.events.push(event);
            return Some(event);
        }
        None
    }

    pub fn reset(&mut self) {
        self.run = 0;
        self.latched = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_after_k_consecutive() {
        let mut d = Detector::new(2);
        assert!(d.push(0, true, 1).is_none());
        let e = d.push(1, true, 2).expect("second consecutive fires");
        assert_eq!(e.window_idx, 1);
        assert!((e.time_s - 2.0 * 0.5).abs() < 1e-12);
        // Latched: further ictal windows do not re-fire.
        assert!(d.push(2, true, 3).is_none());
        // Reset on interictal, then fire again.
        assert!(d.push(3, false, -1).is_none());
        assert!(d.push(4, true, 1).is_none());
        assert!(d.push(5, true, 1).is_some());
        assert_eq!(d.events.len(), 2);
    }

    #[test]
    fn k1_fires_immediately_once() {
        let mut d = Detector::new(1);
        assert!(d.push(0, true, 5).is_some());
        assert!(d.push(1, true, 5).is_none());
        assert_eq!(d.events.len(), 1);
    }

    #[test]
    fn interictal_resets_run() {
        let mut d = Detector::new(3);
        d.push(0, true, 1);
        d.push(1, true, 1);
        d.push(2, false, -1);
        d.push(3, true, 1);
        d.push(4, true, 1);
        assert!(d.events.is_empty());
        assert!(d.push(5, true, 1).is_some());
    }
}
