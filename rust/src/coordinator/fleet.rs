//! Fleet dispatcher: the control plane over N
//! [`WireServer`](crate::coordinator::wire::WireServer) shards.
//!
//! One dispatcher process owns **per-patient placement** across a fleet
//! of worker shards, where each shard is the existing wire server
//! ([`crate::coordinator::wire`]) over the shared `ModelStore`. The
//! split mirrors the trace-dispatcher architecture the ROADMAP names:
//!
//! * **placement** — deterministic: an explicit override table first,
//!   then [`fleet_place`] (a splitmix64 hash of the patient id modulo
//!   the shard count). Placement only decides *routing*; every shard
//!   publishes the full model set from the store, which is what makes
//!   re-leasing a patient to any survivor safe.
//! * **leasing** — each routed session grants (or renews) a lease
//!   `patient → shard` in the [`LeaseTable`]. Leases are renewed by
//!   every proxied frame in either direction and reaped by a background
//!   thread once they outlive their TTL without renewal, so a crashed
//!   proxy session can never pin a patient to a shard forever.
//! * **shard health** — one monitor thread per shard keeps a control
//!   connection registered via `ShardHello` (epoch-stamped, echoed by
//!   the shard as the ack), heartbeats through it, and declares the
//!   shard dead when the connection drops or goes silent. Death flips
//!   the slot's live flag; the affected leases re-lease lazily — the
//!   next `Subscribe` for such a patient lands on a surviving shard and
//!   is counted as a rebalance. The control plane owns the verdict: a
//!   data-path failure (shed session, transient dial error) only
//!   *reports* death, and the monitor re-verifies with an immediate
//!   fresh registration — a healthy shard returns to placement within
//!   one handshake instead of being removed forever.
//! * **data path** — the dispatcher proxies at frame granularity: it
//!   reads the client's `Subscribe`, places it, answers with a `Route`
//!   frame naming the shard, forwards the `Subscribe`, then pumps frames
//!   both ways. If the shard dies mid-session the client receives a
//!   reasoned `Shutdown` naming the re-lease, and can simply replay the
//!   session — per-window outputs are idempotent and the survivor serves
//!   the same model version from the store, so a replay produces the
//!   identical prediction stream (the rebalance pinning contract,
//!   `tests/fleet.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed, Ordering::SeqCst};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::SystemConfig;
use crate::coordinator::metrics::FleetMetrics;
use crate::transport::frame::{close, Frame, ReadOutcome};
use crate::transport::{Duplex, Transport};
use crate::{ensure, err};

/// Poll tick for proxied reads (bounds shutdown latency).
const READ_TICK: Duration = Duration::from_millis(50);
/// Accept-loop poll tick.
const ACCEPT_TICK: Duration = Duration::from_millis(200);
/// How long a dead shard's monitor waits before redialing.
const REDIAL_BACKOFF: Duration = Duration::from_millis(500);
/// Control-connection outbound queue depth (lease grants).
const CONTROL_QUEUE: usize = 64;

/// Deterministic placement: splitmix64 of the patient id, modulo the
/// shard count. Stable across processes and restarts — the dispatcher
/// and `serve --shard-of` agree by construction.
pub fn fleet_place(patient: u32, shards: u32) -> u32 {
    let mut z = (patient as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards.max(1) as u64) as u32
}

/// Placement with the override table consulted first.
pub fn effective_place(patient: u32, shards: u32, overrides: &HashMap<u32, u32>) -> u32 {
    overrides
        .get(&patient)
        .copied()
        .unwrap_or_else(|| fleet_place(patient, shards))
}

/// Parse an override spec `"7=1,9=0"` (patient=shard pairs).
pub fn parse_overrides(spec: &str) -> crate::Result<HashMap<u32, u32>> {
    let mut map = HashMap::new();
    for pair in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (p, s) = pair
            .split_once('=')
            .ok_or_else(|| err!("placement override {pair:?} is not patient=shard"))?;
        let patient: u32 = p
            .trim()
            .parse()
            .map_err(|_| err!("bad patient id in override {pair:?}"))?;
        let shard: u32 = s
            .trim()
            .parse()
            .map_err(|_| err!("bad shard slot in override {pair:?}"))?;
        ensure!(
            map.insert(patient, shard).is_none(),
            "patient {patient} appears twice in the override spec"
        );
    }
    Ok(map)
}

/// Parse a `serve --shard-of K/N` spec into (slot, shard count).
pub fn parse_shard_of(spec: &str) -> crate::Result<(u32, u32)> {
    let (k, n) = spec
        .split_once('/')
        .ok_or_else(|| err!("--shard-of {spec:?} is not K/N"))?;
    let k: u32 = k.trim().parse().map_err(|_| err!("bad shard slot in {spec:?}"))?;
    let n: u32 = n.trim().parse().map_err(|_| err!("bad shard count in {spec:?}"))?;
    ensure!(n > 0, "--shard-of {spec:?} names zero shards");
    ensure!(k < n, "--shard-of {spec:?}: slot {k} is out of range for {n} shards");
    Ok((k, n))
}

/// Fleet knobs (the `[fleet]` section of [`SystemConfig`] plus the shard
/// address list, which only the CLI / config can supply).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Data-plane addresses, one per shard slot (slot = index).
    pub shards: Vec<String>,
    /// Explicit placement overrides (patient → shard slot).
    pub overrides: HashMap<u32, u32>,
    /// Lease TTL: a lease not renewed for this long is reaped.
    pub lease: Duration,
    /// Reaper scan interval.
    pub reap_tick: Duration,
    /// Control-connection heartbeat cadence.
    pub heartbeat: Duration,
    /// A shard silent on its control connection for this long is dead.
    pub staleness: Duration,
}

impl FleetConfig {
    pub fn from_system(system: &SystemConfig, shards: Vec<String>) -> crate::Result<FleetConfig> {
        ensure!(!shards.is_empty(), "fleet needs at least one shard address");
        let overrides = match &system.fleet_overrides {
            Some(spec) => parse_overrides(spec)?,
            None => HashMap::new(),
        };
        for (&patient, &shard) in &overrides {
            ensure!(
                (shard as usize) < shards.len(),
                "override {patient}={shard} names shard {shard}, but only {} shards are configured",
                shards.len()
            );
        }
        Ok(FleetConfig {
            shards,
            overrides,
            lease: Duration::from_millis(system.fleet_lease_ms.max(1)),
            reap_tick: Duration::from_millis(system.fleet_reap_ms.max(1)),
            heartbeat: Duration::from_millis(system.heartbeat_ms.max(1)),
            staleness: Duration::from_millis(system.staleness_ms.max(1)),
        })
    }
}

/// How to dial a shard address — `TcpTransport::connect` in production,
/// a pipe-connector map in tests.
pub type Connector = Arc<dyn Fn(&str) -> crate::Result<Duplex> + Send + Sync>;

/// One lease: which shard serves a patient, until when.
#[derive(Clone, Copy, Debug)]
struct LeaseEntry {
    shard: u32,
    epoch: u64,
    expires: Instant,
}

/// The dispatcher's lease table: `patient → (shard, epoch, expiry)`.
/// Entries are inserted on placement, refreshed by every proxied
/// upstream frame, and removed by the reaper once expired — a lease is
/// exactly "this patient's sessions flowed through this shard recently".
#[derive(Default)]
pub struct LeaseTable {
    inner: Mutex<HashMap<u32, LeaseEntry>>,
}

impl LeaseTable {
    pub fn new() -> Self {
        LeaseTable::default()
    }

    /// The shard currently leasing `patient` (expired or not — expiry is
    /// the reaper's call, placement only cares who held it last).
    pub fn current(&self, patient: u32) -> Option<u32> {
        self.inner.lock().ok()?.get(&patient).map(|l| l.shard)
    }

    /// Grant or move a lease (placement decided by the caller).
    pub fn insert(&self, patient: u32, shard: u32, epoch: u64, ttl: Duration) {
        if let Ok(mut map) = self.inner.lock() {
            map.insert(
                patient,
                LeaseEntry {
                    shard,
                    epoch,
                    expires: Instant::now() + ttl,
                },
            );
        }
    }

    /// Push the expiry out (a frame flowed). Returns false if the lease
    /// is gone (reaped mid-session — the next grant re-creates it).
    pub fn renew(&self, patient: u32, ttl: Duration) -> bool {
        match self.inner.lock() {
            Ok(mut map) => match map.get_mut(&patient) {
                Some(l) => {
                    l.expires = Instant::now() + ttl;
                    true
                }
                None => false,
            },
            Err(_) => false,
        }
    }

    /// Leases currently held by `shard`.
    pub fn held_by(&self, shard: u32) -> Vec<u32> {
        match self.inner.lock() {
            Ok(map) => {
                let mut v: Vec<u32> = map
                    .iter()
                    .filter(|(_, l)| l.shard == shard)
                    .map(|(&p, _)| p)
                    .collect();
                v.sort_unstable();
                v
            }
            Err(_) => Vec::new(),
        }
    }

    /// Remove every lease that expired before `now`; returns the reaped
    /// `(patient, shard)` pairs.
    pub fn reap(&self, now: Instant) -> Vec<(u32, u32)> {
        match self.inner.lock() {
            Ok(mut map) => {
                let dead: Vec<(u32, u32)> = map
                    .iter()
                    .filter(|(_, l)| l.expires <= now)
                    .map(|(&p, l)| (p, l.shard))
                    .collect();
                for (p, _) in &dead {
                    map.remove(p);
                }
                dead
            }
            Err(_) => Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().map(|m| m.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One shard slot: address, liveness, registration epoch, and the
/// monitor-owned control-connection sender (lease grants ride on it).
struct ShardSlot {
    addr: String,
    alive: AtomicBool,
    epoch: AtomicU64,
    control_tx: Mutex<Option<SyncSender<Frame>>>,
}

impl ShardSlot {
    fn new(addr: String) -> Self {
        ShardSlot {
            addr,
            alive: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
            control_tx: Mutex::new(None),
        }
    }

    /// Best-effort send on the control connection (drops when the shard
    /// is between registrations — grants are advisory records, the lease
    /// table is authoritative).
    fn send_control(&self, frame: Frame) {
        if let Ok(guard) = self.control_tx.lock() {
            if let Some(tx) = guard.as_ref() {
                let _ = tx.try_send(frame);
            }
        }
    }
}

/// State shared by the accept loop, proxy sessions, shard monitors and
/// the reaper.
struct FleetInner {
    shards: Vec<ShardSlot>,
    leases: LeaseTable,
    overrides: HashMap<u32, u32>,
    metrics: FleetMetrics,
    connect: Connector,
    cfg: FleetConfig,
    stop: AtomicBool,
}

impl FleetInner {
    fn shard_count(&self) -> u32 {
        self.shards.len() as u32
    }

    fn live_count(&self) -> usize {
        self.shards.iter().filter(|s| s.alive.load(SeqCst)).count()
    }

    fn mark_alive(&self, slot: usize, epoch: u64) {
        if !self.shards[slot].alive.swap(true, SeqCst) {
            self.metrics.shards_live.fetch_add(1, Relaxed);
            eprintln!(
                "fleet: shard {slot} ({}) registered, epoch {epoch}",
                self.shards[slot].addr
            );
        }
    }

    fn mark_dead(&self, slot: usize, why: &str) {
        if self.shards[slot].alive.swap(false, SeqCst) {
            self.metrics.shards_live.fetch_sub(1, Relaxed);
            self.metrics.shards_dead.fetch_add(1, Relaxed);
            let held = self.leases.held_by(slot as u32);
            eprintln!(
                "fleet: shard {slot} ({}) dead ({why}); {} leased patients {:?} \
                 will re-lease to survivors",
                self.shards[slot].addr,
                held.len(),
                held
            );
        }
        if let Ok(mut guard) = self.shards[slot].control_tx.lock() {
            *guard = None;
        }
    }

    /// Place `patient` on a live shard, granting / renewing / moving its
    /// lease. Returns the chosen slot.
    fn lease_for(&self, patient: u32) -> Option<u32> {
        let n = self.shard_count();
        let prior = self.leases.current(patient);
        if let Some(held) = prior {
            if self.shards[held as usize].alive.load(SeqCst) {
                self.leases.renew(patient, self.cfg.lease);
                self.metrics.leases_renewed.fetch_add(1, Relaxed);
                return Some(held);
            }
        }
        let preferred = effective_place(patient, n, &self.overrides);
        for probe in 0..n {
            let slot = (preferred + probe) % n;
            if !self.shards[slot as usize].alive.load(SeqCst) {
                continue;
            }
            let epoch = self.shards[slot as usize].epoch.load(SeqCst);
            self.leases.insert(patient, slot, epoch, self.cfg.lease);
            self.metrics.leases_granted.fetch_add(1, Relaxed);
            self.shards[slot as usize].send_control(Frame::Lease {
                patient,
                shard: slot,
                epoch,
            });
            if let Some(from) = prior {
                if from != slot {
                    self.metrics.rebalances.fetch_add(1, Relaxed);
                    eprintln!(
                        "fleet: patient {patient} re-leased from dead shard {from} \
                         to shard {slot}"
                    );
                }
            }
            return Some(slot);
        }
        None
    }
}

/// Handle to a running dispatcher.
pub struct FleetDispatcher {
    inner: Arc<FleetInner>,
    accept_handle: Option<JoinHandle<crate::Result<()>>>,
    monitor_handles: Vec<JoinHandle<()>>,
    reaper_handle: Option<JoinHandle<()>>,
    addr: String,
}

impl FleetDispatcher {
    /// Start dispatching: register with every shard (monitors keep
    /// retrying in the background), accept clients on `transport`, proxy
    /// sessions by placement. Returns once the accept loop is live — use
    /// [`Self::wait_live`] to block until shards have registered.
    pub fn start(
        mut transport: Box<dyn Transport>,
        connect: Connector,
        cfg: FleetConfig,
    ) -> crate::Result<FleetDispatcher> {
        transport.set_write_timeout(Some(cfg.staleness));
        let addr = transport.local_addr();
        let inner = Arc::new(FleetInner {
            shards: cfg.shards.iter().cloned().map(ShardSlot::new).collect(),
            leases: LeaseTable::new(),
            overrides: cfg.overrides.clone(),
            metrics: FleetMetrics::default(),
            connect,
            cfg,
            stop: AtomicBool::new(false),
        });

        let mut monitor_handles = Vec::new();
        for slot in 0..inner.shards.len() {
            let inner = inner.clone();
            monitor_handles.push(
                std::thread::Builder::new()
                    .name(format!("fleet-monitor-{slot}"))
                    .spawn(move || monitor_loop(&inner, slot))?,
            );
        }

        let reaper_handle = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("fleet-reaper".into())
                .spawn(move || reaper_loop(&inner))?
        };

        let accept_handle = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("fleet-accept".into())
                .spawn(move || -> crate::Result<()> {
                    let mut sessions: Vec<JoinHandle<()>> = Vec::new();
                    while !inner.stop.load(SeqCst) {
                        match transport.accept(ACCEPT_TICK)? {
                            Some(conn) => {
                                inner.metrics.client_connections.fetch_add(1, Relaxed);
                                let inner = inner.clone();
                                sessions.push(
                                    std::thread::Builder::new()
                                        .name("fleet-proxy".into())
                                        .spawn(move || proxy_session(&inner, conn))?,
                                );
                            }
                            None => sessions.retain(|h| !h.is_finished()),
                        }
                    }
                    for h in sessions {
                        let _ = h.join();
                    }
                    Ok(())
                })?
        };

        Ok(FleetDispatcher {
            inner,
            accept_handle: Some(accept_handle),
            monitor_handles,
            reaper_handle: Some(reaper_handle),
            addr,
        })
    }

    /// The client-facing address.
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    pub fn metrics(&self) -> &FleetMetrics {
        &self.inner.metrics
    }

    pub fn leases(&self) -> &LeaseTable {
        &self.inner.leases
    }

    /// Block until at least `n` shards are registered and live.
    pub fn wait_live(&self, n: usize, timeout: Duration) -> crate::Result<()> {
        let deadline = Instant::now() + timeout;
        while self.inner.live_count() < n {
            ensure!(
                Instant::now() < deadline,
                "only {}/{n} shards registered within {timeout:?}",
                self.inner.live_count()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        Ok(())
    }

    /// Stop accepting, close sessions and monitors, join every thread.
    pub fn shutdown(mut self) -> crate::Result<()> {
        self.inner.stop.store(true, SeqCst);
        if let Some(h) = self.accept_handle.take() {
            h.join().map_err(|_| err!("fleet accept thread panicked"))??;
        }
        for h in self.monitor_handles.drain(..) {
            h.join().map_err(|_| err!("fleet monitor thread panicked"))?;
        }
        if let Some(h) = self.reaper_handle.take() {
            h.join().map_err(|_| err!("fleet reaper thread panicked"))?;
        }
        Ok(())
    }

    /// Dispatch until the process dies (`repro dispatch` — CI stops it
    /// with a signal).
    pub fn run(mut self) -> crate::Result<()> {
        if let Some(h) = self.accept_handle.take() {
            h.join().map_err(|_| err!("fleet accept thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for FleetDispatcher {
    fn drop(&mut self) {
        self.inner.stop.store(true, SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.monitor_handles.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.reaper_handle.take() {
            let _ = h.join();
        }
    }
}

/// Keep one shard registered: dial, `ShardHello`, await the echo ack,
/// then heartbeat / relay lease grants / watch for silence. Any failure
/// marks the shard dead and redials after a backoff.
///
/// The control plane **owns** liveness. Proxy sessions may flip the
/// alive flag on a data-path failure (shed, transient dial error, shard
/// crash), but that report is a suspicion, not a verdict: the monitor
/// observes the flag every tick and re-verifies with an immediate fresh
/// registration handshake. A healthy shard is back in placement within
/// one round-trip; a genuinely dead one fails the redial and stays out.
fn monitor_loop(inner: &FleetInner, slot: usize) {
    let addr = inner.shards[slot].addr.clone();
    // True while a data-path report is being re-verified: skip the
    // redial backoff so a healthy shard's absence is one handshake long.
    let mut recovering = false;
    while !inner.stop.load(SeqCst) {
        let mut conn = match (inner.connect)(&addr) {
            Ok(c) => c,
            Err(_) => {
                inner.metrics.shard_conn_errors.fetch_add(1, Relaxed);
                recovering = false;
                sleep_responsive(inner, REDIAL_BACKOFF);
                continue;
            }
        };
        if conn.set_read_timeout(Some(READ_TICK)).is_err() {
            recovering = false;
            sleep_responsive(inner, REDIAL_BACKOFF);
            continue;
        }
        let epoch = inner.shards[slot].epoch.fetch_add(1, SeqCst) + 1;
        let hello = Frame::ShardHello {
            shard: slot as u32,
            epoch,
        };
        if conn.send(&hello).is_err() || !await_hello_ack(inner, &mut conn, slot as u32, epoch) {
            inner.metrics.shard_conn_errors.fetch_add(1, Relaxed);
            recovering = false;
            sleep_responsive(inner, REDIAL_BACKOFF);
            continue;
        }
        let (tx, rx) = sync_channel::<Frame>(CONTROL_QUEUE);
        if let Ok(mut guard) = inner.shards[slot].control_tx.lock() {
            *guard = Some(tx);
        }
        if recovering {
            recovering = false;
            inner.metrics.shards_recovered.fetch_add(1, Relaxed);
        }
        inner.mark_alive(slot, epoch);

        let mut last_rx = Instant::now();
        let mut last_hb = Instant::now();
        let mut hb_seq = 0u64;
        let why = 'control: loop {
            if inner.stop.load(SeqCst) {
                break 'control "dispatcher stopping";
            }
            // A proxy session reported a data-path failure and flipped
            // the alive flag: re-verify via a fresh registration right
            // away instead of trusting (or ignoring) the report.
            if !inner.shards[slot].alive.load(SeqCst) {
                recovering = true;
                break 'control "data-path failure reported; re-verifying registration";
            }
            // Relay queued lease grants onto the control connection. A
            // failed write is a dead control connection — surface it
            // now rather than dropping the frame and limping on to the
            // next heartbeat.
            while let Ok(frame) = rx.try_recv() {
                if conn.send(&frame).is_err() {
                    break 'control "control lease write failed";
                }
            }
            if last_hb.elapsed() >= inner.cfg.heartbeat {
                hb_seq += 1;
                if conn.send(&Frame::Heartbeat { seq: hb_seq }).is_err() {
                    break 'control "control heartbeat write failed";
                }
                last_hb = Instant::now();
            }
            match conn.recv() {
                Ok(ReadOutcome::Frame(_)) => last_rx = Instant::now(),
                Ok(ReadOutcome::Idle) => {
                    if last_rx.elapsed() >= inner.cfg.staleness {
                        break 'control "control connection stale";
                    }
                }
                Ok(ReadOutcome::Eof) => break 'control "control connection closed",
                Err(_) => break 'control "control connection error",
            }
        };
        inner.mark_dead(slot, why);
        if inner.stop.load(SeqCst) {
            return;
        }
        if !recovering {
            sleep_responsive(inner, REDIAL_BACKOFF);
        }
    }
}

/// Wait (bounded by the staleness deadline) for the shard to echo our
/// `ShardHello` registration.
fn await_hello_ack(inner: &FleetInner, conn: &mut Duplex, shard: u32, epoch: u64) -> bool {
    let deadline = Instant::now() + inner.cfg.staleness;
    while Instant::now() < deadline && !inner.stop.load(SeqCst) {
        match conn.recv() {
            Ok(ReadOutcome::Frame(Frame::ShardHello { shard: s, epoch: e })) => {
                return s == shard && e == epoch;
            }
            Ok(ReadOutcome::Frame(Frame::Shutdown { reason })) => {
                eprintln!("fleet: shard {shard} rejected registration: {reason}");
                return false;
            }
            Ok(ReadOutcome::Frame(_)) | Ok(ReadOutcome::Idle) => {}
            Ok(ReadOutcome::Eof) | Err(_) => return false,
        }
    }
    false
}

/// Reap expired leases on a fixed cadence.
fn reaper_loop(inner: &FleetInner) {
    while !inner.stop.load(SeqCst) {
        sleep_responsive(inner, inner.cfg.reap_tick);
        let reaped = inner.leases.reap(Instant::now());
        if !reaped.is_empty() {
            inner
                .metrics
                .leases_expired
                .fetch_add(reaped.len() as u64, Relaxed);
            eprintln!("fleet: reaped {} expired leases: {:?}", reaped.len(), reaped);
        }
    }
}

/// Sleep in stop-checking steps.
fn sleep_responsive(inner: &FleetInner, total: Duration) {
    let deadline = Instant::now() + total;
    while Instant::now() < deadline && !inner.stop.load(SeqCst) {
        std::thread::sleep(READ_TICK.min(deadline.saturating_duration_since(Instant::now())));
    }
}

/// One proxied client session: read the `Subscribe`, place it, `Route`,
/// forward, pump frames both ways until either side closes.
fn proxy_session(inner: &Arc<FleetInner>, mut client: Duplex) {
    if client.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    // First frame must be the Subscribe (heartbeats may precede it).
    let deadline = Instant::now() + inner.cfg.staleness;
    let patient = loop {
        if inner.stop.load(SeqCst) || Instant::now() >= deadline {
            let _ = client.send(&Frame::Shutdown {
                reason: close::stale("no Subscribe within the staleness deadline"),
            });
            return;
        }
        match client.recv() {
            Ok(ReadOutcome::Frame(Frame::Subscribe { patient })) => break patient,
            Ok(ReadOutcome::Frame(Frame::Heartbeat { .. })) | Ok(ReadOutcome::Idle) => {}
            Ok(ReadOutcome::Frame(f)) => {
                let _ = client.send(&Frame::Shutdown {
                    reason: format!("expected Subscribe, got {}", f.kind_name()),
                });
                return;
            }
            Ok(ReadOutcome::Eof) | Err(_) => return,
        }
    };

    let Some(slot) = inner.lease_for(patient) else {
        let _ = client.send(&Frame::Shutdown {
            reason: format!("no live shard for patient {patient}"),
        });
        return;
    };
    let addr = inner.shards[slot as usize].addr.clone();
    let mut shard_conn = match (inner.connect)(&addr) {
        Ok(c) => c,
        Err(_) => {
            inner.metrics.shard_conn_errors.fetch_add(1, Relaxed);
            inner.mark_dead(slot as usize, "data dial failed");
            let _ = client.send(&Frame::Shutdown {
                reason: close::released(format!(
                    "shard {slot} unreachable; patient {patient} moves to a survivor"
                )),
            });
            return;
        }
    };
    if shard_conn.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    if client
        .send(&Frame::Route {
            patient,
            shard: slot,
            addr,
        })
        .is_err()
    {
        return;
    }
    inner.metrics.routes_sent.fetch_add(1, Relaxed);
    if shard_conn.send(&Frame::Subscribe { patient }).is_err() {
        inner.metrics.shard_conn_errors.fetch_add(1, Relaxed);
        inner.mark_dead(slot as usize, "Subscribe forward failed");
        let _ = client.send(&Frame::Shutdown {
            reason: close::released(format!(
                "shard {slot} lost; patient {patient} moves to a survivor"
            )),
        });
        return;
    }
    inner.metrics.sessions_routed.fetch_add(1, Relaxed);

    let (shard_reader, shard_writer, _) = shard_conn.split();
    let (client_reader, mut client_writer, _) = client.split();
    let done = Arc::new(AtomicBool::new(false));

    // Downstream: shard → client (predictions, heartbeats, the final
    // Shutdown). A shard-side EOF/error before the session's Shutdown is
    // a mid-stream shard death: the client gets a reasoned Shutdown
    // naming the re-lease and can replay the session against a survivor.
    let downstream = {
        let inner = inner.clone();
        let done = done.clone();
        let mut reader = shard_reader;
        std::thread::Builder::new()
            .name("fleet-down".into())
            .spawn(move || {
                loop {
                    if done.load(SeqCst) || inner.stop.load(SeqCst) {
                        return;
                    }
                    match reader.read() {
                        Ok(ReadOutcome::Frame(frame)) => {
                            // Downstream flow renews the lease too: a
                            // drain phase (client done sending, shard
                            // still streaming predictions) must not let
                            // the reaper cut an active session's lease.
                            inner.leases.renew(patient, inner.cfg.lease);
                            let last = matches!(frame, Frame::Shutdown { .. });
                            if let Frame::Shutdown { reason } = &frame {
                                if reason == close::END_OF_STREAM {
                                    inner.metrics.leases_released.fetch_add(1, Relaxed);
                                }
                            }
                            let failed = crate::transport::frame::write_frame(
                                &mut client_writer,
                                &frame,
                            )
                            .is_err();
                            inner.metrics.frames_downstream.fetch_add(1, Relaxed);
                            if last || failed {
                                done.store(true, SeqCst);
                                return;
                            }
                        }
                        Ok(ReadOutcome::Idle) => {}
                        Ok(ReadOutcome::Eof) | Err(_) => {
                            if !done.swap(true, SeqCst) {
                                inner.metrics.shard_conn_errors.fetch_add(1, Relaxed);
                                inner.mark_dead(slot as usize, "data connection lost");
                                let _ = crate::transport::frame::write_frame(
                                    &mut client_writer,
                                    &Frame::Shutdown {
                                        reason: close::released(format!(
                                            "shard {slot} lost; patient {patient} moves \
                                             to a surviving shard"
                                        )),
                                    },
                                );
                            }
                            return;
                        }
                    }
                }
            })
    };

    // Upstream: client → shard. Every forwarded frame renews the lease.
    let mut reader = client_reader;
    let mut writer = shard_writer;
    loop {
        if done.load(SeqCst) || inner.stop.load(SeqCst) {
            break;
        }
        match reader.read() {
            Ok(ReadOutcome::Frame(frame)) => {
                inner.leases.renew(patient, inner.cfg.lease);
                if crate::transport::frame::write_frame(&mut writer, &frame).is_err() {
                    // The shard hung up — downstream sees the same close
                    // and notifies the client; nothing more to forward.
                    break;
                }
                inner.metrics.frames_upstream.fetch_add(1, Relaxed);
            }
            Ok(ReadOutcome::Idle) => {
                // A silent client is the shard's staleness call; its
                // Shutdown flows back through the downstream pump.
            }
            Ok(ReadOutcome::Eof) | Err(_) => break,
        }
    }
    drop(writer);
    if let Ok(h) = downstream {
        let _ = h.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_deterministic_and_in_range() {
        for shards in 1..6u32 {
            for patient in 0..200u32 {
                let a = fleet_place(patient, shards);
                let b = fleet_place(patient, shards);
                assert_eq!(a, b, "placement must be stable");
                assert!(a < shards, "slot {a} out of range for {shards} shards");
            }
        }
        // The hash actually spreads: 200 patients over 4 shards never
        // all land on one slot.
        let mut counts = [0usize; 4];
        for patient in 0..200u32 {
            counts[fleet_place(patient, 4) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "degenerate spread {counts:?}");
    }

    #[test]
    fn overrides_win_over_the_hash() {
        let overrides: HashMap<u32, u32> = [(7, 3), (9, 0)].into_iter().collect();
        assert_eq!(effective_place(7, 4, &overrides), 3);
        assert_eq!(effective_place(9, 4, &overrides), 0);
        let free = effective_place(11, 4, &overrides);
        assert_eq!(free, fleet_place(11, 4));
    }

    #[test]
    fn override_spec_parses_and_rejects() {
        let map = parse_overrides("7=1, 9=0").unwrap();
        assert_eq!(map.get(&7), Some(&1));
        assert_eq!(map.get(&9), Some(&0));
        assert_eq!(parse_overrides("").unwrap().len(), 0);
        assert!(parse_overrides("7").is_err());
        assert!(parse_overrides("x=1").is_err());
        assert!(parse_overrides("7=y").is_err());
        assert!(parse_overrides("7=1,7=2").is_err(), "duplicate patient");
    }

    #[test]
    fn shard_of_spec_parses_and_rejects() {
        assert_eq!(parse_shard_of("0/2").unwrap(), (0, 2));
        assert_eq!(parse_shard_of("3/8").unwrap(), (3, 8));
        assert!(parse_shard_of("2/2").is_err(), "slot out of range");
        assert!(parse_shard_of("1/0").is_err(), "zero shards");
        assert!(parse_shard_of("1").is_err());
        assert!(parse_shard_of("a/b").is_err());
    }

    #[test]
    fn lease_table_grant_renew_reap() {
        let t = LeaseTable::new();
        assert!(t.is_empty());
        let ttl = Duration::from_millis(40);
        t.insert(7, 1, 1, ttl);
        t.insert(9, 0, 1, ttl);
        assert_eq!(t.current(7), Some(1));
        assert_eq!(t.held_by(1), vec![7]);
        assert_eq!(t.held_by(0), vec![9]);
        assert_eq!(t.len(), 2);
        // Nothing is expired yet.
        assert!(t.reap(Instant::now()).is_empty());
        // Renewal pushes expiry out; a missing patient cannot renew.
        assert!(t.renew(7, ttl));
        assert!(!t.renew(1234, ttl));
        // Far in the future, everything is reaped (sorted for the assert).
        let mut reaped = t.reap(Instant::now() + Duration::from_secs(3600));
        reaped.sort_unstable();
        assert_eq!(reaped, vec![(7, 1), (9, 0)]);
        assert!(t.is_empty());
        assert_eq!(t.current(7), None);
    }

    #[test]
    fn fleet_config_validates_overrides() {
        let mut system = SystemConfig::default();
        system.fleet_overrides = Some("1=0,2=1".into());
        let cfg = FleetConfig::from_system(
            &system,
            vec!["a:1".into(), "b:2".into()],
        )
        .unwrap();
        assert_eq!(cfg.overrides.len(), 2);
        // An override naming a slot past the shard list is rejected.
        system.fleet_overrides = Some("1=5".into());
        assert!(FleetConfig::from_system(&system, vec!["a:1".into()]).is_err());
        // No shards at all is rejected.
        system.fleet_overrides = None;
        assert!(FleetConfig::from_system(&system, Vec::new()).is_err());
    }
}
