//! Serving metrics: ingest counters, latency distribution, throughput.

use std::time::Instant;

/// Fixed-bucket latency histogram (µs buckets, log-spaced).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_s: f64,
    n: u64,
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 1 µs .. ~16 s, ×2 per bucket.
        let bounds: Vec<f64> = (0..25).map(|i| 1e-6 * 2f64.powi(i)).collect();
        let counts = vec![0; bounds.len() + 1];
        LatencyHistogram {
            bounds,
            counts,
            sum_s: 0.0,
            n: 0,
            max_s: 0.0,
        }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum_s += seconds;
        self.n += 1;
        if seconds > self.max_s {
            self.max_s = seconds;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_s(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        self.sum_s / self.n as f64
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate quantile from the histogram (upper bound of the bucket
    /// containing the q-quantile).
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // Bucket upper bound, clamped to the observed max so
                // quantiles never exceed the true maximum.
                return if i < self.bounds.len() {
                    self.bounds[i].min(self.max_s)
                } else {
                    self.max_s
                };
            }
        }
        self.max_s
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug)]
pub struct ServingMetrics {
    pub started: Instant,
    pub samples_in: u64,
    pub frames_in: u64,
    pub windows_submitted: u64,
    pub windows_completed: u64,
    pub windows_failed: u64,
    pub alarms: u64,
    pub backpressure_stalls: u64,
    /// Mid-stream model swaps picked up from the registry (all sessions).
    pub model_swaps: u64,
    pub latency: LatencyHistogram,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            started: Instant::now(),
            samples_in: 0,
            frames_in: 0,
            windows_submitted: 0,
            windows_completed: 0,
            windows_failed: 0,
            alarms: 0,
            backpressure_stalls: 0,
            model_swaps: 0,
            latency: LatencyHistogram::new(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn windows_per_s(&self) -> f64 {
        self.windows_completed as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn samples_per_s(&self) -> f64 {
        self.samples_in as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "samples {} | windows {}/{} ({} failed) | alarms {} | stalls {} | model swaps {} | \
             window latency mean {:.2} ms p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms max {:.2} ms | \
             {:.0} windows/s, {:.0} samples/s",
            self.samples_in,
            self.windows_completed,
            self.windows_submitted,
            self.windows_failed,
            self.alarms,
            self.backpressure_stalls,
            self.model_swaps,
            self.latency.mean_s() * 1e3,
            self.latency.quantile_s(0.50) * 1e3,
            self.latency.quantile_s(0.95) * 1e3,
            self.latency.quantile_s(0.99) * 1e3,
            self.latency.max_s() * 1e3,
            self.windows_per_s(),
            self.samples_per_s(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10 µs .. 10 ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        let p95 = h.quantile_s(0.95);
        let p99 = h.quantile_s(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.mean_s() > 0.0);
        assert!(h.max_s() >= p99 * 0.5);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = LatencyHistogram::new();
        assert!(h.mean_s().is_nan());
        assert!(h.quantile_s(0.5).is_nan());
    }

    #[test]
    fn metrics_summary_smoke() {
        let mut m = ServingMetrics::new();
        m.samples_in = 100;
        m.windows_completed = 2;
        m.latency.record(0.001);
        let s = m.summary();
        assert!(s.contains("windows 2/0"));
    }
}
