//! Serving metrics: ingest counters, latency distribution, throughput,
//! and the sliding false-alarm-rate estimator that drives the retrain
//! scheduler ([`crate::coordinator::scheduler`]).

use std::time::Instant;

/// Sliding-window false-alarm-rate estimator: a fixed-capacity ring of
/// per-window outcomes (`true` = the window was a false alarm — predicted
/// ictal outside the annotated seizure). O(1) push, O(1) rate. The
/// retrain scheduler reads [`Self::rate`] only once the window is
/// [`Self::full`], so a handful of early windows can never trigger a
/// retrain off a tiny sample.
#[derive(Clone, Debug)]
pub struct FalseAlarmRate {
    buf: Vec<bool>,
    head: usize,
    len: usize,
    false_alarms: usize,
}

impl FalseAlarmRate {
    /// A window of `window` outcomes (clamped to ≥ 1).
    pub fn new(window: usize) -> Self {
        let cap = window.max(1);
        FalseAlarmRate {
            buf: vec![false; cap],
            head: 0,
            len: 0,
            false_alarms: 0,
        }
    }

    /// Record one window outcome, evicting the oldest once full.
    pub fn push(&mut self, false_alarm: bool) {
        if self.len == self.buf.len() {
            self.false_alarms -= self.buf[self.head] as usize;
        } else {
            self.len += 1;
        }
        self.buf[self.head] = false_alarm;
        self.false_alarms += false_alarm as usize;
        self.head = (self.head + 1) % self.buf.len();
    }

    /// Outcomes currently in the window.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The window holds `capacity` outcomes (rate is representative).
    pub fn full(&self) -> bool {
        self.len == self.buf.len()
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// False alarms currently in the window.
    pub fn false_alarms(&self) -> usize {
        self.false_alarms
    }

    /// False-alarm fraction of the windowed outcomes (0.0 when empty).
    pub fn rate(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.false_alarms as f64 / self.len as f64
    }

    /// Forget everything (a retrain was triggered — the next rate must
    /// reflect the *new* model, not the outcomes that indicted the old).
    pub fn clear(&mut self) {
        self.buf.fill(false);
        self.head = 0;
        self.len = 0;
        self.false_alarms = 0;
    }
}

/// Fixed-bucket latency histogram (µs buckets, log-spaced).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_s: f64,
    n: u64,
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 1 µs .. ~16 s, ×2 per bucket.
        let bounds: Vec<f64> = (0..25).map(|i| 1e-6 * 2f64.powi(i)).collect();
        let counts = vec![0; bounds.len() + 1];
        LatencyHistogram {
            bounds,
            counts,
            sum_s: 0.0,
            n: 0,
            max_s: 0.0,
        }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum_s += seconds;
        self.n += 1;
        if seconds > self.max_s {
            self.max_s = seconds;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_s(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        self.sum_s / self.n as f64
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate quantile from the histogram (upper bound of the bucket
    /// containing the q-quantile).
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // Bucket upper bound, clamped to the observed max so
                // quantiles never exceed the true maximum.
                return if i < self.bounds.len() {
                    self.bounds[i].min(self.max_s)
                } else {
                    self.max_s
                };
            }
        }
        self.max_s
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug)]
pub struct ServingMetrics {
    pub started: Instant,
    pub samples_in: u64,
    pub frames_in: u64,
    pub windows_submitted: u64,
    pub windows_completed: u64,
    pub windows_failed: u64,
    pub alarms: u64,
    pub backpressure_stalls: u64,
    /// Mid-stream model swaps picked up from the registry (all sessions).
    pub model_swaps: u64,
    /// Windows predicted ictal outside the annotated seizure (the raw
    /// material of the false-alarm-rate estimator).
    pub false_positives: u64,
    /// Retrains the scheduler triggered during this run (all patients).
    pub retrains_triggered: u64,
    /// Plane-cache lookups served from a resident decoded plane
    /// ([`crate::coordinator::registry::PlaneCache`]).
    pub plane_hits: u64,
    /// Plane-cache lookups that had to decode (first touch of a version).
    pub plane_misses: u64,
    /// Decoded planes evicted to stay inside the `cache_planes` budget.
    pub plane_evictions: u64,
    /// Misses on a version that was decoded before — the cost of an
    /// eviction paid back (each re-decode is also counted as a miss).
    pub plane_redecodes: u64,
    pub latency: LatencyHistogram,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            started: Instant::now(),
            samples_in: 0,
            frames_in: 0,
            windows_submitted: 0,
            windows_completed: 0,
            windows_failed: 0,
            alarms: 0,
            backpressure_stalls: 0,
            model_swaps: 0,
            false_positives: 0,
            retrains_triggered: 0,
            plane_hits: 0,
            plane_misses: 0,
            plane_evictions: 0,
            plane_redecodes: 0,
            latency: LatencyHistogram::new(),
        }
    }

    /// Copy the end-of-run plane-cache counters in from the registry's
    /// [`crate::coordinator::registry::PlaneCacheStats`] snapshot.
    pub fn record_plane_cache(&mut self, stats: crate::coordinator::registry::PlaneCacheStats) {
        self.plane_hits = stats.hits;
        self.plane_misses = stats.misses;
        self.plane_evictions = stats.evictions;
        self.plane_redecodes = stats.redecodes;
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn windows_per_s(&self) -> f64 {
        self.windows_completed as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn samples_per_s(&self) -> f64 {
        self.samples_in as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "samples {} | windows {}/{} ({} failed) | alarms {} | FPs {} | stalls {} | \
             model swaps {} | retrains {} | \
             plane cache {} hits {} misses {} evictions {} re-decodes | \
             window latency mean {:.2} ms p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms max {:.2} ms | \
             {:.0} windows/s, {:.0} samples/s",
            self.samples_in,
            self.windows_completed,
            self.windows_submitted,
            self.windows_failed,
            self.alarms,
            self.false_positives,
            self.backpressure_stalls,
            self.model_swaps,
            self.retrains_triggered,
            self.plane_hits,
            self.plane_misses,
            self.plane_evictions,
            self.plane_redecodes,
            self.latency.mean_s() * 1e3,
            self.latency.quantile_s(0.50) * 1e3,
            self.latency.quantile_s(0.95) * 1e3,
            self.latency.quantile_s(0.99) * 1e3,
            self.latency.max_s() * 1e3,
            self.windows_per_s(),
            self.samples_per_s(),
        )
    }
}

/// Shared counters of the wire serving layer
/// ([`crate::coordinator::wire`]). All fields are atomics: connection
/// reader actors, per-connection writer threads and the completion
/// dispatcher update them concurrently (relaxed ordering — these are
/// statistics, not synchronization).
#[derive(Debug, Default)]
pub struct WireMetrics {
    /// Connections accepted.
    pub connections: std::sync::atomic::AtomicU64,
    /// Sessions opened by a valid Subscribe.
    pub sessions_started: std::sync::atomic::AtomicU64,
    /// Sessions that reached an orderly end-of-stream Shutdown.
    pub sessions_finished: std::sync::atomic::AtomicU64,
    /// Frames received from clients.
    pub frames_in: std::sync::atomic::AtomicU64,
    /// Windows submitted to the engine pool.
    pub windows_submitted: std::sync::atomic::AtomicU64,
    /// Windows whose engine completion was processed.
    pub windows_completed: std::sync::atomic::AtomicU64,
    /// Prediction frames handed to connection writers.
    pub predictions_sent: std::sync::atomic::AtomicU64,
    /// Predictions dropped (shed consumers, failed batches).
    pub predictions_dropped: std::sync::atomic::AtomicU64,
    /// Slow consumers disconnected because their bounded queue filled.
    pub slow_consumers_shed: std::sync::atomic::AtomicU64,
    /// Connections disconnected for missing the staleness deadline.
    pub stale_disconnects: std::sync::atomic::AtomicU64,
    /// Heartbeats written during idle gaps.
    pub heartbeats_sent: std::sync::atomic::AtomicU64,
    /// Malformed / out-of-order / misdirected frames.
    pub protocol_errors: std::sync::atomic::AtomicU64,
    /// Dispatcher control connections registered via `ShardHello`.
    pub control_hellos: std::sync::atomic::AtomicU64,
    /// Lease grants acknowledged on control connections.
    pub leases_acked: std::sync::atomic::AtomicU64,
}

impl WireMetrics {
    pub fn summary(&self) -> String {
        use std::sync::atomic::Ordering::Relaxed;
        format!(
            "conns {} | sessions {}/{} done | frames in {} | windows {}/{} | \
             predictions {} sent, {} dropped | shed {} | stale {} | heartbeats {} | \
             protocol errors {} | control hellos {} | leases acked {}",
            self.connections.load(Relaxed),
            self.sessions_finished.load(Relaxed),
            self.sessions_started.load(Relaxed),
            self.frames_in.load(Relaxed),
            self.windows_completed.load(Relaxed),
            self.windows_submitted.load(Relaxed),
            self.predictions_sent.load(Relaxed),
            self.predictions_dropped.load(Relaxed),
            self.slow_consumers_shed.load(Relaxed),
            self.stale_disconnects.load(Relaxed),
            self.heartbeats_sent.load(Relaxed),
            self.protocol_errors.load(Relaxed),
            self.control_hellos.load(Relaxed),
            self.leases_acked.load(Relaxed),
        )
    }
}

/// Shared counters of the fleet dispatcher
/// ([`crate::coordinator::fleet`]). Same discipline as [`WireMetrics`]:
/// plain atomics updated from the accept loop, per-session proxy threads,
/// the shard monitors and the lease reaper — statistics, never
/// synchronization.
#[derive(Debug, Default)]
pub struct FleetMetrics {
    /// Client connections accepted by the dispatcher.
    pub client_connections: std::sync::atomic::AtomicU64,
    /// Sessions routed to a shard (Subscribe placed + proxied).
    pub sessions_routed: std::sync::atomic::AtomicU64,
    /// `Route` frames sent to clients.
    pub routes_sent: std::sync::atomic::AtomicU64,
    /// Leases granted (first placement of a patient on a shard).
    pub leases_granted: std::sync::atomic::AtomicU64,
    /// Lease renewals (frames flowing on an already-leased session).
    pub leases_renewed: std::sync::atomic::AtomicU64,
    /// Leases expired by the reaper (no renewal within the lease TTL).
    pub leases_expired: std::sync::atomic::AtomicU64,
    /// Leases released on orderly session end.
    pub leases_released: std::sync::atomic::AtomicU64,
    /// Patients re-leased to a surviving shard after their shard died.
    pub rebalances: std::sync::atomic::AtomicU64,
    /// Shards currently registered and live.
    pub shards_live: std::sync::atomic::AtomicU64,
    /// Shards declared dead (control connection lost or dial failed).
    pub shards_dead: std::sync::atomic::AtomicU64,
    /// Shards restored to placement after a data-path failure report:
    /// the monitor's fresh registration handshake proved the shard was
    /// still healthy (self-heal, not a new shard).
    pub shards_recovered: std::sync::atomic::AtomicU64,
    /// Frames proxied client → shard.
    pub frames_upstream: std::sync::atomic::AtomicU64,
    /// Frames proxied shard → client.
    pub frames_downstream: std::sync::atomic::AtomicU64,
    /// Shard connection failures (dial errors, mid-session EOF/IO).
    pub shard_conn_errors: std::sync::atomic::AtomicU64,
}

impl FleetMetrics {
    pub fn summary(&self) -> String {
        use std::sync::atomic::Ordering::Relaxed;
        format!(
            "clients {} | sessions routed {} | routes {} | leases {} granted, {} renewed, \
             {} expired, {} released | rebalances {} | shards {} live, {} dead, {} recovered | \
             frames {} up, {} down | shard errors {}",
            self.client_connections.load(Relaxed),
            self.sessions_routed.load(Relaxed),
            self.routes_sent.load(Relaxed),
            self.leases_granted.load(Relaxed),
            self.leases_renewed.load(Relaxed),
            self.leases_expired.load(Relaxed),
            self.leases_released.load(Relaxed),
            self.rebalances.load(Relaxed),
            self.shards_live.load(Relaxed),
            self.shards_dead.load(Relaxed),
            self.shards_recovered.load(Relaxed),
            self.frames_upstream.load(Relaxed),
            self.frames_downstream.load(Relaxed),
            self.shard_conn_errors.load(Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10 µs .. 10 ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        let p95 = h.quantile_s(0.95);
        let p99 = h.quantile_s(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.mean_s() > 0.0);
        assert!(h.max_s() >= p99 * 0.5);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = LatencyHistogram::new();
        assert!(h.mean_s().is_nan());
        assert!(h.quantile_s(0.5).is_nan());
    }

    #[test]
    fn false_alarm_rate_slides_and_clears() {
        let mut est = FalseAlarmRate::new(4);
        assert!(est.is_empty());
        assert_eq!(est.rate(), 0.0);
        est.push(true);
        est.push(false);
        assert_eq!((est.len(), est.false_alarms()), (2, 1));
        assert!(!est.full());
        assert!((est.rate() - 0.5).abs() < 1e-12);
        est.push(false);
        est.push(false);
        assert!(est.full());
        assert!((est.rate() - 0.25).abs() < 1e-12);
        // Sliding: the initial `true` is evicted by the 5th push.
        est.push(false);
        assert_eq!(est.false_alarms(), 0);
        assert_eq!(est.rate(), 0.0);
        assert_eq!(est.len(), 4);
        // A burst drives the rate to 1.0 within one window span.
        for _ in 0..4 {
            est.push(true);
        }
        assert!((est.rate() - 1.0).abs() < 1e-12);
        est.clear();
        assert!(est.is_empty());
        assert_eq!(est.false_alarms(), 0);
        assert_eq!(est.capacity(), 4);
    }

    #[test]
    fn false_alarm_rate_window_is_exact() {
        // Cross-check the ring against a naive reference over a long
        // deterministic pattern.
        let mut est = FalseAlarmRate::new(7);
        let mut naive: Vec<bool> = Vec::new();
        for i in 0..100usize {
            let fa = i % 3 == 0;
            est.push(fa);
            naive.push(fa);
            let tail: Vec<bool> = naive.iter().rev().take(7).copied().collect();
            let expect = tail.iter().filter(|&&b| b).count();
            assert_eq!(est.false_alarms(), expect, "after push {i}");
            assert_eq!(est.len(), tail.len());
        }
    }

    #[test]
    fn zero_window_clamps_to_one() {
        let mut est = FalseAlarmRate::new(0);
        assert_eq!(est.capacity(), 1);
        est.push(true);
        assert!(est.full());
        assert_eq!(est.rate(), 1.0);
    }

    #[test]
    fn metrics_summary_smoke() {
        let mut m = ServingMetrics::new();
        m.samples_in = 100;
        m.windows_completed = 2;
        m.latency.record(0.001);
        m.record_plane_cache(crate::coordinator::registry::PlaneCacheStats {
            hits: 7,
            misses: 3,
            evictions: 2,
            redecodes: 1,
        });
        let s = m.summary();
        assert!(s.contains("windows 2/0"));
        assert!(s.contains("plane cache 7 hits 3 misses 2 evictions 1 re-decodes"), "{s}");
    }

    #[test]
    fn wire_metrics_summary_smoke() {
        use std::sync::atomic::Ordering::Relaxed;
        let m = WireMetrics::default();
        m.connections.fetch_add(3, Relaxed);
        m.sessions_started.fetch_add(2, Relaxed);
        m.slow_consumers_shed.fetch_add(1, Relaxed);
        let s = m.summary();
        assert!(s.contains("conns 3"), "{s}");
        assert!(s.contains("shed 1"), "{s}");
    }

    #[test]
    fn fleet_metrics_summary_smoke() {
        use std::sync::atomic::Ordering::Relaxed;
        let m = FleetMetrics::default();
        m.client_connections.fetch_add(5, Relaxed);
        m.leases_granted.fetch_add(4, Relaxed);
        m.rebalances.fetch_add(1, Relaxed);
        m.shards_live.store(2, Relaxed);
        let s = m.summary();
        assert!(s.contains("clients 5"), "{s}");
        assert!(s.contains("leases 4 granted"), "{s}");
        assert!(s.contains("rebalances 1"), "{s}");
        assert!(s.contains("shards 2 live"), "{s}");
    }
}
