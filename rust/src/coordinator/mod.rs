//! Layer-3 streaming coordinator — the serving system around the
//! accelerator.
//!
//! A deployment looks like Fig. 1(a): electrode streams arrive per
//! patient, are LBP-encoded, windowed, classified (either through the
//! PJRT-compiled artifacts or the native golden model) and post-processed
//! into alarm events. The coordinator owns:
//!
//! * [`session`] — per-patient state: LBP front-end, window assembly,
//!   the deployed model version, detector state;
//! * [`registry`] — patient → published [`crate::hdc::model::ModelBundle`]
//!   with atomic hot swap (background retrains publish here), plus the
//!   durable [`registry::ModelStore`] backend (`serve --models-dir`);
//! * [`scheduler`] — the false-alarm-driven retrain policy: per-window
//!   outcomes feed a sliding estimator, triggered retrains resume from
//!   the model's counter planes and publish+persist the next version
//!   mid-stream;
//! * [`router`] — routes interleaved sample chunks to sessions;
//! * [`runtime::engine_pool`](crate::runtime::engine_pool) — the engine
//!   worker threads with bounded queues (backpressure);
//! * [`detector`] — K-of-N alarm smoothing and onset events;
//! * [`metrics`] — ingest/latency/throughput counters;
//! * [`server`] — the orchestration loop gluing sources → sessions →
//!   engines → events, with real-time pacing or max-speed replay;
//! * [`wire`] — the wire-level serving layer: actor-per-connection
//!   framed streaming over any [`crate::transport::Transport`], with
//!   heartbeat/staleness deadlines and slow-consumer shedding
//!   (`serve --listen`);
//! * [`fleet`] — the sharded control plane: a dispatcher that owns
//!   per-patient placement across N wire-server shards, leases patients
//!   with a heartbeat-renewed lease table + reaper, and re-leases a dead
//!   shard's patients to survivors (`repro dispatch --shards`).

pub mod detector;
pub mod fleet;
pub mod metrics;
pub mod registry;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod wire;

pub use server::{serve_command, Coordinator, StreamReport};
