//! Versioned model registry: patient → currently-published model, with
//! atomic hot swap.
//!
//! The registry is the serving-side home of [`ModelBundle`]s. Publishing
//! wraps the bundle into a [`PublishedModel`] — the bundle plus its
//! engine-ready [`AmPlane`], built once — and swaps it in under a write
//! lock. Consumers ([`crate::coordinator::session::Session`]s via the
//! server loop) hold an `Arc<PublishedModel>` and refresh it per
//! micro-batch, so a background retrain publishing a new version is
//! picked up **mid-stream with zero queue drain**:
//!
//! * in-flight jobs keep their own `Arc<AmPlane>` (the PR-3 job design),
//!   so nothing already queued is touched;
//! * each version owns a *distinct* `AmPlane` allocation, and the engine
//!   host coalesces jobs only on `Arc` identity — a swap boundary can
//!   therefore never mix two model versions inside one coalesced
//!   `run_batch` call (pinned by `engine_pool` and
//!   `tests/model_lifecycle.rs`);
//! * versions are monotonically increasing per patient: a **stale**
//!   publish (version < current) and a **duplicate** publish (version ==
//!   current) are rejected with distinct errors — a slow retrain racing a
//!   newer model reads differently from a double-publish bug, and
//!   operators triage them differently.
//!
//! ## Persistence ([`ModelStore`])
//!
//! The registry itself is memory-only; [`ModelStore`] is its durable
//! backend. Every published version is written to a per-patient
//! directory (`<root>/<patient>/v<NNN>.hdcm`) via an atomic
//! write-to-temp-then-rename, and a startup [`ModelStore::scan`] recovers
//! the highest *valid* version per patient — quarantining corrupt files
//! (renamed `*.corrupt`) and ignoring leftover temp files from a crashed
//! publish — so `repro serve --models-dir` resumes exactly where the
//! last publish left off.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::ensure;
use crate::error::Context;
use crate::hdc::am::AmPlane;
use crate::hdc::model::ModelBundle;

/// A bundle as deployed: the artifact plus its decoded engine plane.
pub struct PublishedModel {
    pub bundle: ModelBundle,
    /// Shared with every job submitted against this version ([`Arc`]
    /// identity doubles as the engine host's coalescing key).
    pub plane: Arc<AmPlane>,
}

impl PublishedModel {
    pub fn new(bundle: ModelBundle) -> PublishedModel {
        let plane = Arc::new(AmPlane::from_bundle(&bundle));
        PublishedModel { bundle, plane }
    }

    pub fn version(&self) -> u64 {
        self.bundle.version
    }

    /// The temporal thinning threshold jobs against this model carry.
    pub fn threshold(&self) -> u16 {
        self.bundle.config.temporal_threshold
    }

    /// A version-1 model with trivial class HVs (interictal all-zeros,
    /// ictal all-ones) under the default optimized config — for tests
    /// and benchmarks that need *a* deployed model but don't care about
    /// its contents. Not a serving default: real paths always deploy a
    /// trained bundle.
    pub fn placeholder() -> Arc<PublishedModel> {
        use crate::hdc::am::AssociativeMemory;
        use crate::hdc::classifier::{ClassifierConfig, Variant};
        use crate::hdc::hv::Hv;
        use crate::hdc::model::Provenance;
        Arc::new(PublishedModel::new(ModelBundle::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            AssociativeMemory::new(Hv::zero(), Hv::ones()),
            Provenance::default(),
        )))
    }
}

/// Patient → current [`PublishedModel`], atomically swappable.
pub struct ModelRegistry {
    slots: RwLock<BTreeMap<u32, Arc<PublishedModel>>>,
    publishes: AtomicU64,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            slots: RwLock::new(BTreeMap::new()),
            publishes: AtomicU64::new(0),
        }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, BTreeMap<u32, Arc<PublishedModel>>> {
        self.slots.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, BTreeMap<u32, Arc<PublishedModel>>> {
        self.slots.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish a new model version for a patient. Fails unless
    /// `bundle.version` is strictly newer than the current one, with the
    /// two non-monotone cases told apart: a **duplicate** publish
    /// (version == current — usually a double-publish bug or a replayed
    /// request) and a **stale** publish (version < current — a slow
    /// retrain lost the race to a newer model). Either way the current
    /// model is untouched.
    pub fn publish(
        &self,
        patient_id: u32,
        bundle: ModelBundle,
    ) -> crate::Result<Arc<PublishedModel>> {
        let model = Arc::new(PublishedModel::new(bundle));
        let mut slots = self.write();
        if let Some(current) = slots.get(&patient_id) {
            ensure!(
                model.version() != current.version(),
                "duplicate publish for patient {patient_id}: version {} is already current",
                model.version()
            );
            ensure!(
                model.version() > current.version(),
                "stale publish for patient {patient_id}: version {} < current {}",
                model.version(),
                current.version()
            );
        }
        slots.insert(patient_id, model.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        Ok(model)
    }

    /// Publish unless the registry already holds this version or newer;
    /// returns whichever model is current afterwards. This is how the
    /// coordinator seeds stream-spec bundles without racing a background
    /// retrain that may already have published a newer version.
    pub fn ensure(&self, patient_id: u32, bundle: ModelBundle) -> Arc<PublishedModel> {
        let mut slots = self.write();
        if let Some(current) = slots.get(&patient_id) {
            if current.version() >= bundle.version {
                return current.clone();
            }
        }
        let model = Arc::new(PublishedModel::new(bundle));
        slots.insert(patient_id, model.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        model
    }

    /// The currently-published model for a patient.
    pub fn current(&self, patient_id: u32) -> Option<Arc<PublishedModel>> {
        self.read().get(&patient_id).cloned()
    }

    pub fn patients(&self) -> Vec<u32> {
        self.read().keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().is_empty()
    }

    /// Successful publishes (including the initial ones) — a cheap
    /// observability counter for serving reports.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

/// Durable backend of the registry: a per-patient directory of versioned
/// bundle files.
///
/// ```text
/// <root>/
///   1/ v001.hdcm  v002.hdcm            # patient 1, versions 1 and 2
///   7/ v001.hdcm  .v002.hdcm.tmp       # crashed mid-publish: tmp ignored
/// ```
///
/// Publishing is crash-safe: the bundle is written to a hidden
/// `.v<NNN>.hdcm.tmp` in the same directory and `rename`d into place, so
/// a reader (or a restarted server) only ever sees complete files or no
/// file. [`Self::scan`] walks the tree newest-version-first and recovers
/// the highest bundle that parses *and* matches its filename (version)
/// and directory (patient id); anything that fails is renamed
/// `*.corrupt` (quarantined — the next scan will not retry it) and the
/// scan falls back to the next-newest version.
pub struct ModelStore {
    root: PathBuf,
}

/// Outcome of a [`ModelStore::scan`].
#[derive(Default)]
pub struct StoreScan {
    /// Highest valid version per patient.
    pub recovered: BTreeMap<u32, ModelBundle>,
    /// Files that failed to load: renamed `*.corrupt` by [`ModelStore::scan`]
    /// (the returned paths are the new names), reported at their original
    /// paths by the read-only [`ModelStore::peek`].
    pub quarantined: Vec<PathBuf>,
    /// Entries that are not versioned bundle files (leftover `.tmp`
    /// publishes, foreign files, non-numeric directories) — left alone.
    pub ignored: Vec<PathBuf>,
}

impl ModelStore {
    /// Open (creating if needed) a model store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> crate::Result<ModelStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create model store {}", root.display()))?;
        Ok(ModelStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path a given (patient, version) persists at.
    pub fn version_path(&self, patient_id: u32, version: u64) -> PathBuf {
        self.root
            .join(patient_id.to_string())
            .join(format!("v{version:03}.hdcm"))
    }

    /// Persist a bundle under its provenance patient id, atomically:
    /// write to a temp file in the destination directory, then rename
    /// into place. The temp name is unique per writer (process +
    /// sequence), so concurrent saves of the same version (two
    /// schedulers racing, an unlimited-retrain policy) can never
    /// interleave writes into one file — the atomic rename means the
    /// last completed publish wins wholesale. Returns the final path.
    pub fn save(&self, bundle: &ModelBundle) -> crate::Result<PathBuf> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let patient_id = bundle.provenance.patient_id;
        ensure!(
            patient_id != 0,
            "bundle v{} has no patient id (provenance.patient_id = 0) — \
             a model store is keyed by patient",
            bundle.version
        );
        let dir = self.root.join(patient_id.to_string());
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create patient dir {}", dir.display()))?;
        let path = self.version_path(patient_id, bundle.version);
        let tmp = dir.join(format!(
            ".v{:03}.{}.{}.hdcm.tmp",
            bundle.version,
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        // write → fsync → rename → fsync(dir): without the data fsync,
        // delayed allocation can commit the rename before the payload
        // blocks, and an OS crash would leave a truncated "published"
        // file — exactly the torn state the temp file exists to prevent.
        {
            use std::io::Write;
            let mut file = std::fs::File::create(&tmp)
                .with_context(|| format!("create model bundle {}", tmp.display()))?;
            file.write_all(&bundle.to_bytes())
                .with_context(|| format!("write model bundle {}", tmp.display()))?;
            file.sync_all()
                .with_context(|| format!("sync model bundle {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publish {} -> {}", tmp.display(), path.display()))?;
        // Make the rename itself durable (directory metadata). Best
        // effort: not every filesystem lets a directory be fsynced.
        if let Ok(d) = std::fs::File::open(&dir) {
            let _ = d.sync_all();
        }
        Ok(path)
    }

    /// Recover the highest valid version per patient (see the type-level
    /// docs for the corruption / crash-leftover rules). Deterministic:
    /// directory-read order never affects the result.
    pub fn scan(&self) -> crate::Result<StoreScan> {
        self.scan_inner(true)
    }

    /// Read-only [`Self::scan`]: corrupt files are *reported* under
    /// `quarantined` at their original paths but never renamed.
    /// Inspection tools (`repro model-info <dir>`) go through this so
    /// that looking at a store cannot change it.
    pub fn peek(&self) -> crate::Result<StoreScan> {
        self.scan_inner(false)
    }

    fn scan_inner(&self, quarantine_corrupt: bool) -> crate::Result<StoreScan> {
        let mut out = StoreScan::default();
        let entries = std::fs::read_dir(&self.root)
            .with_context(|| format!("scan model store {}", self.root.display()))?;
        for entry in entries {
            let dir = entry?.path();
            let pid = dir
                .file_name()
                .and_then(|n| n.to_str())
                .filter(|n| n.bytes().all(|b| b.is_ascii_digit()))
                .and_then(|n| n.parse::<u32>().ok());
            let (Some(pid), true) = (pid, dir.is_dir()) else {
                out.ignored.push(dir);
                continue;
            };
            let mut candidates: Vec<(u64, PathBuf)> = Vec::new();
            for file in std::fs::read_dir(&dir)? {
                let path = file?.path();
                match path.file_name().and_then(|n| n.to_str()).and_then(parse_version_name) {
                    Some(version) => candidates.push((version, path)),
                    None => out.ignored.push(path),
                }
            }
            // Newest first; the first candidate that loads cleanly wins,
            // older versions stay on disk untouched (history).
            candidates.sort_by(|a, b| b.0.cmp(&a.0));
            for (version, path) in candidates {
                match ModelBundle::load(&path) {
                    Ok(b) if b.version == version && b.provenance.patient_id == pid => {
                        out.recovered.insert(pid, b);
                        break;
                    }
                    // Parses but lies about its name (wrong version or
                    // patient): as untrustworthy as a corrupt file.
                    Ok(_) | Err(_) => out.quarantined.push(if quarantine_corrupt {
                        quarantine(&path)
                    } else {
                        path
                    }),
                }
            }
        }
        Ok(out)
    }
}

/// `v<digits>.hdcm` → version; anything else (tmp files, quarantined
/// files, foreign names) is not a bundle candidate.
fn parse_version_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix('v')?.strip_suffix(".hdcm")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Rename a failed bundle file out of the candidate namespace so the
/// next scan does not retry it; returns the new path. If the rename
/// itself fails the original path is returned — the scan still skips the
/// file this run.
fn quarantine(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(".corrupt");
    let target = PathBuf::from(name);
    match std::fs::rename(path, &target) {
        Ok(()) => target,
        Err(_) => path.to_path_buf(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::hdc::classifier::{ClassifierConfig, Variant};
    use crate::hdc::hv::Hv;
    use crate::hdc::model::Provenance;

    fn bundle(version: u64) -> ModelBundle {
        let mut b = ModelBundle::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            AssociativeMemory::new(Hv::zero(), Hv::ones()),
            Provenance::default(),
        );
        b.version = version;
        b
    }

    #[test]
    fn publish_and_lookup() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.current(7).is_none());
        let m1 = reg.publish(7, bundle(1)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.patients(), vec![7]);
        let got = reg.current(7).unwrap();
        assert!(Arc::ptr_eq(&m1, &got));
        assert_eq!(got.version(), 1);
        assert_eq!(reg.publishes(), 1);
    }

    #[test]
    fn stale_publish_rejected_newer_swaps() {
        let reg = ModelRegistry::new();
        reg.publish(3, bundle(2)).unwrap();
        // Same version and older versions are rejected.
        assert!(reg.publish(3, bundle(2)).is_err());
        assert!(reg.publish(3, bundle(1)).is_err());
        assert_eq!(reg.current(3).unwrap().version(), 2);
        // Strictly newer swaps atomically.
        let m3 = reg.publish(3, bundle(3)).unwrap();
        assert!(Arc::ptr_eq(&m3, &reg.current(3).unwrap()));
        assert_eq!(reg.publishes(), 2);
    }

    #[test]
    fn duplicate_and_stale_publishes_error_distinctly() {
        // The two non-monotone failure modes must be tellable apart: a
        // re-publish of the current version is a *duplicate* (double-
        // publish bug / replayed request), an older version is *stale*
        // (a slow retrain lost the race). Both leave the slot untouched.
        let reg = ModelRegistry::new();
        reg.publish(4, bundle(5)).unwrap();

        let dup = reg.publish(4, bundle(5)).unwrap_err();
        let msg = format!("{dup:#}");
        assert!(msg.contains("duplicate publish"), "{msg}");
        assert!(msg.contains("version 5 is already current"), "{msg}");
        assert!(!msg.contains("stale"), "{msg}");

        let stale = reg.publish(4, bundle(3)).unwrap_err();
        let msg = format!("{stale:#}");
        assert!(msg.contains("stale publish"), "{msg}");
        assert!(msg.contains("version 3 < current 5"), "{msg}");
        assert!(!msg.contains("duplicate"), "{msg}");

        assert_eq!(reg.current(4).unwrap().version(), 5);
        assert_eq!(reg.publishes(), 1, "failed publishes are not counted");
    }

    #[test]
    fn ensure_keeps_the_newer_version() {
        let reg = ModelRegistry::new();
        let first = reg.ensure(5, bundle(1));
        assert_eq!(first.version(), 1);
        // Re-ensuring the same version keeps the existing Arc.
        let again = reg.ensure(5, bundle(1));
        assert!(Arc::ptr_eq(&first, &again));
        // A newer publish wins over a later ensure of the old version.
        reg.publish(5, bundle(4)).unwrap();
        let kept = reg.ensure(5, bundle(1));
        assert_eq!(kept.version(), 4);
        // And ensure with a newer version swaps.
        assert_eq!(reg.ensure(5, bundle(9)).version(), 9);
    }

    #[test]
    fn versions_own_distinct_planes() {
        // The coalescing-safety invariant: two published versions never
        // share an AmPlane allocation, so jobs against different versions
        // can never coalesce into one engine call.
        let reg = ModelRegistry::new();
        let v1 = reg.publish(1, bundle(1)).unwrap();
        let v2 = reg.publish(1, bundle(2)).unwrap();
        assert!(!Arc::ptr_eq(&v1.plane, &v2.plane));
    }

    fn store_dir(tag: &str) -> PathBuf {
        crate::testkit::scratch_dir(&format!("store_{tag}"))
    }

    fn patient_bundle(pid: u32, version: u64) -> ModelBundle {
        let mut b = bundle(version);
        b.provenance.patient_id = pid;
        b
    }

    #[test]
    fn store_save_scan_roundtrip() {
        let dir = store_dir("roundtrip");
        let store = ModelStore::open(&dir).unwrap();
        let path = store.save(&patient_bundle(7, 1)).unwrap();
        assert_eq!(path, store.version_path(7, 1));
        assert!(path.ends_with("7/v001.hdcm"));
        store.save(&patient_bundle(7, 2)).unwrap();
        store.save(&patient_bundle(12, 4)).unwrap();

        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered.len(), 2);
        assert_eq!(scan.recovered[&7], patient_bundle(7, 2));
        assert_eq!(scan.recovered[&12], patient_bundle(12, 4));
        assert!(scan.quarantined.is_empty());
        assert!(scan.ignored.is_empty());
        // Older versions are history, not garbage.
        assert!(store.version_path(7, 1).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_rejects_patientless_bundles() {
        let dir = store_dir("nopid");
        let store = ModelStore::open(&dir).unwrap();
        let err = store.save(&bundle(1)).unwrap_err();
        assert!(format!("{err:#}").contains("patient"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_quarantines_corrupt_and_ignores_tmp() {
        let dir = store_dir("corrupt");
        let store = ModelStore::open(&dir).unwrap();
        store.save(&patient_bundle(3, 1)).unwrap();
        store.save(&patient_bundle(3, 2)).unwrap();
        // Simulate a crash: the newest version is truncated on disk and a
        // temp file from an unfinished publish is left behind.
        let v3 = store.version_path(3, 3);
        let bytes = patient_bundle(3, 3).to_bytes();
        std::fs::write(&v3, &bytes[..bytes.len() / 2]).unwrap();
        std::fs::write(dir.join("3").join(".v004.hdcm.tmp"), b"partial").unwrap();

        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered[&3].version, 2, "fall back to the newest valid version");
        assert_eq!(scan.quarantined.len(), 1);
        assert!(scan.quarantined[0].ends_with("v003.hdcm.corrupt"));
        assert!(!v3.exists(), "corrupt file renamed out of the namespace");
        assert_eq!(scan.ignored.len(), 1, "tmp leftovers are ignored, not quarantined");

        // Idempotent: a second scan finds nothing new to quarantine.
        let again = store.scan().unwrap();
        assert_eq!(again.recovered[&3].version, 2);
        assert!(again.quarantined.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peek_reports_without_touching_the_store() {
        let dir = store_dir("peek");
        let store = ModelStore::open(&dir).unwrap();
        store.save(&patient_bundle(4, 1)).unwrap();
        let v2 = store.version_path(4, 2);
        std::fs::write(&v2, b"torn write").unwrap();

        let peek = store.peek().unwrap();
        assert_eq!(peek.recovered[&4].version, 1);
        assert_eq!(peek.quarantined, vec![v2.clone()], "reported at the original path");
        assert!(v2.exists(), "peek must not rename anything");

        // A real scan afterwards does quarantine it.
        let scan = store.scan().unwrap();
        assert!(!v2.exists());
        assert_eq!(scan.quarantined.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_quarantines_lying_filenames() {
        // A file that parses but claims a different version (or patient)
        // than its name is untrustworthy — quarantined like corruption.
        let dir = store_dir("lying");
        let store = ModelStore::open(&dir).unwrap();
        store.save(&patient_bundle(5, 1)).unwrap();
        std::fs::write(store.version_path(5, 9), patient_bundle(5, 2).to_bytes()).unwrap();
        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered[&5].version, 1);
        assert_eq!(scan.quarantined.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_publish_and_read() {
        let reg = Arc::new(ModelRegistry::new());
        std::thread::scope(|scope| {
            let r = reg.clone();
            scope.spawn(move || {
                for v in 1..=50u64 {
                    let _ = r.publish(1, bundle(v));
                }
            });
            let r = reg.clone();
            scope.spawn(move || {
                let mut last = 0;
                for _ in 0..200 {
                    if let Some(m) = r.current(1) {
                        assert!(m.version() >= last, "versions must be monotone");
                        last = m.version();
                    }
                }
            });
        });
        assert_eq!(reg.current(1).unwrap().version(), 50);
    }
}
