//! Versioned model registry: patient → currently-published model, with
//! atomic hot swap.
//!
//! The registry is the serving-side home of [`ModelBundle`]s. Publishing
//! wraps the bundle into a [`PublishedModel`] — the bundle plus its
//! engine-ready [`AmPlane`], built once — and swaps it in under a write
//! lock. Consumers ([`crate::coordinator::session::Session`]s via the
//! server loop) hold an `Arc<PublishedModel>` and refresh it per
//! micro-batch, so a background retrain publishing a new version is
//! picked up **mid-stream with zero queue drain**:
//!
//! * in-flight jobs keep their own `Arc<AmPlane>` (the PR-3 job design),
//!   so nothing already queued is touched;
//! * each version owns a *distinct* `AmPlane` allocation, and the engine
//!   host coalesces jobs only on `Arc` identity — a swap boundary can
//!   therefore never mix two model versions inside one coalesced
//!   `run_batch` call (pinned by `engine_pool` and
//!   `tests/model_lifecycle.rs`);
//! * versions are monotonically increasing per patient: a **stale**
//!   publish (version < current) and a **duplicate** publish (version ==
//!   current) are rejected with distinct errors — a slow retrain racing a
//!   newer model reads differently from a double-publish bug, and
//!   operators triage them differently.
//!
//! ## Plane memory ([`PlaneCache`])
//!
//! At fleet scale the registry must be a *cache*, not a map: a node
//! serving many patients cannot keep every version's decoded [`AmPlane`]
//! resident. [`PublishedModel`] therefore no longer owns its plane —
//! [`PublishedModel::plane`] goes through the registry-wide
//! [`PlaneCache`], a bounded LRU keyed by `(patient, version)` that
//! decodes on miss and evicts strictly least-recently-used once the
//! `[model] cache_planes` budget is exceeded (0 = unbounded, the
//! default, preserving always-resident behavior). Eviction only drops
//! the cache's own `Arc`: in-flight jobs hold plane clones, so a job
//! mid-`run_batch` is never invalidated, and a re-decode rebuilds the
//! plane from the same bundle bytes — bit-exact by construction and
//! pinned window-for-window in `tests/plane_cache.rs`.
//!
//! ## Persistence ([`ModelStore`])
//!
//! The registry itself is memory-only; [`ModelStore`] is its durable
//! backend. Every published version is written to a per-patient
//! directory (`<root>/<patient>/v<NNN>.hdcm`) via an atomic
//! write-to-temp-then-rename, and a startup [`ModelStore::scan`] recovers
//! the highest *valid* version per patient — quarantining corrupt files
//! (renamed `*.corrupt`) and ignoring leftover temp files from a crashed
//! publish — so `repro serve --models-dir` resumes exactly where the
//! last publish left off. [`ModelStore::peek`] lists the same tree
//! through [`LazyBundle`]s (META/CFGS/PROV only — no plane decode), and
//! [`ModelStore::prune`] retires old versions on publish (renamed
//! `*.pruned`, never unlinked) while keeping the recovery-newest
//! version, live versions and their lineage parents.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::ensure;
use crate::error::Context;
use crate::hdc::am::AmPlane;
use crate::hdc::model::{LazyBundle, ModelBundle};

/// Counter snapshot of a [`PlaneCache`] (see [`PlaneCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlaneCacheStats {
    /// `plane()` calls served from the cache.
    pub hits: u64,
    /// First-ever decodes of a `(patient, version)` key.
    pub misses: u64,
    /// Planes dropped by the LRU to respect the budget.
    pub evictions: u64,
    /// Decodes of a key that was decoded before and evicted since —
    /// the recompute the bounded budget trades memory for.
    pub redecodes: u64,
}

struct CacheSlot {
    plane: Arc<AmPlane>,
    /// Last-use tick for LRU ordering.
    used: u64,
}

struct CacheInner {
    slots: BTreeMap<(u32, u64), CacheSlot>,
    /// Keys ever decoded — distinguishes a first decode (miss) from a
    /// post-eviction re-decode.
    seen: BTreeSet<(u32, u64)>,
    tick: u64,
}

/// Bounded LRU of decoded [`AmPlane`]s keyed by `(patient, version)`.
///
/// The software mirror of the paper's CompIM memory argument: keep the
/// cheap index (the bundle) resident, regenerate the expensive decoded
/// form on demand within a fixed budget. While a key is resident every
/// [`Self::plane_for`] call returns the *same* `Arc` — preserving the
/// engine host's Arc-identity coalescing — and eviction removes only the
/// cache's reference, so planes held by in-flight jobs stay alive until
/// those jobs complete.
pub struct PlaneCache {
    /// Maximum resident planes (0 = unbounded).
    budget: usize,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    redecodes: AtomicU64,
}

impl PlaneCache {
    pub fn unbounded() -> PlaneCache {
        Self::with_budget(0)
    }

    /// A cache holding at most `budget` decoded planes (0 = unbounded).
    pub fn with_budget(budget: usize) -> PlaneCache {
        PlaneCache {
            budget,
            inner: Mutex::new(CacheInner {
                slots: BTreeMap::new(),
                seen: BTreeSet::new(),
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            redecodes: AtomicU64::new(0),
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Planes currently resident (always ≤ budget when bounded).
    pub fn resident(&self) -> usize {
        self.lock().slots.len()
    }

    pub fn stats(&self) -> PlaneCacheStats {
        PlaneCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            redecodes: self.redecodes.load(Ordering::Relaxed),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, CacheInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The decoded plane for `(patient_id, bundle.version)`: cache hit,
    /// or decode-and-insert (evicting the least-recently-used plane past
    /// the budget). The decode is a pure function of the bundle bytes,
    /// so an evicted-and-redecoded plane is bit-identical to the one it
    /// replaces.
    fn plane_for(&self, patient_id: u32, bundle: &ModelBundle) -> Arc<AmPlane> {
        let key = (patient_id, bundle.version);
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(slot) = inner.slots.get_mut(&key) {
            slot.used = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return slot.plane.clone();
        }
        if inner.seen.insert(key) {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.redecodes.fetch_add(1, Ordering::Relaxed);
        }
        let plane = Arc::new(AmPlane::from_bundle(bundle));
        inner.slots.insert(key, CacheSlot { plane: plane.clone(), used: tick });
        if self.budget > 0 {
            while inner.slots.len() > self.budget {
                // O(n) LRU scan: n is the (small) plane budget, not the
                // fleet size, so a heap buys nothing here.
                let lru = inner
                    .slots
                    .iter()
                    .min_by_key(|(_, slot)| slot.used)
                    .map(|(k, _)| *k)
                    .expect("non-empty past-budget cache");
                inner.slots.remove(&lru);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        plane
    }
}

/// A bundle as deployed: the artifact plus a handle to the plane cache
/// its decoded engine plane lives in.
pub struct PublishedModel {
    pub bundle: ModelBundle,
    /// Cache key — the *registry* patient id (test bundles may carry a
    /// default provenance patient), paired with the bundle version.
    key: (u32, u64),
    cache: Arc<PlaneCache>,
}

impl PublishedModel {
    /// A standalone model with its own private unbounded cache — tests,
    /// benches and placeholder paths. Registry publishes go through
    /// [`Self::cached`] so every model shares the registry-wide budget.
    pub fn new(bundle: ModelBundle) -> PublishedModel {
        let patient_id = bundle.provenance.patient_id;
        Self::cached(patient_id, bundle, Arc::new(PlaneCache::unbounded()))
    }

    /// Wrap `bundle` for serving with its plane managed by `cache`.
    pub fn cached(patient_id: u32, bundle: ModelBundle, cache: Arc<PlaneCache>) -> PublishedModel {
        let key = (patient_id, bundle.version);
        PublishedModel { bundle, key, cache }
    }

    /// The engine-ready plane: cache hit or re-decode. Shared with every
    /// job submitted against this version — `Arc` identity doubles as
    /// the engine host's coalescing key, and while the plane is resident
    /// every call returns the same `Arc`. Jobs clone the `Arc`, so a
    /// later eviction never invalidates work already in flight.
    pub fn plane(&self) -> Arc<AmPlane> {
        self.cache.plane_for(self.key.0, &self.bundle)
    }

    pub fn version(&self) -> u64 {
        self.bundle.version
    }

    /// The temporal thinning threshold jobs against this model carry.
    pub fn threshold(&self) -> u16 {
        self.bundle.config.temporal_threshold
    }

    /// A version-1 model with trivial class HVs (interictal all-zeros,
    /// ictal all-ones) under the default optimized config — for tests
    /// and benchmarks that need *a* deployed model but don't care about
    /// its contents. Not a serving default: real paths always deploy a
    /// trained bundle.
    pub fn placeholder() -> Arc<PublishedModel> {
        use crate::hdc::am::AssociativeMemory;
        use crate::hdc::classifier::{ClassifierConfig, Variant};
        use crate::hdc::hv::Hv;
        use crate::hdc::model::Provenance;
        Arc::new(PublishedModel::new(ModelBundle::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            AssociativeMemory::new(Hv::zero(), Hv::ones()),
            Provenance::default(),
        )))
    }
}

/// Patient → current [`PublishedModel`], atomically swappable.
pub struct ModelRegistry {
    slots: RwLock<BTreeMap<u32, Arc<PublishedModel>>>,
    publishes: AtomicU64,
    cache: Arc<PlaneCache>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// A registry with an unbounded plane cache (every published plane
    /// stays resident — the pre-fleet-scale behavior).
    pub fn new() -> ModelRegistry {
        Self::with_cache_planes(0)
    }

    /// A registry whose decoded planes are bounded to `cache_planes`
    /// resident at once (0 = unbounded). See [`PlaneCache`].
    pub fn with_cache_planes(cache_planes: usize) -> ModelRegistry {
        ModelRegistry {
            slots: RwLock::new(BTreeMap::new()),
            publishes: AtomicU64::new(0),
            cache: Arc::new(PlaneCache::with_budget(cache_planes)),
        }
    }

    /// The registry-wide plane cache (hit/miss/eviction observability).
    pub fn plane_cache(&self) -> &PlaneCache {
        &self.cache
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, BTreeMap<u32, Arc<PublishedModel>>> {
        self.slots.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, BTreeMap<u32, Arc<PublishedModel>>> {
        self.slots.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish a new model version for a patient. Fails unless
    /// `bundle.version` is strictly newer than the current one, with the
    /// two non-monotone cases told apart: a **duplicate** publish
    /// (version == current — usually a double-publish bug or a replayed
    /// request) and a **stale** publish (version < current — a slow
    /// retrain lost the race to a newer model). Either way the current
    /// model is untouched.
    pub fn publish(
        &self,
        patient_id: u32,
        bundle: ModelBundle,
    ) -> crate::Result<Arc<PublishedModel>> {
        let model = Arc::new(PublishedModel::cached(patient_id, bundle, self.cache.clone()));
        let mut slots = self.write();
        if let Some(current) = slots.get(&patient_id) {
            ensure!(
                model.version() != current.version(),
                "duplicate publish for patient {patient_id}: version {} is already current",
                model.version()
            );
            ensure!(
                model.version() > current.version(),
                "stale publish for patient {patient_id}: version {} < current {}",
                model.version(),
                current.version()
            );
        }
        slots.insert(patient_id, model.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        Ok(model)
    }

    /// Publish unless the registry already holds this version or newer;
    /// returns whichever model is current afterwards. This is how the
    /// coordinator seeds stream-spec bundles without racing a background
    /// retrain that may already have published a newer version.
    pub fn ensure(&self, patient_id: u32, bundle: ModelBundle) -> Arc<PublishedModel> {
        let mut slots = self.write();
        if let Some(current) = slots.get(&patient_id) {
            if current.version() >= bundle.version {
                return current.clone();
            }
        }
        let model = Arc::new(PublishedModel::cached(patient_id, bundle, self.cache.clone()));
        slots.insert(patient_id, model.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        model
    }

    /// The currently-published model for a patient.
    pub fn current(&self, patient_id: u32) -> Option<Arc<PublishedModel>> {
        self.read().get(&patient_id).cloned()
    }

    pub fn patients(&self) -> Vec<u32> {
        self.read().keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().is_empty()
    }

    /// Successful publishes (including the initial ones) — a cheap
    /// observability counter for serving reports.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

/// Durable backend of the registry: a per-patient directory of versioned
/// bundle files.
///
/// ```text
/// <root>/
///   1/ v001.hdcm  v002.hdcm            # patient 1, versions 1 and 2
///   7/ v001.hdcm  .v002.hdcm.tmp       # crashed mid-publish: tmp ignored
/// ```
///
/// Publishing is crash-safe: the bundle is written to a hidden
/// `.v<NNN>.hdcm.tmp` in the same directory and `rename`d into place, so
/// a reader (or a restarted server) only ever sees complete files or no
/// file. [`Self::scan`] walks the tree newest-version-first and recovers
/// the highest bundle that parses *and* matches its filename (version)
/// and directory (patient id); anything that fails is renamed
/// `*.corrupt` (quarantined — the next scan will not retry it) and the
/// scan falls back to the next-newest version.
pub struct ModelStore {
    root: PathBuf,
    /// Per-patient newest *valid* version, computed once at
    /// [`Self::open`] with lazy (META/PROV-only) validation and kept
    /// current by [`Self::save`] / [`Self::scan`] — so publish-time
    /// [`Self::prune`] and repeated scans never re-read historical
    /// bundle files per patient.
    newest_valid: Mutex<BTreeMap<u32, u64>>,
}

/// Outcome of a [`ModelStore::scan`].
#[derive(Default)]
pub struct StoreScan {
    /// Highest valid version per patient.
    pub recovered: BTreeMap<u32, ModelBundle>,
    /// Files that failed to load: renamed `*.corrupt` by
    /// [`ModelStore::scan`] (the returned paths are the new names).
    pub quarantined: Vec<PathBuf>,
    /// Entries that are not versioned bundle files (leftover `.tmp`
    /// publishes, pruned versions, foreign files, non-numeric
    /// directories) — left alone.
    pub ignored: Vec<PathBuf>,
}

/// Outcome of a read-only [`ModelStore::peek`]: the same per-patient
/// newest-valid selection as [`StoreScan`], but each bundle is a
/// [`LazyBundle`] — only META/CFGS/PROV are read, so listing a
/// 10k-patient store never decodes a class HV or counter plane
/// (asserted via [`LazyBundle::decode_count`]).
#[derive(Default)]
pub struct StorePeek {
    /// Highest lazily-valid version per patient.
    pub recovered: BTreeMap<u32, LazyBundle>,
    /// Files that failed to open lazily, reported at their original
    /// paths — peek never renames anything.
    pub quarantined: Vec<PathBuf>,
    /// Entries that are not versioned bundle files — left alone.
    pub ignored: Vec<PathBuf>,
}

/// Candidate files per patient, newest version first, plus everything
/// that is not a candidate.
struct StoreWalk {
    patients: BTreeMap<u32, Vec<(u64, PathBuf)>>,
    ignored: Vec<PathBuf>,
}

impl ModelStore {
    /// Open (creating if needed) a model store rooted at `root`. The
    /// per-patient newest-valid-version index is computed here, once,
    /// through [`LazyBundle`]s — no plane or counter decode.
    pub fn open(root: impl Into<PathBuf>) -> crate::Result<ModelStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create model store {}", root.display()))?;
        let store = ModelStore {
            root,
            newest_valid: Mutex::new(BTreeMap::new()),
        };
        store.reindex()?;
        Ok(store)
    }

    /// The cached newest valid version for a patient, if any.
    pub fn newest_valid(&self, patient_id: u32) -> Option<u64> {
        self.newest_lock().get(&patient_id).copied()
    }

    fn newest_lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<u32, u64>> {
        self.newest_valid.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Rebuild the newest-valid index with lazy validation (filename
    /// version and directory patient must match META/PROV).
    fn reindex(&self) -> crate::Result<()> {
        let walk = self.walk()?;
        let mut newest = BTreeMap::new();
        for (pid, candidates) in walk.patients {
            for (version, path) in candidates {
                if let Ok(lazy) = LazyBundle::open(&path) {
                    if lazy.version() == version && lazy.provenance().patient_id == pid {
                        newest.insert(pid, version);
                        break;
                    }
                }
            }
        }
        *self.newest_lock() = newest;
        Ok(())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path a given (patient, version) persists at.
    pub fn version_path(&self, patient_id: u32, version: u64) -> PathBuf {
        self.root
            .join(patient_id.to_string())
            .join(format!("v{version:03}.hdcm"))
    }

    /// Persist a bundle under its provenance patient id, atomically:
    /// write to a temp file in the destination directory, then rename
    /// into place. The temp name is unique per writer (process +
    /// sequence), so concurrent saves of the same version (two
    /// schedulers racing, an unlimited-retrain policy) can never
    /// interleave writes into one file — the atomic rename means the
    /// last completed publish wins wholesale. Returns the final path.
    pub fn save(&self, bundle: &ModelBundle) -> crate::Result<PathBuf> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let patient_id = bundle.provenance.patient_id;
        ensure!(
            patient_id != 0,
            "bundle v{} has no patient id (provenance.patient_id = 0) — \
             a model store is keyed by patient",
            bundle.version
        );
        let dir = self.root.join(patient_id.to_string());
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create patient dir {}", dir.display()))?;
        let path = self.version_path(patient_id, bundle.version);
        let tmp = dir.join(format!(
            ".v{:03}.{}.{}.hdcm.tmp",
            bundle.version,
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        // write → fsync → rename → fsync(dir): without the data fsync,
        // delayed allocation can commit the rename before the payload
        // blocks, and an OS crash would leave a truncated "published"
        // file — exactly the torn state the temp file exists to prevent.
        {
            use std::io::Write;
            let mut file = std::fs::File::create(&tmp)
                .with_context(|| format!("create model bundle {}", tmp.display()))?;
            file.write_all(&bundle.to_bytes())
                .with_context(|| format!("write model bundle {}", tmp.display()))?;
            file.sync_all()
                .with_context(|| format!("sync model bundle {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publish {} -> {}", tmp.display(), path.display()))?;
        // Make the rename itself durable (directory metadata). Best
        // effort: not every filesystem lets a directory be fsynced.
        if let Ok(d) = std::fs::File::open(&dir) {
            let _ = d.sync_all();
        }
        // A completed save is by construction a valid bundle on disk —
        // keep the newest-valid index current without re-reading it.
        let mut newest = self.newest_lock();
        let slot = newest.entry(patient_id).or_insert(bundle.version);
        *slot = (*slot).max(bundle.version);
        Ok(path)
    }

    /// Every versioned candidate file per patient (newest first), plus
    /// the non-candidates. Deterministic: directory-read order never
    /// affects the result.
    fn walk(&self) -> crate::Result<StoreWalk> {
        let mut patients = BTreeMap::new();
        let mut ignored = Vec::new();
        let entries = std::fs::read_dir(&self.root)
            .with_context(|| format!("scan model store {}", self.root.display()))?;
        for entry in entries {
            let dir = entry?.path();
            let pid = dir
                .file_name()
                .and_then(|n| n.to_str())
                .filter(|n| n.bytes().all(|b| b.is_ascii_digit()))
                .and_then(|n| n.parse::<u32>().ok());
            let (Some(pid), true) = (pid, dir.is_dir()) else {
                ignored.push(dir);
                continue;
            };
            let mut candidates: Vec<(u64, PathBuf)> = Vec::new();
            for file in std::fs::read_dir(&dir)? {
                let path = file?.path();
                match path.file_name().and_then(|n| n.to_str()).and_then(parse_version_name) {
                    Some(version) => candidates.push((version, path)),
                    None => ignored.push(path),
                }
            }
            // Newest first; the first candidate that loads cleanly wins,
            // older versions stay on disk untouched (history).
            candidates.sort_by(|a, b| b.0.cmp(&a.0));
            patients.insert(pid, candidates);
        }
        Ok(StoreWalk { patients, ignored })
    }

    /// Recover the highest valid version per patient (see the type-level
    /// docs for the corruption / crash-leftover rules). Fully validates
    /// (and decodes) the winning bundle per patient — this is the path
    /// that actually serves models — and refreshes the newest-valid
    /// index with its findings.
    pub fn scan(&self) -> crate::Result<StoreScan> {
        let walk = self.walk()?;
        let mut out = StoreScan {
            ignored: walk.ignored,
            ..StoreScan::default()
        };
        for (pid, candidates) in walk.patients {
            for (version, path) in candidates {
                match ModelBundle::load(&path) {
                    Ok(b) if b.version == version && b.provenance.patient_id == pid => {
                        out.recovered.insert(pid, b);
                        break;
                    }
                    // Parses but lies about its name (wrong version or
                    // patient): as untrustworthy as a corrupt file.
                    Ok(_) | Err(_) => out.quarantined.push(quarantine(&path)),
                }
            }
            let mut newest = self.newest_lock();
            match out.recovered.get(&pid) {
                Some(b) => {
                    newest.insert(pid, b.version);
                }
                None => {
                    newest.remove(&pid);
                }
            }
        }
        Ok(out)
    }

    /// Read-only listing through [`LazyBundle`]s: the same newest-valid
    /// selection as [`Self::scan`], but only META/CFGS/PROV are ever
    /// read — no `AmPlane`, no counter planes — and nothing on disk is
    /// renamed. Inspection tools (`repro model-info <dir>`) go through
    /// this so that looking at a store cannot change it (or blow its
    /// memory budget).
    pub fn peek(&self) -> crate::Result<StorePeek> {
        let walk = self.walk()?;
        let mut out = StorePeek {
            ignored: walk.ignored,
            ..StorePeek::default()
        };
        for (pid, candidates) in walk.patients {
            for (version, path) in candidates {
                match LazyBundle::open(&path) {
                    Ok(b) if b.version() == version && b.provenance().patient_id == pid => {
                        out.recovered.insert(pid, b);
                        break;
                    }
                    Ok(_) | Err(_) => out.quarantined.push(path),
                }
            }
        }
        Ok(out)
    }

    /// Retention GC, run on publish: keep the newest `max_versions`
    /// versions of `patient_id` (0 = keep everything — the default) plus,
    /// always, the recovery target (newest valid version), every `live`
    /// version currently serving, and the lineage parents of those
    /// versions (walked through META/PROV lazy reads). Everything else
    /// is renamed `<name>.pruned` — quarantine-style safety naming on
    /// the delete path; nothing is ever unlinked. Returns the renamed
    /// paths.
    pub fn prune(
        &self,
        patient_id: u32,
        max_versions: usize,
        live: &[u64],
    ) -> crate::Result<Vec<PathBuf>> {
        if max_versions == 0 {
            return Ok(Vec::new());
        }
        let dir = self.root.join(patient_id.to_string());
        if !dir.is_dir() {
            return Ok(Vec::new());
        }
        let mut candidates: Vec<(u64, PathBuf)> = Vec::new();
        for file in std::fs::read_dir(&dir)
            .with_context(|| format!("prune patient dir {}", dir.display()))?
        {
            let path = file?.path();
            if let Some(version) =
                path.file_name().and_then(|n| n.to_str()).and_then(parse_version_name)
            {
                candidates.push((version, path));
            }
        }
        candidates.sort_by(|a, b| b.0.cmp(&a.0));

        let mut keep: BTreeSet<u64> = live.iter().copied().collect();
        keep.extend(self.newest_valid(patient_id));
        // Lineage: a live version's parents stay recoverable, walked
        // through the store without decoding a single plane.
        let by_version: BTreeMap<u64, &Path> =
            candidates.iter().map(|(v, p)| (*v, p.as_path())).collect();
        let mut frontier: Vec<u64> = keep.iter().copied().collect();
        while let Some(version) = frontier.pop() {
            let Some(path) = by_version.get(&version) else { continue };
            let Ok(lazy) = LazyBundle::open(path) else { continue };
            let parent = lazy.provenance().parent_version;
            if parent != 0 && keep.insert(parent) {
                frontier.push(parent);
            }
        }
        for (version, _) in candidates.iter().take(max_versions) {
            keep.insert(*version);
        }

        let mut pruned = Vec::new();
        for (version, path) in &candidates {
            if keep.contains(version) {
                continue;
            }
            let mut name = path.as_os_str().to_owned();
            name.push(".pruned");
            let target = PathBuf::from(name);
            if std::fs::rename(path, &target).is_ok() {
                pruned.push(target);
            }
        }
        Ok(pruned)
    }
}

/// `v<digits>.hdcm` → version; anything else (tmp files, quarantined
/// files, foreign names) is not a bundle candidate.
fn parse_version_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix('v')?.strip_suffix(".hdcm")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Rename a failed bundle file out of the candidate namespace so the
/// next scan does not retry it; returns the new path. If the rename
/// itself fails the original path is returned — the scan still skips the
/// file this run.
fn quarantine(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(".corrupt");
    let target = PathBuf::from(name);
    match std::fs::rename(path, &target) {
        Ok(()) => target,
        Err(_) => path.to_path_buf(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::hdc::classifier::{ClassifierConfig, Variant};
    use crate::hdc::hv::Hv;
    use crate::hdc::model::Provenance;

    fn bundle(version: u64) -> ModelBundle {
        let mut b = ModelBundle::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            AssociativeMemory::new(Hv::zero(), Hv::ones()),
            Provenance::default(),
        );
        b.version = version;
        b
    }

    #[test]
    fn publish_and_lookup() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.current(7).is_none());
        let m1 = reg.publish(7, bundle(1)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.patients(), vec![7]);
        let got = reg.current(7).unwrap();
        assert!(Arc::ptr_eq(&m1, &got));
        assert_eq!(got.version(), 1);
        assert_eq!(reg.publishes(), 1);
    }

    #[test]
    fn stale_publish_rejected_newer_swaps() {
        let reg = ModelRegistry::new();
        reg.publish(3, bundle(2)).unwrap();
        // Same version and older versions are rejected.
        assert!(reg.publish(3, bundle(2)).is_err());
        assert!(reg.publish(3, bundle(1)).is_err());
        assert_eq!(reg.current(3).unwrap().version(), 2);
        // Strictly newer swaps atomically.
        let m3 = reg.publish(3, bundle(3)).unwrap();
        assert!(Arc::ptr_eq(&m3, &reg.current(3).unwrap()));
        assert_eq!(reg.publishes(), 2);
    }

    #[test]
    fn duplicate_and_stale_publishes_error_distinctly() {
        // The two non-monotone failure modes must be tellable apart: a
        // re-publish of the current version is a *duplicate* (double-
        // publish bug / replayed request), an older version is *stale*
        // (a slow retrain lost the race). Both leave the slot untouched.
        let reg = ModelRegistry::new();
        reg.publish(4, bundle(5)).unwrap();

        let dup = reg.publish(4, bundle(5)).unwrap_err();
        let msg = format!("{dup:#}");
        assert!(msg.contains("duplicate publish"), "{msg}");
        assert!(msg.contains("version 5 is already current"), "{msg}");
        assert!(!msg.contains("stale"), "{msg}");

        let stale = reg.publish(4, bundle(3)).unwrap_err();
        let msg = format!("{stale:#}");
        assert!(msg.contains("stale publish"), "{msg}");
        assert!(msg.contains("version 3 < current 5"), "{msg}");
        assert!(!msg.contains("duplicate"), "{msg}");

        assert_eq!(reg.current(4).unwrap().version(), 5);
        assert_eq!(reg.publishes(), 1, "failed publishes are not counted");
    }

    #[test]
    fn ensure_keeps_the_newer_version() {
        let reg = ModelRegistry::new();
        let first = reg.ensure(5, bundle(1));
        assert_eq!(first.version(), 1);
        // Re-ensuring the same version keeps the existing Arc.
        let again = reg.ensure(5, bundle(1));
        assert!(Arc::ptr_eq(&first, &again));
        // A newer publish wins over a later ensure of the old version.
        reg.publish(5, bundle(4)).unwrap();
        let kept = reg.ensure(5, bundle(1));
        assert_eq!(kept.version(), 4);
        // And ensure with a newer version swaps.
        assert_eq!(reg.ensure(5, bundle(9)).version(), 9);
    }

    #[test]
    fn versions_own_distinct_planes() {
        // The coalescing-safety invariant: two published versions never
        // share an AmPlane allocation, so jobs against different versions
        // can never coalesce into one engine call.
        let reg = ModelRegistry::new();
        let v1 = reg.publish(1, bundle(1)).unwrap();
        let v2 = reg.publish(1, bundle(2)).unwrap();
        assert!(!Arc::ptr_eq(&v1.plane(), &v2.plane()));
        // …while one version's plane is stable across calls (the other
        // half of the same invariant: a version can coalesce with itself).
        assert!(Arc::ptr_eq(&v1.plane(), &v1.plane()));
    }

    #[test]
    fn plane_cache_hits_while_resident() {
        let reg = ModelRegistry::with_cache_planes(4);
        let m = reg.publish(7, bundle(1)).unwrap();
        let first = m.plane();
        let second = m.plane();
        assert!(Arc::ptr_eq(&first, &second));
        let stats = reg.plane_cache().stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.redecodes, 0);
        assert_eq!(reg.plane_cache().resident(), 1);
    }

    #[test]
    fn plane_cache_evicts_lru_and_redecodes_bit_exact() {
        let reg = ModelRegistry::with_cache_planes(1);
        let a = reg.publish(1, bundle(1)).unwrap();
        let b = reg.publish(2, bundle(1)).unwrap();

        let plane_a = a.plane(); // miss: decode a
        let plane_b = b.plane(); // miss: decode b, evict a (budget 1)
        assert_eq!(reg.plane_cache().resident(), 1);
        let again_a = a.plane(); // redecode a, evict b
        let stats = reg.plane_cache().stats();
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.evictions, 2);
        assert_eq!(stats.redecodes, 1);
        assert_eq!(reg.plane_cache().resident(), 1, "residency stays bounded");

        // Eviction never invalidates in-flight Arcs, and a re-decode is
        // bit-identical to the plane it replaces (fresh Arc, same bytes).
        assert!(!Arc::ptr_eq(&plane_a, &again_a));
        assert_eq!(plane_a.i32s(), again_a.i32s());
        assert_eq!(plane_b.i32s(), b.plane().i32s());
    }

    #[test]
    fn plane_cache_unbounded_never_evicts() {
        let reg = ModelRegistry::new();
        for pid in 1..=16 {
            reg.publish(pid, bundle(1)).unwrap().plane();
        }
        let stats = reg.plane_cache().stats();
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.redecodes, 0);
        assert_eq!(reg.plane_cache().resident(), 16);
    }

    fn store_dir(tag: &str) -> PathBuf {
        crate::testkit::scratch_dir(&format!("store_{tag}"))
    }

    fn patient_bundle(pid: u32, version: u64) -> ModelBundle {
        let mut b = bundle(version);
        b.provenance.patient_id = pid;
        b
    }

    #[test]
    fn store_save_scan_roundtrip() {
        let dir = store_dir("roundtrip");
        let store = ModelStore::open(&dir).unwrap();
        let path = store.save(&patient_bundle(7, 1)).unwrap();
        assert_eq!(path, store.version_path(7, 1));
        assert!(path.ends_with("7/v001.hdcm"));
        store.save(&patient_bundle(7, 2)).unwrap();
        store.save(&patient_bundle(12, 4)).unwrap();

        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered.len(), 2);
        assert_eq!(scan.recovered[&7], patient_bundle(7, 2));
        assert_eq!(scan.recovered[&12], patient_bundle(12, 4));
        assert!(scan.quarantined.is_empty());
        assert!(scan.ignored.is_empty());
        // Older versions are history, not garbage.
        assert!(store.version_path(7, 1).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_rejects_patientless_bundles() {
        let dir = store_dir("nopid");
        let store = ModelStore::open(&dir).unwrap();
        let err = store.save(&bundle(1)).unwrap_err();
        assert!(format!("{err:#}").contains("patient"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_quarantines_corrupt_and_ignores_tmp() {
        let dir = store_dir("corrupt");
        let store = ModelStore::open(&dir).unwrap();
        store.save(&patient_bundle(3, 1)).unwrap();
        store.save(&patient_bundle(3, 2)).unwrap();
        // Simulate a crash: the newest version is truncated on disk and a
        // temp file from an unfinished publish is left behind.
        let v3 = store.version_path(3, 3);
        let bytes = patient_bundle(3, 3).to_bytes();
        std::fs::write(&v3, &bytes[..bytes.len() / 2]).unwrap();
        std::fs::write(dir.join("3").join(".v004.hdcm.tmp"), b"partial").unwrap();

        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered[&3].version, 2, "fall back to the newest valid version");
        assert_eq!(scan.quarantined.len(), 1);
        assert!(scan.quarantined[0].ends_with("v003.hdcm.corrupt"));
        assert!(!v3.exists(), "corrupt file renamed out of the namespace");
        assert_eq!(scan.ignored.len(), 1, "tmp leftovers are ignored, not quarantined");

        // Idempotent: a second scan finds nothing new to quarantine.
        let again = store.scan().unwrap();
        assert_eq!(again.recovered[&3].version, 2);
        assert!(again.quarantined.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peek_reports_without_touching_the_store() {
        let dir = store_dir("peek");
        let store = ModelStore::open(&dir).unwrap();
        store.save(&patient_bundle(4, 1)).unwrap();
        let v2 = store.version_path(4, 2);
        std::fs::write(&v2, b"torn write").unwrap();

        let peek = store.peek().unwrap();
        assert_eq!(peek.recovered[&4].version(), 1);
        assert_eq!(peek.quarantined, vec![v2.clone()], "reported at the original path");
        assert!(v2.exists(), "peek must not rename anything");

        // A real scan afterwards does quarantine it.
        let scan = store.scan().unwrap();
        assert!(!v2.exists());
        assert_eq!(scan.quarantined.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peek_is_lazy_listings_never_decode_planes() {
        let dir = store_dir("lazy_peek");
        {
            let store = ModelStore::open(&dir).unwrap();
            for pid in 1..=3 {
                store.save(&patient_bundle(pid, 1)).unwrap();
                store.save(&patient_bundle(pid, 2)).unwrap();
            }
        }
        // Fresh open (cold index) + peek: the listing path must not
        // decode a single AMPL/CNTP payload across the whole store.
        let store = ModelStore::open(&dir).unwrap();
        let peek = store.peek().unwrap();
        assert_eq!(peek.recovered.len(), 3);
        for (pid, lazy) in &peek.recovered {
            assert_eq!(lazy.version(), 2);
            assert_eq!(lazy.provenance().patient_id, *pid);
            assert_eq!(lazy.decode_count(), 0, "listing decoded a heavy section");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_indexes_newest_valid_and_save_keeps_it_current() {
        let dir = store_dir("newest");
        {
            let store = ModelStore::open(&dir).unwrap();
            store.save(&patient_bundle(9, 1)).unwrap();
            store.save(&patient_bundle(9, 2)).unwrap();
            assert_eq!(store.newest_valid(9), Some(2), "save updates the index");
        }
        // Re-open: the index is rebuilt lazily from disk. A truncated
        // newer version is lazily invalid and must not win.
        let v3 = patient_bundle(9, 3).to_bytes();
        {
            let store = ModelStore::open(&dir).unwrap();
            assert_eq!(store.newest_valid(9), Some(2));
            std::fs::write(store.version_path(9, 3), &v3[..v3.len() / 2]).unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        assert_eq!(store.newest_valid(9), Some(2));
        // scan() quarantines the truncated v3 and confirms the index.
        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered[&9].version, 2);
        assert_eq!(store.newest_valid(9), Some(2));
        assert_eq!(store.newest_valid(42), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn lineage_bundle(pid: u32, version: u64, parent: u64) -> ModelBundle {
        let mut b = patient_bundle(pid, version);
        b.provenance.parent_version = parent;
        b
    }

    #[test]
    fn prune_keeps_newest_live_and_lineage() {
        let dir = store_dir("prune");
        let store = ModelStore::open(&dir).unwrap();
        // v1 ← v2 ← v3 ← v4 ← v5 (each derived from the previous).
        for v in 1..=5u64 {
            store.save(&lineage_bundle(6, v, v - 1)).unwrap();
        }
        // Serving v3: keep = newest 1 (v5) ∪ live (v3) ∪ lineage of
        // {v5, v3} = {v4, v2, v1} — nothing prunable in a full chain.
        let pruned = store.prune(6, 1, &[3]).unwrap();
        assert!(pruned.is_empty(), "{pruned:?}");

        // Break the chain: v3 freshly trained (parent 0). Now keep =
        // {v5, v4, v3} and v1/v2 are history.
        store.save(&lineage_bundle(6, 3, 0)).unwrap();
        let mut pruned = store.prune(6, 1, &[3]).unwrap();
        pruned.sort();
        assert_eq!(pruned.len(), 2, "{pruned:?}");
        assert!(pruned[0].ends_with("v001.hdcm.pruned"), "{pruned:?}");
        assert!(pruned[1].ends_with("v002.hdcm.pruned"), "{pruned:?}");
        assert!(!store.version_path(6, 1).exists());
        assert!(store.version_path(6, 3).exists());
        assert!(store.version_path(6, 4).exists(), "v4 stays: lineage parent of newest v5");

        // Pruned files leave the candidate namespace: scans ignore them.
        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered[&6].version, 5);
        assert!(scan.quarantined.is_empty());
        assert_eq!(scan.ignored.len(), 2, "pruned files are ignored, not quarantined");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prune_zero_budget_is_a_no_op() {
        let dir = store_dir("prune_off");
        let store = ModelStore::open(&dir).unwrap();
        for v in 1..=4u64 {
            store.save(&patient_bundle(2, v)).unwrap();
        }
        assert!(store.prune(2, 0, &[4]).unwrap().is_empty());
        for v in 1..=4u64 {
            assert!(store.version_path(2, v).exists());
        }
        // An unknown patient is a no-op too, not an error.
        assert!(store.prune(99, 1, &[1]).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_quarantines_lying_filenames() {
        // A file that parses but claims a different version (or patient)
        // than its name is untrustworthy — quarantined like corruption.
        let dir = store_dir("lying");
        let store = ModelStore::open(&dir).unwrap();
        store.save(&patient_bundle(5, 1)).unwrap();
        std::fs::write(store.version_path(5, 9), patient_bundle(5, 2).to_bytes()).unwrap();
        let scan = store.scan().unwrap();
        assert_eq!(scan.recovered[&5].version, 1);
        assert_eq!(scan.quarantined.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_publish_and_read() {
        let reg = Arc::new(ModelRegistry::new());
        std::thread::scope(|scope| {
            let r = reg.clone();
            scope.spawn(move || {
                for v in 1..=50u64 {
                    let _ = r.publish(1, bundle(v));
                }
            });
            let r = reg.clone();
            scope.spawn(move || {
                let mut last = 0;
                for _ in 0..200 {
                    if let Some(m) = r.current(1) {
                        assert!(m.version() >= last, "versions must be monotone");
                        last = m.version();
                    }
                }
            });
        });
        assert_eq!(reg.current(1).unwrap().version(), 50);
    }
}
