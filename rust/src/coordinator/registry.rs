//! Versioned model registry: patient → currently-published model, with
//! atomic hot swap.
//!
//! The registry is the serving-side home of [`ModelBundle`]s. Publishing
//! wraps the bundle into a [`PublishedModel`] — the bundle plus its
//! engine-ready [`AmPlane`], built once — and swaps it in under a write
//! lock. Consumers ([`crate::coordinator::session::Session`]s via the
//! server loop) hold an `Arc<PublishedModel>` and refresh it per
//! micro-batch, so a background retrain publishing a new version is
//! picked up **mid-stream with zero queue drain**:
//!
//! * in-flight jobs keep their own `Arc<AmPlane>` (the PR-3 job design),
//!   so nothing already queued is touched;
//! * each version owns a *distinct* `AmPlane` allocation, and the engine
//!   host coalesces jobs only on `Arc` identity — a swap boundary can
//!   therefore never mix two model versions inside one coalesced
//!   `run_batch` call (pinned by `engine_pool` and
//!   `tests/model_lifecycle.rs`);
//! * versions are monotonically increasing per patient: a stale publish
//!   (version <= current) is rejected, so a slow retrain can never
//!   clobber a newer model.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::ensure;
use crate::hdc::am::AmPlane;
use crate::hdc::model::ModelBundle;

/// A bundle as deployed: the artifact plus its decoded engine plane.
pub struct PublishedModel {
    pub bundle: ModelBundle,
    /// Shared with every job submitted against this version ([`Arc`]
    /// identity doubles as the engine host's coalescing key).
    pub plane: Arc<AmPlane>,
}

impl PublishedModel {
    pub fn new(bundle: ModelBundle) -> PublishedModel {
        let plane = Arc::new(AmPlane::from_bundle(&bundle));
        PublishedModel { bundle, plane }
    }

    pub fn version(&self) -> u64 {
        self.bundle.version
    }

    /// The temporal thinning threshold jobs against this model carry.
    pub fn threshold(&self) -> u16 {
        self.bundle.config.temporal_threshold
    }

    /// A version-1 model with trivial class HVs (interictal all-zeros,
    /// ictal all-ones) under the default optimized config — for tests
    /// and benchmarks that need *a* deployed model but don't care about
    /// its contents. Not a serving default: real paths always deploy a
    /// trained bundle.
    pub fn placeholder() -> Arc<PublishedModel> {
        use crate::hdc::am::AssociativeMemory;
        use crate::hdc::classifier::{ClassifierConfig, Variant};
        use crate::hdc::hv::Hv;
        use crate::hdc::model::Provenance;
        Arc::new(PublishedModel::new(ModelBundle::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            AssociativeMemory::new(Hv::zero(), Hv::ones()),
            Provenance::default(),
        )))
    }
}

/// Patient → current [`PublishedModel`], atomically swappable.
pub struct ModelRegistry {
    slots: RwLock<BTreeMap<u32, Arc<PublishedModel>>>,
    publishes: AtomicU64,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            slots: RwLock::new(BTreeMap::new()),
            publishes: AtomicU64::new(0),
        }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, BTreeMap<u32, Arc<PublishedModel>>> {
        self.slots.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, BTreeMap<u32, Arc<PublishedModel>>> {
        self.slots.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish a new model version for a patient. Fails on a stale
    /// publish (`bundle.version` not strictly newer than the current
    /// one), so concurrent retrains cannot roll a patient back.
    pub fn publish(
        &self,
        patient_id: u32,
        bundle: ModelBundle,
    ) -> crate::Result<Arc<PublishedModel>> {
        let model = Arc::new(PublishedModel::new(bundle));
        let mut slots = self.write();
        if let Some(current) = slots.get(&patient_id) {
            ensure!(
                model.version() > current.version(),
                "stale publish for patient {patient_id}: version {} <= current {}",
                model.version(),
                current.version()
            );
        }
        slots.insert(patient_id, model.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        Ok(model)
    }

    /// Publish unless the registry already holds this version or newer;
    /// returns whichever model is current afterwards. This is how the
    /// coordinator seeds stream-spec bundles without racing a background
    /// retrain that may already have published a newer version.
    pub fn ensure(&self, patient_id: u32, bundle: ModelBundle) -> Arc<PublishedModel> {
        let mut slots = self.write();
        if let Some(current) = slots.get(&patient_id) {
            if current.version() >= bundle.version {
                return current.clone();
            }
        }
        let model = Arc::new(PublishedModel::new(bundle));
        slots.insert(patient_id, model.clone());
        self.publishes.fetch_add(1, Ordering::Relaxed);
        model
    }

    /// The currently-published model for a patient.
    pub fn current(&self, patient_id: u32) -> Option<Arc<PublishedModel>> {
        self.read().get(&patient_id).cloned()
    }

    pub fn patients(&self) -> Vec<u32> {
        self.read().keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().is_empty()
    }

    /// Successful publishes (including the initial ones) — a cheap
    /// observability counter for serving reports.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::hdc::classifier::{ClassifierConfig, Variant};
    use crate::hdc::hv::Hv;
    use crate::hdc::model::Provenance;

    fn bundle(version: u64) -> ModelBundle {
        let mut b = ModelBundle::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            AssociativeMemory::new(Hv::zero(), Hv::ones()),
            Provenance::default(),
        );
        b.version = version;
        b
    }

    #[test]
    fn publish_and_lookup() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.current(7).is_none());
        let m1 = reg.publish(7, bundle(1)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.patients(), vec![7]);
        let got = reg.current(7).unwrap();
        assert!(Arc::ptr_eq(&m1, &got));
        assert_eq!(got.version(), 1);
        assert_eq!(reg.publishes(), 1);
    }

    #[test]
    fn stale_publish_rejected_newer_swaps() {
        let reg = ModelRegistry::new();
        reg.publish(3, bundle(2)).unwrap();
        // Same version and older versions are stale.
        assert!(reg.publish(3, bundle(2)).is_err());
        assert!(reg.publish(3, bundle(1)).is_err());
        assert_eq!(reg.current(3).unwrap().version(), 2);
        // Strictly newer swaps atomically.
        let m3 = reg.publish(3, bundle(3)).unwrap();
        assert!(Arc::ptr_eq(&m3, &reg.current(3).unwrap()));
        assert_eq!(reg.publishes(), 2);
    }

    #[test]
    fn ensure_keeps_the_newer_version() {
        let reg = ModelRegistry::new();
        let first = reg.ensure(5, bundle(1));
        assert_eq!(first.version(), 1);
        // Re-ensuring the same version keeps the existing Arc.
        let again = reg.ensure(5, bundle(1));
        assert!(Arc::ptr_eq(&first, &again));
        // A newer publish wins over a later ensure of the old version.
        reg.publish(5, bundle(4)).unwrap();
        let kept = reg.ensure(5, bundle(1));
        assert_eq!(kept.version(), 4);
        // And ensure with a newer version swaps.
        assert_eq!(reg.ensure(5, bundle(9)).version(), 9);
    }

    #[test]
    fn versions_own_distinct_planes() {
        // The coalescing-safety invariant: two published versions never
        // share an AmPlane allocation, so jobs against different versions
        // can never coalesce into one engine call.
        let reg = ModelRegistry::new();
        let v1 = reg.publish(1, bundle(1)).unwrap();
        let v2 = reg.publish(1, bundle(2)).unwrap();
        assert!(!Arc::ptr_eq(&v1.plane, &v2.plane));
    }

    #[test]
    fn concurrent_publish_and_read() {
        let reg = Arc::new(ModelRegistry::new());
        std::thread::scope(|scope| {
            let r = reg.clone();
            scope.spawn(move || {
                for v in 1..=50u64 {
                    let _ = r.publish(1, bundle(v));
                }
            });
            let r = reg.clone();
            scope.spawn(move || {
                let mut last = 0;
                for _ in 0..200 {
                    if let Some(m) = r.current(1) {
                        assert!(m.version() >= last, "versions must be monotone");
                        last = m.version();
                    }
                }
            });
        });
        assert_eq!(reg.current(1).unwrap().version(), 50);
    }
}
