//! Routing: interleaved multi-patient sample chunks → sessions.
//!
//! Sources (file replay, network front-ends, generators) emit
//! [`SampleChunk`]s tagged with a session id; the router owns the session
//! table and dispatches chunk-by-chunk, preserving per-session sample
//! order (chunks from one session must arrive in order; chunks from
//! different sessions interleave freely — exactly the multi-implant
//! serving scenario).

use std::collections::BTreeMap;

use crate::params::CHANNELS;

use super::session::{ReadyBatch, Session};

/// A contiguous run of multichannel samples for one session.
pub struct SampleChunk {
    pub session_id: u64,
    /// Time-major `[n * CHANNELS]`.
    pub samples: Vec<f32>,
}

impl SampleChunk {
    pub fn num_samples(&self) -> usize {
        self.samples.len() / CHANNELS
    }
}

/// Session table + dispatch.
pub struct Router {
    sessions: BTreeMap<u64, Session>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Self {
        Router {
            sessions: BTreeMap::new(),
        }
    }

    pub fn add_session(&mut self, session: Session) {
        self.sessions.insert(session.id, session);
    }

    pub fn session(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    pub fn session_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    pub fn sessions(&self) -> impl Iterator<Item = &Session> {
        self.sessions.values()
    }

    pub fn sessions_mut(&mut self) -> impl Iterator<Item = &mut Session> {
        self.sessions.values_mut()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Route one chunk; completed window batches are appended to `out`.
    /// Unknown session ids are an error (a production system would 404),
    /// as is a chunk that is not a whole number of multichannel frames.
    pub fn route(&mut self, chunk: &SampleChunk, out: &mut Vec<ReadyBatch>) -> crate::Result<()> {
        let session = self
            .sessions
            .get_mut(&chunk.session_id)
            .ok_or_else(|| crate::err!("unknown session {}", chunk.session_id))?;
        session.push_samples(&chunk.samples, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::PublishedModel;
    use crate::params::FRAMES_PER_PREDICTION;

    fn router_with(ids: &[u64]) -> Router {
        let model = PublishedModel::placeholder();
        let mut r = Router::new();
        for &id in ids {
            r.add_session(Session::new(id, id as u32, model.clone(), 1));
        }
        r
    }

    #[test]
    fn interleaved_sessions_window_independently() {
        let mut r = router_with(&[1, 2]);
        let mut out = Vec::new();
        let half = FRAMES_PER_PREDICTION / 2;
        let chunk = |id| SampleChunk {
            session_id: id,
            samples: vec![0.0; half * CHANNELS],
        };
        r.route(&chunk(1), &mut out).unwrap();
        r.route(&chunk(2), &mut out).unwrap();
        assert!(out.is_empty());
        r.route(&chunk(1), &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].session_id, 1);
        r.route(&chunk(2), &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[1].session_id, 2);
    }

    #[test]
    fn unknown_session_rejected() {
        let mut r = router_with(&[1]);
        let mut out = Vec::new();
        let chunk = SampleChunk {
            session_id: 99,
            samples: vec![0.0; CHANNELS],
        };
        assert!(r.route(&chunk, &mut out).is_err());
    }

    #[test]
    fn ragged_chunk_rejected() {
        let mut r = router_with(&[1]);
        let mut out = Vec::new();
        let chunk = SampleChunk {
            session_id: 1,
            samples: vec![0.0; CHANNELS + 1],
        };
        let err = r.route(&chunk, &mut out).unwrap_err();
        assert!(format!("{err:#}").contains("whole number"), "{err:#}");
    }

    #[test]
    fn partial_chunks_accumulate() {
        let mut r = router_with(&[7]);
        let mut out = Vec::new();
        for _ in 0..FRAMES_PER_PREDICTION {
            r.route(
                &SampleChunk {
                    session_id: 7,
                    samples: vec![0.0; CHANNELS],
                },
                &mut out,
            )
            .unwrap();
        }
        assert_eq!(out.len(), 1);
    }
}
