//! False-alarm-driven retrain scheduling.
//!
//! PR 4's `--retrain-epochs` launched one unconditional background
//! retrain per patient at startup. This module replaces that one-shot
//! pass with a **policy**: sessions feed per-window outcomes (was the
//! window a false alarm?) into a sliding estimator
//! ([`crate::coordinator::metrics::FalseAlarmRate`]), and when a
//! patient's rate crosses the configured trigger the scheduler launches
//! an **incremental** retrain — resumed from the model's persisted
//! counter planes ([`crate::pipeline::retrain_bundle`]) — then persists
//! the new version to the [`ModelStore`] (when configured) and publishes
//! it into the [`ModelRegistry`], where serving sessions hot-swap it at
//! their next micro-batch. Persist-then-publish: a version that is being
//! served is always already on disk, so a crash right after the publish
//! still resumes at that version.
//!
//! The trigger decision ([`PatientWatch::observe`]) is a pure function
//! of the per-patient outcome stream — no clocks, no thread timing — so
//! tests can pin the exact window index a planted false-alarm burst
//! fires at (`tests/retrain_scheduler.rs`). Only the retrain *execution*
//! is asynchronous (a background thread per trigger); foreground mode
//! ([`RetrainScheduler::foreground`]) runs it inline for deterministic
//! end-to-end tests.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::metrics::FalseAlarmRate;
use crate::coordinator::registry::{ModelRegistry, ModelStore};
use crate::data::synth::Record;
use crate::pipeline::{self, RetrainOptions};

/// When and how to retrain a patient's model.
#[derive(Clone, Debug)]
pub struct RetrainPolicy {
    /// Upper bound on online epochs per retrain
    /// ([`crate::hdc::online::OnlineConfig::max_epochs`]).
    pub epochs: usize,
    /// Sliding-window size (prediction windows) of the false-alarm-rate
    /// estimator. The rate is only consulted once the window is full.
    pub fa_window: usize,
    /// Trigger threshold: retrain when the windowed false-alarm rate
    /// reaches this fraction. `0.0` triggers as soon as the window fills
    /// — the "retrain once, early in the stream" behaviour the old
    /// one-shot pass approximated.
    pub fa_rate: f64,
    /// Windows to hold off after a trigger before the rate is consulted
    /// again (gives the retrained model time to prove itself).
    pub cooldown: usize,
    /// Retrains allowed per patient over the stream (0 = unlimited).
    pub max_retrains: u64,
}

impl Default for RetrainPolicy {
    fn default() -> Self {
        RetrainPolicy {
            epochs: 4,
            fa_window: 64,
            fa_rate: 0.0,
            cooldown: 512,
            max_retrains: 1,
        }
    }
}

/// Per-patient trigger state: the estimator plus cooldown/budget
/// bookkeeping. Purely deterministic — see the module docs.
#[derive(Clone, Debug)]
pub struct PatientWatch {
    est: FalseAlarmRate,
    cooldown_left: usize,
    /// Retrains triggered for this patient so far.
    pub retrains: u64,
    /// Outcomes observed (1-based index of the latest window fed in).
    pub windows_seen: u64,
}

impl PatientWatch {
    pub fn new(policy: &RetrainPolicy) -> Self {
        PatientWatch {
            est: FalseAlarmRate::new(policy.fa_window),
            cooldown_left: 0,
            retrains: 0,
            windows_seen: 0,
        }
    }

    /// Current windowed false-alarm rate (diagnostic).
    pub fn rate(&self) -> f64 {
        self.est.rate()
    }

    /// Feed one window outcome; returns `true` when this outcome crosses
    /// the retrain trigger. On a trigger the estimator is cleared and
    /// the cooldown starts; outcomes during the cooldown are *not* fed
    /// to the estimator (they straddle the swap to the retrained model),
    /// so the post-cooldown rate indicts only the new model.
    pub fn observe(&mut self, policy: &RetrainPolicy, false_alarm: bool) -> bool {
        self.windows_seen += 1;
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return false;
        }
        self.est.push(false_alarm);
        if policy.max_retrains != 0 && self.retrains >= policy.max_retrains {
            return false;
        }
        if !self.est.full() || self.est.rate() < policy.fa_rate {
            return false;
        }
        self.retrains += 1;
        self.cooldown_left = policy.cooldown;
        self.est.clear();
        true
    }
}

/// The scheduler: per-patient [`PatientWatch`]es plus everything a
/// triggered retrain needs (the training record, the registry to read
/// the current version from and publish the next into, and optionally
/// the store to persist it first).
pub struct RetrainScheduler {
    policy: RetrainPolicy,
    registry: Arc<ModelRegistry>,
    store: Option<Arc<ModelStore>>,
    /// Training record per patient (the labelled seizure the retrain's
    /// epoch loop classifies against). A patient without one can trigger
    /// but not retrain — reported, not fatal.
    train: BTreeMap<u32, Record>,
    background: bool,
    watches: Mutex<BTreeMap<u32, PatientWatch>>,
    /// (patient, 1-based window index) of every trigger, in order.
    trigger_log: Mutex<Vec<(u32, u64)>>,
    /// Store-GC budget applied after each persisted retrain: keep at
    /// most this many bundle versions per patient (0 = keep everything).
    max_versions: usize,
    /// Patients with a retrain currently executing. A trigger that lands
    /// while one is in flight is *not* re-launched (it would re-derive
    /// the same base version, burn a full retrain and then hit the
    /// registry's duplicate-publish rejection); the next trigger after
    /// the job lands picks up the newly published base instead. Shared
    /// with the background jobs (they clear their own entry on exit).
    in_flight: Arc<Mutex<BTreeSet<u32>>>,
    threads: Mutex<Vec<JoinHandle<String>>>,
    /// Messages from foreground (inline) retrains, drained by `join`.
    messages: Mutex<Vec<String>>,
}

impl RetrainScheduler {
    pub fn new(
        policy: RetrainPolicy,
        registry: Arc<ModelRegistry>,
        store: Option<Arc<ModelStore>>,
        train: BTreeMap<u32, Record>,
    ) -> RetrainScheduler {
        RetrainScheduler {
            policy,
            registry,
            store,
            train,
            max_versions: 0,
            background: true,
            watches: Mutex::new(BTreeMap::new()),
            trigger_log: Mutex::new(Vec::new()),
            in_flight: Arc::new(Mutex::new(BTreeSet::new())),
            threads: Mutex::new(Vec::new()),
            messages: Mutex::new(Vec::new()),
        }
    }

    /// Prune the store to `max_versions` bundles per patient after each
    /// persisted retrain (0 = keep everything). The prune never removes
    /// the version just published, its lineage parents, or the newest
    /// valid version — see [`ModelStore::prune`].
    pub fn with_max_versions(mut self, max_versions: usize) -> Self {
        self.max_versions = max_versions;
        self
    }

    /// Run triggered retrains inline on the observing thread instead of
    /// spawning — publishes land at a deterministic point in the stream
    /// (tests pin hot-swap boundaries through this).
    pub fn foreground(mut self) -> Self {
        self.background = false;
        self
    }

    pub fn policy(&self) -> &RetrainPolicy {
        &self.policy
    }

    fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Feed one per-window outcome for a patient; launches a retrain and
    /// returns `true` when the policy triggers.
    pub fn observe(&self, patient_id: u32, false_alarm: bool) -> bool {
        let (triggered, at) = {
            let mut watches = Self::lock(&self.watches);
            let watch = watches
                .entry(patient_id)
                .or_insert_with(|| PatientWatch::new(&self.policy));
            (watch.observe(&self.policy, false_alarm), watch.windows_seen)
        };
        if triggered {
            Self::lock(&self.trigger_log).push((patient_id, at));
            self.launch(patient_id);
        }
        triggered
    }

    /// Every trigger so far as (patient, 1-based window index), in
    /// trigger order — the deterministic record the tests pin.
    pub fn triggers(&self) -> Vec<(u32, u64)> {
        Self::lock(&self.trigger_log).clone()
    }

    /// Retrains triggered for one patient.
    pub fn retrains(&self, patient_id: u32) -> u64 {
        Self::lock(&self.watches)
            .get(&patient_id)
            .map(|w| w.retrains)
            .unwrap_or(0)
    }

    fn launch(&self, patient_id: u32) {
        let Some(record) = self.train.get(&patient_id).cloned() else {
            Self::lock(&self.messages).push(format!(
                "patient {patient_id}: retrain triggered but no training record was \
                 retained — skipped"
            ));
            return;
        };
        let Some(current) = self.registry.current(patient_id) else {
            Self::lock(&self.messages).push(format!(
                "patient {patient_id}: retrain triggered before any model was published — \
                 skipped"
            ));
            return;
        };
        if !Self::lock(&self.in_flight).insert(patient_id) {
            Self::lock(&self.messages).push(format!(
                "patient {patient_id}: retrain triggered while a previous retrain is \
                 still in flight — skipped (a later trigger will see the new base)"
            ));
            return;
        }
        let base = current.bundle.clone();
        let registry = self.registry.clone();
        let store = self.store.clone();
        let epochs = self.policy.epochs;
        let max_versions = self.max_versions;
        let in_flight = self.in_flight.clone();
        let job = move || {
            let msg = retrain_job(
                &registry,
                store.as_deref(),
                patient_id,
                base,
                &record,
                epochs,
                max_versions,
            );
            Self::lock(&in_flight).remove(&patient_id);
            msg
        };
        if self.background {
            Self::lock(&self.threads).push(std::thread::spawn(job));
        } else {
            let msg = job();
            Self::lock(&self.messages).push(msg);
        }
    }

    /// Wait for every in-flight retrain and drain all outcome messages
    /// (in completion order; foreground messages first).
    pub fn join(&self) -> Vec<String> {
        let mut out: Vec<String> = Self::lock(&self.messages).drain(..).collect();
        let handles: Vec<JoinHandle<String>> = Self::lock(&self.threads).drain(..).collect();
        for handle in handles {
            out.push(
                handle
                    .join()
                    .unwrap_or_else(|_| "a retrain thread panicked".to_string()),
            );
        }
        out
    }
}

/// One triggered retrain, start to finish: derive v+1 (incrementally
/// when the bundle carries counter planes), persist it, prune the store
/// to the version budget, publish it.
fn retrain_job(
    registry: &ModelRegistry,
    store: Option<&ModelStore>,
    patient_id: u32,
    base: crate::hdc::model::ModelBundle,
    record: &Record,
    epochs: usize,
    max_versions: usize,
) -> String {
    let opts = RetrainOptions {
        max_epochs: epochs,
        ..Default::default()
    };
    let (mut next, report) = pipeline::retrain_bundle(&base, record, &opts);
    next.provenance.patient_id = patient_id;
    let version = next.version;
    let mut pruned = 0usize;
    if let Some(store) = store {
        if let Err(e) = store.save(&next) {
            return format!("patient {patient_id}: persist of v{version} failed: {e:#}");
        }
        if max_versions > 0 {
            // The base version may still be serving in-flight jobs until
            // the hot-swap lands — keep it live alongside the new one.
            match store.prune(patient_id, max_versions, &[base.version, version]) {
                Ok(paths) => pruned = paths.len(),
                Err(e) => {
                    return format!(
                        "patient {patient_id}: store prune after v{version} failed: {e:#}"
                    )
                }
            }
        }
    }
    let gc = if pruned > 0 {
        format!(", pruned {pruned} stale bundle(s)")
    } else {
        String::new()
    };
    match registry.publish(patient_id, next) {
        Ok(_) => format!(
            "patient {patient_id}: published model v{version} \
             (training-window errors {} -> {}){gc}",
            report.initial_errors, report.best_errors
        ),
        Err(e) => format!("patient {patient_id}: publish of v{version} skipped: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::hdc::classifier::{ClassifierConfig, Variant};
    use crate::hdc::hv::Hv;
    use crate::hdc::model::{ModelBundle, Provenance};

    fn policy(window: usize, rate: f64, cooldown: usize, max: u64) -> RetrainPolicy {
        RetrainPolicy {
            epochs: 2,
            fa_window: window,
            fa_rate: rate,
            cooldown,
            max_retrains: max,
        }
    }

    #[test]
    fn zero_rate_triggers_exactly_when_the_window_fills() {
        let p = policy(8, 0.0, 1000, 1);
        let mut w = PatientWatch::new(&p);
        for i in 1..=7u64 {
            assert!(!w.observe(&p, false), "window not full at {i}");
        }
        assert!(w.observe(&p, false), "full window + rate 0.0 >= 0.0 fires");
        assert_eq!(w.windows_seen, 8);
        assert_eq!(w.retrains, 1);
        // Budget of 1: never again, cooldown or not.
        for _ in 0..2000 {
            assert!(!w.observe(&p, true));
        }
    }

    #[test]
    fn rate_threshold_needs_the_burst() {
        // 25% threshold over a 16-window estimator: clean stream never
        // fires; 4 false alarms inside one window span do.
        let p = policy(16, 0.25, 1000, 1);
        let mut w = PatientWatch::new(&p);
        for _ in 0..100 {
            assert!(!w.observe(&p, false));
        }
        assert!(!w.observe(&p, true));
        assert!(!w.observe(&p, true));
        assert!(!w.observe(&p, true));
        assert!(w.observe(&p, true), "4/16 = 25% reaches the trigger");
        assert_eq!(w.windows_seen, 104);
    }

    #[test]
    fn cooldown_spaces_triggers() {
        let p = policy(2, 1.0, 10, 0); // unlimited retrains, 10-window cooldown
        let mut w = PatientWatch::new(&p);
        assert!(!w.observe(&p, true));
        assert!(w.observe(&p, true), "2/2 false alarms fire");
        // Cooldown: the next 10 outcomes cannot fire…
        for i in 0..10 {
            assert!(!w.observe(&p, true), "cooldown window {i}");
        }
        // …after which the (cleared, refilled) estimator fires again.
        assert!(!w.observe(&p, true), "estimator refilling after clear");
        assert!(w.observe(&p, true));
        assert_eq!(w.retrains, 2);
    }

    #[test]
    fn scheduler_without_training_record_reports_instead_of_retraining() {
        let registry = Arc::new(ModelRegistry::new());
        registry
            .publish(3, {
                let mut b = ModelBundle::new(
                    Variant::Optimized,
                    ClassifierConfig::optimized(),
                    AssociativeMemory::new(Hv::zero(), Hv::ones()),
                    Provenance::default(),
                );
                b.provenance.patient_id = 3;
                b
            })
            .unwrap();
        let sched = RetrainScheduler::new(
            policy(2, 0.0, 100, 1),
            registry.clone(),
            None,
            BTreeMap::new(),
        )
        .foreground();
        assert!(!sched.observe(3, false));
        assert!(sched.observe(3, false), "trigger fires at window 2");
        assert_eq!(sched.triggers(), vec![(3, 2)]);
        assert_eq!(sched.retrains(3), 1);
        let msgs = sched.join();
        assert_eq!(msgs.len(), 1);
        assert!(msgs[0].contains("no training record"), "{}", msgs[0]);
        // No publish happened: still v1.
        assert_eq!(registry.current(3).unwrap().version(), 1);
    }
}
