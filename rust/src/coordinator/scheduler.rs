//! False-alarm-driven retrain scheduling.
//!
//! PR 4's `--retrain-epochs` launched one unconditional background
//! retrain per patient at startup. This module replaces that one-shot
//! pass with a **policy**: sessions feed per-window outcomes (was the
//! window a false alarm?) into a sliding estimator
//! ([`crate::coordinator::metrics::FalseAlarmRate`]), and when a
//! patient's rate crosses the configured trigger the scheduler launches
//! an **incremental** retrain — resumed from the model's persisted
//! counter planes ([`crate::pipeline::retrain_bundle`]) — then persists
//! the new version to the [`ModelStore`] (when configured) and publishes
//! it into the [`ModelRegistry`], where serving sessions hot-swap it at
//! their next micro-batch. Persist-then-publish: a version that is being
//! served is always already on disk, so a crash right after the publish
//! still resumes at that version.
//!
//! The trigger decision ([`PatientWatch::observe`]) is a pure function
//! of the per-patient outcome stream — no clocks, no thread timing — so
//! tests can pin the exact window index a planted false-alarm burst
//! fires at (`tests/retrain_scheduler.rs`). Only the retrain *execution*
//! is asynchronous (a background thread per trigger); foreground mode
//! ([`RetrainScheduler::foreground`]) runs it inline for deterministic
//! end-to-end tests.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::metrics::FalseAlarmRate;
use crate::coordinator::registry::{ModelRegistry, ModelStore};
use crate::data::synth::Record;
use crate::pipeline::{self, RetrainOptions};
use crate::transport::frame::PatientStatus;

/// When and how to retrain a patient's model.
#[derive(Clone, Debug)]
pub struct RetrainPolicy {
    /// Upper bound on online epochs per retrain
    /// ([`crate::hdc::online::OnlineConfig::max_epochs`]).
    pub epochs: usize,
    /// Sliding-window size (prediction windows) of the false-alarm-rate
    /// estimator. The rate is only consulted once the window is full.
    pub fa_window: usize,
    /// Trigger threshold: retrain when the windowed false-alarm rate
    /// reaches this fraction. `0.0` triggers as soon as the window fills
    /// — the "retrain once, early in the stream" behaviour the old
    /// one-shot pass approximated.
    pub fa_rate: f64,
    /// Windows to hold off after a trigger before the rate is consulted
    /// again (gives the retrained model time to prove itself).
    pub cooldown: usize,
    /// Retrains allowed per patient over the stream (0 = unlimited).
    pub max_retrains: u64,
}

impl Default for RetrainPolicy {
    fn default() -> Self {
        RetrainPolicy {
            epochs: 4,
            fa_window: 64,
            fa_rate: 0.0,
            cooldown: 512,
            max_retrains: 1,
        }
    }
}

/// Per-patient trigger state: the estimator plus cooldown/budget
/// bookkeeping. Purely deterministic — see the module docs.
#[derive(Clone, Debug)]
pub struct PatientWatch {
    est: FalseAlarmRate,
    cooldown_left: usize,
    /// Retrains triggered for this patient so far.
    pub retrains: u64,
    /// Outcomes observed (1-based index of the latest window fed in).
    pub windows_seen: u64,
}

impl PatientWatch {
    pub fn new(policy: &RetrainPolicy) -> Self {
        PatientWatch {
            est: FalseAlarmRate::new(policy.fa_window),
            cooldown_left: 0,
            retrains: 0,
            windows_seen: 0,
        }
    }

    /// Current windowed false-alarm rate (diagnostic).
    pub fn rate(&self) -> f64 {
        self.est.rate()
    }

    /// False alarms currently inside the estimator window (telemetry).
    pub fn fa_hits(&self) -> u64 {
        self.est.false_alarms()
    }

    /// Outcomes currently inside the estimator window (telemetry).
    pub fn fa_seen(&self) -> u64 {
        self.est.len() as u64
    }

    /// Feed one window outcome; returns `true` when this outcome crosses
    /// the retrain trigger. On a trigger the estimator is cleared and
    /// the cooldown starts; outcomes during the cooldown are *not* fed
    /// to the estimator (they straddle the swap to the retrained model),
    /// so the post-cooldown rate indicts only the new model.
    pub fn observe(&mut self, policy: &RetrainPolicy, false_alarm: bool) -> bool {
        self.windows_seen += 1;
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return false;
        }
        self.est.push(false_alarm);
        if policy.max_retrains != 0 && self.retrains >= policy.max_retrains {
            return false;
        }
        if !self.est.full() || self.est.rate() < policy.fa_rate {
            return false;
        }
        self.retrains += 1;
        self.cooldown_left = policy.cooldown;
        self.est.clear();
        true
    }
}

/// The scheduler: per-patient [`PatientWatch`]es plus everything a
/// triggered retrain needs (the training record, the registry to read
/// the current version from and publish the next into, and optionally
/// the store to persist it first).
pub struct RetrainScheduler {
    policy: RetrainPolicy,
    registry: Arc<ModelRegistry>,
    store: Option<Arc<ModelStore>>,
    /// Training record per patient (the labelled seizure the retrain's
    /// epoch loop classifies against). A patient without one can trigger
    /// but not retrain — reported, not fatal.
    train: BTreeMap<u32, Record>,
    /// Feedback capture budget (`[model] feedback_window`): how many
    /// labelled serving windows are retained per patient. 0 disables the
    /// feedback path — every retrain falls back to the retained record.
    feedback_window: usize,
    /// Per-patient ring of ground-truthed serving windows, oldest first:
    /// `(frame-major window codes, ictal)`. A trigger retrains from this
    /// ring when it is full ([`pipeline::retrain_bundle_from_windows`]),
    /// so v+1 reflects what the stream looks like *now*.
    feedback: Mutex<BTreeMap<u32, VecDeque<(Vec<u8>, bool)>>>,
    /// Models actually published by the retrain loop, per patient.
    /// Distinct from [`PatientWatch::retrains`] (triggers): a trigger can
    /// skip (no base model, in flight) or its publish can fail. Shared
    /// with background jobs, which increment on success.
    published: Arc<Mutex<BTreeMap<u32, u64>>>,
    background: bool,
    watches: Mutex<BTreeMap<u32, PatientWatch>>,
    /// (patient, 1-based window index) of every trigger, in order.
    trigger_log: Mutex<Vec<(u32, u64)>>,
    /// Store-GC budget applied after each persisted retrain: keep at
    /// most this many bundle versions per patient (0 = keep everything).
    max_versions: usize,
    /// Patients with a retrain currently executing. A trigger that lands
    /// while one is in flight is *not* re-launched (it would re-derive
    /// the same base version, burn a full retrain and then hit the
    /// registry's duplicate-publish rejection); the next trigger after
    /// the job lands picks up the newly published base instead. Shared
    /// with the background jobs (they clear their own entry on exit).
    in_flight: Arc<Mutex<BTreeSet<u32>>>,
    threads: Mutex<Vec<JoinHandle<String>>>,
    /// Messages from foreground (inline) retrains, drained by `join`.
    messages: Mutex<Vec<String>>,
}

impl RetrainScheduler {
    pub fn new(
        policy: RetrainPolicy,
        registry: Arc<ModelRegistry>,
        store: Option<Arc<ModelStore>>,
        train: BTreeMap<u32, Record>,
    ) -> RetrainScheduler {
        RetrainScheduler {
            policy,
            registry,
            store,
            train,
            feedback_window: 0,
            feedback: Mutex::new(BTreeMap::new()),
            published: Arc::new(Mutex::new(BTreeMap::new())),
            max_versions: 0,
            background: true,
            watches: Mutex::new(BTreeMap::new()),
            trigger_log: Mutex::new(Vec::new()),
            in_flight: Arc::new(Mutex::new(BTreeSet::new())),
            threads: Mutex::new(Vec::new()),
            messages: Mutex::new(Vec::new()),
        }
    }

    /// Prune the store to `max_versions` bundles per patient after each
    /// persisted retrain (0 = keep everything). The prune never removes
    /// the version just published, its lineage parents, or the newest
    /// valid version — see [`ModelStore::prune`].
    pub fn with_max_versions(mut self, max_versions: usize) -> Self {
        self.max_versions = max_versions;
        self
    }

    /// Run triggered retrains inline on the observing thread instead of
    /// spawning — publishes land at a deterministic point in the stream
    /// (tests pin hot-swap boundaries through this).
    pub fn foreground(mut self) -> Self {
        self.background = false;
        self
    }

    /// Retain up to `windows` labelled serving windows per patient and
    /// prefer retraining from that ring once it is full (0 disables the
    /// feedback path).
    pub fn with_feedback_window(mut self, windows: usize) -> Self {
        self.feedback_window = windows;
        self
    }

    pub fn policy(&self) -> &RetrainPolicy {
        &self.policy
    }

    fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Feed one per-window outcome for a patient; launches a retrain and
    /// returns `true` when the policy triggers.
    pub fn observe(&self, patient_id: u32, false_alarm: bool) -> bool {
        let (triggered, at) = {
            let mut watches = Self::lock(&self.watches);
            let watch = watches
                .entry(patient_id)
                .or_insert_with(|| PatientWatch::new(&self.policy));
            (watch.observe(&self.policy, false_alarm), watch.windows_seen)
        };
        if triggered {
            Self::lock(&self.trigger_log).push((patient_id, at));
            self.launch(patient_id);
        }
        triggered
    }

    /// Every trigger so far as (patient, 1-based window index), in
    /// trigger order — the deterministic record the tests pin.
    pub fn triggers(&self) -> Vec<(u32, u64)> {
        Self::lock(&self.trigger_log).clone()
    }

    /// Retrains triggered for one patient.
    pub fn retrains(&self, patient_id: u32) -> u64 {
        Self::lock(&self.watches)
            .get(&patient_id)
            .map(|w| w.retrains)
            .unwrap_or(0)
    }

    /// Models actually published by the retrain loop for one patient.
    pub fn published_retrains(&self, patient_id: u32) -> u64 {
        Self::lock(&self.published)
            .get(&patient_id)
            .copied()
            .unwrap_or(0)
    }

    /// Stash one ground-truthed serving window for a patient's feedback
    /// ring (oldest falls off past the budget). No-op when the feedback
    /// path is disabled.
    pub fn record_feedback(&self, patient_id: u32, codes: Vec<u8>, ictal: bool) {
        if self.feedback_window == 0 {
            return;
        }
        let mut feedback = Self::lock(&self.feedback);
        let ring = feedback.entry(patient_id).or_default();
        if ring.len() >= self.feedback_window {
            ring.pop_front();
        }
        ring.push_back((codes, ictal));
    }

    /// Labelled serving windows currently retained for a patient.
    pub fn feedback_depth(&self, patient_id: u32) -> usize {
        Self::lock(&self.feedback)
            .get(&patient_id)
            .map(|r| r.len())
            .unwrap_or(0)
    }

    /// Per-patient telemetry snapshot (ascending patient id) — the
    /// payload of a `StatusReport` wire frame and `serve --status`.
    pub fn status(&self) -> Vec<PatientStatus> {
        let watches = Self::lock(&self.watches);
        let feedback = Self::lock(&self.feedback);
        let published = Self::lock(&self.published);
        let mut patients: BTreeSet<u32> = watches.keys().copied().collect();
        patients.extend(feedback.keys().copied());
        patients
            .into_iter()
            .map(|patient| {
                let watch = watches.get(&patient);
                PatientStatus {
                    patient,
                    fa_hits: watch.map(|w| w.fa_hits()).unwrap_or(0) as u32,
                    fa_seen: watch.map(|w| w.fa_seen()).unwrap_or(0) as u32,
                    retrains: published.get(&patient).copied().unwrap_or(0) as u32,
                    triggers: watch.map(|w| w.retrains).unwrap_or(0) as u32,
                    feedback_depth: feedback.get(&patient).map(|r| r.len()).unwrap_or(0) as u32,
                }
            })
            .collect()
    }

    fn launch(&self, patient_id: u32) {
        // A full feedback ring wins over the retained record: the ring is
        // what the patient's stream looks like *now*. A partial ring is
        // not enough signal — fall back to the record until it fills.
        let feedback: Option<Vec<(Vec<u8>, bool)>> = {
            let rings = Self::lock(&self.feedback);
            rings.get(&patient_id).and_then(|ring| {
                (self.feedback_window > 0 && ring.len() >= self.feedback_window)
                    .then(|| ring.iter().cloned().collect())
            })
        };
        let source = match (feedback, self.train.get(&patient_id).cloned()) {
            (Some(windows), _) => RetrainSource::Feedback(windows),
            (None, Some(record)) => RetrainSource::Record(record),
            (None, None) => {
                Self::lock(&self.messages).push(format!(
                    "patient {patient_id}: retrain triggered but the feedback ring is not \
                     full and no training record was retained — skipped"
                ));
                return;
            }
        };
        let Some(current) = self.registry.current(patient_id) else {
            Self::lock(&self.messages).push(format!(
                "patient {patient_id}: retrain triggered before any model was published — \
                 skipped"
            ));
            return;
        };
        if !Self::lock(&self.in_flight).insert(patient_id) {
            Self::lock(&self.messages).push(format!(
                "patient {patient_id}: retrain triggered while a previous retrain is \
                 still in flight — skipped (a later trigger will see the new base)"
            ));
            return;
        }
        let base = current.bundle.clone();
        let registry = self.registry.clone();
        let store = self.store.clone();
        let epochs = self.policy.epochs;
        let max_versions = self.max_versions;
        let in_flight = self.in_flight.clone();
        let published = self.published.clone();
        let job = move || {
            let (msg, ok) = retrain_job(
                &registry,
                store.as_deref(),
                patient_id,
                base,
                &source,
                epochs,
                max_versions,
            );
            if ok {
                *Self::lock(&published).entry(patient_id).or_insert(0) += 1;
            }
            Self::lock(&in_flight).remove(&patient_id);
            msg
        };
        if self.background {
            Self::lock(&self.threads).push(std::thread::spawn(job));
        } else {
            let msg = job();
            Self::lock(&self.messages).push(msg);
        }
    }

    /// Wait for every in-flight retrain and drain all outcome messages
    /// (in completion order; foreground messages first).
    pub fn join(&self) -> Vec<String> {
        let mut out: Vec<String> = Self::lock(&self.messages).drain(..).collect();
        let handles: Vec<JoinHandle<String>> = Self::lock(&self.threads).drain(..).collect();
        for handle in handles {
            out.push(
                handle
                    .join()
                    .unwrap_or_else(|_| "a retrain thread panicked".to_string()),
            );
        }
        out
    }
}

/// What a triggered retrain trains on: the retained training record, or
/// a full ring of labelled serving windows from the feedback loop.
enum RetrainSource {
    Record(Record),
    Feedback(Vec<(Vec<u8>, bool)>),
}

/// One triggered retrain, start to finish: derive v+1 (incrementally
/// when the bundle carries counter planes), persist it, prune the store
/// to the version budget, publish it. Returns the outcome message and
/// whether the new version was actually published.
fn retrain_job(
    registry: &ModelRegistry,
    store: Option<&ModelStore>,
    patient_id: u32,
    base: crate::hdc::model::ModelBundle,
    source: &RetrainSource,
    epochs: usize,
    max_versions: usize,
) -> (String, bool) {
    let opts = RetrainOptions {
        max_epochs: epochs,
        ..Default::default()
    };
    let ((mut next, report), material) = match source {
        RetrainSource::Record(record) => {
            (pipeline::retrain_bundle(&base, record, &opts), "record".to_string())
        }
        RetrainSource::Feedback(windows) => (
            pipeline::retrain_bundle_from_windows(&base, windows, &opts),
            format!("{} feedback window(s)", windows.len()),
        ),
    };
    next.provenance.patient_id = patient_id;
    let version = next.version;
    let mut pruned = 0usize;
    if let Some(store) = store {
        if let Err(e) = store.save(&next) {
            return (
                format!("patient {patient_id}: persist of v{version} failed: {e:#}"),
                false,
            );
        }
        if max_versions > 0 {
            // The base version may still be serving in-flight jobs until
            // the hot-swap lands — keep it live alongside the new one.
            match store.prune(patient_id, max_versions, &[base.version, version]) {
                Ok(paths) => pruned = paths.len(),
                Err(e) => {
                    return (
                        format!(
                            "patient {patient_id}: store prune after v{version} failed: {e:#}"
                        ),
                        false,
                    )
                }
            }
        }
    }
    let gc = if pruned > 0 {
        format!(", pruned {pruned} stale bundle(s)")
    } else {
        String::new()
    };
    match registry.publish(patient_id, next) {
        Ok(_) => (
            format!(
                "patient {patient_id}: published model v{version} from {material} \
                 (training-window errors {} -> {}){gc}",
                report.initial_errors, report.best_errors
            ),
            true,
        ),
        Err(e) => (
            format!("patient {patient_id}: publish of v{version} skipped: {e:#}"),
            false,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::hdc::classifier::{ClassifierConfig, Variant};
    use crate::hdc::hv::Hv;
    use crate::hdc::model::{ModelBundle, Provenance};

    fn policy(window: usize, rate: f64, cooldown: usize, max: u64) -> RetrainPolicy {
        RetrainPolicy {
            epochs: 2,
            fa_window: window,
            fa_rate: rate,
            cooldown,
            max_retrains: max,
        }
    }

    #[test]
    fn zero_rate_triggers_exactly_when_the_window_fills() {
        let p = policy(8, 0.0, 1000, 1);
        let mut w = PatientWatch::new(&p);
        for i in 1..=7u64 {
            assert!(!w.observe(&p, false), "window not full at {i}");
        }
        assert!(w.observe(&p, false), "full window + rate 0.0 >= 0.0 fires");
        assert_eq!(w.windows_seen, 8);
        assert_eq!(w.retrains, 1);
        // Budget of 1: never again, cooldown or not.
        for _ in 0..2000 {
            assert!(!w.observe(&p, true));
        }
    }

    #[test]
    fn rate_threshold_needs_the_burst() {
        // 25% threshold over a 16-window estimator: clean stream never
        // fires; 4 false alarms inside one window span do.
        let p = policy(16, 0.25, 1000, 1);
        let mut w = PatientWatch::new(&p);
        for _ in 0..100 {
            assert!(!w.observe(&p, false));
        }
        assert!(!w.observe(&p, true));
        assert!(!w.observe(&p, true));
        assert!(!w.observe(&p, true));
        assert!(w.observe(&p, true), "4/16 = 25% reaches the trigger");
        assert_eq!(w.windows_seen, 104);
    }

    #[test]
    fn cooldown_spaces_triggers() {
        let p = policy(2, 1.0, 10, 0); // unlimited retrains, 10-window cooldown
        let mut w = PatientWatch::new(&p);
        assert!(!w.observe(&p, true));
        assert!(w.observe(&p, true), "2/2 false alarms fire");
        // Cooldown: the next 10 outcomes cannot fire…
        for i in 0..10 {
            assert!(!w.observe(&p, true), "cooldown window {i}");
        }
        // …after which the (cleared, refilled) estimator fires again.
        assert!(!w.observe(&p, true), "estimator refilling after clear");
        assert!(w.observe(&p, true));
        assert_eq!(w.retrains, 2);
    }

    /// Hand-traced pin of the cooldown boundary, outcome by outcome,
    /// against [`PatientWatch::observe`]'s doc comment ("outcomes during
    /// the cooldown are *not* fed to the estimator"). The trace AGREES
    /// with the implementation — the decrement happens before the
    /// estimator push, so exactly `cooldown` outcomes are swallowed and
    /// the very next outcome is the first fed to the cleared estimator.
    ///
    /// Trace for fa_window=2, fa_rate=1.0, cooldown=3, unlimited budget:
    ///   w1  push(T)            len 1, not full          → no fire
    ///   w2  push(T)            full, rate 1.0 ≥ 1.0     → FIRE, clear, cd=3
    ///   w3  cd 3→2, swallowed                           → no fire
    ///   w4  cd 2→1, swallowed                           → no fire
    ///   w5  cd 1→0, swallowed  (3rd and last swallowed) → no fire
    ///   w6  push(T)            len 1, not full          → no fire
    ///   w7  push(T)            full, rate 1.0           → FIRE at window 7
    /// An off-by-one in either direction moves the second fire to 6 or 8.
    #[test]
    fn cooldown_boundary_hand_trace() {
        let p = policy(2, 1.0, 3, 0);
        let mut w = PatientWatch::new(&p);
        assert!(!w.observe(&p, true), "w1: estimator filling");
        assert!(w.observe(&p, true), "w2: first fire");
        assert!(!w.observe(&p, true), "w3: swallowed (cooldown 3→2)");
        assert!(!w.observe(&p, true), "w4: swallowed (cooldown 2→1)");
        assert!(!w.observe(&p, true), "w5: swallowed (cooldown 1→0)");
        assert_eq!(w.fa_seen(), 0, "w5 was swallowed, not fed post-clear");
        assert!(!w.observe(&p, true), "w6: fed — estimator refilling");
        assert_eq!(w.fa_seen(), 1, "w6 was fed to the estimator");
        assert!(w.observe(&p, true), "w7: second fire, not 6 or 8");
        assert_eq!(w.windows_seen, 7);
        assert_eq!(w.retrains, 2);
    }

    #[test]
    fn feedback_ring_is_bounded_and_reported_in_status() {
        let registry = Arc::new(ModelRegistry::new());
        let sched = RetrainScheduler::new(
            policy(4, 0.5, 100, 1),
            registry,
            None,
            BTreeMap::new(),
        )
        .foreground()
        .with_feedback_window(3);
        for i in 0..5u8 {
            sched.record_feedback(9, vec![i; 4], i % 2 == 0);
        }
        assert_eq!(sched.feedback_depth(9), 3, "oldest two fell off");
        sched.record_feedback(2, vec![0; 4], false);
        sched.observe(9, true);
        sched.observe(9, false);

        let status = sched.status();
        let patients: Vec<u32> = status.iter().map(|s| s.patient).collect();
        assert_eq!(patients, vec![2, 9], "ascending patient order");
        let p9 = &status[1];
        assert_eq!((p9.fa_hits, p9.fa_seen), (1, 2));
        assert_eq!((p9.retrains, p9.triggers), (0, 0));
        assert_eq!(p9.feedback_depth, 3);
        assert_eq!(status[0].feedback_depth, 1);
    }

    #[test]
    fn feedback_disabled_scheduler_retains_nothing() {
        let registry = Arc::new(ModelRegistry::new());
        let sched =
            RetrainScheduler::new(policy(4, 0.5, 100, 1), registry, None, BTreeMap::new())
                .foreground();
        sched.record_feedback(1, vec![0; 4], true);
        assert_eq!(sched.feedback_depth(1), 0);
        assert!(sched.status().is_empty());
    }

    #[test]
    fn scheduler_without_training_record_reports_instead_of_retraining() {
        let registry = Arc::new(ModelRegistry::new());
        registry
            .publish(3, {
                let mut b = ModelBundle::new(
                    Variant::Optimized,
                    ClassifierConfig::optimized(),
                    AssociativeMemory::new(Hv::zero(), Hv::ones()),
                    Provenance::default(),
                );
                b.provenance.patient_id = 3;
                b
            })
            .unwrap();
        let sched = RetrainScheduler::new(
            policy(2, 0.0, 100, 1),
            registry.clone(),
            None,
            BTreeMap::new(),
        )
        .foreground();
        assert!(!sched.observe(3, false));
        assert!(sched.observe(3, false), "trigger fires at window 2");
        assert_eq!(sched.triggers(), vec![(3, 2)]);
        assert_eq!(sched.retrains(3), 1);
        let msgs = sched.join();
        assert_eq!(msgs.len(), 1);
        assert!(msgs[0].contains("no training record"), "{}", msgs[0]);
        // No publish happened: still v1.
        assert_eq!(registry.current(3).unwrap().version(), 1);
    }
}
