//! The streaming orchestrator: sources → router/sessions → engine
//! workers → detector events, with backpressure and metrics.
//!
//! Two interchangeable window backends behind one [`EngineHost`]:
//! * **native** — the bit-accurate Rust golden model (no artifacts
//!   needed; the default build's serving path);
//! * **pjrt**  — the AOT-compiled HLO artifacts executed through the
//!   `xla` PJRT client (cargo feature `pjrt`), i.e. the full three-layer
//!   stack on the request path. Without the feature, selecting
//!   [`Backend::Pjrt`] fails fast with an actionable error.
//!
//! Both run on dedicated worker threads behind bounded queues, so a slow
//! engine stalls the sources (backpressure) instead of ballooning memory.
//!
//! Sessions submit **micro-batches** of `batch_windows` windows per
//! engine job (flushed at stream end), and the engine host coalesces
//! AM-sharing jobs further; predictions are bit-identical at every batch
//! size — batching changes only when work reaches the engine.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::cli::Args;
use crate::config::{ConfigFile, SystemConfig};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::router::{Router, SampleChunk};
use crate::coordinator::session::Session;
use crate::data::metrics::{evaluate_record, AlarmPolicy, EvalSummary};
use crate::data::synth::Record;
use crate::ensure;
use crate::err;
use crate::error::Context;
use crate::hdc::am::AssociativeMemory;
use crate::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};
use crate::params::{CHANNELS, CLASS_ICTAL, CLASS_INTERICTAL, SAMPLE_RATE_HZ};
use crate::pipeline;
use crate::runtime::engine_pool::{Completion, EngineHost, EngineSpec, Job};
use crate::runtime::EngineKind;

/// Window-backend selection.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Golden-model engine ([`crate::runtime::native`]) on a worker thread.
    Native,
    /// PJRT-compiled artifact from this directory (`--features pjrt`).
    Pjrt { artifacts_dir: PathBuf },
}

/// Spawn the engine host for the selected backend.
fn spawn_host(
    backend: &Backend,
    cfg: &ClassifierConfig,
    queue_depth: usize,
) -> crate::Result<EngineHost> {
    match backend {
        Backend::Native => EngineHost::spawn(
            EngineSpec::Native { cfg: cfg.clone() },
            EngineKind::SparseWindow,
            queue_depth,
        ),
        #[cfg(feature = "pjrt")]
        Backend::Pjrt { artifacts_dir } => EngineHost::spawn(
            EngineSpec::Pjrt {
                artifacts_dir: artifacts_dir.clone(),
            },
            EngineKind::SparseWindow,
            queue_depth,
        ),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt { artifacts_dir } => crate::bail!(
            "backend 'pjrt' (artifacts dir {}) is not compiled into this binary — \
             rebuild with `cargo build --features pjrt`, or use the native backend \
             (drop --use-pjrt / set runtime.use_pjrt = false)",
            artifacts_dir.display()
        ),
    }
}

/// One patient stream to serve: the session's trained model plus the
/// record to replay.
pub struct StreamSpec {
    pub session_id: u64,
    pub patient_id: u32,
    pub record: Record,
    pub am: AssociativeMemory,
    pub threshold: u16,
}

/// Per-session outcome of a serving run.
pub struct SessionReport {
    pub session_id: u64,
    pub patient_id: u32,
    pub windows: u64,
    pub alarms: Vec<crate::coordinator::detector::AlarmEvent>,
    pub eval: crate::data::metrics::RecordOutcome,
}

/// Full report of one serving run.
pub struct StreamReport {
    pub sessions: Vec<SessionReport>,
    pub metrics: ServingMetrics,
    pub summary: EvalSummary,
}

/// The coordinator: owns the router and the engine host.
pub struct Coordinator {
    system: SystemConfig,
    backend: Backend,
    /// Samples per source chunk (smaller → finer interleaving, more
    /// routing overhead).
    pub chunk_samples: usize,
    /// Pace sources at the iEEG sample rate (wall-clock realtime).
    pub realtime: bool,
    /// Windows per engine micro-batch (from `SystemConfig`; 1 submits
    /// every window immediately). Predictions are bit-identical at any
    /// value — batching only changes when work reaches the engine.
    pub batch_windows: usize,
}

impl Coordinator {
    pub fn new(system: SystemConfig, backend: Backend) -> Self {
        let batch_windows = system.batch_windows.max(1);
        Coordinator {
            system,
            backend,
            chunk_samples: 64,
            realtime: false,
            batch_windows,
        }
    }

    /// Serve a set of patient streams to completion and score the
    /// detections against the records' annotations.
    pub fn run(&self, streams: Vec<StreamSpec>) -> crate::Result<StreamReport> {
        ensure!(!streams.is_empty(), "no streams to serve");
        let mut metrics = ServingMetrics::new();
        let host = spawn_host(
            &self.backend,
            &self.system.classifier,
            self.system.queue_depth,
        )?;

        // Build sessions + retain records for scoring/pacing.
        let mut router = Router::new();
        let mut records: std::collections::BTreeMap<u64, Record> = Default::default();
        for s in &streams {
            let mut cfg_threshold = s.threshold;
            if cfg_threshold == 0 {
                cfg_threshold = self.system.classifier.temporal_threshold;
            }
            let mut session = Session::new(
                s.session_id,
                s.patient_id,
                s.am.clone(),
                cfg_threshold,
                self.system.alarm_consecutive,
            );
            session.set_batch_windows(self.batch_windows);
            router.add_session(session);
            records.insert(s.session_id, s.record.clone());
        }

        // Source cursors.
        struct Cursor {
            session_id: u64,
            pos: usize,
            len: usize,
        }
        let mut cursors: Vec<Cursor> = streams
            .iter()
            .map(|s| Cursor {
                session_id: s.session_id,
                pos: 0,
                len: s.record.num_samples(),
            })
            .collect();

        let t0 = Instant::now();
        let mut ready = Vec::new();
        let mut pending_jobs: Vec<Job> = Vec::new();
        let mut in_flight: u64 = 0;

        loop {
            let mut any_active = false;
            for cur in cursors.iter_mut() {
                if cur.pos >= cur.len {
                    continue;
                }
                any_active = true;
                let n = self.chunk_samples.min(cur.len - cur.pos);
                if self.realtime {
                    // Pace: this chunk's last sample becomes due at
                    // (pos + n) / fs seconds after stream start.
                    let due = (cur.pos + n) as f64 / SAMPLE_RATE_HZ;
                    let elapsed = t0.elapsed().as_secs_f64();
                    if due > elapsed {
                        std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                    }
                }
                let rec = &records[&cur.session_id];
                let chunk = SampleChunk {
                    session_id: cur.session_id,
                    samples: rec.samples[cur.pos * CHANNELS..(cur.pos + n) * CHANNELS].to_vec(),
                };
                cur.pos += n;
                metrics.samples_in += n as u64;
                metrics.frames_in += n as u64;
                ready.clear();
                router.route(&chunk, &mut ready)?;
                if cur.pos >= cur.len {
                    // Stream exhausted: flush the session's partial batch
                    // so the tail windows don't wait for a fill that
                    // never comes.
                    if let Some(b) = router
                        .session_mut(cur.session_id)
                        .and_then(|s| s.flush_batch())
                    {
                        ready.push(b);
                    }
                }
                for b in ready.drain(..) {
                    let session = router.session(b.session_id).expect("routed");
                    pending_jobs.push(Job {
                        tag: b.session_id,
                        seq: b.seq0,
                        codes: b.codes,
                        am: session.am.clone(),
                        thresholds: vec![session.threshold as i32; b.windows],
                        submitted: Instant::now(),
                    });
                }
                // Submit in arrival order, with backpressure accounting.
                for job in pending_jobs.drain(..) {
                    let windows = job.windows() as u64;
                    match host.try_submit(job) {
                        Ok(()) => {
                            metrics.windows_submitted += windows;
                            in_flight += 1;
                        }
                        Err(job) => {
                            metrics.backpressure_stalls += 1;
                            host.submit(job)?; // blocking
                            metrics.windows_submitted += windows;
                            in_flight += 1;
                        }
                    }
                }
                // Opportunistically drain completions.
                while let Ok(c) = host.completions.try_recv() {
                    in_flight -= 1;
                    Self::finish(&mut router, &mut metrics, c);
                }
            }
            if !any_active {
                break;
            }
        }

        // Drain the tail.
        while in_flight > 0 {
            let c = host
                .completions
                .recv()
                .map_err(|_| err!("engine worker dropped completions"))?;
            in_flight -= 1;
            Self::finish(&mut router, &mut metrics, c);
        }

        // Score each session against its record's annotation.
        let policy = AlarmPolicy {
            consecutive: self.system.alarm_consecutive,
        };
        let mut summary = EvalSummary::default();
        let mut sessions = Vec::new();
        for s in router.sessions() {
            let rec = &records[&s.id];
            let eval = evaluate_record(rec, &s.predictions, policy, pipeline::DETECT_GRACE_S);
            summary.add(&eval);
            sessions.push(SessionReport {
                session_id: s.id,
                patient_id: s.patient_id,
                windows: s.windows(),
                alarms: s.detector.events.clone(),
                eval,
            });
        }
        Ok(StreamReport {
            sessions,
            metrics,
            summary,
        })
    }

    fn finish(router: &mut Router, metrics: &mut ServingMetrics, c: Completion) {
        // Submit→complete latency of the whole job, recorded per window
        // (batched windows share one engine round-trip by design).
        let latency = c.latency_s();
        match c.outputs {
            Ok(outs) => {
                for (k, out) in outs.iter().enumerate() {
                    metrics.windows_completed += 1;
                    metrics.latency.record(latency);
                    let is_ictal = out.scores[CLASS_ICTAL] > out.scores[CLASS_INTERICTAL];
                    let margin = out.margin();
                    if let Some(session) = router.session_mut(c.tag) {
                        if session.complete(c.seq + k as u64, is_ictal, margin).is_some() {
                            metrics.alarms += 1;
                        }
                    }
                }
            }
            Err(e) => {
                metrics.windows_failed += c.windows as u64;
                eprintln!(
                    "batch failed (session {}, seq {}, {} windows): {e:#}",
                    c.tag, c.seq, c.windows
                );
            }
        }
    }
}

/// One session's setup: load the patient, one-shot-train on record 0,
/// and keep only the record to stream — returning the full record set
/// from N parallel setups would hold the whole cohort in memory at
/// once (the serial loop peaked at one patient).
fn setup_session(
    data: &std::path::Path,
    pid: u32,
    record_idx: usize,
    cfg: &ClassifierConfig,
) -> crate::Result<(u32, Record, AssociativeMemory)> {
    let mut records = crate::data::dataset::load_patient(data, pid)
        .with_context(|| format!("load patient {pid}"))?;
    ensure!(
        records.len() > record_idx,
        "patient {pid} has {} records, need index {record_idx}",
        records.len()
    );
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
    let am = pipeline::train_on_record(&mut enc, &records[0], cfg.train_density);
    Ok((pid, records.swap_remove(record_idx), am))
}

/// `repro serve --data DIR [--patients LIST] [--use-pjrt] [--realtime]
/// [--config FILE] [--record K]`
pub fn serve_command(args: &Args) -> crate::Result<()> {
    args.check_known(&[
        "data",
        "patients",
        "use-pjrt",
        "realtime",
        "config",
        "record",
        "artifacts",
        "chunk",
        "batch",
    ])?;
    let data = PathBuf::from(args.require("data")?);
    let mut system = match args.get("config") {
        Some(path) => SystemConfig::from_file(&ConfigFile::load(std::path::Path::new(path))?)?,
        None => SystemConfig::default(),
    };
    system.classifier.spatial_threshold = 1;
    if args.flag("use-pjrt") {
        system.use_pjrt = true;
    }
    let artifacts = args.get_str("artifacts", &system.artifacts_dir);
    let record_idx: usize = args.get_parse("record", 1usize)?;

    let patient_ids: Vec<u32> = {
        let list = args.get_list("patients");
        if list.is_empty() {
            vec![1, 2, 3, 4]
        } else {
            list.iter()
                .map(|s| s.parse::<u32>())
                .collect::<Result<_, _>>()?
        }
    };

    // Train per patient (one-shot on record 0), then stream `record_idx`.
    // Session setup is embarrassingly parallel (each patient loads + trains
    // independently); the evalpool keeps session ids in patient-list order.
    // A failure flag restores fail-fast: workers skip launching new
    // load+train passes (returning `None`) once any setup errors, and the
    // drain below surfaces the first *real* error — a worker that races
    // the flag leaves only a skipped slot, never a masking placeholder.
    let classifier_cfg = &system.classifier;
    let failed = std::sync::atomic::AtomicBool::new(false);
    let specs = crate::evalpool::map(&patient_ids, |&pid| {
        if failed.load(std::sync::atomic::Ordering::Relaxed) {
            return None;
        }
        let spec = setup_session(&data, pid, record_idx, classifier_cfg);
        if spec.is_err() {
            failed.store(true, std::sync::atomic::Ordering::Relaxed);
        }
        Some(spec)
    });
    let mut streams = Vec::new();
    for (i, spec) in specs.into_iter().enumerate() {
        let (pid, record, am) = match spec {
            Some(spec) => spec?,
            // Skipped after another slot's failure; that slot holds the
            // real error and the loop returns it when it gets there.
            None => continue,
        };
        println!(
            "patient {pid}: trained (class densities {:.1}% / {:.1}%), streaming record {record_idx}",
            am.classes[0].density() * 100.0,
            am.classes[1].density() * 100.0
        );
        streams.push(StreamSpec {
            session_id: i as u64 + 1,
            patient_id: pid,
            record,
            am,
            threshold: classifier_cfg.temporal_threshold,
        });
    }

    let backend = if system.use_pjrt {
        Backend::Pjrt {
            artifacts_dir: PathBuf::from(artifacts),
        }
    } else {
        Backend::Native
    };
    let mut coordinator = Coordinator::new(system, backend);
    coordinator.realtime = args.flag("realtime");
    coordinator.chunk_samples = args.get_parse("chunk", 64usize)?;
    // Realtime pacing wants per-window submission (a filling batch would
    // add whole-window latencies); explicit --batch overrides.
    let default_batch = if coordinator.realtime { 1 } else { coordinator.batch_windows };
    coordinator.batch_windows = args.get_parse("batch", default_batch)?.max(1);

    println!(
        "serving {} sessions ({} backend, {}, chunk {} samples, batch {} windows)…",
        streams.len(),
        if coordinator_is_pjrt(&coordinator) { "pjrt" } else { "native" },
        if coordinator.realtime { "realtime pacing" } else { "max speed" },
        coordinator.chunk_samples,
        coordinator.batch_windows
    );
    let report = coordinator.run(streams)?;

    for s in &report.sessions {
        let delay = s
            .eval
            .delay_s
            .map(|d| format!("{d:.2} s"))
            .unwrap_or_else(|| "—".into());
        println!(
            "session {} (patient {}): {} windows, {} alarms, detected={:?}, delay {}, FA {}",
            s.session_id,
            s.patient_id,
            s.windows,
            s.alarms.len(),
            s.eval.detected,
            delay,
            s.eval.false_alarms
        );
    }
    println!(
        "\ndetection: {}/{} seizures, mean delay {:.2} s",
        report.summary.detected,
        report.summary.seizures,
        report.summary.mean_delay_s()
    );
    println!("serving:   {}", report.metrics.summary());
    println!(
        "note: accelerator-model latency per window is {:.1} µs @10 MHz (Table I); the numbers\n\
         above are host-serving latencies of this coordinator, not the ASIC estimate.",
        crate::params::PREDICT_LATENCY_S * 1e6
    );
    Ok(())
}

fn coordinator_is_pjrt(c: &Coordinator) -> bool {
    matches!(c.backend, Backend::Pjrt { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthPatient};
    use crate::params::FRAMES_PER_PREDICTION;

    fn tiny_streams(n: usize) -> Vec<StreamSpec> {
        let synth = SynthConfig {
            records_per_patient: 2,
            pre_s: 4.0,
            ictal_s: 3.0,
            post_s: 1.0,
            ..Default::default()
        };
        (0..n)
            .map(|i| {
                let p = SynthPatient::generate(&synth, i as u32 + 1);
                let cfg = ClassifierConfig::optimized();
                let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
                let am = pipeline::train_on_record(&mut enc, &p.records[0], cfg.train_density);
                StreamSpec {
                    session_id: i as u64 + 1,
                    patient_id: i as u32 + 1,
                    record: p.records[1].clone(),
                    am,
                    threshold: cfg.temporal_threshold,
                }
            })
            .collect()
    }

    #[test]
    fn native_streaming_end_to_end() {
        let streams = tiny_streams(2);
        let expected_windows: u64 = streams
            .iter()
            .map(|s| (s.record.num_samples() / FRAMES_PER_PREDICTION) as u64)
            .sum();
        let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
        let report = coordinator.run(streams).unwrap();
        assert_eq!(report.metrics.windows_completed, expected_windows);
        assert_eq!(report.metrics.windows_failed, 0);
        assert_eq!(report.sessions.len(), 2);
        assert_eq!(report.summary.seizures, 2);
        // The synthetic seizures are strong; the native path must detect.
        assert!(report.summary.detected >= 1);
        for s in &report.sessions {
            assert!(s.windows > 0);
        }
    }

    #[test]
    fn native_matches_offline_pipeline() {
        // The streaming path must produce exactly the predictions the
        // offline pipeline produces for the same record + model.
        let streams = tiny_streams(1);
        let record = streams[0].record.clone();
        let am = streams[0].am.clone();
        let cfg = ClassifierConfig::optimized();

        let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
        let report = coordinator.run(streams).unwrap();

        let mut clf = crate::hdc::classifier::Classifier::new(
            Variant::Optimized,
            cfg,
            am,
        );
        let offline = pipeline::run_on_record(&mut clf, &record);
        let streamed = &report.sessions[0];
        assert_eq!(streamed.windows as usize, offline.len());
        // Re-evaluate: detection outcome must agree.
        let offline_eval = evaluate_record(
            &record,
            &offline,
            AlarmPolicy { consecutive: 1 },
            pipeline::DETECT_GRACE_S,
        );
        assert_eq!(streamed.eval.detected, offline_eval.detected);
        assert_eq!(streamed.eval.delay_s, offline_eval.delay_s);
    }

    #[test]
    fn batched_serving_bit_identical_to_unbatched() {
        // The N=1 degenerate-case guarantee, end to end: any batch size
        // yields exactly the same per-session outcome.
        let mut unbatched = Coordinator::new(SystemConfig::default(), Backend::Native);
        unbatched.batch_windows = 1;
        let r1 = unbatched.run(tiny_streams(2)).unwrap();
        let mut batched = Coordinator::new(SystemConfig::default(), Backend::Native);
        batched.batch_windows = 5;
        let r5 = batched.run(tiny_streams(2)).unwrap();

        assert_eq!(r1.metrics.windows_completed, r5.metrics.windows_completed);
        assert_eq!(r1.sessions.len(), r5.sessions.len());
        for (a, b) in r1.sessions.iter().zip(&r5.sessions) {
            assert_eq!(a.session_id, b.session_id);
            assert_eq!(a.windows, b.windows);
            assert_eq!(a.eval.detected, b.eval.detected);
            assert_eq!(a.eval.delay_s, b.eval.delay_s);
            assert_eq!(a.eval.false_alarms, b.eval.false_alarms);
            assert_eq!(a.alarms.len(), b.alarms.len());
            for (x, y) in a.alarms.iter().zip(&b.alarms) {
                assert_eq!(x.window_idx, y.window_idx);
            }
        }
    }

    /// Satellite contract for the default build: `Backend::Pjrt` must fail
    /// fast with a message that tells the operator exactly what to do,
    /// while `Backend::Native` (above) serves full synthetic records.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_without_feature_fails_actionably() {
        let streams = tiny_streams(1);
        let coordinator = Coordinator::new(
            SystemConfig::default(),
            Backend::Pjrt {
                artifacts_dir: "artifacts".into(),
            },
        );
        let err = match coordinator.run(streams) {
            Err(e) => e,
            Ok(_) => panic!("pjrt backend must not serve without the feature"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("--features pjrt"), "unactionable error: {msg}");
        assert!(msg.contains("native"), "should point at the fallback: {msg}");
    }
}
