//! The streaming orchestrator: sources → router/sessions → engine
//! workers → detector events, with backpressure and metrics.
//!
//! Two interchangeable window backends behind one [`EngineHost`]:
//! * **native** — the bit-accurate Rust golden model (no artifacts
//!   needed; the default build's serving path);
//! * **pjrt**  — the AOT-compiled HLO artifacts executed through the
//!   `xla` PJRT client (cargo feature `pjrt`), i.e. the full three-layer
//!   stack on the request path. Without the feature, selecting
//!   [`Backend::Pjrt`] fails fast with an actionable error.
//!
//! Both run on dedicated worker threads behind bounded queues, so a slow
//! engine stalls the sources (backpressure) instead of ballooning memory.
//!
//! Sessions submit **micro-batches** of `batch_windows` windows per
//! engine job (flushed at stream end), and the engine host coalesces
//! AM-sharing jobs further; predictions are bit-identical at every batch
//! size — batching changes only when work reaches the engine.
//!
//! ## Model lifecycle
//!
//! Streams carry [`ModelBundle`]s (not bare AMs): `repro serve` either
//! one-shot-trains them at startup, loads a saved bundle
//! (`--model <path>`), or **resumes** the highest persisted version from
//! a [`ModelStore`] (`--models-dir <dir>` / `[model] dir`). Every
//! deployed version is published into a [`ModelRegistry`] (and persisted
//! to the store when one is configured), and each session re-reads the
//! registry per micro-batch. Retraining is driven by the
//! [`RetrainScheduler`]: per-window outcomes feed a sliding false-alarm
//! estimator, and a crossed trigger launches an incremental retrain
//! (resumed from the bundle's counter planes) whose result is
//! persisted + published **mid-stream** without draining a single
//! queued job — a serve restart from the same `--models-dir` picks up
//! exactly where the last publish left off.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cli::Args;
use crate::config::{ConfigFile, SystemConfig};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::registry::{ModelRegistry, ModelStore};
use crate::coordinator::router::{Router, SampleChunk};
use crate::coordinator::scheduler::{RetrainPolicy, RetrainScheduler};
use crate::coordinator::session::Session;
use crate::data::metrics::{evaluate_record, window_label, AlarmPolicy, EvalSummary};
use crate::data::synth::Record;
use crate::ensure;
use crate::err;
use crate::error::Context;
use crate::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};
use crate::hdc::model::ModelBundle;
use crate::params::{CHANNELS, CLASS_ICTAL, CLASS_INTERICTAL, SAMPLE_RATE_HZ};
use crate::pipeline;
use crate::runtime::engine_pool::{Completion, EngineHost, EngineSpec, Job};
use crate::runtime::EngineKind;

/// Window-backend selection.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Golden-model engine ([`crate::runtime::native`]) on a worker thread.
    Native,
    /// PJRT-compiled artifact from this directory (`--features pjrt`).
    Pjrt { artifacts_dir: PathBuf },
}

/// Spawn the engine host for the selected backend. Shared with the wire
/// server ([`crate::coordinator::wire`]), which owns its host from a
/// dispatcher thread.
pub(crate) fn spawn_host(
    backend: &Backend,
    cfg: &ClassifierConfig,
    queue_depth: usize,
) -> crate::Result<EngineHost> {
    match backend {
        Backend::Native => EngineHost::spawn(
            EngineSpec::Native { cfg: cfg.clone() },
            EngineKind::SparseWindow,
            queue_depth,
        ),
        #[cfg(feature = "pjrt")]
        Backend::Pjrt { artifacts_dir } => EngineHost::spawn(
            EngineSpec::Pjrt {
                artifacts_dir: artifacts_dir.clone(),
            },
            EngineKind::SparseWindow,
            queue_depth,
        ),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt { artifacts_dir } => crate::bail!(
            "backend 'pjrt' (artifacts dir {}) is not compiled into this binary — \
             rebuild with `cargo build --features pjrt`, or use the native backend \
             (drop --use-pjrt / set runtime.use_pjrt = false)",
            artifacts_dir.display()
        ),
    }
}

/// One patient stream to serve: the model bundle to deploy (published
/// into the registry as this patient's initial version) plus the record
/// to replay.
pub struct StreamSpec {
    pub session_id: u64,
    pub patient_id: u32,
    pub record: Record,
    pub bundle: ModelBundle,
}

/// Per-session outcome of a serving run.
pub struct SessionReport {
    pub session_id: u64,
    pub patient_id: u32,
    pub windows: u64,
    /// Model version deployed when the stream ended.
    pub model_version: u64,
    /// Mid-stream model swaps the session picked up.
    pub model_swaps: u64,
    /// Windows predicted ictal outside the annotated seizure.
    pub false_positives: u64,
    pub alarms: Vec<crate::coordinator::detector::AlarmEvent>,
    /// Per-window predictions, in window order.
    pub predictions: Vec<crate::data::metrics::WindowPrediction>,
    pub eval: crate::data::metrics::RecordOutcome,
}

/// Full report of one serving run.
pub struct StreamReport {
    pub sessions: Vec<SessionReport>,
    pub metrics: ServingMetrics,
    pub summary: EvalSummary,
}

/// The coordinator: owns the router and the engine host.
pub struct Coordinator {
    system: SystemConfig,
    backend: Backend,
    /// Samples per source chunk (smaller → finer interleaving, more
    /// routing overhead).
    pub chunk_samples: usize,
    /// Pace sources at the iEEG sample rate (wall-clock realtime).
    pub realtime: bool,
    /// Windows per engine micro-batch (from `SystemConfig`; 1 submits
    /// every window immediately). Predictions are bit-identical at any
    /// value — batching only changes when work reaches the engine.
    pub batch_windows: usize,
    /// False-alarm-driven retrain scheduler. When set, every completed
    /// window's outcome (prediction vs the record annotation) is fed to
    /// it, and triggered retrains publish into the run's registry —
    /// sessions hot-swap the result at their next micro-batch. Sessions
    /// additionally retain completed windows' codes (bounded by
    /// `[model] feedback_window`) and hand each, with its ground truth,
    /// to the scheduler's feedback ring at outcome time.
    pub scheduler: Option<Arc<RetrainScheduler>>,
    /// Label-noise injector on the feedback path
    /// ([`crate::testkit::hostile`]): when set, the ground truth fed to
    /// the outcome stream and the feedback ring is flipped per the
    /// injector's seed-keyed coin — the annotation used for *scoring*
    /// ([`evaluate_record`]) is untouched. The chaos testkit's hook for
    /// "label noise below the policy floor never triggers".
    pub hostile_labels: Option<crate::testkit::hostile::HostileStream>,
}

impl Coordinator {
    pub fn new(system: SystemConfig, backend: Backend) -> Self {
        let batch_windows = system.batch_windows.max(1);
        Coordinator {
            system,
            backend,
            chunk_samples: 64,
            realtime: false,
            batch_windows,
            scheduler: None,
            hostile_labels: None,
        }
    }

    /// Serve a set of patient streams to completion and score the
    /// detections against the records' annotations. Stream bundles are
    /// published into a private registry; use [`Self::run_with_registry`]
    /// to share the registry with background publishers.
    pub fn run(&self, streams: Vec<StreamSpec>) -> crate::Result<StreamReport> {
        self.run_with_registry(streams, &ModelRegistry::new(), |_| {})
    }

    /// [`Self::run`] against a caller-owned [`ModelRegistry`]: each
    /// spec's bundle is seeded via [`ModelRegistry::ensure`] (a newer
    /// version already published wins), and sessions re-read the
    /// registry per micro-batch, so anything publishing into `registry`
    /// while this runs — a background retrain thread, or the `tick`
    /// hook — hot-swaps models at a batch boundary with zero queue
    /// drain.
    ///
    /// `tick(windows_submitted)` runs after every routed source chunk
    /// (deterministically interleaved with submissions — the tests pin
    /// swap boundaries through it).
    pub fn run_with_registry(
        &self,
        streams: Vec<StreamSpec>,
        registry: &ModelRegistry,
        mut tick: impl FnMut(u64),
    ) -> crate::Result<StreamReport> {
        ensure!(!streams.is_empty(), "no streams to serve");
        let mut metrics = ServingMetrics::new();
        let host = spawn_host(
            &self.backend,
            &self.system.classifier,
            self.system.queue_depth,
        )?;

        // Source cursors.
        struct Cursor {
            session_id: u64,
            pos: usize,
            len: usize,
        }

        // Build sessions + retain records for scoring/pacing.
        let mut router = Router::new();
        let mut records: std::collections::BTreeMap<u64, Record> = Default::default();
        let mut cursors: Vec<Cursor> = Vec::with_capacity(streams.len());
        for s in streams {
            // Sessions of one patient share the registry slot by design;
            // a *different* bundle at the same version would be silently
            // dropped by `ensure`, so reject the ambiguity instead of
            // serving the wrong model (compare two models by giving them
            // distinct versions, or serve them as distinct patient ids).
            if let Some(current) = registry.current(s.patient_id) {
                ensure!(
                    current.version() != s.bundle.version || current.bundle == s.bundle,
                    "patient {} already has a different model published at version {} — \
                     one model per (patient, version); bump the version or use distinct \
                     patient ids",
                    s.patient_id,
                    s.bundle.version
                );
            }
            let model = registry.ensure(s.patient_id, s.bundle);
            let mut session =
                Session::new(s.session_id, s.patient_id, model, self.system.alarm_consecutive);
            session.set_batch_windows(self.batch_windows);
            if self.scheduler.is_some() {
                session.set_feedback_window(self.system.feedback_window);
            }
            router.add_session(session);
            cursors.push(Cursor {
                session_id: s.session_id,
                pos: 0,
                len: s.record.num_samples(),
            });
            records.insert(s.session_id, s.record);
        }

        let t0 = Instant::now();
        let mut ready = Vec::new();
        let mut pending_jobs: Vec<Job> = Vec::new();
        let mut in_flight: u64 = 0;

        loop {
            let mut any_active = false;
            for cur in cursors.iter_mut() {
                if cur.pos >= cur.len {
                    continue;
                }
                any_active = true;
                let n = self.chunk_samples.min(cur.len - cur.pos);
                if self.realtime {
                    // Pace: this chunk's last sample becomes due at
                    // (pos + n) / fs seconds after stream start.
                    let due = (cur.pos + n) as f64 / SAMPLE_RATE_HZ;
                    let elapsed = t0.elapsed().as_secs_f64();
                    if due > elapsed {
                        std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                    }
                }
                let rec = &records[&cur.session_id];
                let chunk = SampleChunk {
                    session_id: cur.session_id,
                    samples: rec.samples[cur.pos * CHANNELS..(cur.pos + n) * CHANNELS].to_vec(),
                };
                cur.pos += n;
                metrics.samples_in += n as u64;
                metrics.frames_in += n as u64;
                ready.clear();
                router.route(&chunk, &mut ready)?;
                if cur.pos >= cur.len {
                    // Stream exhausted: flush the session's partial batch
                    // so the tail windows don't wait for a fill that
                    // never comes.
                    if let Some(b) = router
                        .session_mut(cur.session_id)
                        .and_then(|s| s.flush_batch())
                    {
                        ready.push(b);
                    }
                }
                for b in ready.drain(..) {
                    let session = router.session_mut(b.session_id).expect("routed");
                    // Pick up a hot-swapped model for this and later
                    // batches; jobs already in flight keep their own Arc.
                    // An encoder-incompatible publish fails the run loudly
                    // instead of scoring against the wrong item memory.
                    if session.refresh_model(registry)? {
                        metrics.model_swaps += 1;
                    }
                    let model = session.model();
                    pending_jobs.push(Job {
                        tag: b.session_id,
                        seq: b.seq0,
                        codes: b.codes,
                        am: model.plane(),
                        thresholds: vec![model.threshold() as i32; b.windows],
                        version: model.version(),
                        submitted: Instant::now(),
                    });
                }
                // Submit in arrival order, with backpressure accounting.
                for job in pending_jobs.drain(..) {
                    let windows = job.windows() as u64;
                    match host.try_submit(job) {
                        Ok(()) => {
                            metrics.windows_submitted += windows;
                            in_flight += 1;
                        }
                        Err(job) => {
                            metrics.backpressure_stalls += 1;
                            host.submit(job)?; // blocking
                            metrics.windows_submitted += windows;
                            in_flight += 1;
                        }
                    }
                }
                tick(metrics.windows_submitted);
                // Opportunistically drain completions.
                while let Ok(c) = host.completions.try_recv() {
                    in_flight -= 1;
                    self.finish(&mut router, &mut metrics, &records, c);
                }
            }
            if !any_active {
                break;
            }
        }

        // Drain the tail.
        while in_flight > 0 {
            let c = host
                .completions
                .recv()
                .map_err(|_| err!("engine worker dropped completions"))?;
            in_flight -= 1;
            self.finish(&mut router, &mut metrics, &records, c);
        }

        // Score each session against its record's annotation.
        let policy = AlarmPolicy {
            consecutive: self.system.alarm_consecutive,
        };
        let mut summary = EvalSummary::default();
        let mut sessions = Vec::new();
        for s in router.sessions() {
            let rec = &records[&s.id];
            let eval = evaluate_record(rec, &s.predictions, policy, pipeline::DETECT_GRACE_S);
            summary.add(&eval);
            sessions.push(SessionReport {
                session_id: s.id,
                patient_id: s.patient_id,
                windows: s.windows(),
                model_version: s.model().version(),
                model_swaps: s.model_swaps,
                false_positives: s.false_positives,
                alarms: s.detector.events.clone(),
                predictions: s.predictions.clone(),
                eval,
            });
        }
        // End-of-run plane-cache accounting: every published model in
        // this run shares the registry's cache, so its counters are the
        // run's model-memory story (hits vs misses vs eviction churn).
        metrics.record_plane_cache(registry.plane_cache().stats());
        Ok(StreamReport {
            sessions,
            metrics,
            summary,
        })
    }

    fn finish(
        &self,
        router: &mut Router,
        metrics: &mut ServingMetrics,
        records: &std::collections::BTreeMap<u64, Record>,
        c: Completion,
    ) {
        // Submit→complete latency of the whole job, recorded per window
        // (batched windows share one engine round-trip by design).
        let latency = c.latency_s();
        match c.outputs {
            Ok(outs) => {
                for (k, out) in outs.iter().enumerate() {
                    metrics.windows_completed += 1;
                    metrics.latency.record(latency);
                    let is_ictal = out.scores[CLASS_ICTAL] > out.scores[CLASS_INTERICTAL];
                    let margin = out.margin();
                    let seq = c.seq + k as u64;
                    if let Some(session) = router.session_mut(c.tag) {
                        if session.complete(seq, is_ictal, margin).is_some() {
                            metrics.alarms += 1;
                        }
                        // Ground-truth the prediction against the record
                        // annotation and feed the outcome stream: a
                        // false positive here is a false alarm to the
                        // retrain scheduler's sliding estimator.
                        let mut truth = records
                            .get(&c.tag)
                            .map(|r| window_label(r, seq as usize))
                            .unwrap_or(false);
                        if let Some(hostile) = &self.hostile_labels {
                            truth = hostile.corrupt_label(seq, truth);
                        }
                        let false_positive = is_ictal && !truth;
                        metrics.false_positives += false_positive as u64;
                        session.record_outcome(false_positive);
                        let patient_id = session.patient_id;
                        if let Some(scheduler) = &self.scheduler {
                            // Feedback before observe: a trigger at this
                            // very window already sees this window's
                            // labelled codes in the ring.
                            if let Some(codes) = session.take_feedback(seq) {
                                scheduler.record_feedback(patient_id, codes, truth);
                            }
                            if scheduler.observe(patient_id, false_positive) {
                                metrics.retrains_triggered += 1;
                            }
                        }
                    }
                }
            }
            Err(e) => {
                metrics.windows_failed += c.windows as u64;
                eprintln!(
                    "batch failed (session {}, seq {}, {} windows): {e:#}",
                    c.tag, c.seq, c.windows
                );
            }
        }
    }
}

/// One session's setup: load the patient, deploy the pre-resolved
/// bundle (store-recovered or `--model`-saved — no startup training) or
/// a fresh one-shot model trained on record 0, and keep only the record
/// to stream — returning the full record set from N parallel setups
/// would hold the whole cohort in memory at once (the serial loop
/// peaked at one patient). `keep_train` additionally retains record 0
/// for the retrain scheduler.
fn setup_session(
    data: &std::path::Path,
    pid: u32,
    record_idx: usize,
    cfg: &ClassifierConfig,
    keep_train: bool,
    deploy: Option<&ModelBundle>,
) -> crate::Result<(u32, Record, ModelBundle, Option<Record>)> {
    let mut records = crate::data::dataset::load_patient(data, pid)
        .with_context(|| format!("load patient {pid}"))?;
    ensure!(
        records.len() > record_idx,
        "patient {pid} has {} records, need index {record_idx}",
        records.len()
    );
    let bundle = match deploy {
        // Pre-resolved bundle: no startup retraining. Re-key the clone
        // to the served patient (a `--model` bundle fans out to every
        // patient on the list; store-recovered bundles already match).
        Some(bundle) => {
            let mut bundle = bundle.clone();
            bundle.provenance.patient_id = pid;
            bundle
        }
        None => {
            let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
            let mut bundle = pipeline::train_on_record(&mut enc, &records[0], cfg);
            bundle.provenance.patient_id = pid;
            bundle
        }
    };
    // Clone before the swap_remove: streaming record 0 itself must not
    // silently retrain on a different record.
    let train = if keep_train { Some(records[0].clone()) } else { None };
    let stream = records.swap_remove(record_idx);
    Ok((pid, stream, bundle, train))
}

/// Load a saved model bundle for serving: the bundle's own encoder
/// config replaces the system classifier config (engines must encode
/// with exactly what the model was trained against).
fn deploy_saved_bundle(path: &str, system: &mut SystemConfig) -> crate::Result<ModelBundle> {
    let bundle = ModelBundle::load(std::path::Path::new(path))?;
    ensure!(
        bundle.variant == Variant::Optimized,
        "serve deploys the sparse-optimized design point, bundle is {}",
        bundle.variant.name()
    );
    if system.classifier != bundle.config {
        println!(
            "using the bundle's encoder config (seed {:#x}, temporal threshold {}) \
             over the system config",
            bundle.config.seed, bundle.config.temporal_threshold
        );
    }
    system.classifier = bundle.config.clone();
    Ok(bundle)
}

/// Dial a running wire server, send a `Status` query and print the
/// `StatusReport` as scrapeable `status:` lines (`serve --status ADDR`;
/// CI and `repro loadgen` grep these).
fn print_status(addr: &str) -> crate::Result<()> {
    let conn =
        crate::transport::tcp::TcpTransport::connect(addr, Some(Duration::from_secs(5)))?;
    let report = crate::transport::client::query_status(
        conn,
        &crate::transport::client::StreamClientConfig::default(),
    )?;
    println!(
        "status: plane cache hits={} misses={} evictions={} redecodes={}",
        report.cache_hits, report.cache_misses, report.cache_evictions, report.cache_redecodes
    );
    let (mut retrains, mut triggers) = (0u64, 0u64);
    for p in &report.patients {
        retrains += p.retrains as u64;
        triggers += p.triggers as u64;
        println!(
            "status: patient {} fa={}/{} retrains={} triggers={} feedback={}",
            p.patient, p.fa_hits, p.fa_seen, p.retrains, p.triggers, p.feedback_depth
        );
    }
    println!(
        "status: total retrains={retrains} triggers={triggers} patients={}",
        report.patients.len()
    );
    Ok(())
}

/// `repro serve --data DIR [--patients LIST] [--model FILE]
/// [--models-dir DIR] [--retrain-epochs N] [--retrain-fa-rate R]
/// [--feedback-window N] [--use-pjrt] [--realtime] [--config FILE]
/// [--record K] [--listen ADDR] [--shard-of K/N] | serve --status ADDR`
pub fn serve_command(args: &Args) -> crate::Result<()> {
    args.check_known(&[
        "data",
        "patients",
        "use-pjrt",
        "realtime",
        "config",
        "record",
        "artifacts",
        "chunk",
        "batch",
        "model",
        "models-dir",
        "retrain-epochs",
        "retrain-fa-rate",
        "feedback-window",
        "cache-planes",
        "max-model-versions",
        "listen",
        "shard-of",
        "kernels",
        "status",
    ])?;
    // Telemetry query mode: scrape a running server and exit.
    if let Some(addr) = args.get("status") {
        return print_status(addr);
    }
    let data = PathBuf::from(args.require("data")?);
    let mut system = match args.get("config") {
        Some(path) => SystemConfig::from_file(&ConfigFile::load(std::path::Path::new(path))?)?,
        None => SystemConfig::default(),
    };
    system.classifier.spatial_threshold = 1;
    if args.flag("use-pjrt") {
        system.use_pjrt = true;
    }
    // Pin the SIMD kernel set before any encode/score work touches it:
    // CLI `--kernels` wins over `[runtime] kernels`; with neither, the
    // first kernel call resolves HDC_KERNELS / auto-detection lazily.
    let kernels_choice = args
        .get("kernels")
        .map(str::to_string)
        .or_else(|| system.kernels.clone());
    if let Some(name) = &kernels_choice {
        crate::hdc::simd::select(name)?;
    }
    println!("kernels: {}", crate::hdc::simd::active().name);
    let artifacts = args.get_str("artifacts", &system.artifacts_dir);
    let record_idx: usize = args.get_parse("record", 1usize)?;
    let retrain_epochs: usize = args.get_parse("retrain-epochs", system.retrain_epochs)?;
    let retrain_fa_rate: f64 = args.get_parse("retrain-fa-rate", system.retrain_fa_rate)?;
    // Feedback capture budget: labelled serving windows retained per
    // patient; a triggered retrain prefers a full ring over the record.
    system.feedback_window = args.get_parse("feedback-window", system.feedback_window)?;
    // Model-memory knobs: a plane budget bounds decoded associative
    // memories resident at once (0 = unbounded), and a version budget
    // garbage-collects stale bundle files at publish time (0 = keep all).
    let cache_planes: usize = args.get_parse("cache-planes", system.cache_planes)?;
    let max_model_versions: usize =
        args.get_parse("max-model-versions", system.max_versions_per_patient)?;

    // Durable model store: `--models-dir` / `[model] dir`. Opening scans
    // the tree once — the recovered bundles (highest valid version per
    // patient) replace startup training, so a serve restart resumes
    // exactly where the last persisted publish left off.
    let models_dir = args
        .get("models-dir")
        .map(str::to_string)
        .or_else(|| system.model_dir.clone());
    let store: Option<Arc<ModelStore>> = match &models_dir {
        Some(dir) => Some(Arc::new(ModelStore::open(dir)?)),
        None => None,
    };
    let mut recovered = std::collections::BTreeMap::new();
    if let Some(store) = &store {
        let scan = store.scan()?;
        for path in &scan.quarantined {
            eprintln!("model store: quarantined corrupt bundle {}", path.display());
        }
        recovered = scan.recovered;
    }

    let patient_ids: Vec<u32> = {
        let list = args.get_list("patients");
        if list.is_empty() {
            vec![1, 2, 3, 4]
        } else {
            list.iter()
                .map(|s| s.parse::<u32>())
                .collect::<Result<_, _>>()?
        }
    };

    // The model per patient, by precedence: a version recovered from the
    // model store (`--models-dir`, resuming the last persisted publish),
    // else one saved bundle for every served patient (`--model`), else
    // one-shot training. Setup is embarrassingly parallel (each patient
    // loads + trains independently); the evalpool keeps session ids in
    // patient-list order. A failure flag restores fail-fast: workers
    // skip launching new load+train passes (returning `None`) once any
    // setup errors, and the drain below surfaces the first *real* error
    // — a worker that races the flag leaves only a skipped slot, never a
    // masking placeholder.
    let model_path = args
        .get("model")
        .map(str::to_string)
        .or_else(|| system.model_path.clone());
    let saved_bundle = match &model_path {
        Some(path) => {
            let bundle = deploy_saved_bundle(path, &mut system)?;
            println!("loaded model bundle from {path}:\n{}", bundle.describe());
            Some(bundle)
        }
        None => None,
    };
    // Recovered bundles must match the encoder identity serving deploys
    // (the engine's item memory is fixed at spawn) — a store written
    // under a different seed/config must not silently score garbage.
    // Only the patients this run serves are held to that: other
    // patients' bundles in the store are simply left alone.
    for (pid, bundle) in recovered.iter().filter(|(pid, _)| patient_ids.contains(*pid)) {
        ensure!(
            bundle.variant == system.variant
                && bundle.config.seed == system.classifier.seed
                && bundle.config.spatial_threshold == system.classifier.spatial_threshold,
            "model store {}: patient {pid}'s recovered v{} was trained under a \
             different encoder ({}, seed {:#x}, spatial {}) than this serve deploys \
             ({}, seed {:#x}, spatial {}) — serve with the matching config or point \
             --models-dir at a fresh directory",
            models_dir.as_deref().unwrap_or("?"),
            bundle.version,
            bundle.variant.name(),
            bundle.config.seed,
            bundle.config.spatial_threshold,
            system.variant.name(),
            system.classifier.seed,
            system.classifier.spatial_threshold
        );
    }
    let classifier_cfg = &system.classifier;
    let keep_train = retrain_epochs > 0;
    let saved_ref = &saved_bundle;
    let recovered_ref = &recovered;
    let failed = std::sync::atomic::AtomicBool::new(false);
    let specs = crate::evalpool::map(&patient_ids, |&pid| {
        if failed.load(std::sync::atomic::Ordering::Relaxed) {
            return None;
        }
        let spec = setup_session(
            &data,
            pid,
            record_idx,
            classifier_cfg,
            keep_train,
            recovered_ref.get(&pid).or(saved_ref.as_ref()),
        );
        if spec.is_err() {
            failed.store(true, std::sync::atomic::Ordering::Relaxed);
        }
        Some(spec)
    });

    let registry = Arc::new(ModelRegistry::with_cache_planes(cache_planes));
    if cache_planes > 0 {
        println!("plane cache: budget {cache_planes} decoded plane(s), LRU eviction");
    }
    let mut streams = Vec::new();
    let mut train_records: std::collections::BTreeMap<u32, Record> = Default::default();
    for (i, spec) in specs.into_iter().enumerate() {
        let (pid, record, bundle, train) = match spec {
            Some(spec) => spec?,
            // Skipped after another slot's failure; that slot holds the
            // real error and the loop returns it when it gets there.
            None => continue,
        };
        let resumed = recovered.contains_key(&pid);
        let source = if resumed {
            format!(
                " [resumed model v{} from {}]",
                bundle.version,
                models_dir.as_deref().unwrap_or("store")
            )
        } else if model_path.is_some() {
            " [saved bundle — no startup retraining]".to_string()
        } else {
            String::new()
        };
        println!(
            "patient {pid}: model v{} (class densities {:.1}% / {:.1}%), streaming record {record_idx}{}",
            bundle.version,
            bundle.am.classes[0].density() * 100.0,
            bundle.am.classes[1].density() * 100.0,
            source
        );
        // Persist-then-publish: the deployed version is on disk before
        // it can serve, so a restart always finds it. Recovered bundles
        // are already the store's newest — no rewrite.
        if let (Some(store), false) = (&store, resumed) {
            store.save(&bundle)?;
        }
        // Store GC at publish time: versions past the per-patient budget
        // are renamed aside (never the deployed, newest, or lineage
        // versions — prune keeps those unconditionally).
        if let (Some(store), true) = (&store, max_model_versions > 0) {
            for p in store.prune(pid, max_model_versions, &[bundle.version])? {
                println!("model store: pruned stale bundle {}", p.display());
            }
        }
        // Publish the startup version *before* any retrain can publish
        // its successor, so version monotonicity holds per patient.
        registry.ensure(pid, bundle.clone());
        if let Some(train) = train {
            train_records.insert(pid, train);
        }
        streams.push(StreamSpec {
            session_id: i as u64 + 1,
            patient_id: pid,
            record,
            bundle,
        });
    }

    // False-alarm-driven retraining: sessions feed per-window outcomes
    // into the scheduler's sliding estimator, and a crossed trigger
    // launches a background incremental retrain — from a full feedback
    // ring of labelled serving windows when one exists, else resumed
    // from the bundle's counter planes against the retained record —
    // that persists + publishes v+1 mid-stream through the hot-swap
    // path. Built before the wire/in-process fork: both serving planes
    // drive the same scheduler.
    let scheduler = if retrain_epochs > 0 {
        Some(Arc::new(
            RetrainScheduler::new(
                RetrainPolicy {
                    epochs: retrain_epochs,
                    fa_window: system.retrain_fa_window,
                    fa_rate: retrain_fa_rate,
                    cooldown: system.retrain_cooldown,
                    max_retrains: system.retrain_max,
                },
                registry.clone(),
                store.clone(),
                train_records,
            )
            .with_max_versions(max_model_versions)
            .with_feedback_window(system.feedback_window),
        ))
    } else {
        None
    };

    // Wire mode: `--listen ADDR` (or `[server] listen`) serves the
    // published models over framed TCP instead of replaying the local
    // records in-process. Setup above is identical — same training /
    // store recovery / registry publish — so a wire client streaming a
    // record sees window-for-window the same predictions the in-process
    // replay would produce. With `--retrain-epochs`, the dispatcher
    // ground-truths completions against the served record's annotation
    // (clients are expected to stream that record, possibly corrupted),
    // feeds the scheduler, and answers `Status` telemetry queries.
    let listen = args
        .get("listen")
        .map(str::to_string)
        .or_else(|| system.listen.clone());
    if let Some(addr) = listen {
        let backend = if system.use_pjrt {
            Backend::Pjrt {
                artifacts_dir: PathBuf::from(&artifacts),
            }
        } else {
            Backend::Native
        };
        let mut wire_cfg = crate::coordinator::wire::WireConfig::from_system(&system);
        wire_cfg.batch_windows = args.get_parse("batch", wire_cfg.batch_windows)?.max(1);
        // `--shard-of K/N` pins this server's shard identity for the
        // fleet dispatcher's ShardHello handshake. It deliberately does
        // NOT filter the served patients: every shard publishes every
        // patient's model, which is what lets a dead shard's patients
        // re-lease to any survivor and resume from the shared store.
        if let Some(spec) = args.get("shard-of") {
            let (slot, count) = crate::coordinator::fleet::parse_shard_of(spec)?;
            wire_cfg.shard = Some(slot);
            println!("shard: slot {slot} of {count}");
        }
        let retrain_ctx = scheduler.clone().map(|scheduler| {
            Arc::new(crate::coordinator::wire::RetrainContext {
                scheduler,
                records: streams
                    .iter()
                    .map(|s| (s.patient_id, s.record.clone()))
                    .collect(),
            })
        });
        let transport = crate::transport::tcp::TcpTransport::bind(&addr)?;
        let server = crate::coordinator::wire::WireServer::start_with_retrain(
            Box::new(transport),
            &backend,
            &system,
            registry,
            wire_cfg,
            retrain_ctx,
        )?;
        // CI greps a redirected log for this line before pointing the
        // load generator at the port — flush past the block buffering.
        println!("listening on {}", server.local_addr());
        use std::io::Write as _;
        std::io::stdout().flush()?;
        return server.run();
    }

    let backend = if system.use_pjrt {
        Backend::Pjrt {
            artifacts_dir: PathBuf::from(artifacts),
        }
    } else {
        Backend::Native
    };
    let mut coordinator = Coordinator::new(system, backend);
    coordinator.realtime = args.flag("realtime");
    coordinator.chunk_samples = args.get_parse("chunk", 64usize)?;
    coordinator.scheduler = scheduler.clone();
    // Realtime pacing wants per-window submission (a filling batch would
    // add whole-window latencies); explicit --batch overrides.
    let default_batch = if coordinator.realtime { 1 } else { coordinator.batch_windows };
    coordinator.batch_windows = args.get_parse("batch", default_batch)?.max(1);

    println!(
        "serving {} sessions ({} backend, {}, chunk {} samples, batch {} windows{})…",
        streams.len(),
        if coordinator_is_pjrt(&coordinator) { "pjrt" } else { "native" },
        if coordinator.realtime { "realtime pacing" } else { "max speed" },
        coordinator.chunk_samples,
        coordinator.batch_windows,
        match &scheduler {
            Some(s) => format!(
                ", retrain on FA rate >= {:.2} over {} windows (x{} epochs)",
                s.policy().fa_rate,
                s.policy().fa_window,
                s.policy().epochs
            ),
            None => String::new(),
        }
    );
    let report = coordinator.run_with_registry(streams, &registry, |_| {})?;

    if let Some(scheduler) = &scheduler {
        for msg in scheduler.join() {
            println!("{msg}");
        }
    }

    for s in &report.sessions {
        let delay = s
            .eval
            .delay_s
            .map(|d| format!("{d:.2} s"))
            .unwrap_or_else(|| "—".into());
        println!(
            "session {} (patient {}, model v{}, {} swaps): {} windows, {} alarms, \
             {} FPs, detected={:?}, delay {}, FA {}",
            s.session_id,
            s.patient_id,
            s.model_version,
            s.model_swaps,
            s.windows,
            s.alarms.len(),
            s.false_positives,
            s.eval.detected,
            delay,
            s.eval.false_alarms
        );
    }
    println!(
        "\ndetection: {}/{} seizures, mean delay {:.2} s",
        report.summary.detected,
        report.summary.seizures,
        report.summary.mean_delay_s()
    );
    println!("serving:   {}", report.metrics.summary());
    println!(
        "note: accelerator-model latency per window is {:.1} µs @10 MHz (Table I); the numbers\n\
         above are host-serving latencies of this coordinator, not the ASIC estimate.",
        crate::params::PREDICT_LATENCY_S * 1e6
    );
    Ok(())
}

fn coordinator_is_pjrt(c: &Coordinator) -> bool {
    matches!(c.backend, Backend::Pjrt { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthPatient};
    use crate::params::FRAMES_PER_PREDICTION;

    fn tiny_streams(n: usize) -> Vec<StreamSpec> {
        let synth = SynthConfig {
            records_per_patient: 2,
            pre_s: 4.0,
            ictal_s: 3.0,
            post_s: 1.0,
            ..Default::default()
        };
        (0..n)
            .map(|i| {
                let p = SynthPatient::generate(&synth, i as u32 + 1);
                let cfg = ClassifierConfig::optimized();
                let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
                let bundle = pipeline::train_on_record(&mut enc, &p.records[0], &cfg);
                StreamSpec {
                    session_id: i as u64 + 1,
                    patient_id: i as u32 + 1,
                    record: p.records[1].clone(),
                    bundle,
                }
            })
            .collect()
    }

    #[test]
    fn native_streaming_end_to_end() {
        let streams = tiny_streams(2);
        let expected_windows: u64 = streams
            .iter()
            .map(|s| (s.record.num_samples() / FRAMES_PER_PREDICTION) as u64)
            .sum();
        let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
        let report = coordinator.run(streams).unwrap();
        assert_eq!(report.metrics.windows_completed, expected_windows);
        assert_eq!(report.metrics.windows_failed, 0);
        assert_eq!(report.metrics.model_swaps, 0, "nothing published mid-run");
        assert_eq!(report.sessions.len(), 2);
        assert_eq!(report.summary.seizures, 2);
        // The synthetic seizures are strong; the native path must detect.
        assert!(report.summary.detected >= 1);
        for s in &report.sessions {
            assert!(s.windows > 0);
            assert_eq!(s.model_version, 1);
            assert_eq!(s.model_swaps, 0);
            assert_eq!(s.predictions.len(), s.windows as usize);
        }
    }

    #[test]
    fn native_matches_offline_pipeline() {
        // The streaming path must produce exactly the predictions the
        // offline pipeline produces for the same record + model.
        let streams = tiny_streams(1);
        let record = streams[0].record.clone();
        let am = streams[0].bundle.am.clone();
        let cfg = ClassifierConfig::optimized();

        let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
        let report = coordinator.run(streams).unwrap();

        let mut clf = crate::hdc::classifier::Classifier::new(Variant::Optimized, cfg, am);
        let offline = pipeline::run_on_record(&mut clf, &record);
        let streamed = &report.sessions[0];
        assert_eq!(streamed.windows as usize, offline.len());
        // Re-evaluate: detection outcome must agree.
        let offline_eval = evaluate_record(
            &record,
            &offline,
            AlarmPolicy { consecutive: 1 },
            pipeline::DETECT_GRACE_S,
        );
        assert_eq!(streamed.eval.detected, offline_eval.detected);
        assert_eq!(streamed.eval.delay_s, offline_eval.delay_s);
    }

    #[test]
    fn batched_serving_bit_identical_to_unbatched() {
        // The N=1 degenerate-case guarantee, end to end: any batch size
        // yields exactly the same per-session outcome.
        let mut unbatched = Coordinator::new(SystemConfig::default(), Backend::Native);
        unbatched.batch_windows = 1;
        let r1 = unbatched.run(tiny_streams(2)).unwrap();
        let mut batched = Coordinator::new(SystemConfig::default(), Backend::Native);
        batched.batch_windows = 5;
        let r5 = batched.run(tiny_streams(2)).unwrap();

        assert_eq!(r1.metrics.windows_completed, r5.metrics.windows_completed);
        assert_eq!(r1.sessions.len(), r5.sessions.len());
        for (a, b) in r1.sessions.iter().zip(&r5.sessions) {
            assert_eq!(a.session_id, b.session_id);
            assert_eq!(a.windows, b.windows);
            assert_eq!(a.eval.detected, b.eval.detected);
            assert_eq!(a.eval.delay_s, b.eval.delay_s);
            assert_eq!(a.eval.false_alarms, b.eval.false_alarms);
            assert_eq!(a.alarms.len(), b.alarms.len());
            for (x, y) in a.alarms.iter().zip(&b.alarms) {
                assert_eq!(x.window_idx, y.window_idx);
            }
        }
    }

    /// End-to-end scheduler integration: a zero-rate policy fires exactly
    /// once, at the deterministic window where the estimator fills, and
    /// the (foreground) retrain persists nothing but publishes v2 into
    /// the run's registry mid-stream.
    #[test]
    fn scheduler_retrains_and_publishes_midstream() {
        use crate::coordinator::scheduler::{RetrainPolicy, RetrainScheduler};

        let synth = SynthConfig {
            records_per_patient: 2,
            pre_s: 8.0,
            ictal_s: 4.0,
            post_s: 2.0,
            ..Default::default()
        };
        let p = SynthPatient::generate(&synth, 5);
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let mut bundle = pipeline::train_on_record(&mut enc, &p.records[0], &cfg);
        bundle.provenance.patient_id = 5;

        let registry = Arc::new(ModelRegistry::new());
        let mut train = std::collections::BTreeMap::new();
        train.insert(5, p.records[0].clone());
        let scheduler = Arc::new(
            RetrainScheduler::new(
                RetrainPolicy {
                    epochs: 2,
                    fa_window: 4,
                    fa_rate: 0.0,
                    cooldown: 10_000,
                    max_retrains: 1,
                },
                registry.clone(),
                None,
                train,
            )
            .foreground(),
        );
        let mut coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
        coordinator.scheduler = Some(scheduler.clone());
        let report = coordinator
            .run_with_registry(
                vec![StreamSpec {
                    session_id: 1,
                    patient_id: 5,
                    record: p.records[1].clone(),
                    bundle,
                }],
                &registry,
                |_| {},
            )
            .unwrap();

        // The trigger index is a pure function of the outcome stream:
        // window 4 fills the estimator, rate 0.0 >= 0.0 fires, once.
        assert_eq!(scheduler.triggers(), vec![(5, 4)]);
        assert_eq!(report.metrics.retrains_triggered, 1);
        assert_eq!(registry.current(5).unwrap().version(), 2);
        let msgs = scheduler.join();
        assert_eq!(msgs.len(), 1);
        assert!(msgs[0].contains("published model v2"), "{}", msgs[0]);
        // Which batch first serves v2 depends on completion timing (the
        // engine runs on its own thread) — but the stream must end on a
        // version the registry actually published.
        assert!(report.sessions[0].model_version <= 2);
    }

    /// Closing the feedback loop: with `[model] feedback_window` set, the
    /// session's labelled serving windows reach the scheduler's ring, and
    /// a trigger whose ring is full retrains from feedback — not from the
    /// retained record. The publish message names its material.
    #[test]
    fn scheduler_prefers_full_feedback_ring() {
        use crate::coordinator::scheduler::{RetrainPolicy, RetrainScheduler};

        let synth = SynthConfig {
            records_per_patient: 2,
            pre_s: 8.0,
            ictal_s: 4.0,
            post_s: 2.0,
            ..Default::default()
        };
        let p = SynthPatient::generate(&synth, 6);
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let mut bundle = pipeline::train_on_record(&mut enc, &p.records[0], &cfg);
        bundle.provenance.patient_id = 6;

        let registry = Arc::new(ModelRegistry::new());
        let mut train = std::collections::BTreeMap::new();
        train.insert(6, p.records[0].clone());
        let scheduler = Arc::new(
            RetrainScheduler::new(
                RetrainPolicy {
                    epochs: 2,
                    fa_window: 4,
                    fa_rate: 0.0,
                    cooldown: 10_000,
                    max_retrains: 1,
                },
                registry.clone(),
                None,
                train,
            )
            .with_feedback_window(4)
            .foreground(),
        );
        let mut system = SystemConfig::default();
        system.feedback_window = 4;
        let mut coordinator = Coordinator::new(system, Backend::Native);
        coordinator.scheduler = Some(scheduler.clone());
        coordinator
            .run_with_registry(
                vec![StreamSpec {
                    session_id: 1,
                    patient_id: 6,
                    record: p.records[1].clone(),
                    bundle,
                }],
                &registry,
                |_| {},
            )
            .unwrap();

        // Window 4's feedback lands in the ring *before* its outcome is
        // observed, so the ring is full (4/4) at the trigger.
        assert_eq!(scheduler.triggers(), vec![(6, 4)]);
        assert_eq!(registry.current(6).unwrap().version(), 2);
        let msgs = scheduler.join();
        assert_eq!(msgs.len(), 1);
        assert!(
            msgs[0].contains("from 4 feedback window(s)"),
            "retrain material should be the feedback ring: {}",
            msgs[0]
        );
    }

    /// Satellite contract for the default build: `Backend::Pjrt` must fail
    /// fast with a message that tells the operator exactly what to do,
    /// while `Backend::Native` (above) serves full synthetic records.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_without_feature_fails_actionably() {
        let streams = tiny_streams(1);
        let coordinator = Coordinator::new(
            SystemConfig::default(),
            Backend::Pjrt {
                artifacts_dir: "artifacts".into(),
            },
        );
        let err = match coordinator.run(streams) {
            Err(e) => e,
            Ok(_) => panic!("pjrt backend must not serve without the feature"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("--features pjrt"), "unactionable error: {msg}");
        assert!(msg.contains("native"), "should point at the fallback: {msg}");
    }
}
