//! Per-patient session state: LBP front-end → frame assembly → window
//! batching, plus the deployed model (a registry-swappable
//! [`PublishedModel`]) and detector.
//!
//! Sessions emit [`ReadyBatch`]es: up to `batch_windows` consecutive
//! prediction windows coalesced into one engine submission (micro-batch).
//! The default batch size is 1, so the unbatched behaviour is the N=1
//! degenerate case of the same path.
//!
//! The model is *not* baked into the session: the server refreshes it
//! from the [`ModelRegistry`] at job-creation time
//! ([`Session::refresh_model`]), so a background retrain that publishes
//! a new version takes effect from the next micro-batch — no queue
//! drain, no session restart; jobs already in flight keep the old
//! version's `Arc<AmPlane>`.

use std::sync::Arc;

use crate::coordinator::detector::Detector;
use crate::coordinator::registry::{ModelRegistry, PublishedModel};
use crate::data::metrics::WindowPrediction;
use crate::lbp::LbpFrontend;
use crate::params::{CHANNELS, FRAMES_PER_PREDICTION};

/// A batch of consecutive fully-assembled prediction windows ready for an
/// engine.
pub struct ReadyBatch {
    pub session_id: u64,
    /// Sequence number of the batch's first window.
    pub seq0: u64,
    /// Windows in the batch.
    pub windows: usize,
    /// Frame-major codes, `windows * FRAMES_PER_PREDICTION * CHANNELS`.
    pub codes: Vec<u8>,
}

/// Per-patient streaming session.
pub struct Session {
    pub id: u64,
    pub patient_id: u32,
    lbp: LbpFrontend,
    window: Vec<u8>,
    frames_in_window: usize,
    next_seq: u64,
    /// Windows per emitted batch (1 = emit every window immediately).
    batch_windows: usize,
    /// Completed windows waiting for the batch to fill.
    batch: Vec<u8>,
    batch_seq0: u64,
    batch_count: usize,
    /// Model currently deployed on this session (AM plane + threshold +
    /// version). Swapped in-place by [`Self::refresh_model`]; shared with
    /// every job this session submits.
    model: Arc<PublishedModel>,
    /// Mid-stream model swaps this session has picked up.
    pub model_swaps: u64,
    /// Windows predicted ictal outside the annotated seizure — the
    /// session's share of the false-alarm-rate signal the retrain
    /// scheduler watches (fed by the server, which holds the annotation).
    pub false_positives: u64,
    pub detector: Detector,
    /// Collected predictions (for offline scoring after the stream ends).
    pub predictions: Vec<WindowPrediction>,
    /// Feedback capture budget (`[model] feedback_window`): how many
    /// completed serving windows' codes are retained while they await
    /// their ground-truth label. 0 disables capture.
    feedback_window: usize,
    /// Completed windows awaiting their outcome, oldest first:
    /// `(window seq, FRAMES_PER_PREDICTION * CHANNELS codes)`.
    pending_feedback: std::collections::VecDeque<(u64, Vec<u8>)>,
}

impl Session {
    pub fn new(id: u64, patient_id: u32, model: Arc<PublishedModel>, consecutive: usize) -> Self {
        Session {
            id,
            patient_id,
            lbp: LbpFrontend::new(),
            window: Vec::with_capacity(FRAMES_PER_PREDICTION * CHANNELS),
            frames_in_window: 0,
            next_seq: 0,
            batch_windows: 1,
            batch: Vec::new(),
            batch_seq0: 0,
            batch_count: 0,
            model,
            model_swaps: 0,
            false_positives: 0,
            detector: Detector::new(consecutive),
            predictions: Vec::new(),
            feedback_window: 0,
            pending_feedback: std::collections::VecDeque::new(),
        }
    }

    /// The deployed model (current version).
    pub fn model(&self) -> &Arc<PublishedModel> {
        &self.model
    }

    /// Pick up the registry's current model for this patient if it is a
    /// different published instance. Returns `Ok(true)` on a swap. Takes
    /// effect for batches submitted *after* the call — in-flight jobs
    /// keep their own `Arc` to the old plane.
    ///
    /// A published model trained under a different *encoder identity*
    /// (variant, IM seed, spatial threshold) than the deployed one is
    /// refused with an error: the serving engine's encoder is fixed at
    /// spawn, so swapping in such a model would silently score windows
    /// encoded with the wrong item memory. (The temporal threshold rides
    /// on every job, so it may change freely across versions.)
    pub fn refresh_model(&mut self, registry: &ModelRegistry) -> crate::Result<bool> {
        let Some(current) = registry.current(self.patient_id) else {
            return Ok(false);
        };
        if Arc::ptr_eq(&current, &self.model) {
            return Ok(false);
        }
        let old = &self.model.bundle;
        let new = &current.bundle;
        crate::ensure!(
            new.variant == old.variant
                && new.config.seed == old.config.seed
                && new.config.spatial_threshold == old.config.spatial_threshold,
            "session {}: published model v{} ({}, seed {:#x}, spatial {}) does not match \
             the deployed encoder ({}, seed {:#x}, spatial {}) — refusing the hot swap",
            self.id,
            new.version,
            new.variant.name(),
            new.config.seed,
            new.config.spatial_threshold,
            old.variant.name(),
            old.config.seed,
            old.config.spatial_threshold
        );
        self.model = current;
        self.model_swaps += 1;
        Ok(true)
    }

    /// Set the micro-batch size (clamped to ≥ 1). Takes effect from the
    /// next completed window.
    pub fn set_batch_windows(&mut self, windows: usize) {
        self.batch_windows = windows.max(1);
    }

    /// Feed one multichannel sample; returns a batch when `batch_windows`
    /// windows of 256 frames each have been assembled.
    pub fn push_sample(&mut self, sample: &[f32; CHANNELS]) -> Option<ReadyBatch> {
        let codes = self.lbp.push(sample);
        self.window.extend_from_slice(&codes);
        self.frames_in_window += 1;
        if self.frames_in_window < FRAMES_PER_PREDICTION {
            return None;
        }
        // Window complete: append it to the pending batch.
        if self.batch_count == 0 {
            self.batch_seq0 = self.next_seq;
        }
        self.batch.extend_from_slice(&self.window);
        // Retain the window for the feedback loop until its outcome is
        // ground-truthed (bounded: oldest unlabelled window falls off).
        if self.feedback_window > 0 {
            if self.pending_feedback.len() >= self.feedback_window {
                self.pending_feedback.pop_front();
            }
            self.pending_feedback
                .push_back((self.next_seq, self.window.clone()));
        }
        self.window.clear();
        self.frames_in_window = 0;
        self.next_seq += 1;
        self.batch_count += 1;
        if self.batch_count >= self.batch_windows {
            self.flush_batch()
        } else {
            None
        }
    }

    /// Feed a contiguous time-major sample run (`samples.len()` must be
    /// a whole number of `CHANNELS`-channel frames), appending completed
    /// micro-batches to `out` — the chunk-level entry point shared by
    /// the in-process [`crate::coordinator::router::Router`] and the
    /// wire connection actors, so both paths window identically by
    /// construction.
    pub fn push_samples(&mut self, samples: &[f32], out: &mut Vec<ReadyBatch>) -> crate::Result<()> {
        crate::ensure!(
            samples.len() % CHANNELS == 0,
            "sample run of {} f32s is not a whole number of {CHANNELS}-channel frames",
            samples.len()
        );
        let mut sample = [0f32; CHANNELS];
        for frame in samples.chunks_exact(CHANNELS) {
            sample.copy_from_slice(frame);
            if let Some(b) = self.push_sample(&sample) {
                out.push(b);
            }
        }
        Ok(())
    }

    /// Emit the pending (possibly partial) batch, if any — called at
    /// stream end so no completed window waits forever for the batch to
    /// fill.
    pub fn flush_batch(&mut self) -> Option<ReadyBatch> {
        if self.batch_count == 0 {
            return None;
        }
        let codes = std::mem::replace(
            &mut self.batch,
            Vec::with_capacity(self.batch_windows * FRAMES_PER_PREDICTION * CHANNELS),
        );
        let batch = ReadyBatch {
            session_id: self.id,
            seq0: self.batch_seq0,
            windows: self.batch_count,
            codes,
        };
        self.batch_count = 0;
        Some(batch)
    }

    /// Record a completed prediction and run the detector.
    /// Returns `Some(event)` when an alarm fires.
    pub fn complete(
        &mut self,
        seq: u64,
        is_ictal: bool,
        margin: i64,
    ) -> Option<crate::coordinator::detector::AlarmEvent> {
        self.predictions.push(WindowPrediction {
            idx: seq as usize,
            is_ictal,
            margin,
        });
        self.detector.push(seq, is_ictal, margin)
    }

    /// Record one ground-truthed window outcome (called by the server,
    /// which owns the record annotation).
    pub fn record_outcome(&mut self, false_positive: bool) {
        self.false_positives += false_positive as u64;
    }

    /// Set the feedback capture budget (`[model] feedback_window`;
    /// 0 disables capture). Takes effect from the next completed window.
    pub fn set_feedback_window(&mut self, windows: usize) {
        self.feedback_window = windows;
    }

    /// Claim the retained codes of window `seq` for the feedback loop
    /// (outcome time). Entries older than `seq` are discarded — their
    /// outcome was never attributed (e.g. a failed batch) and outcomes
    /// arrive in window order, so they can never be claimed later.
    /// `None` when the window was not retained (capture disabled, or it
    /// fell off the bounded buffer).
    pub fn take_feedback(&mut self, seq: u64) -> Option<Vec<u8>> {
        while let Some((s, _)) = self.pending_feedback.front() {
            if *s < seq {
                self.pending_feedback.pop_front();
            } else if *s == seq {
                return self.pending_feedback.pop_front().map(|(_, codes)| codes);
            } else {
                return None;
            }
        }
        None
    }

    /// Move every retained window out of the session (the wire path:
    /// the reader actor drains at submit time and hands the entries to
    /// the dispatcher, which owns outcome attribution).
    pub fn drain_feedback(&mut self) -> Vec<(u64, Vec<u8>)> {
        self.pending_feedback.drain(..).collect()
    }

    /// Windows emitted so far.
    pub fn windows(&self) -> u64 {
        self.next_seq
    }

    /// Reset stream state (new record), keeping the deployed model.
    pub fn reset_stream(&mut self) {
        self.lbp.reset();
        self.window.clear();
        self.frames_in_window = 0;
        self.next_seq = 0;
        self.batch.clear();
        self.batch_count = 0;
        self.detector.reset();
        self.predictions.clear();
        self.false_positives = 0;
        self.pending_feedback.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session() -> Session {
        Session::new(1, 11, PublishedModel::placeholder(), 1)
    }

    #[test]
    fn emits_window_every_256_samples() {
        let mut s = session();
        let sample = [0f32; CHANNELS];
        for i in 0..FRAMES_PER_PREDICTION * 2 {
            let b = s.push_sample(&sample);
            if (i + 1) % FRAMES_PER_PREDICTION == 0 {
                let b = b.expect("window boundary");
                assert_eq!(b.windows, 1);
                assert_eq!(b.codes.len(), FRAMES_PER_PREDICTION * CHANNELS);
                assert_eq!(b.seq0, (i / FRAMES_PER_PREDICTION) as u64);
            } else {
                assert!(b.is_none());
            }
        }
        assert_eq!(s.windows(), 2);
    }

    #[test]
    fn batches_accumulate_and_flush() {
        let mut s = session();
        s.set_batch_windows(3);
        let sample = [0f32; CHANNELS];
        // Two full windows: still pending (batch of 3 not full).
        for _ in 0..FRAMES_PER_PREDICTION * 2 {
            assert!(s.push_sample(&sample).is_none());
        }
        // Third window completes the batch.
        let mut got = None;
        for _ in 0..FRAMES_PER_PREDICTION {
            got = got.or(s.push_sample(&sample));
        }
        let b = got.expect("batch of 3 emits");
        assert_eq!((b.seq0, b.windows), (0, 3));
        assert_eq!(b.codes.len(), 3 * FRAMES_PER_PREDICTION * CHANNELS);
        // One more window, then a stream-end flush emits the partial batch.
        for _ in 0..FRAMES_PER_PREDICTION {
            assert!(s.push_sample(&sample).is_none());
        }
        let tail = s.flush_batch().expect("partial batch flushes");
        assert_eq!((tail.seq0, tail.windows), (3, 1));
        assert!(s.flush_batch().is_none(), "flush is idempotent");
        assert_eq!(s.windows(), 4);
    }

    #[test]
    fn complete_collects_predictions_and_alarms() {
        let mut s = session();
        assert!(s.complete(0, false, -3).is_none());
        let e = s.complete(1, true, 7).expect("alarm");
        assert_eq!(e.window_idx, 1);
        assert_eq!(s.predictions.len(), 2);
        assert!(s.predictions[1].is_ictal);
    }

    #[test]
    fn feedback_ring_retains_bounded_labelled_windows() {
        let mut s = session();
        s.set_feedback_window(2);
        let sample = [0f32; CHANNELS];
        for _ in 0..FRAMES_PER_PREDICTION * 4 {
            s.push_sample(&sample);
        }
        // Bounded at 2: windows 0 and 1 fell off, 2 and 3 remain.
        assert_eq!(s.take_feedback(0), None);
        let codes = s.take_feedback(2).expect("window 2 retained");
        assert_eq!(codes.len(), FRAMES_PER_PREDICTION * CHANNELS);
        // Claiming 3 after 2 works; re-claiming 2 does not.
        assert!(s.take_feedback(3).is_some());
        assert!(s.take_feedback(3).is_none());

        // Claiming a later window discards the skipped ones.
        for _ in 0..FRAMES_PER_PREDICTION * 2 {
            s.push_sample(&sample);
        }
        assert!(s.take_feedback(5).is_some());
        assert!(s.take_feedback(4).is_none(), "window 4 was discarded by the seek");

        // Capture disabled: nothing retained.
        let mut off = session();
        for _ in 0..FRAMES_PER_PREDICTION {
            off.push_sample(&sample);
        }
        assert!(off.take_feedback(0).is_none());
        assert!(off.drain_feedback().is_empty());
    }

    #[test]
    fn reset_stream_keeps_model() {
        let mut s = session();
        let sample = [1f32; CHANNELS];
        for _ in 0..100 {
            s.push_sample(&sample);
        }
        s.complete(0, true, 1);
        let m = s.model().clone();
        s.reset_stream();
        assert_eq!(s.windows(), 0);
        assert!(s.predictions.is_empty());
        assert!(Arc::ptr_eq(&m, s.model()));
    }

    #[test]
    fn refresh_model_swaps_only_on_new_versions() {
        let registry = ModelRegistry::new();
        let mut s = session();
        // No entry for this patient: nothing to swap.
        assert!(!s.refresh_model(&registry).unwrap());
        assert_eq!(s.model_swaps, 0);
        assert_eq!(s.model().version(), 1);

        // A published model for the session's patient is picked up once.
        registry
            .publish(11, {
                let mut b = s.model().bundle.clone();
                b.version = 2;
                b
            })
            .unwrap();
        assert!(s.refresh_model(&registry).unwrap());
        assert!(!s.refresh_model(&registry).unwrap(), "same instance: no re-swap");
        assert_eq!(s.model_swaps, 1);
        assert_eq!(s.model().version(), 2);
        assert!(Arc::ptr_eq(s.model(), &registry.current(11).unwrap()));
    }

    #[test]
    fn refresh_model_refuses_an_encoder_incompatible_swap() {
        let registry = ModelRegistry::new();
        let mut s = session();
        // v2 trained under a different IM seed: the engine's encoder
        // cannot serve it — the swap must error, not silently deploy.
        registry
            .publish(11, {
                let mut b = s.model().bundle.clone();
                b.version = 2;
                b.config.seed ^= 1;
                b
            })
            .unwrap();
        let err = s.refresh_model(&registry).unwrap_err();
        assert!(format!("{err:#}").contains("hot swap"), "{err:#}");
        // The session keeps serving the deployed model.
        assert_eq!(s.model().version(), 1);
        assert_eq!(s.model_swaps, 0);
        // A temporal-threshold-only change is a legal swap.
        registry
            .publish(11, {
                let mut b = s.model().bundle.clone();
                b.version = 3;
                b.config.temporal_threshold += 7;
                b
            })
            .unwrap();
        assert!(s.refresh_model(&registry).unwrap());
        assert_eq!(s.model().version(), 3);
    }
}
