//! Per-patient session state: LBP front-end → frame assembly → window
//! submission, plus the trained model (AM + threshold) and detector.

use std::sync::Arc;

use crate::coordinator::detector::Detector;
use crate::data::metrics::WindowPrediction;
use crate::hdc::am::AssociativeMemory;
use crate::lbp::LbpFrontend;
use crate::params::{CHANNELS, FRAMES_PER_PREDICTION};

/// A fully-assembled prediction window ready for an engine.
pub struct ReadyWindow {
    pub session_id: u64,
    pub seq: u64,
    /// Frame-major codes `[FRAMES_PER_PREDICTION * CHANNELS]`.
    pub codes: Vec<u8>,
}

/// Per-patient streaming session.
pub struct Session {
    pub id: u64,
    pub patient_id: u32,
    lbp: LbpFrontend,
    window: Vec<u8>,
    frames_in_window: usize,
    next_seq: u64,
    /// Trained model deployed on this session.
    pub am: Arc<Vec<i32>>,
    pub am_native: AssociativeMemory,
    pub threshold: u16,
    pub detector: Detector,
    /// Collected predictions (for offline scoring after the stream ends).
    pub predictions: Vec<WindowPrediction>,
}

impl Session {
    pub fn new(
        id: u64,
        patient_id: u32,
        am: AssociativeMemory,
        threshold: u16,
        consecutive: usize,
    ) -> Self {
        Session {
            id,
            patient_id,
            lbp: LbpFrontend::new(),
            window: Vec::with_capacity(FRAMES_PER_PREDICTION * CHANNELS),
            frames_in_window: 0,
            next_seq: 0,
            am: Arc::new(am.to_i32s()),
            am_native: am,
            threshold,
            detector: Detector::new(consecutive),
            predictions: Vec::new(),
        }
    }

    /// Feed one multichannel sample; returns a window when 256 frames have
    /// been assembled.
    pub fn push_sample(&mut self, sample: &[f32; CHANNELS]) -> Option<ReadyWindow> {
        let codes = self.lbp.push(sample);
        self.window.extend_from_slice(&codes);
        self.frames_in_window += 1;
        if self.frames_in_window < FRAMES_PER_PREDICTION {
            return None;
        }
        let codes = std::mem::replace(
            &mut self.window,
            Vec::with_capacity(FRAMES_PER_PREDICTION * CHANNELS),
        );
        self.frames_in_window = 0;
        let seq = self.next_seq;
        self.next_seq += 1;
        Some(ReadyWindow {
            session_id: self.id,
            seq,
            codes,
        })
    }

    /// Record a completed prediction and run the detector.
    /// Returns `Some(event)` when an alarm fires.
    pub fn complete(
        &mut self,
        seq: u64,
        is_ictal: bool,
        margin: i64,
    ) -> Option<crate::coordinator::detector::AlarmEvent> {
        self.predictions.push(WindowPrediction {
            idx: seq as usize,
            is_ictal,
            margin,
        });
        self.detector.push(seq, is_ictal, margin)
    }

    /// Windows emitted so far.
    pub fn windows(&self) -> u64 {
        self.next_seq
    }

    /// Reset stream state (new record), keeping the trained model.
    pub fn reset_stream(&mut self) {
        self.lbp.reset();
        self.window.clear();
        self.frames_in_window = 0;
        self.next_seq = 0;
        self.detector.reset();
        self.predictions.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::hv::Hv;

    fn session() -> Session {
        Session::new(1, 11, AssociativeMemory::new(Hv::zero(), Hv::ones()), 130, 1)
    }

    #[test]
    fn emits_window_every_256_samples() {
        let mut s = session();
        let sample = [0f32; CHANNELS];
        for i in 0..FRAMES_PER_PREDICTION * 2 {
            let w = s.push_sample(&sample);
            if (i + 1) % FRAMES_PER_PREDICTION == 0 {
                let w = w.expect("window boundary");
                assert_eq!(w.codes.len(), FRAMES_PER_PREDICTION * CHANNELS);
                assert_eq!(w.seq, (i / FRAMES_PER_PREDICTION) as u64);
            } else {
                assert!(w.is_none());
            }
        }
        assert_eq!(s.windows(), 2);
    }

    #[test]
    fn complete_collects_predictions_and_alarms() {
        let mut s = session();
        assert!(s.complete(0, false, -3).is_none());
        let e = s.complete(1, true, 7).expect("alarm");
        assert_eq!(e.window_idx, 1);
        assert_eq!(s.predictions.len(), 2);
        assert!(s.predictions[1].is_ictal);
    }

    #[test]
    fn reset_stream_keeps_model() {
        let mut s = session();
        let sample = [1f32; CHANNELS];
        for _ in 0..100 {
            s.push_sample(&sample);
        }
        s.complete(0, true, 1);
        let am = s.am.clone();
        s.reset_stream();
        assert_eq!(s.windows(), 0);
        assert!(s.predictions.is_empty());
        assert!(Arc::ptr_eq(&am, &s.am));
    }
}
