//! Actor-per-connection wire serving: the coordinator behind a
//! [`Transport`].
//!
//! Thread shape (SNIPPETS-style connection actors over the existing
//! engine pool):
//!
//! * **accept loop** — polls [`Transport::accept`], spawns one reader
//!   actor + one writer thread per connection;
//! * **reader actor** — owns the connection's framed read half and its
//!   coordinator [`Session`] (created on `Subscribe`), enforces frame
//!   ordering and the staleness deadline, windows samples via the same
//!   [`Session::push_samples`] the in-process router uses, and submits
//!   engine jobs through a cloned
//!   [`JobSender`](crate::runtime::engine_pool::JobSender) — engine
//!   backpressure blocks *this* connection's intake, never the pool;
//! * **writer thread** — drains the connection's bounded outbound queue
//!   onto the wire, emitting heartbeats whenever the queue stays empty
//!   for a heartbeat interval;
//! * **dispatcher** — the single consumer of the engine host's
//!   completions: turns [`WindowOutput`]s into `Prediction` frames
//!   (bit-identical post-processing to the in-process path) and
//!   `try_send`s them to the owning connection's queue. A full queue
//!   means the consumer stopped draining: the connection is **shed**
//!   (disconnected, its predictions dropped) instead of stalling the
//!   engine pool — other sessions' outputs are unaffected.
//!
//! Ordering: per session, jobs are submitted from one thread in window
//! order, the engine completes in submission order, the bounded queue
//! and writer preserve it — so each client sees its predictions in
//! exact window order, and the outputs pin window-for-window against
//! [`crate::coordinator::server::Coordinator`]'s in-process replay.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed, Ordering::SeqCst};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::SystemConfig;
use crate::coordinator::metrics::WireMetrics;
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::scheduler::RetrainScheduler;
use crate::coordinator::server::{spawn_host, Backend};
use crate::coordinator::session::{ReadyBatch, Session};
use crate::data::metrics::window_label;
use crate::data::synth::Record;
use crate::err;
use crate::params::{CLASS_ICTAL, CLASS_INTERICTAL};
use crate::runtime::engine_pool::{EngineHost, Job, JobSender};
use crate::runtime::WindowOutput;
use crate::transport::frame::{close, Frame, FrameReader, ReadOutcome};
use crate::transport::{Transport, WireRead, WireWrite};

/// Reader-side poll tick: how often a blocked read wakes to check stop /
/// close flags and the staleness deadline.
const READ_TICK: Duration = Duration::from_millis(50);
/// Accept-loop poll tick (bounds shutdown latency).
const ACCEPT_TICK: Duration = Duration::from_millis(200);
/// Dispatcher poll tick on the completions channel.
const DISPATCH_TICK: Duration = Duration::from_millis(100);

/// Wire-serving knobs (the `[server]` section of [`SystemConfig`]).
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Writer emits a Heartbeat after this long with nothing to send.
    pub heartbeat: Duration,
    /// A connection sending no frames for this long is disconnected.
    pub staleness: Duration,
    /// Outbound frames buffered per connection before the consumer is
    /// declared slow and shed.
    pub conn_queue: usize,
    /// Windows per engine micro-batch (same meaning as the in-process
    /// coordinator's; outputs are bit-identical at any value).
    pub batch_windows: usize,
    /// Engine job queue depth (global backpressure bound).
    pub engine_queue: usize,
    /// Alarm policy: consecutive ictal windows (detector state lives in
    /// the session even though wire clients do their own alarming).
    pub alarm_consecutive: usize,
    /// Placement slot when this server runs as a fleet shard
    /// (`serve --shard-of K/N`): `ShardHello` control handshakes naming a
    /// different slot are rejected, so a dispatcher can never register a
    /// mis-addressed shard. `None` = standalone server, any hello is
    /// acknowledged as addressed.
    pub shard: Option<u32>,
    /// Labelled serving windows retained per session for feedback
    /// retraining (`[model] feedback_window`; 0 disables capture). Only
    /// consulted when the server carries a [`RetrainContext`].
    pub feedback_window: usize,
}

impl WireConfig {
    pub fn from_system(system: &SystemConfig) -> WireConfig {
        WireConfig {
            heartbeat: Duration::from_millis(system.heartbeat_ms.max(1)),
            staleness: Duration::from_millis(system.staleness_ms.max(1)),
            conn_queue: system.conn_queue.max(1),
            batch_windows: system.batch_windows.max(1),
            engine_queue: system.queue_depth.max(1),
            alarm_consecutive: system.alarm_consecutive,
            shard: None,
            feedback_window: system.feedback_window,
        }
    }
}

/// Everything the wire server needs to close the retrain loop: the
/// policy-driven scheduler plus per-patient annotated records for
/// ground-truthing served windows (the same
/// [`window_label`] rule every other layer uses).
pub struct RetrainContext {
    pub scheduler: Arc<RetrainScheduler>,
    pub records: BTreeMap<u32, Record>,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig::from_system(&SystemConfig::default())
    }
}

/// Per-connection state shared between the reader actor, the writer
/// thread and the dispatcher.
struct ConnShared {
    /// Bounded outbound frame queue (reader/dispatcher produce, writer
    /// consumes). `try_send` only — a full queue is the shed signal,
    /// never a stall.
    out: SyncSender<Frame>,
    /// Windows submitted to the engine for this connection.
    submitted: AtomicU64,
    /// Windows whose completion the dispatcher has processed (delivered
    /// or dropped).
    completed: AtomicU64,
    /// Client sent its end-of-stream Shutdown — no more submissions.
    draining: AtomicBool,
    /// Final server Shutdown enqueued (exactly once).
    finished: AtomicBool,
    /// Torn down (shed / stale / error): every thread exits ASAP.
    closed: AtomicBool,
    /// Subscribed patient + 1 (0 = no data session) — lets the
    /// dispatcher ground-truth completions without touching the session.
    patient: AtomicU64,
    /// Completed windows' retained codes awaiting their outcome, oldest
    /// first (`(window seq, codes)`), drained from the session at submit
    /// time and claimed by the dispatcher at completion time. Bounded:
    /// the session ring caps what enters, the dispatcher pops in window
    /// order as completions land.
    feedback: Mutex<VecDeque<(u64, Vec<u8>)>>,
}

impl ConnShared {
    fn new(out: SyncSender<Frame>) -> Self {
        ConnShared {
            out,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            finished: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            patient: AtomicU64::new(0),
            feedback: Mutex::new(VecDeque::new()),
        }
    }

    /// Claim the retained codes of window `seq` (dispatcher side).
    /// Earlier windows still queued were never ground-truthed (their
    /// batch failed) — discarded in passing.
    fn claim_feedback(&self, seq: u64) -> Option<Vec<u8>> {
        let mut pending = self.feedback.lock().ok()?;
        while let Some((s, _)) = pending.front() {
            if *s < seq {
                pending.pop_front();
            } else if *s == seq {
                return pending.pop_front().map(|(_, codes)| codes);
            } else {
                return None;
            }
        }
        None
    }

    /// Once the client has drained (end-of-stream received and every
    /// submitted window completed), enqueue the final Shutdown exactly
    /// once. Both the reader (after its last submit) and the dispatcher
    /// (after each completion) call this — whichever observes both
    /// conditions wins and returns `true` (then unregisters the entry).
    fn maybe_finish(&self) -> bool {
        if self.draining.load(SeqCst)
            && self.completed.load(SeqCst) >= self.submitted.load(SeqCst)
            && !self.finished.swap(true, SeqCst)
        {
            let _ = self.out.try_send(Frame::Shutdown {
                reason: close::END_OF_STREAM.into(),
            });
            return true;
        }
        false
    }
}

type ConnMap = Arc<Mutex<HashMap<u64, Arc<ConnShared>>>>;

/// Handle to a running wire server.
pub struct WireServer {
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<crate::Result<()>>>,
    dispatch_handle: Option<JoinHandle<()>>,
    metrics: Arc<WireMetrics>,
    addr: String,
}

impl WireServer {
    /// Start serving `registry`'s published models over `transport`.
    ///
    /// The engine host is spawned here (native or PJRT per `backend`,
    /// encoding with `system.classifier`) and owned by the dispatcher
    /// thread. Returns once the accept loop is live.
    pub fn start(
        transport: Box<dyn Transport>,
        backend: &Backend,
        system: &SystemConfig,
        registry: Arc<ModelRegistry>,
        cfg: WireConfig,
    ) -> crate::Result<WireServer> {
        WireServer::start_with_retrain(transport, backend, system, registry, cfg, None)
    }

    /// [`WireServer::start`] plus the closed retrain loop: with a
    /// [`RetrainContext`], served windows are ground-truthed at
    /// completion time, outcomes feed the scheduler's per-patient
    /// false-alarm watches, retained window codes feed its feedback
    /// rings, and `Status` queries report the whole loop.
    pub fn start_with_retrain(
        mut transport: Box<dyn Transport>,
        backend: &Backend,
        system: &SystemConfig,
        registry: Arc<ModelRegistry>,
        cfg: WireConfig,
        retrain: Option<Arc<RetrainContext>>,
    ) -> crate::Result<WireServer> {
        transport.set_write_timeout(Some(cfg.staleness));
        let addr = transport.local_addr();
        let host = spawn_host(backend, &system.classifier, cfg.engine_queue)?;
        let sender = host.sender();
        let metrics = Arc::new(WireMetrics::default());
        let conns: ConnMap = Arc::new(Mutex::new(HashMap::new()));
        let outstanding = Arc::new(AtomicU64::new(0)); // engine jobs in flight
        let stop = Arc::new(AtomicBool::new(false));
        let next_session = Arc::new(AtomicU64::new(0));

        let dispatch_handle = {
            let (conns, metrics, outstanding, stop) =
                (conns.clone(), metrics.clone(), outstanding.clone(), stop.clone());
            let retrain = retrain.clone();
            std::thread::Builder::new()
                .name("wire-dispatch".into())
                .spawn(move || dispatch_loop(host, conns, metrics, outstanding, stop, retrain))?
        };

        let accept_handle = {
            let (conns, metrics, stop) = (conns.clone(), metrics.clone(), stop.clone());
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("wire-accept".into())
                .spawn(move || -> crate::Result<()> {
                    let mut actors: Vec<JoinHandle<()>> = Vec::new();
                    while !stop.load(SeqCst) {
                        match transport.accept(ACCEPT_TICK)? {
                            Some(conn) => {
                                metrics.connections.fetch_add(1, Relaxed);
                                let actor = ConnectionActor {
                                    registry: registry.clone(),
                                    sender: sender.clone(),
                                    conns: conns.clone(),
                                    metrics: metrics.clone(),
                                    outstanding: outstanding.clone(),
                                    next_session: next_session.clone(),
                                    stop: stop.clone(),
                                    cfg: cfg.clone(),
                                    retrain: retrain.clone(),
                                };
                                actors.push(
                                    std::thread::Builder::new()
                                        .name("wire-conn".into())
                                        .spawn(move || actor.run(conn))?,
                                );
                            }
                            None => {
                                // Reap finished actors so a long-lived
                                // server doesn't accumulate handles.
                                actors.retain(|h| !h.is_finished());
                            }
                        }
                    }
                    // Shutdown: close every live connection, join actors.
                    for shared in conns.lock().map_err(|_| err!("conns lock poisoned"))?.values()
                    {
                        shared.closed.store(true, SeqCst);
                    }
                    for h in actors {
                        let _ = h.join();
                    }
                    Ok(())
                })?
        };

        Ok(WireServer {
            stop,
            accept_handle: Some(accept_handle),
            dispatch_handle: Some(dispatch_handle),
            metrics,
            addr,
        })
    }

    /// The transport's resolved address (what clients dial).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    pub fn metrics(&self) -> &Arc<WireMetrics> {
        &self.metrics
    }

    /// Stop accepting, close connections, drain in-flight jobs, join
    /// every thread, and return the final metrics snapshot.
    pub fn shutdown(mut self) -> crate::Result<Arc<WireMetrics>> {
        self.stop.store(true, SeqCst);
        if let Some(h) = self.accept_handle.take() {
            h.join().map_err(|_| err!("wire accept thread panicked"))??;
        }
        if let Some(h) = self.dispatch_handle.take() {
            h.join().map_err(|_| err!("wire dispatch thread panicked"))?;
        }
        Ok(self.metrics.clone())
    }

    /// Serve until the process dies (`repro serve --listen` — the CI
    /// smoke stops it with SIGTERM). Joins the accept loop, which only
    /// returns on a transport error.
    pub fn run(mut self) -> crate::Result<()> {
        if let Some(h) = self.accept_handle.take() {
            h.join().map_err(|_| err!("wire accept thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop.store(true, SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatch_handle.take() {
            let _ = h.join();
        }
    }
}

/// Everything one connection's reader actor needs.
struct ConnectionActor {
    registry: Arc<ModelRegistry>,
    sender: JobSender,
    conns: ConnMap,
    metrics: Arc<WireMetrics>,
    outstanding: Arc<AtomicU64>,
    next_session: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    cfg: WireConfig,
    retrain: Option<Arc<RetrainContext>>,
}

impl ConnectionActor {
    fn run(self, conn: crate::transport::Duplex) {
        let (mut reader, writer, _peer) = conn.split();
        if reader.get_mut().set_read_timeout(Some(READ_TICK)).is_err() {
            return;
        }
        let (out_tx, out_rx) = sync_channel::<Frame>(self.cfg.conn_queue);
        let shared = Arc::new(ConnShared::new(out_tx));
        {
            // Writer thread: detached — it outlives the reader on the
            // drain path (delivering queued predictions + the final
            // Shutdown) and exits on its own via the Shutdown frame,
            // the closed flag, or a bounded-write error.
            let (shared, metrics) = (shared.clone(), self.metrics.clone());
            let heartbeat = self.cfg.heartbeat;
            let _ = std::thread::Builder::new()
                .name("wire-write".into())
                .spawn(move || writer_loop(writer, out_rx, heartbeat, shared, metrics));
        }
        let sid = self.read_loop(&mut reader, &shared);
        // Non-drain exits (stale, shed, protocol error, EOF, server
        // stop): unregister so the dispatcher stops delivering. The
        // drain path unregisters via maybe_finish's winner instead.
        if sid != 0 && shared.closed.load(SeqCst) {
            if let Ok(mut map) = self.conns.lock() {
                map.remove(&sid);
            }
        }
    }

    /// The reader actor proper; returns the session id (0 = never
    /// subscribed).
    fn read_loop(
        &self,
        reader: &mut FrameReader<Box<dyn WireRead>>,
        shared: &Arc<ConnShared>,
    ) -> u64 {
        let mut session: Option<Session> = None;
        let mut sid = 0u64;
        let mut expected_seq = 0u64;
        let mut last_rx = Instant::now();
        let mut batches: Vec<ReadyBatch> = Vec::new();
        // Dispatcher control connection (opened with ShardHello): carries
        // lease grants and heartbeats, never a data session, and is
        // exempt from the staleness reaper — the dispatcher's own
        // heartbeat cadence is its liveness contract.
        let mut control = false;
        loop {
            if self.stop.load(SeqCst) || shared.closed.load(SeqCst) {
                shared.closed.store(true, SeqCst);
                return sid;
            }
            let outcome = match reader.read() {
                Ok(o) => o,
                Err(e) => {
                    self.protocol_error(shared, format!("protocol error: {e:#}"));
                    return sid;
                }
            };
            match outcome {
                ReadOutcome::Idle => {
                    if !control && last_rx.elapsed() >= self.cfg.staleness {
                        self.metrics.stale_disconnects.fetch_add(1, Relaxed);
                        let _ = shared.out.try_send(Frame::Shutdown {
                            reason: close::stale(format!(
                                "no frames within the {:?} staleness deadline",
                                self.cfg.staleness
                            )),
                        });
                        shared.closed.store(true, SeqCst);
                        return sid;
                    }
                }
                ReadOutcome::Eof => {
                    shared.closed.store(true, SeqCst);
                    return sid;
                }
                ReadOutcome::Frame(frame) => {
                    last_rx = Instant::now();
                    self.metrics.frames_in.fetch_add(1, Relaxed);
                    match frame {
                        Frame::Subscribe { patient } => {
                            if control {
                                self.protocol_error(
                                    shared,
                                    "Subscribe on a control connection".into(),
                                );
                                return sid;
                            }
                            if session.is_some() {
                                self.protocol_error(shared, "duplicate Subscribe".into());
                                return sid;
                            }
                            let Some(model) = self.registry.current(patient) else {
                                self.protocol_error(
                                    shared,
                                    format!("no model published for patient {patient}"),
                                );
                                return sid;
                            };
                            sid = self.next_session.fetch_add(1, SeqCst) + 1;
                            let mut s =
                                Session::new(sid, patient, model, self.cfg.alarm_consecutive);
                            s.set_batch_windows(self.cfg.batch_windows);
                            if self.retrain.is_some() {
                                s.set_feedback_window(self.cfg.feedback_window);
                                shared.patient.store(patient as u64 + 1, SeqCst);
                            }
                            session = Some(s);
                            if let Ok(mut map) = self.conns.lock() {
                                map.insert(sid, shared.clone());
                            }
                            self.metrics.sessions_started.fetch_add(1, Relaxed);
                        }
                        Frame::Samples { seq, samples } => {
                            let Some(s) = session.as_mut() else {
                                self.protocol_error(shared, "Samples before Subscribe".into());
                                return sid;
                            };
                            if seq != expected_seq {
                                self.protocol_error(
                                    shared,
                                    format!("Samples seq {seq}, expected {expected_seq}"),
                                );
                                return sid;
                            }
                            expected_seq += 1;
                            if let Err(e) = s.push_samples(&samples, &mut batches) {
                                self.protocol_error(shared, format!("{e:#}"));
                                return sid;
                            }
                            if let Err(e) = self.submit_batches(s, &mut batches, shared) {
                                self.protocol_error(shared, format!("{e:#}"));
                                return sid;
                            }
                        }
                        Frame::Heartbeat { .. } => {}
                        Frame::Shutdown { .. } => {
                            // Orderly end-of-stream: flush the partial
                            // batch, then drain — the dispatcher (or
                            // this maybe_finish, if everything already
                            // completed) sends the final Shutdown once
                            // every submitted window is accounted for.
                            let Some(s) = session.as_mut() else {
                                shared.closed.store(true, SeqCst);
                                return sid;
                            };
                            if let Some(b) = s.flush_batch() {
                                batches.push(b);
                            }
                            if let Err(e) = self.submit_batches(s, &mut batches, shared) {
                                self.protocol_error(shared, format!("{e:#}"));
                                return sid;
                            }
                            shared.draining.store(true, SeqCst);
                            if shared.maybe_finish() {
                                self.metrics.sessions_finished.fetch_add(1, Relaxed);
                                if let Ok(mut map) = self.conns.lock() {
                                    map.remove(&sid);
                                }
                            }
                            return sid;
                        }
                        Frame::Prediction { .. } => {
                            self.protocol_error(
                                shared,
                                "client sent a server-side Prediction frame".into(),
                            );
                            return sid;
                        }
                        Frame::ShardHello { shard, epoch } => {
                            if session.is_some() {
                                self.protocol_error(
                                    shared,
                                    "ShardHello on a data connection".into(),
                                );
                                return sid;
                            }
                            if let Some(own) = self.cfg.shard {
                                if shard != own {
                                    self.protocol_error(
                                        shared,
                                        format!(
                                            "ShardHello for shard {shard}, this server is shard {own}"
                                        ),
                                    );
                                    return sid;
                                }
                            }
                            control = true;
                            self.metrics.control_hellos.fetch_add(1, Relaxed);
                            // Echo the hello back as the registration ack.
                            let _ = shared.out.try_send(Frame::ShardHello { shard, epoch });
                        }
                        Frame::Lease {
                            patient,
                            shard,
                            epoch,
                        } => {
                            if !control {
                                self.protocol_error(
                                    shared,
                                    "Lease on a data connection".into(),
                                );
                                return sid;
                            }
                            self.metrics.leases_acked.fetch_add(1, Relaxed);
                            // Echo the grant back as the ack.
                            let _ = shared.out.try_send(Frame::Lease {
                                patient,
                                shard,
                                epoch,
                            });
                        }
                        Frame::Route { .. } => {
                            self.protocol_error(
                                shared,
                                "client sent a dispatcher-side Route frame".into(),
                            );
                            return sid;
                        }
                        Frame::Status => {
                            // Telemetry query — allowed on any connection
                            // (data, control, or a bare dial) at any time.
                            let stats = self.registry.plane_cache().stats();
                            let patients = self
                                .retrain
                                .as_ref()
                                .map(|ctx| ctx.scheduler.status())
                                .unwrap_or_default();
                            let _ = shared.out.try_send(Frame::StatusReport {
                                cache_hits: stats.hits,
                                cache_misses: stats.misses,
                                cache_evictions: stats.evictions,
                                cache_redecodes: stats.redecodes,
                                patients,
                            });
                        }
                        Frame::StatusReport { .. } => {
                            self.protocol_error(
                                shared,
                                "client sent a server-side StatusReport frame".into(),
                            );
                            return sid;
                        }
                    }
                }
            }
        }
    }

    /// Submit ready batches as engine jobs (blocking on a full engine
    /// queue — per-connection backpressure).
    fn submit_batches(
        &self,
        session: &mut Session,
        batches: &mut Vec<ReadyBatch>,
        shared: &ConnShared,
    ) -> crate::Result<()> {
        // Hand the session's retained window codes to the dispatcher,
        // which owns outcome attribution (the session itself is never
        // touched off the reader thread).
        if self.retrain.is_some() {
            let drained = session.drain_feedback();
            if !drained.is_empty() {
                if let Ok(mut pending) = shared.feedback.lock() {
                    pending.extend(drained);
                }
            }
        }
        for b in batches.drain(..) {
            // Hot-swap exactly like the in-process path: refresh at
            // batch-creation time; in-flight jobs keep their own Arc.
            session.refresh_model(&self.registry)?;
            let model = session.model();
            let windows = b.windows as u64;
            let job = Job {
                tag: b.session_id,
                seq: b.seq0,
                codes: b.codes,
                am: model.plane(),
                thresholds: vec![model.threshold() as i32; b.windows],
                version: model.version(),
                submitted: Instant::now(),
            };
            shared.submitted.fetch_add(windows, SeqCst);
            self.outstanding.fetch_add(1, SeqCst);
            self.metrics.windows_submitted.fetch_add(windows, Relaxed);
            if self.sender.submit(job).is_err() {
                self.outstanding.fetch_sub(1, SeqCst);
                crate::bail!("engine worker has shut down");
            }
        }
        Ok(())
    }

    fn protocol_error(&self, shared: &ConnShared, reason: String) {
        self.metrics.protocol_errors.fetch_add(1, Relaxed);
        let _ = shared.out.try_send(Frame::Shutdown { reason });
        shared.closed.store(true, SeqCst);
    }
}

/// Per-connection writer: drains the bounded queue onto the wire,
/// heartbeats through idle gaps, exits on the final Shutdown frame, the
/// closed flag, or a write error (bounded by the transport's write
/// timeout — a stalled peer cannot hold this thread forever).
fn writer_loop(
    mut writer: Box<dyn WireWrite>,
    out_rx: Receiver<Frame>,
    heartbeat: Duration,
    shared: Arc<ConnShared>,
    metrics: Arc<WireMetrics>,
) {
    let mut hb_seq = 0u64;
    loop {
        match out_rx.recv_timeout(heartbeat) {
            Ok(frame) => {
                let last = matches!(frame, Frame::Shutdown { .. });
                if crate::transport::frame::write_frame(&mut writer, &frame).is_err() {
                    shared.closed.store(true, SeqCst);
                    return;
                }
                if matches!(frame, Frame::Prediction { .. }) {
                    metrics.predictions_sent.fetch_add(1, Relaxed);
                }
                if last {
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if shared.closed.load(SeqCst) {
                    return;
                }
                hb_seq += 1;
                if crate::transport::frame::write_frame(
                    &mut writer,
                    &Frame::Heartbeat { seq: hb_seq },
                )
                .is_err()
                {
                    shared.closed.store(true, SeqCst);
                    return;
                }
                metrics.heartbeats_sent.fetch_add(1, Relaxed);
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Identical post-processing to the in-process coordinator's `finish`
/// (the pinning contract: one definition of the label per layer, same
/// tie-breaking, same margin).
fn prediction_frame(window: u64, version: u64, out: &WindowOutput) -> Frame {
    Frame::Prediction {
        window,
        is_ictal: out.scores[CLASS_ICTAL] > out.scores[CLASS_INTERICTAL],
        margin: out.margin(),
        model_version: version,
    }
}

/// The single completions consumer: owns the engine host, fans
/// completions out to connection queues, sheds slow consumers.
fn dispatch_loop(
    host: EngineHost,
    conns: ConnMap,
    metrics: Arc<WireMetrics>,
    outstanding: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    retrain: Option<Arc<RetrainContext>>,
) {
    loop {
        match host.completions.recv_timeout(DISPATCH_TICK) {
            Ok(c) => {
                outstanding.fetch_sub(1, SeqCst);
                let windows = c.windows as u64;
                metrics.windows_completed.fetch_add(windows, Relaxed);
                let shared = match conns.lock() {
                    Ok(map) => map.get(&c.tag).cloned(),
                    Err(_) => None,
                };
                let Some(shared) = shared else {
                    // Connection already torn down (shed / stale / gone):
                    // its windows are drops, not deliveries.
                    metrics.predictions_dropped.fetch_add(windows, Relaxed);
                    continue;
                };
                let mut shed = false;
                match &c.outputs {
                    Ok(outs) => {
                        for (k, out) in outs.iter().enumerate() {
                            let seq = c.seq + k as u64;
                            // Close the retrain loop on every scored
                            // window (even past a shed — the window was
                            // served, its outcome indicts the model).
                            if let Some(ctx) = &retrain {
                                let tagged = shared.patient.load(SeqCst);
                                if tagged > 0 {
                                    let patient = (tagged - 1) as u32;
                                    let truth = ctx
                                        .records
                                        .get(&patient)
                                        .map(|r| window_label(r, seq as usize))
                                        .unwrap_or(false);
                                    let is_ictal =
                                        out.scores[CLASS_ICTAL] > out.scores[CLASS_INTERICTAL];
                                    if let Some(codes) = shared.claim_feedback(seq) {
                                        ctx.scheduler.record_feedback(patient, codes, truth);
                                    }
                                    ctx.scheduler.observe(patient, is_ictal && !truth);
                                }
                            }
                            if shed {
                                metrics.predictions_dropped.fetch_add(1, Relaxed);
                                continue;
                            }
                            let frame = prediction_frame(seq, c.version, out);
                            if shared.out.try_send(frame).is_err() {
                                // Full (slow consumer) or writer gone:
                                // either way this consumer is done.
                                shed = true;
                                metrics.predictions_dropped.fetch_add(1, Relaxed);
                            }
                        }
                    }
                    Err(e) => {
                        metrics.predictions_dropped.fetch_add(windows, Relaxed);
                        eprintln!(
                            "wire batch failed (session {}, seq {}, {} windows): {e:#}",
                            c.tag, c.seq, c.windows
                        );
                    }
                }
                shared.completed.fetch_add(windows, SeqCst);
                if shed {
                    metrics.slow_consumers_shed.fetch_add(1, Relaxed);
                    shared.closed.store(true, SeqCst);
                    if let Ok(mut map) = conns.lock() {
                        map.remove(&c.tag);
                    }
                } else if shared.maybe_finish() {
                    metrics.sessions_finished.fetch_add(1, Relaxed);
                    if let Ok(mut map) = conns.lock() {
                        map.remove(&c.tag);
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(SeqCst) && outstanding.load(SeqCst) == 0 {
                    return; // dropping `host` joins the engine worker
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}
