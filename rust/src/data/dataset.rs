//! Dataset containers and a simple binary on-disk format.
//!
//! Records are stored in a dependency-free little-endian binary format
//! (`.ieeg`): the coordinator's file source streams these, and `repro
//! gen-data` writes them. Layout:
//!
//! ```text
//! magic  u32  = 0x1EEC_0DA7
//! version u32 = 1
//! channels u32, fs_mhz u32 (fs * 1000)
//! n_samples u64
//! has_seizure u8; if 1: onset u64, offset u64
//! samples: n_samples * channels * f32 (time-major)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::error::Context;

use crate::params::CHANNELS;

use super::synth::{Record, Seizure};

const MAGIC: u32 = 0x1EEC_0DA7;
const VERSION: u32 = 1;

/// Write one record to `path`.
pub fn save_record(record: &Record, path: &Path) -> crate::Result<()> {
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(CHANNELS as u32).to_le_bytes())?;
    w.write_all(&((record.fs * 1000.0) as u32).to_le_bytes())?;
    w.write_all(&(record.num_samples() as u64).to_le_bytes())?;
    match record.seizure {
        Some(s) => {
            w.write_all(&[1u8])?;
            w.write_all(&(s.onset as u64).to_le_bytes())?;
            w.write_all(&(s.offset as u64).to_le_bytes())?;
        }
        None => w.write_all(&[0u8])?,
    }
    for &x in &record.samples {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Load one record from `path`.
pub fn load_record(path: &Path) -> crate::Result<Record> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);

    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    let mut read_u32 = |r: &mut BufReader<File>| -> crate::Result<u32> {
        r.read_exact(&mut u32buf)?;
        Ok(u32::from_le_bytes(u32buf))
    };

    let magic = read_u32(&mut r)?;
    if magic != MAGIC {
        bail!("bad magic {magic:#x} in {}", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let channels = read_u32(&mut r)? as usize;
    if channels != CHANNELS {
        bail!("record has {channels} channels, build expects {CHANNELS}");
    }
    let fs = read_u32(&mut r)? as f64 / 1000.0;

    r.read_exact(&mut u64buf)?;
    let n_samples = u64::from_le_bytes(u64buf) as usize;

    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let seizure = if flag[0] == 1 {
        r.read_exact(&mut u64buf)?;
        let onset = u64::from_le_bytes(u64buf) as usize;
        r.read_exact(&mut u64buf)?;
        let offset = u64::from_le_bytes(u64buf) as usize;
        Some(Seizure { onset, offset })
    } else {
        None
    };

    let mut bytes = vec![0u8; n_samples * CHANNELS * 4];
    r.read_exact(&mut bytes)
        .with_context(|| format!("truncated sample payload in {}", path.display()))?;
    let samples = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();

    Ok(Record {
        samples,
        seizure,
        fs,
    })
}

/// A patient directory: `patient_<id>/record_<k>.ieeg`.
pub fn save_patient(
    records: &[Record],
    dir: &Path,
    patient_id: u32,
) -> crate::Result<Vec<std::path::PathBuf>> {
    let pdir = dir.join(format!("patient_{patient_id:02}"));
    std::fs::create_dir_all(&pdir)?;
    let mut paths = Vec::new();
    for (k, rec) in records.iter().enumerate() {
        let path = pdir.join(format!("record_{k:02}.ieeg"));
        save_record(rec, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Load all records of a patient directory, sorted by record index.
pub fn load_patient(dir: &Path, patient_id: u32) -> crate::Result<Vec<Record>> {
    let pdir = dir.join(format!("patient_{patient_id:02}"));
    let mut entries: Vec<_> = std::fs::read_dir(&pdir)
        .with_context(|| format!("read {}", pdir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "ieeg").unwrap_or(false))
        .collect();
    entries.sort();
    entries.iter().map(|p| load_record(p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthPatient};

    #[test]
    fn save_load_roundtrip() {
        let cfg = SynthConfig {
            pre_s: 1.0,
            ictal_s: 1.0,
            post_s: 0.5,
            records_per_patient: 1,
            ..Default::default()
        };
        let p = SynthPatient::generate(&cfg, 1);
        let dir = std::env::temp_dir().join(format!("hdc_ds_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.ieeg");
        save_record(&p.records[0], &path).unwrap();
        let loaded = load_record(&path).unwrap();
        assert_eq!(loaded.samples, p.records[0].samples);
        assert_eq!(loaded.seizure, p.records[0].seizure);
        assert_eq!(loaded.fs, p.records[0].fs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn patient_roundtrip_and_ordering() {
        let cfg = SynthConfig {
            pre_s: 0.5,
            ictal_s: 0.5,
            post_s: 0.2,
            records_per_patient: 3,
            ..Default::default()
        };
        let p = SynthPatient::generate(&cfg, 2);
        let dir = std::env::temp_dir().join(format!("hdc_pat_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        save_patient(&p.records, &dir, 2).unwrap();
        let loaded = load_patient(&dir, 2).unwrap();
        assert_eq!(loaded.len(), 3);
        for (a, b) in loaded.iter().zip(&p.records) {
            assert_eq!(a.samples, b.samples);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hdc_bad_{}.ieeg", std::process::id()));
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_record(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
