//! Detection metrics — paper §IV-A.
//!
//! * **detection delay**: time from the expert-marked electrographic onset
//!   to the moment the detector first raises an alarm (prediction windows
//!   are emitted at their *end*, so the minimum achievable delay is up to
//!   one window period after onset).
//! * **detection accuracy**: fraction of test seizures detected (an alarm
//!   inside `[onset, offset + grace]`).
//! * **false alarms**: alarm events (runs of consecutive ictal windows)
//!   entirely before the onset, normalised per hour.
//! * **window accuracy**: per-window classification accuracy (diagnostic).

use crate::params::{FRAMES_PER_PREDICTION, SAMPLE_RATE_HZ};

use super::synth::Record;

/// One classifier output for one prediction window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowPrediction {
    /// Window index: covers samples `[idx * W, (idx+1) * W)`.
    pub idx: usize,
    pub is_ictal: bool,
    /// Decision margin (ictal score − interictal score).
    pub margin: i64,
}

/// Alarm policy: raise after `consecutive` ictal windows in a row.
#[derive(Clone, Copy, Debug)]
pub struct AlarmPolicy {
    pub consecutive: usize,
}

impl Default for AlarmPolicy {
    fn default() -> Self {
        AlarmPolicy { consecutive: 1 }
    }
}

/// Outcome of evaluating one record.
#[derive(Clone, Debug)]
pub struct RecordOutcome {
    /// Detected within the grace interval (None when the record has no
    /// seizure).
    pub detected: Option<bool>,
    /// Delay in seconds from onset to the first alarm (only when detected).
    pub delay_s: Option<f64>,
    /// Alarm events entirely pre-onset (or any alarms in seizure-free
    /// records).
    pub false_alarms: usize,
    /// Record duration (for FA/h normalisation).
    pub duration_s: f64,
    /// Fraction of windows classified correctly against the annotation.
    pub window_accuracy: f64,
}

/// Sample index at which a window's prediction is emitted.
#[inline]
pub fn window_end_sample(idx: usize) -> usize {
    (idx + 1) * FRAMES_PER_PREDICTION
}

/// True window label: majority of the window's samples inside the ictal
/// interval (consistent with `hdc::train`).
pub fn window_label(record: &Record, idx: usize) -> bool {
    let start = idx * FRAMES_PER_PREDICTION;
    let end = window_end_sample(idx).min(record.num_samples());
    if start >= end {
        return false;
    }
    let ictal = (start..end).filter(|&t| record.is_ictal(t)).count();
    ictal * 2 > end - start
}

/// Evaluate window predictions against a record's annotation.
///
/// `grace_s`: a seizure counts as detected if the alarm fires between the
/// onset and `offset + grace_s`.
pub fn evaluate_record(
    record: &Record,
    predictions: &[WindowPrediction],
    policy: AlarmPolicy,
    grace_s: f64,
) -> RecordOutcome {
    let fs = record.fs;
    // Build alarm events: runs of >= policy.consecutive ictal windows.
    // An alarm fires at the end sample of the `consecutive`-th window of
    // the run.
    let mut alarms: Vec<usize> = Vec::new(); // alarm sample indices
    let mut run = 0usize;
    for p in predictions {
        if p.is_ictal {
            run += 1;
            if run == policy.consecutive {
                alarms.push(window_end_sample(p.idx));
            }
        } else {
            run = 0;
        }
    }

    // Window-level accuracy.
    let mut correct = 0usize;
    for p in predictions {
        if p.is_ictal == window_label(record, p.idx) {
            correct += 1;
        }
    }
    let window_accuracy = if predictions.is_empty() {
        1.0
    } else {
        correct as f64 / predictions.len() as f64
    };

    let duration_s = record.duration_s();

    match record.seizure {
        Some(s) => {
            let grace_end = s.offset + (grace_s * fs) as usize;
            let mut detected = false;
            let mut delay_s = None;
            let mut false_alarms = 0usize;
            for &a in &alarms {
                if a < s.onset {
                    false_alarms += 1;
                } else if a <= grace_end && !detected {
                    detected = true;
                    delay_s = Some((a - s.onset) as f64 / fs);
                }
            }
            RecordOutcome {
                detected: Some(detected),
                delay_s,
                false_alarms,
                duration_s,
                window_accuracy,
            }
        }
        None => RecordOutcome {
            detected: None,
            delay_s: None,
            false_alarms: alarms.len(),
            duration_s,
            window_accuracy,
        },
    }
}

/// Aggregate over records / patients.
#[derive(Clone, Debug, Default)]
pub struct EvalSummary {
    pub seizures: usize,
    pub detected: usize,
    pub delays_s: Vec<f64>,
    pub false_alarms: usize,
    pub total_hours: f64,
    pub window_accuracy_sum: f64,
    pub records: usize,
}

impl EvalSummary {
    pub fn add(&mut self, o: &RecordOutcome) {
        if let Some(det) = o.detected {
            self.seizures += 1;
            if det {
                self.detected += 1;
                if let Some(d) = o.delay_s {
                    self.delays_s.push(d);
                }
            }
        }
        self.false_alarms += o.false_alarms;
        self.total_hours += o.duration_s / 3600.0;
        self.window_accuracy_sum += o.window_accuracy;
        self.records += 1;
    }

    pub fn merge(&mut self, other: &EvalSummary) {
        self.seizures += other.seizures;
        self.detected += other.detected;
        self.delays_s.extend_from_slice(&other.delays_s);
        self.false_alarms += other.false_alarms;
        self.total_hours += other.total_hours;
        self.window_accuracy_sum += other.window_accuracy_sum;
        self.records += other.records;
    }

    /// Fraction of seizures detected — the paper's "detection accuracy".
    pub fn detection_accuracy(&self) -> f64 {
        if self.seizures == 0 {
            return 0.0;
        }
        self.detected as f64 / self.seizures as f64
    }

    /// Mean detection delay over detected seizures (s). Undetected
    /// seizures are *excluded* (the accuracy metric captures them).
    pub fn mean_delay_s(&self) -> f64 {
        if self.delays_s.is_empty() {
            return f64::NAN;
        }
        self.delays_s.iter().sum::<f64>() / self.delays_s.len() as f64
    }

    pub fn false_alarms_per_hour(&self) -> f64 {
        if self.total_hours <= 0.0 {
            return 0.0;
        }
        self.false_alarms as f64 / self.total_hours
    }

    pub fn mean_window_accuracy(&self) -> f64 {
        if self.records == 0 {
            return 0.0;
        }
        self.window_accuracy_sum / self.records as f64
    }
}

/// Convenience: seconds per prediction window.
pub fn window_period_s() -> f64 {
    FRAMES_PER_PREDICTION as f64 / SAMPLE_RATE_HZ
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Seizure;
    use crate::params::CHANNELS;

    fn record_with_seizure(n_windows: usize, onset_window: usize, offset_window: usize) -> Record {
        let n = n_windows * FRAMES_PER_PREDICTION;
        Record {
            samples: vec![0f32; n * CHANNELS],
            seizure: Some(Seizure {
                onset: onset_window * FRAMES_PER_PREDICTION,
                offset: offset_window * FRAMES_PER_PREDICTION,
            }),
            fs: SAMPLE_RATE_HZ,
        }
    }

    fn preds(labels: &[bool]) -> Vec<WindowPrediction> {
        labels
            .iter()
            .enumerate()
            .map(|(idx, &is_ictal)| WindowPrediction {
                idx,
                is_ictal,
                margin: if is_ictal { 1 } else { -1 },
            })
            .collect()
    }

    #[test]
    fn perfect_detection_has_one_window_delay() {
        let rec = record_with_seizure(10, 4, 8);
        // Ictal predicted exactly on the ictal windows 4..8.
        let p = preds(&[false, false, false, false, true, true, true, true, false, false]);
        let o = evaluate_record(&rec, &p, AlarmPolicy::default(), 10.0);
        assert_eq!(o.detected, Some(true));
        // First alarm at end of window 4 = sample 5*256; onset = 4*256 →
        // delay = 256 samples = 0.5 s.
        assert!((o.delay_s.unwrap() - 0.5).abs() < 1e-9);
        assert_eq!(o.false_alarms, 0);
        assert!((o.window_accuracy - 1.0).abs() < 1e-9);
    }

    #[test]
    fn late_detection_increases_delay() {
        let rec = record_with_seizure(10, 4, 8);
        let p = preds(&[false, false, false, false, false, false, true, true, false, false]);
        let o = evaluate_record(&rec, &p, AlarmPolicy::default(), 10.0);
        assert_eq!(o.detected, Some(true));
        assert!((o.delay_s.unwrap() - 1.5).abs() < 1e-9); // window 6 ends 3 windows after onset
    }

    #[test]
    fn missed_seizure() {
        let rec = record_with_seizure(10, 4, 8);
        let p = preds(&[false; 10]);
        let o = evaluate_record(&rec, &p, AlarmPolicy::default(), 10.0);
        assert_eq!(o.detected, Some(false));
        assert!(o.delay_s.is_none());
    }

    #[test]
    fn pre_onset_alarms_are_false_alarms() {
        let rec = record_with_seizure(10, 4, 8);
        let p = preds(&[true, false, false, false, true, true, true, true, false, false]);
        let o = evaluate_record(&rec, &p, AlarmPolicy::default(), 10.0);
        assert_eq!(o.false_alarms, 1);
        assert_eq!(o.detected, Some(true));
    }

    #[test]
    fn consecutive_policy_suppresses_singletons() {
        let rec = record_with_seizure(10, 4, 8);
        let p = preds(&[true, false, true, false, true, true, true, true, false, false]);
        let o = evaluate_record(
            &rec,
            &p,
            AlarmPolicy { consecutive: 2 },
            10.0,
        );
        assert_eq!(o.false_alarms, 0, "isolated pre-onset windows filtered");
        assert_eq!(o.detected, Some(true));
        // Alarm fires at end of window 5 (second consecutive) → delay 1.0 s.
        assert!((o.delay_s.unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn seizure_free_record_counts_all_alarms_false() {
        let rec = Record {
            samples: vec![0f32; 10 * FRAMES_PER_PREDICTION * CHANNELS],
            seizure: None,
            fs: SAMPLE_RATE_HZ,
        };
        let p = preds(&[false, true, false, false, true, true, false, false, false, false]);
        let o = evaluate_record(&rec, &p, AlarmPolicy::default(), 10.0);
        assert_eq!(o.detected, None);
        assert_eq!(o.false_alarms, 2); // two runs
    }

    #[test]
    fn summary_aggregation() {
        let rec = record_with_seizure(10, 4, 8);
        let hit = evaluate_record(
            &rec,
            &preds(&[false, false, false, false, true, true, true, true, false, false]),
            AlarmPolicy::default(),
            10.0,
        );
        let miss = evaluate_record(&rec, &preds(&[false; 10]), AlarmPolicy::default(), 10.0);
        let mut sum = EvalSummary::default();
        sum.add(&hit);
        sum.add(&miss);
        assert_eq!(sum.seizures, 2);
        assert_eq!(sum.detected, 1);
        assert!((sum.detection_accuracy() - 0.5).abs() < 1e-9);
        assert!((sum.mean_delay_s() - 0.5).abs() < 1e-9);
        assert!(sum.false_alarms_per_hour() == 0.0);
    }

    #[test]
    fn window_label_majority() {
        let rec = record_with_seizure(4, 1, 2);
        assert!(!window_label(&rec, 0));
        assert!(window_label(&rec, 1));
        assert!(!window_label(&rec, 2));
    }
}
