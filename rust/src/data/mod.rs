//! Data substrate: synthetic iEEG generation, dataset containers and
//! detection metrics.
//!
//! The paper evaluates on the one-shot-learning subset of the SWEC-ETHZ
//! iEEG dataset (via Burrello'18), which is not redistributable; DESIGN.md
//! §2 documents the substitution: [`synth`] generates per-patient iEEG-like
//! records whose *LBP statistics* (the only thing the classifier sees)
//! mirror the interictal/ictal contrast of the real data.

pub mod synth;
pub mod dataset;
pub mod metrics;
