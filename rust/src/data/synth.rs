//! Synthetic iEEG generator — the dataset substitution (DESIGN.md §2).
//!
//! Each *patient* has a stable electrographic signature drawn from a
//! patient-seeded RNG: a set of seizure-focus electrodes, a dominant ictal
//! rhythm (3–12 Hz, drifting), a propagation pattern to non-focus
//! electrodes and an onset build-up time. Each *record* holds one seizure
//! flanked by interictal background, mirroring the one-shot-learning
//! protocol of Burrello'18 (train on seizure 1, test on the others).
//!
//! Background activity is AR(1)-filtered noise (a serviceable stand-in for
//! the 1/f iEEG spectrum as seen by a *sign-of-difference* front-end);
//! seizures superimpose a rhythmic oscillation with an amplitude ramp.
//! What must be faithful for the reproduction is the **LBP code
//! statistics**: near-uniform code usage interictally versus strongly
//! concentrated run-length codes (long monotone stretches) ictally, focused
//! on a patient-specific electrode subset — exactly the contrast the HDC
//! classifier exploits.

use crate::params::{CHANNELS, SAMPLE_RATE_HZ};
use crate::rng::Xoshiro256;

/// Seizure annotation, in samples (expert-marked electrographic onset;
/// paper §IV-A measures detection delay from this point).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Seizure {
    pub onset: usize,
    pub offset: usize,
}

impl Seizure {
    pub fn contains(&self, sample: usize) -> bool {
        (self.onset..self.offset).contains(&sample)
    }

    pub fn duration_s(&self) -> f64 {
        (self.offset - self.onset) as f64 / SAMPLE_RATE_HZ
    }
}

/// One continuous multichannel recording with (at most) one seizure.
#[derive(Clone)]
pub struct Record {
    /// Samples, time-major: `samples[t * CHANNELS + c]`.
    pub samples: Vec<f32>,
    pub seizure: Option<Seizure>,
    pub fs: f64,
}

impl Record {
    pub fn num_samples(&self) -> usize {
        self.samples.len() / CHANNELS
    }

    pub fn duration_s(&self) -> f64 {
        self.num_samples() as f64 / self.fs
    }

    /// Multichannel sample at time `t`.
    #[inline]
    pub fn sample(&self, t: usize) -> &[f32] {
        &self.samples[t * CHANNELS..(t + 1) * CHANNELS]
    }

    #[inline]
    pub fn sample_array(&self, t: usize) -> [f32; CHANNELS] {
        let mut out = [0f32; CHANNELS];
        out.copy_from_slice(self.sample(t));
        out
    }

    /// Is sample `t` inside the annotated ictal interval?
    #[inline]
    pub fn is_ictal(&self, t: usize) -> bool {
        self.seizure.map(|s| s.contains(t)).unwrap_or(false)
    }
}

/// Generator configuration (defaults follow DESIGN.md §2).
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Records (one seizure each) per patient. One-shot protocol: record 0
    /// trains, records 1.. test.
    pub records_per_patient: usize,
    /// Interictal lead-in before the seizure (seconds).
    pub pre_s: f64,
    /// Seizure duration (seconds).
    pub ictal_s: f64,
    /// Interictal tail after the seizure (seconds).
    pub post_s: f64,
    /// Background noise scale.
    pub noise: f64,
    /// Peak ictal oscillation amplitude (relative to noise).
    pub ictal_gain: f64,
    /// Seconds for the ictal amplitude to ramp from 0 to peak.
    pub buildup_s: f64,
    /// Number of focus electrodes (others receive attenuated spread).
    pub focus_channels: usize,
    /// Attenuation of the rhythm on non-focus electrodes.
    pub spread: f64,
    /// Master seed (combined with the patient id).
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            records_per_patient: 5,
            pre_s: 60.0,
            ictal_s: 30.0,
            post_s: 30.0,
            noise: 1.0,
            ictal_gain: 14.0,
            buildup_s: 4.0,
            focus_channels: 12,
            spread: 0.25,
            seed: 0xDA7A_5EED,
        }
    }
}

impl SynthConfig {
    /// A short configuration for fast tests.
    pub fn tiny() -> Self {
        SynthConfig {
            records_per_patient: 2,
            pre_s: 6.0,
            ictal_s: 4.0,
            post_s: 2.0,
            ..Default::default()
        }
    }
}

/// Patient-level signature (stable across that patient's records).
#[derive(Clone, Debug)]
pub struct PatientProfile {
    pub id: u32,
    pub focus: Vec<usize>,
    /// Dominant ictal rhythm in Hz.
    pub rhythm_hz: f64,
    /// Per-channel phase offsets of the rhythm.
    pub phase: Vec<f64>,
    /// Patient-specific detectability scale (harder/easier patients —
    /// drives the per-patient optimal density of Fig. 4).
    pub severity: f64,
}

impl PatientProfile {
    pub fn derive(cfg: &SynthConfig, id: u32) -> Self {
        let mut rng = Xoshiro256::new(crate::rng::hash_chain(cfg.seed, &[0x9A71E17, id as u64]));
        // Choose focus electrodes without replacement.
        let mut all: Vec<usize> = (0..CHANNELS).collect();
        let mut focus = Vec::with_capacity(cfg.focus_channels);
        for _ in 0..cfg.focus_channels.min(CHANNELS) {
            let i = rng.next_below(all.len() as u64) as usize;
            focus.push(all.swap_remove(i));
        }
        focus.sort_unstable();
        let rhythm_hz = 3.0 + rng.next_f64() * 9.0; // 3–12 Hz
        let phase = (0..CHANNELS)
            .map(|_| rng.next_f64() * std::f64::consts::TAU)
            .collect();
        let severity = 0.6 + rng.next_f64() * 0.8; // 0.6–1.4
        PatientProfile {
            id,
            focus,
            rhythm_hz,
            phase,
            severity,
        }
    }
}

/// A synthetic patient: profile + generated records.
pub struct SynthPatient {
    pub profile: PatientProfile,
    pub records: Vec<Record>,
}

impl SynthPatient {
    /// Generate all records for patient `id`.
    pub fn generate(cfg: &SynthConfig, id: u32) -> Self {
        let profile = PatientProfile::derive(cfg, id);
        let records = (0..cfg.records_per_patient)
            .map(|r| generate_record(cfg, &profile, r as u32))
            .collect();
        SynthPatient { profile, records }
    }

    /// One-shot protocol: the training record.
    pub fn train_record(&self) -> &Record {
        &self.records[0]
    }

    /// One-shot protocol: the test records.
    pub fn test_records(&self) -> &[Record] {
        &self.records[1..]
    }
}

/// Generate a single record for a patient.
pub fn generate_record(cfg: &SynthConfig, profile: &PatientProfile, record_idx: u32) -> Record {
    let fs = SAMPLE_RATE_HZ;
    let mut rng = Xoshiro256::new(crate::rng::hash_chain(
        cfg.seed,
        &[0x5E12, profile.id as u64, record_idx as u64],
    ));
    let n_pre = (cfg.pre_s * fs) as usize;
    let n_ictal = (cfg.ictal_s * fs) as usize;
    let n_post = (cfg.post_s * fs) as usize;
    let n = n_pre + n_ictal + n_post;
    let onset = n_pre;
    let offset = n_pre + n_ictal;

    let mut samples = vec![0f32; n * CHANNELS];
    // AR(1) state per channel.
    let mut ar = vec![0f64; CHANNELS];
    let is_focus: Vec<bool> = {
        let mut v = vec![false; CHANNELS];
        for &f in &profile.focus {
            v[f] = true;
        }
        v
    };
    // Per-record rhythm drift (seizures differ between records).
    let rhythm = profile.rhythm_hz * (0.9 + 0.2 * rng.next_f64());
    let drift = (rng.next_f64() - 0.5) * 0.02; // Hz per second
    let peak = cfg.noise * cfg.ictal_gain * profile.severity;

    let mut phase_acc = 0.0f64;
    for t in 0..n {
        let time_s = t as f64 / fs;
        // Instantaneous rhythm frequency with slow drift.
        let f_inst = (rhythm + drift * (time_s - cfg.pre_s)).max(1.0);
        phase_acc += std::f64::consts::TAU * f_inst / fs;

        // Ictal envelope: ramp over buildup_s, then sustain with slow
        // waxing, then cut off at the annotated offset.
        let env = if t >= onset && t < offset {
            let since = (t - onset) as f64 / fs;
            let ramp = (since / cfg.buildup_s).min(1.0);
            let wax = 0.85 + 0.15 * (std::f64::consts::TAU * since / 7.0).sin();
            ramp * wax
        } else {
            0.0
        };

        for c in 0..CHANNELS {
            // Background: AR(1) low-passed white noise.
            ar[c] = 0.97 * ar[c] + rng.next_gaussian() * cfg.noise * 0.35;
            let mut x = ar[c];
            if env > 0.0 {
                let gain = if is_focus[c] { 1.0 } else { cfg.spread };
                // Rhythm plus a first harmonic for sharper (spike-wave-ish)
                // morphology; per-channel phase offsets model propagation.
                let ph = phase_acc + profile.phase[c];
                let osc = ph.sin() + 0.35 * (2.0 * ph).sin();
                x += env * peak * gain * osc;
                // Ictal state also raises broadband power.
                x += env * rng.next_gaussian() * cfg.noise * 0.15 * gain;
            }
            samples[t * CHANNELS + c] = x as f32;
        }
    }

    Record {
        samples,
        seizure: Some(Seizure { onset, offset }),
        fs,
    }
}

/// Generate a seizure-free interictal record (for false-alarm testing).
pub fn generate_interictal(cfg: &SynthConfig, profile: &PatientProfile, seconds: f64) -> Record {
    let fs = SAMPLE_RATE_HZ;
    let mut rng = Xoshiro256::new(crate::rng::hash_chain(
        cfg.seed,
        &[0x1D1E, profile.id as u64],
    ));
    let n = (seconds * fs) as usize;
    let mut samples = vec![0f32; n * CHANNELS];
    let mut ar = vec![0f64; CHANNELS];
    for t in 0..n {
        for c in 0..CHANNELS {
            ar[c] = 0.97 * ar[c] + rng.next_gaussian() * cfg.noise * 0.35;
            samples[t * CHANNELS + c] = ar[c] as f32;
        }
    }
    Record {
        samples,
        seizure: None,
        fs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lbp::LbpFrontend;

    #[test]
    fn record_shape_and_annotation() {
        let cfg = SynthConfig::tiny();
        let p = SynthPatient::generate(&cfg, 1);
        assert_eq!(p.records.len(), cfg.records_per_patient);
        let r = &p.records[0];
        let expect_n = ((cfg.pre_s + cfg.ictal_s + cfg.post_s) * SAMPLE_RATE_HZ) as usize;
        assert_eq!(r.num_samples(), expect_n);
        let s = r.seizure.unwrap();
        assert_eq!(s.onset, (cfg.pre_s * SAMPLE_RATE_HZ) as usize);
        assert!((s.duration_s() - cfg.ictal_s).abs() < 0.01);
        assert!(!r.is_ictal(0));
        assert!(r.is_ictal(s.onset));
        assert!(!r.is_ictal(s.offset));
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = SynthConfig::tiny();
        let a = SynthPatient::generate(&cfg, 3);
        let b = SynthPatient::generate(&cfg, 3);
        assert_eq!(a.records[1].samples, b.records[1].samples);
        assert_eq!(a.profile.focus, b.profile.focus);
    }

    #[test]
    fn patients_differ() {
        let cfg = SynthConfig::tiny();
        let a = SynthPatient::generate(&cfg, 1);
        let b = SynthPatient::generate(&cfg, 2);
        assert_ne!(a.profile.focus, b.profile.focus);
        assert_ne!(a.records[0].samples, b.records[0].samples);
    }

    #[test]
    fn ictal_amplitude_rises_on_focus_channels() {
        let cfg = SynthConfig::tiny();
        let p = SynthPatient::generate(&cfg, 5);
        let r = &p.records[0];
        let s = r.seizure.unwrap();
        let focus = p.profile.focus[0];
        let rms = |range: std::ops::Range<usize>| {
            let mut acc = 0.0f64;
            for t in range.clone() {
                let v = r.sample(t)[focus] as f64;
                acc += v * v;
            }
            (acc / range.len() as f64).sqrt()
        };
        let pre = rms(s.onset / 2..s.onset);
        let mid = rms(s.onset + (s.offset - s.onset) / 2..s.offset);
        assert!(
            mid > pre * 3.0,
            "ictal RMS {mid} should dominate interictal {pre}"
        );
    }

    #[test]
    fn lbp_statistics_shift_during_seizure() {
        // The property the classifier depends on: ictal LBP codes
        // concentrate (long monotone runs), interictal codes spread out.
        let cfg = SynthConfig::tiny();
        let p = SynthPatient::generate(&cfg, 7);
        let r = &p.records[0];
        let s = r.seizure.unwrap();
        let mut fe = LbpFrontend::new();
        let mut inter_hist = [0u32; 64];
        let mut ictal_hist = [0u32; 64];
        for t in 0..r.num_samples() {
            let codes = fe.push(&r.sample_array(t));
            // Use a focus channel, skip ramp-up.
            let code = codes[p.profile.focus[0]] as usize;
            if t > 64 && t < s.onset {
                inter_hist[code] += 1;
            } else if t >= s.onset + (2.0 * SAMPLE_RATE_HZ) as usize && t < s.offset {
                ictal_hist[code] += 1;
            }
        }
        let concentration = |h: &[u32; 64]| {
            let total: u32 = h.iter().sum();
            // Fraction in the two monotone-run codes {0, 63}.
            (h[0] + h[63]) as f64 / total.max(1) as f64
        };
        let ci = concentration(&inter_hist);
        let cs = concentration(&ictal_hist);
        assert!(
            cs > 2.5 * ci && cs > ci + 0.08,
            "ictal monotone-code fraction {cs} should clearly exceed interictal {ci}"
        );
    }

    #[test]
    fn interictal_record_has_no_seizure() {
        let cfg = SynthConfig::tiny();
        let profile = PatientProfile::derive(&cfg, 1);
        let r = generate_interictal(&cfg, &profile, 3.0);
        assert!(r.seizure.is_none());
        assert_eq!(r.num_samples(), (3.0 * SAMPLE_RATE_HZ) as usize);
    }
}
