//! Dependency-free error type (anyhow is unavailable in the offline build
//! environment — DESIGN.md §2).
//!
//! Mirrors the small slice of the `anyhow` idiom the crate actually uses,
//! so call sites keep their shape:
//!
//! * [`Error`] — an erased error holding a context chain (outermost
//!   context first, root cause last);
//! * [`Context`] — `.context(..)` / `.with_context(|| ..)` on both
//!   `Result` and `Option`;
//! * [`err!`](crate::err), [`bail!`](crate::bail),
//!   [`ensure!`](crate::ensure) — `format!`-style constructors.
//!
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! prints the whole chain separated by `": "` (what `main` uses for
//! one-line error output); `Debug` prints an `anyhow`-style multi-line
//! "Caused by" report (what `unwrap`/`expect` show).
//!
//! Unlike `anyhow`, the chain is flattened to strings at construction
//! time — nothing in this crate downcasts errors, and flattening keeps
//! the type trivially `Send + Sync` for the engine-worker channels.

use std::fmt;

/// Crate-wide boxed error with context chaining.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain[last]` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (the root cause).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain on one line, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any standard error converts via `?`, flattening its `source()` chain.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `.context(..)` / `.with_context(|| ..)` for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> crate::Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> crate::Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> crate::Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> crate::Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> crate::Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> crate::Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` shim).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::err!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::error::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn msg_and_display() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
        assert_eq!(e.root_cause(), "boom");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Error = io_err().into();
        let e = e.context("reading dataset").context("loading patient 3");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(
            chain,
            vec!["loading patient 3", "reading dataset", "file missing"]
        );
        assert_eq!(format!("{e}"), "loading patient 3");
        assert_eq!(
            format!("{e:#}"),
            "loading patient 3: reading dataset: file missing"
        );
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn debug_prints_caused_by() {
        let e = Error::msg("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("root"), "{dbg}");
    }

    #[test]
    fn result_context_trait() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening config: file missing");

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("opening {}", "x.toml")).unwrap_err();
        assert_eq!(format!("{e:#}"), "opening x.toml: file missing");
    }

    #[test]
    fn option_context_trait() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing key");
        let v: Option<u32> = Some(7);
        assert_eq!(v.context("missing key").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert!(format!("{e}").contains("invalid digit"), "{e}");
    }

    #[test]
    fn err_bail_ensure_formatting() {
        fn check(n: usize) -> crate::Result<usize> {
            ensure!(n != 3, "n must not be 3, got {n}");
            if n > 10 {
                bail!("n too large: {n}");
            }
            Ok(n)
        }
        assert_eq!(check(5).unwrap(), 5);
        assert_eq!(format!("{}", check(3).unwrap_err()), "n must not be 3, got 3");
        assert_eq!(format!("{}", check(11).unwrap_err()), "n too large: 11");

        let e = err!("code {:#04x}", 7);
        assert_eq!(format!("{e}"), "code 0x07");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn check(n: usize) -> crate::Result<()> {
            ensure!(n < 2);
            Ok(())
        }
        let e = check(5).unwrap_err();
        assert_eq!(format!("{e}"), "condition failed: `n < 2`");
    }

    #[test]
    fn source_chain_is_flattened() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer failed")
            }
        }
        impl std::error::Error for Outer {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }
        let e: Error = Outer(io_err()).into();
        assert_eq!(format!("{e:#}"), "outer failed: file missing");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
