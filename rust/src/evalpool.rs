//! Sharded evaluation pool.
//!
//! The sweep commands (`repro fig4`, the density-sweep example, the
//! §III-B ablation, the Fig. 5 design comparison) and the coordinator's
//! session setup all run many independent `(variant × max-density ×
//! patient)` jobs. This module shards such job lists over a
//! `std::thread::scope` worker pool:
//!
//! * **deterministic ordering** — results come back in input order
//!   regardless of which worker finished first, so parallel output is
//!   byte-identical to the serial loop (`tests/kernels.rs` pins this);
//! * **work stealing by index** — workers pull the next unclaimed job
//!   from a shared atomic cursor, so long jobs (big patients) don't
//!   stall a statically assigned shard;
//! * **no runtime dependencies** — scoped threads borrow the job slice
//!   and the closure directly; each result lands in its own slot, and a
//!   panicking job's payload is re-raised in the caller with its
//!   original message.
//!
//! Worker count defaults to the machine's available parallelism and can
//! be pinned with `EVAL_WORKERS=<n>` (`EVAL_WORKERS=1` forces the serial
//! path — useful for profiling and for A/B-ing determinism).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: `EVAL_WORKERS` override, else available parallelism.
pub fn default_workers() -> usize {
    if let Some(n) = std::env::var("EVAL_WORKERS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every job on the default worker count; results are
/// returned in input order.
pub fn map<T, R, F>(jobs: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    map_with(default_workers(), jobs, f)
}

/// Apply `f` to every job on `workers` threads; results are returned in
/// input order. `workers <= 1` (or a 0/1-job list) runs inline with no
/// threads spawned.
pub fn map_with<T, R, F>(workers: usize, jobs: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.clamp(1, jobs.len().max(1));
    if workers == 1 {
        return jobs.iter().map(&f).collect();
    }

    let cursor = AtomicUsize::new(0);
    // One slot per job; each index is claimed by exactly one worker, so
    // the per-slot mutexes are never contended — they only carry the
    // value across the thread boundary.
    let slots: Vec<Mutex<Option<R>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    let panicked: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    // Early cancel: once any job panics, no worker claims further jobs
    // (matching the serial path's abort-on-first-failure wall-clock).
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                match catch_unwind(AssertUnwindSafe(|| f(&jobs[i]))) {
                    Ok(r) => *slots[i].lock().unwrap() = Some(r),
                    Err(payload) => {
                        *panicked.lock().unwrap() = Some(payload);
                        stop.store(true, Ordering::Relaxed);
                        break;
                    }
                }
            });
        }
    });
    if let Some(payload) = panicked.into_inner().unwrap() {
        resume_unwind(payload);
    }
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("worker filled every claimed slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let jobs: Vec<usize> = (0..257).collect();
        let out = map_with(8, &jobs, |&j| j * 3);
        assert_eq!(out, jobs.iter().map(|j| j * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_matches_serial() {
        let jobs: Vec<u64> = (0..100).collect();
        let f = |&j: &u64| crate::rng::splitmix64_mix(j);
        assert_eq!(map_with(1, &jobs, f), map_with(7, &jobs, f));
    }

    #[test]
    fn degenerate_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(map_with(4, &empty, |&j| j).is_empty());
        assert_eq!(map_with(4, &[41u32], |&j| j + 1), vec![42]);
        assert_eq!(map_with(0, &[1u32, 2], |&j| j), vec![1, 2]);
    }

    #[test]
    fn uneven_job_durations_still_ordered() {
        // Early jobs sleep longest — a finish-order collector would come
        // back reversed.
        let jobs: Vec<u64> = (0..16).collect();
        let out = map_with(8, &jobs, |&j| {
            std::thread::sleep(std::time::Duration::from_millis(16 - j));
            j
        });
        assert_eq!(out, jobs);
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    #[should_panic(expected = "job 3 panicked")]
    fn job_panics_propagate_with_message() {
        let jobs: Vec<usize> = (0..8).collect();
        let _ = map_with(4, &jobs, |&j| {
            if j == 3 {
                panic!("job 3 panicked");
            }
            j
        });
    }
}
