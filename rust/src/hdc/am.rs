//! Associative memory and similarity search — paper §II-D.
//!
//! The AM stores one class-representing HV per class (interictal, ictal).
//! Sparse-HDC similarity is the overlap `popcount(query AND class)` —
//! "there is no information in the 0-bits". The hardware computes the two
//! class scores sequentially over two cycles with one AND-gate array +
//! adder tree; the model exposes both scores plus the argmax.

use crate::params::{CLASS_ICTAL, CLASS_INTERICTAL, NUM_CLASSES};

use super::hv::Hv;

/// The associative memory for the 2-class seizure detector.
#[derive(Clone, Debug)]
pub struct AssociativeMemory {
    /// `classes[CLASS_INTERICTAL]`, `classes[CLASS_ICTAL]`.
    pub classes: [Hv; NUM_CLASSES],
}

/// Result of one similarity search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchResult {
    /// Winning class index (ties break toward interictal, i.e. a strict
    /// `ictal > interictal` comparator — conservative for false alarms).
    pub class: usize,
    /// Overlap scores per class.
    pub scores: [u32; NUM_CLASSES],
}

impl SearchResult {
    pub fn is_ictal(&self) -> bool {
        self.class == CLASS_ICTAL
    }

    /// Signed margin `ictal - interictal` (decision confidence).
    pub fn margin(&self) -> i64 {
        self.scores[CLASS_ICTAL] as i64 - self.scores[CLASS_INTERICTAL] as i64
    }
}

impl AssociativeMemory {
    pub fn new(interictal: Hv, ictal: Hv) -> Self {
        let mut classes = [Hv::zero(); NUM_CLASSES];
        classes[CLASS_INTERICTAL] = interictal;
        classes[CLASS_ICTAL] = ictal;
        AssociativeMemory { classes }
    }

    /// Sparse similarity search: AND + popcount per class, argmax.
    pub fn search(&self, query: &Hv) -> SearchResult {
        let mut scores = [0u32; NUM_CLASSES];
        for (i, class) in self.classes.iter().enumerate() {
            scores[i] = query.overlap(class);
        }
        let class = if scores[CLASS_ICTAL] > scores[CLASS_INTERICTAL] {
            CLASS_ICTAL
        } else {
            CLASS_INTERICTAL
        };
        SearchResult { class, scores }
    }

    /// Serialize to i32 planes for the PJRT artifacts (`int32[2,1024]`).
    pub fn to_i32s(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(NUM_CLASSES * crate::params::DIM);
        for c in &self.classes {
            out.extend(c.to_i32s());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn search_prefers_overlapping_class() {
        let mut rng = Xoshiro256::new(1);
        let inter = Hv::random(&mut rng, 0.25);
        let ictal = Hv::random(&mut rng, 0.25);
        let am = AssociativeMemory::new(inter, ictal);
        // Query = subset of ictal bits → ictal must win.
        let query = ictal.and(&Hv::random(&mut rng, 0.8));
        let r = am.search(&query);
        assert!(r.is_ictal());
        assert_eq!(r.scores[CLASS_ICTAL], query.overlap(&ictal));
        assert!(r.margin() > 0);
    }

    #[test]
    fn tie_breaks_interictal() {
        let am = AssociativeMemory::new(Hv::zero(), Hv::zero());
        let mut q = Hv::zero();
        q.set(5, true);
        let r = am.search(&q);
        assert_eq!(r.class, CLASS_INTERICTAL);
        assert_eq!(r.scores, [0, 0]);
        assert_eq!(r.margin(), 0);
    }

    #[test]
    fn scores_match_manual_overlap() {
        let mut rng = Xoshiro256::new(2);
        let inter = Hv::random(&mut rng, 0.3);
        let ictal = Hv::random(&mut rng, 0.3);
        let q = Hv::random(&mut rng, 0.25);
        let am = AssociativeMemory::new(inter, ictal);
        let r = am.search(&q);
        assert_eq!(r.scores[0], q.overlap(&inter));
        assert_eq!(r.scores[1], q.overlap(&ictal));
    }

    #[test]
    fn i32_serialization_shape() {
        let am = AssociativeMemory::new(Hv::zero(), Hv::ones());
        let v = am.to_i32s();
        assert_eq!(v.len(), NUM_CLASSES * crate::params::DIM);
        assert!(v[..crate::params::DIM].iter().all(|&x| x == 0));
        assert!(v[crate::params::DIM..].iter().all(|&x| x == 1));
    }
}
