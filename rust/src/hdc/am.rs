//! Associative memory and similarity search — paper §II-D.
//!
//! The AM stores one class-representing HV per class (interictal, ictal).
//! Sparse-HDC similarity is the overlap `popcount(query AND class)` —
//! "there is no information in the 0-bits". The hardware computes the two
//! class scores sequentially over two cycles with one AND-gate array +
//! adder tree; the model exposes both scores plus the argmax.
//!
//! ## Batched search
//!
//! The hardware amortises its AM loads across the AND-popcount array;
//! the software mirror is [`AssociativeMemory::search_batch`]: the class
//! HVs are held once and every query streams through a fused word-wise
//! kernel that produces both class scores in a single pass. The dense
//! design's Hamming scoring sits behind the same interface via
//! [`Metric`], so every caller — `Classifier`, the native window engine,
//! the engine pool — scores through one code path. [`AmPlane`] carries
//! the AM in both engine representations (flat i32 plane for the PJRT
//! artifacts, packed HVs for the native engine) with the decode done at
//! most once per instance.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::ensure;
use crate::params::{CLASS_ICTAL, CLASS_INTERICTAL, DIM, NUM_CLASSES};

use super::hv::Hv;
use super::simd::{self, KernelSet};

/// The associative memory for the 2-class seizure detector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AssociativeMemory {
    /// `classes[CLASS_INTERICTAL]`, `classes[CLASS_ICTAL]`.
    pub classes: [Hv; NUM_CLASSES],
}

/// Similarity metric of a search, normalised to "bigger = more similar".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Sparse AND-popcount overlap (paper §II-D).
    Overlap,
    /// Dense similarity `DIM - hamming(query, class)` (Burrello'18).
    Hamming,
}

/// Result of one similarity search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchResult {
    /// Winning class index (ties break toward interictal, i.e. a strict
    /// `ictal > interictal` comparator — conservative for false alarms).
    pub class: usize,
    /// Overlap scores per class.
    pub scores: [u32; NUM_CLASSES],
}

impl SearchResult {
    /// Argmax with the hardware tie-break (strict `ictal > interictal`).
    pub fn from_scores(scores: [u32; NUM_CLASSES]) -> SearchResult {
        let class = if scores[CLASS_ICTAL] > scores[CLASS_INTERICTAL] {
            CLASS_ICTAL
        } else {
            CLASS_INTERICTAL
        };
        SearchResult { class, scores }
    }

    pub fn is_ictal(&self) -> bool {
        self.class == CLASS_ICTAL
    }

    /// Signed margin `ictal - interictal` (decision confidence).
    pub fn margin(&self) -> i64 {
        self.scores[CLASS_ICTAL] as i64 - self.scores[CLASS_INTERICTAL] as i64
    }
}

impl AssociativeMemory {
    pub fn new(interictal: Hv, ictal: Hv) -> Self {
        let mut classes = [Hv::zero(); NUM_CLASSES];
        classes[CLASS_INTERICTAL] = interictal;
        classes[CLASS_ICTAL] = ictal;
        AssociativeMemory { classes }
    }

    /// Sparse similarity search: AND + popcount per class, argmax.
    pub fn search(&self, query: &Hv) -> SearchResult {
        SearchResult::from_scores(self.score2(query, Metric::Overlap))
    }

    /// Dense similarity search: `DIM - hamming` per class, argmax — the
    /// same normalised [`SearchResult`] contract as the sparse search.
    pub fn search_dense(&self, query: &Hv) -> SearchResult {
        SearchResult::from_scores(self.score2(query, Metric::Hamming))
    }

    /// Batched similarity search: the class HVs are loaded once and every
    /// query streams through the fused two-class kernel. Bit-exact with
    /// N calls to [`Self::search`] / [`Self::search_dense`] at every
    /// batch size (including 0 and 1) — `tests/batching.rs` pins this.
    pub fn search_batch(&self, queries: &[Hv], metric: Metric) -> Vec<SearchResult> {
        self.search_batch_with(queries, metric, simd::active())
    }

    /// [`Self::search_batch`] with an explicit kernel set (benches and
    /// the bit-exactness fuzz run scalar and SIMD side by side).
    pub fn search_batch_with(
        &self,
        queries: &[Hv],
        metric: Metric,
        ks: &KernelSet,
    ) -> Vec<SearchResult> {
        queries
            .iter()
            .map(|q| SearchResult::from_scores(self.score2_with(q, metric, ks)))
            .collect()
    }

    /// Fused two-class scoring: one pass over the query words produces
    /// both class scores — the software mirror of the hardware's 2-cycle
    /// AND-popcount array reusing the loaded AM row. The word loop is the
    /// kernel set's fused AND/XOR-popcount (vectorized under AVX2/NEON).
    fn score2(&self, query: &Hv, metric: Metric) -> [u32; NUM_CLASSES] {
        self.score2_with(query, metric, simd::active())
    }

    fn score2_with(&self, query: &Hv, metric: Metric, ks: &KernelSet) -> [u32; NUM_CLASSES] {
        let c0 = &self.classes[CLASS_INTERICTAL];
        let c1 = &self.classes[CLASS_ICTAL];
        match metric {
            Metric::Overlap => (ks.overlap2)(query, c0, c1),
            Metric::Hamming => {
                let [d0, d1] = (ks.hamming2)(query, c0, c1);
                [DIM as u32 - d0, DIM as u32 - d1]
            }
        }
    }

    /// Serialize to i32 planes for the PJRT artifacts (`int32[2,1024]`).
    pub fn to_i32s(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(NUM_CLASSES * DIM);
        for c in &self.classes {
            out.extend(c.to_i32s());
        }
        out
    }
}

/// An AM in both engine representations: the flat `int32[NUM_CLASSES *
/// DIM]` plane the PJRT artifacts take as an input, plus the packed class
/// HVs the native engine scores with. The decode happens at most once per
/// instance, so jobs sharing one `Arc<AmPlane>` (a session's model) never
/// re-parse the plane — the path this replaces rebuilt both class HVs
/// from the i32s on *every* engine call.
pub struct AmPlane {
    i32s: Vec<i32>,
    decoded: OnceLock<AssociativeMemory>,
    decodes: AtomicUsize,
}

impl AmPlane {
    /// Wrap a flat i32 plane (length-checked; decode deferred to first
    /// [`Self::memory`] call).
    pub fn from_i32s(plane: &[i32]) -> crate::Result<AmPlane> {
        ensure!(
            plane.len() == NUM_CLASSES * DIM,
            "am plane length {} != {}",
            plane.len(),
            NUM_CLASSES * DIM
        );
        Ok(AmPlane {
            i32s: plane.to_vec(),
            decoded: OnceLock::new(),
            decodes: AtomicUsize::new(0),
        })
    }

    /// Build from a trained AM: both representations are known up front,
    /// so the serving path never decodes at all.
    pub fn from_memory(am: &AssociativeMemory) -> AmPlane {
        let plane = AmPlane {
            i32s: am.to_i32s(),
            decoded: OnceLock::new(),
            decodes: AtomicUsize::new(0),
        };
        let _ = plane.decoded.set(am.clone());
        plane
    }

    /// The flat i32 plane (PJRT marshalling layout).
    pub fn i32s(&self) -> &[i32] {
        &self.i32s
    }

    /// The decoded class HVs; the first call decodes, later calls reuse.
    pub fn memory(&self) -> &AssociativeMemory {
        self.decoded.get_or_init(|| {
            self.decodes.fetch_add(1, Ordering::Relaxed);
            let class = |c: usize| {
                let p = &self.i32s[c * DIM..(c + 1) * DIM];
                Hv::from_fn(|i| p[i] != 0)
            };
            AssociativeMemory::new(class(CLASS_INTERICTAL), class(CLASS_ICTAL))
        })
    }

    /// How many times the i32 plane has been decoded (0 or 1) —
    /// regression guard for the per-call rebuild this type replaced.
    pub fn decode_count(&self) -> usize {
        self.decodes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn search_prefers_overlapping_class() {
        let mut rng = Xoshiro256::new(1);
        let inter = Hv::random(&mut rng, 0.25);
        let ictal = Hv::random(&mut rng, 0.25);
        let am = AssociativeMemory::new(inter, ictal);
        // Query = subset of ictal bits → ictal must win.
        let query = ictal.and(&Hv::random(&mut rng, 0.8));
        let r = am.search(&query);
        assert!(r.is_ictal());
        assert_eq!(r.scores[CLASS_ICTAL], query.overlap(&ictal));
        assert!(r.margin() > 0);
    }

    #[test]
    fn tie_breaks_interictal() {
        let am = AssociativeMemory::new(Hv::zero(), Hv::zero());
        let mut q = Hv::zero();
        q.set(5, true);
        let r = am.search(&q);
        assert_eq!(r.class, CLASS_INTERICTAL);
        assert_eq!(r.scores, [0, 0]);
        assert_eq!(r.margin(), 0);
    }

    #[test]
    fn scores_match_manual_overlap() {
        let mut rng = Xoshiro256::new(2);
        let inter = Hv::random(&mut rng, 0.3);
        let ictal = Hv::random(&mut rng, 0.3);
        let q = Hv::random(&mut rng, 0.25);
        let am = AssociativeMemory::new(inter, ictal);
        let r = am.search(&q);
        assert_eq!(r.scores[0], q.overlap(&inter));
        assert_eq!(r.scores[1], q.overlap(&ictal));
    }

    #[test]
    fn i32_serialization_shape() {
        let am = AssociativeMemory::new(Hv::zero(), Hv::ones());
        let v = am.to_i32s();
        assert_eq!(v.len(), NUM_CLASSES * crate::params::DIM);
        assert!(v[..crate::params::DIM].iter().all(|&x| x == 0));
        assert!(v[crate::params::DIM..].iter().all(|&x| x == 1));
    }

    #[test]
    fn dense_search_is_normalised_hamming() {
        let mut rng = Xoshiro256::new(3);
        let inter = Hv::random_half(&mut rng);
        let ictal = Hv::random_half(&mut rng);
        let q = Hv::random_half(&mut rng);
        let am = AssociativeMemory::new(inter, ictal);
        let r = am.search_dense(&q);
        assert_eq!(r.scores[0], DIM as u32 - q.hamming(&inter));
        assert_eq!(r.scores[1], DIM as u32 - q.hamming(&ictal));
        // A query equal to a class HV must pick that class at full score.
        let exact = am.search_dense(&ictal);
        assert!(exact.is_ictal());
        assert_eq!(exact.scores[CLASS_ICTAL], DIM as u32);
    }

    #[test]
    fn batch_matches_serial_both_metrics() {
        let mut rng = Xoshiro256::new(4);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let queries: Vec<Hv> = (0..17).map(|_| Hv::random(&mut rng, 0.25)).collect();
        let batch = am.search_batch(&queries, Metric::Overlap);
        for (q, r) in queries.iter().zip(&batch) {
            assert_eq!(*r, am.search(q));
        }
        let batch = am.search_batch(&queries, Metric::Hamming);
        for (q, r) in queries.iter().zip(&batch) {
            assert_eq!(*r, am.search_dense(q));
        }
        assert!(am.search_batch(&[], Metric::Overlap).is_empty());
    }

    #[test]
    fn am_plane_roundtrip_and_lazy_decode() {
        let mut rng = Xoshiro256::new(5);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let plane = AmPlane::from_i32s(&am.to_i32s()).unwrap();
        assert_eq!(plane.decode_count(), 0, "decode is deferred");
        assert_eq!(plane.memory().classes, am.classes);
        let first = plane.memory() as *const AssociativeMemory;
        assert_eq!(plane.memory() as *const AssociativeMemory, first);
        assert_eq!(plane.decode_count(), 1, "decode happens exactly once");
        assert_eq!(plane.i32s(), &am.to_i32s()[..]);
    }

    #[test]
    fn am_plane_from_memory_never_decodes() {
        let am = AssociativeMemory::new(Hv::zero(), Hv::ones());
        let plane = AmPlane::from_memory(&am);
        assert_eq!(plane.memory().classes, am.classes);
        assert_eq!(plane.decode_count(), 0);
        assert_eq!(plane.i32s().len(), NUM_CLASSES * DIM);
    }

    #[test]
    fn am_plane_rejects_bad_length() {
        assert!(AmPlane::from_i32s(&[0i32; 5]).is_err());
    }
}
