//! Shared bit-plane (bit-sliced) counter primitives.
//!
//! Both word-parallel counter structures — the spatial adder tree
//! ([`super::bundling::SpatialCounts`], 7 planes) and the temporal
//! accumulator ([`super::temporal::TemporalAccumulator`], 8 planes) —
//! store per-element counts as `N` bit planes over the 16 × u64 HV
//! words: plane `b` holds bit `b` of the counts of elements
//! `w*64..w*64+64`. The operations they share live here, so the
//! carry-save adder, the magnitude comparator and the transpose have
//! exactly one scalar implementation each.
//!
//! Two shapes are exposed: the original const-generic per-word helpers
//! (kept as the always-available reference semantics) and whole-HV
//! kernels over *plane slices* (`&[[u64; WORDS]]`). The slice shape is
//! what the runtime-dispatched SIMD tier ([`super::simd`]) mirrors —
//! every [`super::simd::KernelSet`] entry is pinned bit-exact against
//! the slice kernels in this file.

use crate::params::DIM;

use super::hv::{Hv, WORDS};

/// Ripple-carry add of the set bits of `bits` into word column `w`
/// (LSB plane first). Returns the carry out of the top plane — `0`
/// unless a counter wrapped; the caller decides whether that is an
/// overflow (spatial: impossible by construction) or a saturation to
/// fix up (temporal). Early-exits once the carry dies.
#[inline]
pub fn ripple_add<const N: usize>(planes: &mut [[u64; WORDS]; N], w: usize, bits: u64) -> u64 {
    let mut carry = bits;
    for plane in planes.iter_mut() {
        if carry == 0 {
            return 0;
        }
        let sum = plane[w] ^ carry;
        carry &= plane[w];
        plane[w] = sum;
    }
    carry
}

/// Whole-HV carry-save add of the set bits of `hv` into every word
/// column at once. Returns the OR of the per-column carries out of the
/// top plane — `0` unless at least one counter wrapped. This is the
/// scalar `KernelSet::plane_add` kernel; spatial bundling asserts the
/// carry is zero (fan-in bounded by construction).
pub fn plane_add(planes: &mut [[u64; WORDS]], hv: &Hv) -> u64 {
    let mut spilled = 0u64;
    for (w, &bits) in hv.words.iter().enumerate() {
        let mut carry = bits;
        for plane in planes.iter_mut() {
            if carry == 0 {
                break;
            }
            let sum = plane[w] ^ carry;
            carry &= plane[w];
            plane[w] = sum;
        }
        spilled |= carry;
    }
    spilled
}

/// [`plane_add`] with temporal saturation semantics: any column whose
/// counter wraps is clamped back to all-ones (`2^N - 1`) instead of
/// wrapping to the small residue the ripple left behind. This is the
/// scalar `KernelSet::plane_add_saturating` kernel.
pub fn plane_add_saturating(planes: &mut [[u64; WORDS]], hv: &Hv) {
    for (w, &bits) in hv.words.iter().enumerate() {
        let mut carry = bits;
        for plane in planes.iter_mut() {
            if carry == 0 {
                break;
            }
            let sum = plane[w] ^ carry;
            carry &= plane[w];
            plane[w] = sum;
        }
        if carry != 0 {
            for plane in planes.iter_mut() {
                plane[w] |= carry;
            }
        }
    }
}

/// Branchless word-level `count >= threshold` over bit-sliced planes:
/// walk the planes MSB→LSB keeping per-column "greater" /
/// "equal-so-far" masks. Caller handles the trivial thresholds
/// (`0` → all ones, `>= 1 << N` → all zeros).
pub fn ge_threshold<const N: usize>(planes: &[[u64; WORDS]; N], threshold: u64) -> Hv {
    ge_threshold_planes(planes, threshold)
}

/// Slice-shaped [`ge_threshold`] — the scalar `KernelSet::ge_threshold`
/// kernel (fn pointers need a monomorphic signature).
pub fn ge_threshold_planes(planes: &[[u64; WORDS]], threshold: u64) -> Hv {
    debug_assert!(threshold >= 1 && threshold < (1u64 << planes.len()));
    let mut out = Hv::zero();
    for w in 0..WORDS {
        let mut gt = 0u64;
        let mut eq = u64::MAX;
        for b in (0..planes.len()).rev() {
            let p = planes[b][w];
            if (threshold >> b) & 1 == 1 {
                eq &= p;
            } else {
                gt |= eq & p;
            }
        }
        out.words[w] = gt | eq;
    }
    out
}

/// Transpose bit-sliced planes back to per-element counts (diagnostic /
/// tuning path — the hot paths never materialize this).
pub fn transpose_counts<const N: usize>(planes: &[[u64; WORDS]; N]) -> Box<[u16; DIM]> {
    transpose_counts_planes(planes)
}

/// Slice-shaped [`transpose_counts`] — the scalar
/// `KernelSet::transpose_counts` kernel.
pub fn transpose_counts_planes(planes: &[[u64; WORDS]]) -> Box<[u16; DIM]> {
    let mut out = Box::new([0u16; DIM]);
    for w in 0..WORDS {
        for (b, plane) in planes.iter().enumerate() {
            let mut bits = plane[w];
            while bits != 0 {
                let i = bits.trailing_zeros() as usize;
                out[w * 64 + i] |= 1 << b;
                bits &= bits - 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ripple_add_counts_and_overflows() {
        let mut planes = [[0u64; WORDS]; 2];
        // Three adds of the same bit: count goes 1, 2, 3.
        assert_eq!(ripple_add(&mut planes, 0, 1), 0);
        assert_eq!(ripple_add(&mut planes, 0, 1), 0);
        assert_eq!(ripple_add(&mut planes, 0, 1), 0);
        assert_eq!(transpose_counts(&planes)[0], 3);
        // Fourth add wraps a 2-bit counter: carry out reports it.
        assert_eq!(ripple_add(&mut planes, 0, 1), 1);
        assert_eq!(transpose_counts(&planes)[0], 0);
    }

    #[test]
    fn ge_threshold_matches_scalar_compare() {
        let mut planes = [[0u64; WORDS]; 4];
        for (i, count) in [0u64, 1, 5, 7, 8, 15].iter().enumerate() {
            for _ in 0..*count {
                ripple_add(&mut planes, 0, 1 << i);
            }
        }
        let counts = transpose_counts(&planes);
        for t in 1..16u64 {
            let hv = ge_threshold(&planes, t);
            for i in 0..6 {
                assert_eq!(hv.get(i), counts[i] as u64 >= t, "element {i} t {t}");
            }
        }
    }
}
