//! Spatial bundling — paper §II-C and §III-B.
//!
//! The spatial encoder combines the 64 bound HVs of one frame:
//!
//! * **baseline**: a per-element adder tree over the 64 inputs followed by
//!   a thinning threshold (`count >= threshold` → 1),
//! * **optimized**: the thinning is removed (64 HVs of density 0.78% can
//!   reach at most 50% density, so the HV can never saturate) and the adder
//!   trees collapse into OR trees.
//!
//! `OR == threshold 1` exactly; the baseline design point uses a
//! configurable threshold ≥ 1 (the hyperparameter trades density against
//! algorithmic performance, §II-C). Both implementations are provided in
//! the bit domain (as the hardware computes) and in the position domain
//! (as the CompIM-fed optimized datapath computes); equivalence is tested.
//!
//! ## Word-parallel hot path
//!
//! The adder trees are modelled with *bit-sliced* carry-save counters
//! ([`SpatialCounts`]): plane `b` holds bit `b` of every element's count,
//! so adding one input HV is a word-wise ripple-carry over at most
//! [`SPATIAL_PLANES`] planes (64 counters advance per u64 operation), and
//! thinning is a branchless word-level magnitude comparator. The original
//! per-bit implementations are retained as `*_reference` functions;
//! `tests/kernels.rs` pins the two bit-exactly against each other.

use crate::params::{CHANNELS, DIM, SEG_LEN};

use super::bitplanes;
use super::hv::{Hv, WORDS, WORDS_PER_SEG};
use super::simd::{self, KernelSet};
use super::sparse::SparseHv;

/// Bit planes of one [`SpatialCounts`]: counts reach at most the fan-in
/// (64 channels), so 7 planes hold any value in `0..=127` and the top
/// carry out of plane 6 can never fire for valid inputs.
pub const SPATIAL_PLANES: usize = 7;

/// Bit-sliced per-element counters for the spatial adder tree: 64
/// counters per u64 word, one bit plane per counter bit. This is the
/// software mirror of the hardware argument — the adder tree is a column
/// of carry-save adders, and modelling it column-wise makes the golden
/// model word-parallel instead of per-bit.
///
/// Capacity is [`SPATIAL_PLANES`] bits: at most 127 accumulated inputs.
/// `add_*` panic past that rather than wrapping silently (the
/// `bundle_adder_thin*` wrappers route larger fan-ins to the scalar
/// path instead).
#[derive(Clone)]
pub struct SpatialCounts {
    /// `planes[b][w]` = bit `b` of the counts of elements `w*64..w*64+64`.
    planes: [[u64; WORDS]; SPATIAL_PLANES],
    inputs: usize,
}

impl Default for SpatialCounts {
    fn default() -> Self {
        Self::new()
    }
}

impl SpatialCounts {
    pub fn new() -> Self {
        SpatialCounts {
            planes: [[0u64; WORDS]; SPATIAL_PLANES],
            inputs: 0,
        }
    }

    /// Number of HVs accumulated so far.
    pub fn inputs(&self) -> usize {
        self.inputs
    }

    /// Add one bit-domain HV: word-wise ripple-carry across the planes,
    /// through the process-wide [`simd::active`] kernel set.
    pub fn add_hv(&mut self, hv: &Hv) {
        self.add_hv_with(hv, simd::active());
    }

    /// [`Self::add_hv`] with an explicit kernel set (benches and the
    /// bit-exactness fuzz run scalar and SIMD side by side).
    pub fn add_hv_with(&mut self, hv: &Hv, ks: &KernelSet) {
        let carry = (ks.plane_add)(&mut self.planes, hv);
        assert_eq!(carry, 0, "spatial counter overflow (> 127 inputs)");
        self.inputs += 1;
    }

    /// Add one position-domain HV: scatter its 8 one-bits, rippling one
    /// word column per segment (the CompIM datapath's 7→128 decode feeds
    /// exactly one counter column per segment).
    pub fn add_sparse(&mut self, hv: &SparseHv) {
        for (s, &p) in hv.pos.iter().enumerate() {
            let w = s * WORDS_PER_SEG + ((p as usize) >> 6);
            let carry = bitplanes::ripple_add(&mut self.planes, w, 1u64 << (p & 63));
            assert_eq!(carry, 0, "spatial counter overflow (> 127 inputs)");
        }
        self.inputs += 1;
    }

    /// Thin to a binary HV (`count >= threshold`) with the branchless
    /// word-level magnitude comparator ([`bitplanes::ge_threshold`]).
    pub fn thin(&self, threshold: u16) -> Hv {
        self.thin_with(threshold, simd::active())
    }

    /// [`Self::thin`] with an explicit kernel set.
    pub fn thin_with(&self, threshold: u16, ks: &KernelSet) -> Hv {
        if threshold == 0 {
            return Hv::ones();
        }
        if (threshold as usize) >= (1 << SPATIAL_PLANES) {
            return Hv::zero();
        }
        (ks.ge_threshold)(&self.planes, threshold as u64)
    }

    /// Transpose back to per-element counts (diagnostics / the activity
    /// model; the hot path never materializes this).
    pub fn counts(&self) -> Box<[u16; DIM]> {
        self.counts_with(simd::active())
    }

    /// [`Self::counts`] with an explicit kernel set.
    pub fn counts_with(&self, ks: &KernelSet) -> Box<[u16; DIM]> {
        (ks.transpose_counts)(&self.planes)
    }
}

/// Does a fan-in of `n` inputs fit the bit-sliced planes? The hardware
/// fan-in is 64 channels; anything larger than 127 takes the exact
/// scalar path instead (cold, but keeps the public u16 contract).
fn fits_planes(n: usize) -> bool {
    n < (1 << SPATIAL_PLANES)
}

/// Per-element counts of 1-bits across a set of HVs (the adder-tree
/// outputs). Max count = number of inputs (64 → fits u16 easily).
///
/// Materializing u16 counts is fastest as a direct scatter — the
/// bit-sliced planes only win when thinning *without* materializing
/// (see [`bundle_adder_thin`] / [`bundle_adder_thin_pos`]) — so this
/// delegates to the scatter implementation.
pub fn element_counts(bound: &[Hv]) -> Box<[u16; DIM]> {
    element_counts_reference(bound)
}

/// Scalar reference for [`element_counts`] (per-bit scatter).
pub fn element_counts_reference(bound: &[Hv]) -> Box<[u16; DIM]> {
    let mut counts = Box::new([0u16; DIM]);
    for hv in bound {
        for (w, &word) in hv.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                counts[w * 64 + b] += 1;
                bits &= bits - 1;
            }
        }
    }
    counts
}

/// Position-domain counts. Same materialization argument as
/// [`element_counts`]: the direct scatter is the fast path here.
pub fn element_counts_pos(bound: &[SparseHv]) -> Box<[u16; DIM]> {
    element_counts_pos_reference(bound)
}

/// Scalar reference for [`element_counts_pos`] (per-position scatter).
pub fn element_counts_pos_reference(bound: &[SparseHv]) -> Box<[u16; DIM]> {
    let mut counts = Box::new([0u16; DIM]);
    for hv in bound {
        for (s, &p) in hv.pos.iter().enumerate() {
            counts[s * SEG_LEN + p as usize] += 1;
        }
    }
    counts
}

/// Thinning: threshold the counts back to a binary HV. Assembles each
/// output word branchlessly instead of going through `Hv::set`.
pub fn thin(counts: &[u16; DIM], threshold: u16) -> Hv {
    let mut hv = Hv::zero();
    for (w, word) in hv.words.iter_mut().enumerate() {
        let base = w * 64;
        let mut bits = 0u64;
        for b in 0..64 {
            bits |= ((counts[base + b] >= threshold) as u64) << b;
        }
        *word = bits;
    }
    hv
}

/// Scalar reference for [`thin`] (per-bit `Hv::from_fn`).
pub fn thin_reference(counts: &[u16; DIM], threshold: u16) -> Hv {
    Hv::from_fn(|i| counts[i] >= threshold)
}

/// Baseline spatial bundling: adder tree + thinning, bit domain. The hot
/// path stays bit-sliced end to end (no u16 materialization).
pub fn bundle_adder_thin(bound: &[Hv], threshold: u16) -> Hv {
    if !fits_planes(bound.len()) {
        return thin(&element_counts_reference(bound), threshold);
    }
    let mut acc = SpatialCounts::new();
    for hv in bound {
        acc.add_hv(hv);
    }
    acc.thin(threshold)
}

/// Adder tree + thinning fed directly from position space (the CompIM
/// datapath of the `SparseCompIm` design point).
pub fn bundle_adder_thin_pos(bound: &[SparseHv], threshold: u16) -> Hv {
    if !fits_planes(bound.len()) {
        return thin(&element_counts_pos_reference(bound), threshold);
    }
    let mut acc = SpatialCounts::new();
    for hv in bound {
        acc.add_sparse(hv);
    }
    acc.thin(threshold)
}

/// Optimized spatial bundling: OR tree (no thinning), bit domain.
pub fn bundle_or(bound: &[Hv]) -> Hv {
    let mut out = Hv::zero();
    for hv in bound {
        out.or_assign(hv);
    }
    out
}

/// Optimized spatial bundling fed directly from position space (the
/// CompIM datapath: 7→128 decode + OR tree). Each position ORs one
/// precomputed word mask — no per-bit `Hv::set` bounds/branch work.
pub fn bundle_or_pos(bound: &[SparseHv]) -> Hv {
    let mut out = Hv::zero();
    for hv in bound {
        for (s, &p) in hv.pos.iter().enumerate() {
            out.words[s * WORDS_PER_SEG + ((p as usize) >> 6)] |= 1u64 << (p & 63);
        }
    }
    out
}

/// Scalar reference for [`bundle_or_pos`] (per-bit `Hv::set`).
pub fn bundle_or_pos_reference(bound: &[SparseHv]) -> Hv {
    let mut out = Hv::zero();
    for hv in bound {
        for (s, &p) in hv.pos.iter().enumerate() {
            out.set(s * SEG_LEN + p as usize, true);
        }
    }
    out
}

/// Maximum possible density after bundling `n` sparse HVs (no-overlap
/// bound) — the §III-B argument that thinning is unnecessary: for
/// n = 64 channels this is 64·8/1024 = 50%.
pub fn max_density_after_bundling(n: usize) -> f64 {
    (n * crate::params::SEGMENTS) as f64 / DIM as f64
}

/// Expected density after bundling `n` independent random sparse HVs
/// (birthday-style overlap): `1 - (1 - 1/SEG_LEN)^n` per element.
pub fn expected_density_after_bundling(n: usize) -> f64 {
    1.0 - (1.0 - 1.0 / SEG_LEN as f64).powi(n as i32)
}

/// Sanity helper: all-channels bundle width used by the hardware model.
pub fn fan_in() -> usize {
    CHANNELS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_bound(rng: &mut Xoshiro256, n: usize) -> (Vec<SparseHv>, Vec<Hv>) {
        let pos: Vec<SparseHv> = (0..n).map(|_| SparseHv::random(rng)).collect();
        let bits: Vec<Hv> = pos.iter().map(|p| p.to_hv()).collect();
        (pos, bits)
    }

    #[test]
    fn or_equals_threshold_one() {
        let mut rng = Xoshiro256::new(1);
        let (_, bits) = random_bound(&mut rng, CHANNELS);
        assert_eq!(bundle_or(&bits), bundle_adder_thin(&bits, 1));
    }

    #[test]
    fn position_and_bit_domain_agree() {
        let mut rng = Xoshiro256::new(2);
        let (pos, bits) = random_bound(&mut rng, CHANNELS);
        assert_eq!(bundle_or_pos(&pos), bundle_or(&bits));
        assert_eq!(*element_counts_pos(&pos), *element_counts(&bits));
        for t in [1u16, 2, 3] {
            assert_eq!(bundle_adder_thin_pos(&pos, t), bundle_adder_thin(&bits, t));
        }
    }

    #[test]
    fn word_parallel_matches_reference() {
        let mut rng = Xoshiro256::new(7);
        for n in [0usize, 1, 3, CHANNELS] {
            let (pos, bits) = random_bound(&mut rng, n);
            assert_eq!(bundle_or_pos(&pos), bundle_or_pos_reference(&pos));
            let counts = element_counts(&bits);
            assert_eq!(*counts, *element_counts_reference(&bits));
            assert_eq!(*element_counts_pos(&pos), *element_counts_pos_reference(&pos));
            for t in 0..=(n as u16 + 2) {
                assert_eq!(thin(&counts, t), thin_reference(&counts, t), "n {n} t {t}");
                assert_eq!(bundle_adder_thin(&bits, t), thin_reference(&counts, t), "n {n} t {t}");
            }
        }
    }

    #[test]
    fn bit_sliced_counts_roundtrip() {
        let mut rng = Xoshiro256::new(8);
        let (pos, bits) = random_bound(&mut rng, CHANNELS);
        let mut a = SpatialCounts::new();
        let mut b = SpatialCounts::new();
        for (p, h) in pos.iter().zip(bits.iter()) {
            a.add_sparse(p);
            b.add_hv(h);
        }
        assert_eq!(a.inputs(), CHANNELS);
        assert_eq!(*a.counts(), *b.counts());
        assert_eq!(*a.counts(), *element_counts_reference(&bits));
    }

    #[test]
    fn counts_sum_equals_total_ones() {
        let mut rng = Xoshiro256::new(3);
        let (_, bits) = random_bound(&mut rng, 10);
        let counts = element_counts(&bits);
        let total: u32 = counts.iter().map(|&c| c as u32).sum();
        assert_eq!(total, 10 * crate::params::SEGMENTS as u32);
    }

    #[test]
    fn higher_threshold_is_sparser() {
        let mut rng = Xoshiro256::new(4);
        let (_, bits) = random_bound(&mut rng, CHANNELS);
        let d1 = bundle_adder_thin(&bits, 1).density();
        let d2 = bundle_adder_thin(&bits, 2).density();
        let d3 = bundle_adder_thin(&bits, 3).density();
        assert!(d1 >= d2 && d2 >= d3);
        assert!(d1 > 0.0);
    }

    #[test]
    fn density_never_exceeds_max_bound() {
        let mut rng = Xoshiro256::new(5);
        for _ in 0..20 {
            let (_, bits) = random_bound(&mut rng, CHANNELS);
            let d = bundle_or(&bits).density();
            assert!(d <= max_density_after_bundling(CHANNELS) + 1e-12);
        }
        assert!((max_density_after_bundling(CHANNELS) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn expected_density_matches_simulation() {
        let mut rng = Xoshiro256::new(6);
        let n_trials = 200;
        let mut acc = 0.0;
        for _ in 0..n_trials {
            let (_, bits) = random_bound(&mut rng, CHANNELS);
            acc += bundle_or(&bits).density();
        }
        let sim = acc / n_trials as f64;
        let expect = expected_density_after_bundling(CHANNELS);
        assert!(
            (sim - expect).abs() < 0.01,
            "simulated {sim} vs analytic {expect}"
        );
    }

    #[test]
    fn empty_bundle_is_zero() {
        assert_eq!(bundle_or(&[]), Hv::zero());
        assert_eq!(bundle_adder_thin(&[], 1), Hv::zero());
        assert_eq!(bundle_adder_thin_pos(&[], 1), Hv::zero());
    }

    #[test]
    fn large_fan_in_falls_back_exactly() {
        // > 127 inputs exceed the bit-plane capacity; the public API must
        // transparently take the exact scalar path.
        let mut rng = Xoshiro256::new(9);
        let (pos, bits) = random_bound(&mut rng, 130);
        assert_eq!(*element_counts(&bits), *element_counts_reference(&bits));
        assert_eq!(*element_counts_pos(&pos), *element_counts_pos_reference(&pos));
        let counts = element_counts_reference(&bits);
        for t in [1u16, 64, 129, 130, 131] {
            assert_eq!(bundle_adder_thin(&bits, t), thin_reference(&counts, t), "t {t}");
            assert_eq!(bundle_adder_thin_pos(&pos, t), bundle_adder_thin(&bits, t), "t {t}");
        }
    }

    #[test]
    fn thin_threshold_extremes() {
        // threshold 0 is vacuously true everywhere; a threshold above the
        // plane capacity can never be met.
        let acc = SpatialCounts::new();
        assert_eq!(acc.thin(0), Hv::ones());
        assert_eq!(acc.thin(1 << SPATIAL_PLANES), Hv::zero());
        let counts = Box::new([0u16; DIM]);
        assert_eq!(thin(&counts, 0), thin_reference(&counts, 0));
    }
}
