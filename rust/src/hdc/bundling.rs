//! Spatial bundling — paper §II-C and §III-B.
//!
//! The spatial encoder combines the 64 bound HVs of one frame:
//!
//! * **baseline**: a per-element adder tree over the 64 inputs followed by
//!   a thinning threshold (`count >= threshold` → 1),
//! * **optimized**: the thinning is removed (64 HVs of density 0.78% can
//!   reach at most 50% density, so the HV can never saturate) and the adder
//!   trees collapse into OR trees.
//!
//! `OR == threshold 1` exactly; the baseline design point uses a
//! configurable threshold ≥ 1 (the hyperparameter trades density against
//! algorithmic performance, §II-C). Both implementations are provided in
//! the bit domain (as the hardware computes) and in the position domain
//! (as the CompIM-fed optimized datapath computes); equivalence is tested.

use crate::params::{CHANNELS, DIM, SEG_LEN};

use super::hv::Hv;
use super::sparse::SparseHv;

/// Per-element counts of 1-bits across a set of HVs (the adder-tree
/// outputs). Max count = number of inputs (64 → fits u16 easily).
pub fn element_counts(bound: &[Hv]) -> Box<[u16; DIM]> {
    let mut counts = Box::new([0u16; DIM]);
    for hv in bound {
        for (w, &word) in hv.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                counts[w * 64 + b] += 1;
                bits &= bits - 1;
            }
        }
    }
    counts
}

/// Position-domain counts: scatter each bound HV's 8 positions.
pub fn element_counts_pos(bound: &[SparseHv]) -> Box<[u16; DIM]> {
    let mut counts = Box::new([0u16; DIM]);
    for hv in bound {
        for (s, &p) in hv.pos.iter().enumerate() {
            counts[s * SEG_LEN + p as usize] += 1;
        }
    }
    counts
}

/// Thinning: threshold the counts back to a binary HV.
pub fn thin(counts: &[u16; DIM], threshold: u16) -> Hv {
    Hv::from_fn(|i| counts[i] >= threshold)
}

/// Baseline spatial bundling: adder tree + thinning.
pub fn bundle_adder_thin(bound: &[Hv], threshold: u16) -> Hv {
    thin(&element_counts(bound), threshold)
}

/// Optimized spatial bundling: OR tree (no thinning), bit domain.
pub fn bundle_or(bound: &[Hv]) -> Hv {
    let mut out = Hv::zero();
    for hv in bound {
        out.or_assign(hv);
    }
    out
}

/// Optimized spatial bundling fed directly from position space (the
/// CompIM datapath: 7→128 decode + OR tree).
pub fn bundle_or_pos(bound: &[SparseHv]) -> Hv {
    let mut out = Hv::zero();
    for hv in bound {
        for (s, &p) in hv.pos.iter().enumerate() {
            out.set(s * SEG_LEN + p as usize, true);
        }
    }
    out
}

/// Maximum possible density after bundling `n` sparse HVs (no-overlap
/// bound) — the §III-B argument that thinning is unnecessary: for
/// n = 64 channels this is 64·8/1024 = 50%.
pub fn max_density_after_bundling(n: usize) -> f64 {
    (n * crate::params::SEGMENTS) as f64 / DIM as f64
}

/// Expected density after bundling `n` independent random sparse HVs
/// (birthday-style overlap): `1 - (1 - 1/SEG_LEN)^n` per element.
pub fn expected_density_after_bundling(n: usize) -> f64 {
    1.0 - (1.0 - 1.0 / SEG_LEN as f64).powi(n as i32)
}

/// Sanity helper: all-channels bundle width used by the hardware model.
pub fn fan_in() -> usize {
    CHANNELS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_bound(rng: &mut Xoshiro256, n: usize) -> (Vec<SparseHv>, Vec<Hv>) {
        let pos: Vec<SparseHv> = (0..n).map(|_| SparseHv::random(rng)).collect();
        let bits: Vec<Hv> = pos.iter().map(|p| p.to_hv()).collect();
        (pos, bits)
    }

    #[test]
    fn or_equals_threshold_one() {
        let mut rng = Xoshiro256::new(1);
        let (_, bits) = random_bound(&mut rng, CHANNELS);
        assert_eq!(bundle_or(&bits), bundle_adder_thin(&bits, 1));
    }

    #[test]
    fn position_and_bit_domain_agree() {
        let mut rng = Xoshiro256::new(2);
        let (pos, bits) = random_bound(&mut rng, CHANNELS);
        assert_eq!(bundle_or_pos(&pos), bundle_or(&bits));
        assert_eq!(*element_counts_pos(&pos), *element_counts(&bits));
    }

    #[test]
    fn counts_sum_equals_total_ones() {
        let mut rng = Xoshiro256::new(3);
        let (_, bits) = random_bound(&mut rng, 10);
        let counts = element_counts(&bits);
        let total: u32 = counts.iter().map(|&c| c as u32).sum();
        assert_eq!(total, 10 * crate::params::SEGMENTS as u32);
    }

    #[test]
    fn higher_threshold_is_sparser() {
        let mut rng = Xoshiro256::new(4);
        let (_, bits) = random_bound(&mut rng, CHANNELS);
        let d1 = bundle_adder_thin(&bits, 1).density();
        let d2 = bundle_adder_thin(&bits, 2).density();
        let d3 = bundle_adder_thin(&bits, 3).density();
        assert!(d1 >= d2 && d2 >= d3);
        assert!(d1 > 0.0);
    }

    #[test]
    fn density_never_exceeds_max_bound() {
        let mut rng = Xoshiro256::new(5);
        for _ in 0..20 {
            let (_, bits) = random_bound(&mut rng, CHANNELS);
            let d = bundle_or(&bits).density();
            assert!(d <= max_density_after_bundling(CHANNELS) + 1e-12);
        }
        assert!((max_density_after_bundling(CHANNELS) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn expected_density_matches_simulation() {
        let mut rng = Xoshiro256::new(6);
        let n_trials = 200;
        let mut acc = 0.0;
        for _ in 0..n_trials {
            let (_, bits) = random_bound(&mut rng, CHANNELS);
            acc += bundle_or(&bits).density();
        }
        let sim = acc / n_trials as f64;
        let expect = expected_density_after_bundling(CHANNELS);
        assert!(
            (sim - expect).abs() < 0.01,
            "simulated {sim} vs analytic {expect}"
        );
    }

    #[test]
    fn empty_bundle_is_zero() {
        assert_eq!(bundle_or(&[]), Hv::zero());
        assert_eq!(bundle_adder_thin(&[], 1), Hv::zero());
    }
}
