//! Assembled classifier pipelines for every design point of the paper.
//!
//! | [`Variant`]          | IM       | binding                | spatial bundling    | paper |
//! |----------------------|----------|------------------------|---------------------|-------|
//! | `DenseBaseline`      | dense    | XOR                    | majority            | [1]   |
//! | `SparseBaseline`     | 1024-bit | decode + barrel shift  | adder tree + thin   | §II   |
//! | `SparseCompIm`       | CompIM   | 7-bit add              | adder tree + thin   | §III-A|
//! | `Optimized`          | CompIM   | 7-bit add              | OR tree (no thin)   | §III  |
//!
//! All sparse variants share the temporal encoder (8-bit counters +
//! threshold) and the AND-popcount AM; the dense variant uses the majority
//! temporal encoder and Hamming AM. `SparseBaseline`, `SparseCompIm` and
//! `Optimized` with `spatial_threshold == 1` are bit-exact equal by
//! construction — the tests pin this, because it is the paper's §III
//! correctness claim.

use std::sync::Arc;

use crate::params::{CHANNELS, IM_SEED, TEMPORAL_THRESHOLD_DEFAULT};

use super::am::{AssociativeMemory, Metric, SearchResult};
use super::bundling;
use super::compim::CompIm;
use super::dense::{self, DenseTemporal};
use super::hv::Hv;
use super::im::{DenseItemMemory, ItemMemory};
use super::imcache::{self, SparseIms};
use super::sparse::{bind_bitdomain, SparseHv};
use super::temporal::TemporalAccumulator;

/// One frame of preprocessed input: the LBP code of every channel.
pub type Frame = [u8; CHANNELS];

/// The four hardware design points.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    DenseBaseline,
    SparseBaseline,
    SparseCompIm,
    Optimized,
}

impl Variant {
    pub const ALL: [Variant; 4] = [
        Variant::DenseBaseline,
        Variant::SparseBaseline,
        Variant::SparseCompIm,
        Variant::Optimized,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Variant::DenseBaseline => "dense-baseline",
            Variant::SparseBaseline => "sparse-baseline",
            Variant::SparseCompIm => "sparse-compim",
            Variant::Optimized => "sparse-optimized",
        }
    }

    pub fn is_sparse(&self) -> bool {
        !matches!(self, Variant::DenseBaseline)
    }

    pub fn from_name(s: &str) -> Option<Variant> {
        Variant::ALL.iter().copied().find(|v| v.name() == s)
    }
}

/// Tunable parameters of the classifier (hardware-fixed values live in
/// [`crate::params`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ClassifierConfig {
    /// IM generation seed (shared with the Python compile path).
    pub seed: u64,
    /// Spatial thinning threshold for the adder-tree variants. `1` makes
    /// the adder tree equivalent to the OR tree.
    pub spatial_threshold: u16,
    /// Temporal thinning threshold (paper operating point: 130 → query
    /// density 20–30%).
    pub temporal_threshold: u16,
    /// Density target for the class HVs built during one-shot training.
    pub train_density: f64,
}

impl Default for ClassifierConfig {
    fn default() -> Self {
        ClassifierConfig {
            seed: IM_SEED,
            spatial_threshold: 2,
            temporal_threshold: TEMPORAL_THRESHOLD_DEFAULT,
            train_density: 0.5,
        }
    }
}

impl ClassifierConfig {
    /// The paper's optimized operating point (§IV-B).
    pub fn optimized() -> Self {
        ClassifierConfig {
            spatial_threshold: 1,
            ..Default::default()
        }
    }
}

/// Streaming encoder trait: feed one frame of LBP codes per clock cycle,
/// receive a query HV every [`crate::params::FRAMES_PER_PREDICTION`]
/// frames.
pub trait Encoder {
    /// Process one frame; returns the query HV when a prediction window
    /// completes.
    fn push_frame(&mut self, codes: &Frame) -> Option<Hv>;
    /// Spatial encoding of a single frame (exposed for training and the
    /// activity model).
    fn spatial_encode(&mut self, codes: &Frame) -> Hv;
    /// Drop any partial window.
    fn reset(&mut self);
    fn variant(&self) -> Variant;
}

/// The sparse encoder, covering `SparseBaseline`, `SparseCompIm` and
/// `Optimized` (selected by [`Variant`]).
pub struct SparseEncoder {
    variant: Variant,
    cfg: ClassifierConfig,
    /// Seed-interned IM + CompIM ([`imcache`]) — construction is an
    /// `Arc` clone after the first encoder for a seed.
    ims: Arc<SparseIms>,
    temporal: TemporalAccumulator,
    /// Scratch for the per-frame bound HVs (avoids 64 allocations/frame).
    bound_bits: Vec<Hv>,
    bound_pos: Vec<SparseHv>,
}

impl SparseEncoder {
    pub fn new(variant: Variant, cfg: ClassifierConfig) -> Self {
        assert!(variant.is_sparse(), "use DenseEncoder for the dense design");
        let ims = imcache::sparse(cfg.seed);
        SparseEncoder {
            variant,
            cfg,
            ims,
            temporal: TemporalAccumulator::new(),
            bound_bits: Vec::with_capacity(CHANNELS),
            bound_pos: Vec::with_capacity(CHANNELS),
        }
    }

    pub fn config(&self) -> &ClassifierConfig {
        &self.cfg
    }

    pub fn set_temporal_threshold(&mut self, t: u16) {
        self.cfg.temporal_threshold = t;
    }

    pub fn item_memory(&self) -> &ItemMemory {
        &self.ims.im
    }

    pub fn comp_im(&self) -> &CompIm {
        &self.ims.compim
    }

    pub fn temporal(&self) -> &TemporalAccumulator {
        &self.temporal
    }

    /// Bind all channels of one frame in the representation the variant's
    /// hardware uses, then bundle spatially.
    fn spatial_encode_inner(&mut self, codes: &Frame) -> Hv {
        match self.variant {
            Variant::SparseBaseline => {
                // Baseline datapath: IM 1024-bit read → one-hot decode →
                // barrel shift → adder tree + thinning.
                self.bound_bits.clear();
                for (c, &code) in codes.iter().enumerate() {
                    let data = self.ims.im.lookup_hv(c, code);
                    let bound = bind_bitdomain(&self.ims.im.electrode_hv(c), &data)
                        .expect("IM entries are sparse by construction");
                    self.bound_bits.push(bound);
                }
                bundling::bundle_adder_thin(&self.bound_bits, self.cfg.spatial_threshold)
            }
            Variant::SparseCompIm => {
                // CompIM binding, but the baseline adder-tree bundling
                // (bit-sliced end to end — no per-element counts).
                self.bound_pos.clear();
                for (c, &code) in codes.iter().enumerate() {
                    self.bound_pos.push(self.ims.compim.bind(c, code));
                }
                bundling::bundle_adder_thin_pos(&self.bound_pos, self.cfg.spatial_threshold)
            }
            Variant::Optimized => {
                // CompIM binding + OR-tree bundling (no thinning).
                self.bound_pos.clear();
                for (c, &code) in codes.iter().enumerate() {
                    self.bound_pos.push(self.ims.compim.bind(c, code));
                }
                bundling::bundle_or_pos(&self.bound_pos)
            }
            Variant::DenseBaseline => unreachable!(),
        }
    }
}

impl SparseEncoder {
    /// Like [`Encoder::push_frame`] but invokes `inspect` with the full
    /// temporal accumulator right before a window is thinned — used by the
    /// threshold-tuning pass (`pipeline::tune_temporal_threshold`).
    pub fn push_frame_inspect(
        &mut self,
        codes: &Frame,
        inspect: &mut dyn FnMut(&TemporalAccumulator),
    ) -> Option<Hv> {
        let spatial = self.spatial_encode_inner(codes);
        self.temporal.add(&spatial);
        if self.temporal.is_full() {
            inspect(&self.temporal);
            Some(self.temporal.finish(self.cfg.temporal_threshold))
        } else {
            None
        }
    }
}

impl Encoder for SparseEncoder {
    fn push_frame(&mut self, codes: &Frame) -> Option<Hv> {
        let spatial = self.spatial_encode_inner(codes);
        self.temporal.add(&spatial);
        if self.temporal.is_full() {
            Some(self.temporal.finish(self.cfg.temporal_threshold))
        } else {
            None
        }
    }

    fn spatial_encode(&mut self, codes: &Frame) -> Hv {
        self.spatial_encode_inner(codes)
    }

    fn reset(&mut self) {
        self.temporal.reset();
    }

    fn variant(&self) -> Variant {
        self.variant
    }
}

/// The dense encoder (Burrello'18 design point).
pub struct DenseEncoder {
    cfg: ClassifierConfig,
    im: Arc<DenseItemMemory>,
    temporal: DenseTemporal,
}

impl DenseEncoder {
    pub fn new(cfg: ClassifierConfig) -> Self {
        DenseEncoder {
            im: imcache::dense(cfg.seed),
            cfg,
            temporal: DenseTemporal::new(),
        }
    }

    pub fn item_memory(&self) -> &DenseItemMemory {
        &self.im
    }

    pub fn config(&self) -> &ClassifierConfig {
        &self.cfg
    }
}

impl Encoder for DenseEncoder {
    fn push_frame(&mut self, codes: &Frame) -> Option<Hv> {
        let (spatial, _) = dense::dense_spatial_encode(&self.im, codes);
        self.temporal.add(&spatial);
        if self.temporal.is_full() {
            let tie = *self.im.tiebreak(1);
            Some(self.temporal.finish(&tie))
        } else {
            None
        }
    }

    fn spatial_encode(&mut self, codes: &Frame) -> Hv {
        dense::dense_spatial_encode(&self.im, codes).0
    }

    fn reset(&mut self) {
        self.temporal.reset();
    }

    fn variant(&self) -> Variant {
        Variant::DenseBaseline
    }
}

/// Construct the encoder for a design point.
pub fn make_encoder(variant: Variant, cfg: ClassifierConfig) -> Box<dyn Encoder + Send> {
    match variant {
        Variant::DenseBaseline => Box::new(DenseEncoder::new(cfg)),
        _ => Box::new(SparseEncoder::new(variant, cfg)),
    }
}

/// A full classifier: encoder + trained associative memory.
pub struct Classifier {
    pub encoder: Box<dyn Encoder + Send>,
    pub am: AssociativeMemory,
    variant: Variant,
}

impl Classifier {
    pub fn new(variant: Variant, cfg: ClassifierConfig, am: AssociativeMemory) -> Self {
        Classifier {
            encoder: make_encoder(variant, cfg),
            am,
            variant,
        }
    }

    pub fn from_encoder(encoder: Box<dyn Encoder + Send>, am: AssociativeMemory) -> Self {
        let variant = encoder.variant();
        Classifier {
            encoder,
            am,
            variant,
        }
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Feed one frame; emits a classification every prediction window.
    pub fn push_frame(&mut self, codes: &Frame) -> Option<SearchResult> {
        let query = self.encoder.push_frame(codes)?;
        Some(self.search(&query))
    }

    /// The AM similarity metric this variant's hardware uses:
    /// AND-popcount overlap for sparse, normalised Hamming for dense.
    pub fn metric(&self) -> Metric {
        if self.variant.is_sparse() {
            Metric::Overlap
        } else {
            Metric::Hamming
        }
    }

    /// Similarity search appropriate to the variant. Scores are
    /// normalized to "bigger = more similar" (dense scores are
    /// `DIM - hamming`) so the [`SearchResult`] contract is uniform.
    pub fn search(&self, query: &Hv) -> SearchResult {
        match self.metric() {
            Metric::Overlap => self.am.search(query),
            Metric::Hamming => self.am.search_dense(query),
        }
    }

    /// Batched similarity search over many window queries — the class HVs
    /// are held once across the whole batch
    /// ([`AssociativeMemory::search_batch`]). Bit-exact with N
    /// [`Self::search`] calls.
    pub fn search_batch(&self, queries: &[Hv]) -> Vec<SearchResult> {
        self.am.search_batch(queries, self.metric())
    }

    pub fn reset(&mut self) {
        self.encoder.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::FRAMES_PER_PREDICTION;
    use crate::rng::Xoshiro256;

    fn random_frames(n: usize, seed: u64) -> Vec<Frame> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let mut f = [0u8; CHANNELS];
                for c in f.iter_mut() {
                    *c = rng.next_below(crate::params::LBP_CODES as u64) as u8;
                }
                f
            })
            .collect()
    }

    #[test]
    fn emits_query_every_window() {
        let mut enc = SparseEncoder::new(Variant::Optimized, ClassifierConfig::optimized());
        let frames = random_frames(FRAMES_PER_PREDICTION * 2, 1);
        let mut outputs = 0;
        for (i, f) in frames.iter().enumerate() {
            let out = enc.push_frame(f);
            if (i + 1) % FRAMES_PER_PREDICTION == 0 {
                assert!(out.is_some(), "frame {i}");
                outputs += 1;
            } else {
                assert!(out.is_none(), "frame {i}");
            }
        }
        assert_eq!(outputs, 2);
    }

    #[test]
    fn three_sparse_variants_agree_at_threshold_one() {
        // The paper's §III claim: CompIM and thinning removal change the
        // hardware, not the function (for spatial_threshold == 1).
        let cfg = ClassifierConfig {
            spatial_threshold: 1,
            ..Default::default()
        };
        let mut base = SparseEncoder::new(Variant::SparseBaseline, cfg.clone());
        let mut comp = SparseEncoder::new(Variant::SparseCompIm, cfg.clone());
        let mut opt = SparseEncoder::new(Variant::Optimized, cfg);
        for f in random_frames(FRAMES_PER_PREDICTION, 2) {
            let a = base.push_frame(&f);
            let b = comp.push_frame(&f);
            let c = opt.push_frame(&f);
            assert_eq!(a, b);
            assert_eq!(b, c);
        }
    }

    #[test]
    fn spatial_threshold_changes_baseline_only() {
        let cfg2 = ClassifierConfig {
            spatial_threshold: 2,
            ..Default::default()
        };
        let mut base = SparseEncoder::new(Variant::SparseBaseline, cfg2.clone());
        let mut comp = SparseEncoder::new(Variant::SparseCompIm, cfg2.clone());
        let mut opt = SparseEncoder::new(Variant::Optimized, cfg2);
        let frames = random_frames(8, 3);
        for f in &frames {
            // Baseline and CompIM honour the threshold identically...
            assert_eq!(base.spatial_encode(f), comp.spatial_encode(f));
            // ...while the OR tree is threshold-1 by construction, so it is
            // a superset of the threshold-2 output.
            let t2 = base.spatial_encode(f);
            let or = opt.spatial_encode(f);
            assert_eq!(t2.and(&or), t2, "thinned output must be subset of OR");
            assert!(or.popcount() >= t2.popcount());
        }
    }

    #[test]
    fn spatial_density_bounded_by_half() {
        let mut opt = SparseEncoder::new(Variant::Optimized, ClassifierConfig::optimized());
        for f in random_frames(32, 4) {
            let d = opt.spatial_encode(&f).density();
            assert!(d <= 0.5 + 1e-12, "{d}");
            assert!(d > 0.1, "plausible lower bound, got {d}");
        }
    }

    #[test]
    fn query_density_in_paper_band_for_default_threshold() {
        // With threshold 130 over varied frames the paper reports 20–30%
        // query density; random codes give a looser but bounded band.
        let mut opt = SparseEncoder::new(Variant::Optimized, ClassifierConfig::optimized());
        let mut got = None;
        for f in random_frames(FRAMES_PER_PREDICTION, 5) {
            if let Some(q) = opt.push_frame(&f) {
                got = Some(q.density());
            }
        }
        let d = got.expect("one window completes");
        assert!((0.0..=0.5).contains(&d));
    }

    #[test]
    fn dense_encoder_window() {
        let mut enc = DenseEncoder::new(ClassifierConfig::default());
        let frames = random_frames(FRAMES_PER_PREDICTION, 6);
        let mut out = None;
        for f in &frames {
            out = out.or(enc.push_frame(f));
        }
        let q = out.expect("window completes");
        // Element-wise temporal majority of ~50%-density frames: each
        // element's per-frame probability p_i hovers around 0.5 (set by the
        // fixed IM), so the majority is near-deterministic per element and
        // only the *fraction* of elements with p_i > 0.5 is ~50% — allow a
        // wide statistical band.
        assert!((0.2..0.8).contains(&q.density()), "density {}", q.density());
    }

    #[test]
    fn classifier_search_dense_vs_sparse_contract() {
        let mut rng = Xoshiro256::new(7);
        let a = Hv::random(&mut rng, 0.25);
        let b = Hv::random(&mut rng, 0.25);
        let am = AssociativeMemory::new(a, b);
        let sparse_clf = Classifier::new(
            Variant::Optimized,
            ClassifierConfig::optimized(),
            am.clone(),
        );
        let dense_clf = Classifier::new(Variant::DenseBaseline, ClassifierConfig::default(), am);
        // Query equal to class-1 HV: both metrics must pick class 1.
        assert_eq!(sparse_clf.search(&b).class, crate::params::CLASS_ICTAL);
        assert_eq!(dense_clf.search(&b).class, crate::params::CLASS_ICTAL);
    }

    #[test]
    fn classifier_batch_search_matches_serial() {
        let mut rng = Xoshiro256::new(77);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.25), Hv::random(&mut rng, 0.25));
        let sparse_clf =
            Classifier::new(Variant::Optimized, ClassifierConfig::optimized(), am.clone());
        let dense_clf = Classifier::new(Variant::DenseBaseline, ClassifierConfig::default(), am);
        assert_eq!(sparse_clf.metric(), Metric::Overlap);
        assert_eq!(dense_clf.metric(), Metric::Hamming);
        let queries: Vec<Hv> = (0..9).map(|_| Hv::random(&mut rng, 0.25)).collect();
        for clf in [&sparse_clf, &dense_clf] {
            let batch = clf.search_batch(&queries);
            for (q, r) in queries.iter().zip(&batch) {
                assert_eq!(*r, clf.search(q));
            }
        }
    }

    #[test]
    fn encoders_share_interned_item_memory() {
        // imcache: every encoder for one seed reads the same tables.
        let a = SparseEncoder::new(Variant::Optimized, ClassifierConfig::optimized());
        let b = SparseEncoder::new(Variant::SparseBaseline, ClassifierConfig::optimized());
        assert!(std::ptr::eq(a.item_memory(), b.item_memory()));
        assert!(std::ptr::eq(a.comp_im(), b.comp_im()));
        let c = DenseEncoder::new(ClassifierConfig::default());
        let d = DenseEncoder::new(ClassifierConfig::default());
        assert!(std::ptr::eq(c.item_memory(), d.item_memory()));
    }

    #[test]
    fn reset_drops_partial_window() {
        let mut enc = SparseEncoder::new(Variant::Optimized, ClassifierConfig::optimized());
        for f in random_frames(100, 8) {
            enc.push_frame(&f);
        }
        enc.reset();
        assert_eq!(enc.temporal().frames(), 0);
        // A full window after reset still emits exactly at frame 256.
        let frames = random_frames(FRAMES_PER_PREDICTION, 9);
        for (i, f) in frames.iter().enumerate() {
            let out = enc.push_frame(f);
            assert_eq!(out.is_some(), i == FRAMES_PER_PREDICTION - 1);
        }
    }
}
