//! Compressed item memory (CompIM) — paper §III-A.
//!
//! The key observation: a sparse HV carries information only in the
//! *positions* of its 8 one-bits, so the one-hot→binary decoder that the
//! baseline binder needs can be folded into the IM. The CompIM stores each
//! HV as 8 × 7 = 56 bits instead of 1024, and binding degenerates to eight
//! 7-bit modular adders.
//!
//! This module is a faithful model of that datapath: it stores *packed*
//! 56-bit words (as the hardware would) and exposes the position-domain
//! bind. Its contents are proven equal to [`super::im::ItemMemory`] by
//! construction tests, and the full binding path is proven equal to the
//! baseline bit-domain path in `sparse.rs` equivalence tests.

use crate::params::{CHANNELS, LBP_CODES, SEGMENTS, SEG_POS_BITS};

use super::im::ItemMemory;
use super::sparse::SparseHv;

/// Packed CompIM word: 8 positions × 7 bits = 56 bits, position `s` at bit
/// offset `s * 7` (LSB first) — the exact memory word of the optimized IM.
pub type PackedEntry = u64;

/// Pack a sparse HV into a 56-bit CompIM word.
#[inline]
pub fn pack(hv: &SparseHv) -> PackedEntry {
    let mut w = 0u64;
    for (s, &p) in hv.pos.iter().enumerate() {
        debug_assert!((p as usize) < (1 << SEG_POS_BITS));
        w |= (p as u64) << (s * SEG_POS_BITS);
    }
    w
}

/// Unpack a 56-bit CompIM word into position space.
#[inline]
pub fn unpack(w: PackedEntry) -> SparseHv {
    let mut pos = [0u8; SEGMENTS];
    for (s, p) in pos.iter_mut().enumerate() {
        *p = ((w >> (s * SEG_POS_BITS)) & ((1 << SEG_POS_BITS) - 1)) as u8;
    }
    SparseHv::new(pos)
}

/// The compressed item memory: per-channel LUTs of packed 56-bit entries
/// plus packed electrode words.
#[derive(Clone)]
pub struct CompIm {
    pub seed: u64,
    /// `table[channel][code]` packed data HVs.
    table: Vec<[PackedEntry; LBP_CODES]>,
    /// Packed electrode HVs.
    electrodes: Vec<PackedEntry>,
}

impl CompIm {
    /// Compress an existing item memory (design-time transformation — this
    /// is what "integrating the one-hot decoder with the IM" means).
    pub fn from_item_memory(im: &ItemMemory) -> Self {
        let mut table = Vec::with_capacity(CHANNELS);
        for c in 0..CHANNELS {
            let mut row = [0u64; LBP_CODES];
            for (k, e) in row.iter_mut().enumerate() {
                *e = pack(&im.lookup(c, k as u8));
            }
            table.push(row);
        }
        let electrodes = (0..CHANNELS).map(|c| pack(&im.electrode(c))).collect();
        CompIm {
            seed: im.seed,
            table,
            electrodes,
        }
    }

    pub fn generate(seed: u64) -> Self {
        Self::from_item_memory(&ItemMemory::generate(seed))
    }

    pub fn default_im() -> Self {
        Self::from_item_memory(&ItemMemory::default_im())
    }

    /// Raw 56-bit word (hardware read port).
    #[inline]
    pub fn lookup_packed(&self, channel: usize, code: u8) -> PackedEntry {
        self.table[channel][code as usize]
    }

    #[inline]
    pub fn lookup(&self, channel: usize, code: u8) -> SparseHv {
        unpack(self.table[channel][code as usize])
    }

    #[inline]
    pub fn electrode(&self, channel: usize) -> SparseHv {
        unpack(self.electrodes[channel])
    }

    /// The optimized binder: CompIM lookup + eight 7-bit modular adds,
    /// producing the bound HV directly in position space.
    #[inline]
    pub fn bind(&self, channel: usize, code: u8) -> SparseHv {
        self.electrode(channel).bind(&self.lookup(channel, code))
    }

    /// Storage bits of one entry (8 × 7 = 56) — the paper's headline
    /// compression from 1024 bits.
    pub const ENTRY_BITS: usize = SEGMENTS * SEG_POS_BITS;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::IM_SEED;
    use crate::rng::Xoshiro256;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..200 {
            let s = SparseHv::random(&mut rng);
            assert_eq!(unpack(pack(&s)), s);
        }
    }

    #[test]
    fn entry_is_56_bits() {
        assert_eq!(CompIm::ENTRY_BITS, 56);
        let mut rng = Xoshiro256::new(2);
        for _ in 0..100 {
            let w = pack(&SparseHv::random(&mut rng));
            assert_eq!(w >> 56, 0, "no bits above 56");
        }
    }

    #[test]
    fn matches_item_memory() {
        let im = ItemMemory::default_im();
        let cim = CompIm::default_im();
        for c in 0..CHANNELS {
            assert_eq!(cim.electrode(c), im.electrode(c));
            for k in 0..LBP_CODES {
                assert_eq!(cim.lookup(c, k as u8), im.lookup(c, k as u8));
            }
        }
    }

    #[test]
    fn bind_matches_baseline_bit_domain_path() {
        // End-to-end CompIM equivalence: CompIM bind (7-bit adds) must equal
        // baseline IM read → one-hot decode → barrel shift.
        use super::super::sparse::bind_bitdomain;
        let im = ItemMemory::generate(IM_SEED);
        let cim = CompIm::from_item_memory(&im);
        for c in (0..CHANNELS).step_by(7) {
            for k in 0..LBP_CODES {
                let optimized = cim.bind(c, k as u8).to_hv();
                let baseline =
                    bind_bitdomain(&im.electrode_hv(c), &im.lookup_hv(c, k as u8)).unwrap();
                assert_eq!(optimized, baseline, "channel {c} code {k}");
            }
        }
    }
}
