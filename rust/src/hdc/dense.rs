//! Dense HDC operations — the Burrello'18 baseline the paper compares
//! against (its §II: "adapted from the dense HDC classification system …
//! by changing the dense HDC operations to their sparse equivalents";
//! we implement the original dense ops for the dense design point).
//!
//! * binding: bit-wise XOR,
//! * spatial bundling: bit-wise majority over the 64 bound HVs,
//! * temporal bundling: per-element counters over 256 frames + majority,
//! * similarity: Hamming distance (smaller = more similar).

use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION};

use super::hv::Hv;
use super::im::DenseItemMemory;

/// XOR binding (dense HDC).
#[inline]
pub fn bind(a: &Hv, b: &Hv) -> Hv {
    a.xor(b)
}

/// Bit-wise majority bundling of `n` HVs given per-element counts.
/// Ties (count == n/2 for even n) break toward 0, matching a strict
/// `count > n/2` comparator in hardware.
pub fn majority_from_counts(counts: &[u16; DIM], n: usize) -> Hv {
    let half = (n / 2) as u16;
    Hv::from_fn(|i| counts[i] > half)
}

/// Majority of `n` HVs plus a fixed tie-break HV (an implicit (n+1)-th
/// input making the fan-in odd). For even `n`, a strict majority is biased
/// low — the count lands exactly on n/2 with probability ≈ C(n,n/2)/2^n —
/// so dense HDC bundles an odd number of items; the tie HV realises that
/// without changing the adder tree.
pub fn majority_with_tie(counts: &[u16; DIM], n: usize, tie: &Hv) -> Hv {
    let half = ((n + 1) / 2) as u16;
    Hv::from_fn(|i| counts[i] + tie.get(i) as u16 > half)
}

/// Spatial encoder of the dense baseline: per-channel IM⊕electrode binding
/// followed by a bit-wise majority across channels (+ tie-break HV, since
/// the 64-channel fan-in is even). Also returns the raw per-element counts
/// (needed by the switching-activity model).
pub fn dense_spatial_encode(im: &DenseItemMemory, codes: &[u8; CHANNELS]) -> (Hv, Box<[u16; DIM]>) {
    let mut counts = Box::new([0u16; DIM]);
    for (c, &code) in codes.iter().enumerate() {
        let bound = bind(im.lookup(code), im.electrode(c));
        for (w, &word) in bound.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                counts[w * 64 + b] += 1;
                bits &= bits - 1;
            }
        }
    }
    (
        majority_with_tie(&counts, CHANNELS, im.tiebreak(0)),
        counts,
    )
}

/// Temporal accumulator of the dense baseline: counts 1-bits over
/// [`FRAMES_PER_PREDICTION`] spatial outputs, then takes the majority.
#[derive(Clone)]
pub struct DenseTemporal {
    counts: Box<[u16; DIM]>,
    frames: usize,
}

impl Default for DenseTemporal {
    fn default() -> Self {
        Self::new()
    }
}

impl DenseTemporal {
    pub fn new() -> Self {
        DenseTemporal {
            counts: Box::new([0u16; DIM]),
            frames: 0,
        }
    }

    pub fn add(&mut self, hv: &Hv) {
        for (w, &word) in hv.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                self.counts[w * 64 + b] += 1;
                bits &= bits - 1;
            }
        }
        self.frames += 1;
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn is_full(&self) -> bool {
        self.frames >= FRAMES_PER_PREDICTION
    }

    /// Majority over the accumulated frames (+ tie-break HV for the even
    /// 256-frame fan-in); resets the accumulator.
    pub fn finish(&mut self, tie: &Hv) -> Hv {
        let out = majority_with_tie(&self.counts, self.frames, tie);
        self.reset();
        out
    }

    pub fn counts(&self) -> &[u16; DIM] {
        &self.counts
    }

    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.frames = 0;
    }
}

/// Hamming-distance similarity search over dense class HVs.
/// Returns `(best_class, distances)` — *smallest* distance wins.
pub fn dense_classify(query: &Hv, classes: &[Hv]) -> (usize, Vec<u32>) {
    let dists: Vec<u32> = classes.iter().map(|c| query.hamming(c)).collect();
    let best = dists
        .iter()
        .enumerate()
        .min_by_key(|(_, &d)| d)
        .map(|(i, _)| i)
        .unwrap_or(0);
    (best, dists)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn xor_bind_is_involution() {
        let mut rng = Xoshiro256::new(1);
        let a = Hv::random_half(&mut rng);
        let b = Hv::random_half(&mut rng);
        assert_eq!(bind(&bind(&a, &b), &b), a);
    }

    #[test]
    fn xor_bind_preserves_half_density_statistically() {
        let mut rng = Xoshiro256::new(2);
        let a = Hv::random_half(&mut rng);
        let b = Hv::random_half(&mut rng);
        let d = bind(&a, &b).density();
        assert!((0.4..0.6).contains(&d), "density {d}");
    }

    #[test]
    fn majority_basic() {
        let mut counts = [0u16; DIM];
        counts[0] = 33; // > 32 → 1
        counts[1] = 32; // == n/2 → 0 (strict majority)
        counts[2] = 64;
        let hv = majority_from_counts(&counts, 64);
        assert!(hv.get(0));
        assert!(!hv.get(1));
        assert!(hv.get(2));
        assert!(!hv.get(3));
    }

    #[test]
    fn spatial_encode_counts_sum() {
        let im = DenseItemMemory::default_im();
        let codes = [7u8; CHANNELS];
        let (_, counts) = dense_spatial_encode(&im, &codes);
        let total: u64 = counts.iter().map(|&c| c as u64).sum();
        // Every channel contributes ~512 one-bits (density 0.5).
        let per_channel = total as f64 / CHANNELS as f64 / DIM as f64;
        assert!((0.4..0.6).contains(&per_channel), "{per_channel}");
    }

    #[test]
    fn temporal_majority_of_identical_frames_is_frame() {
        let mut rng = Xoshiro256::new(3);
        let hv = Hv::random_half(&mut rng);
        let tie = Hv::random_half(&mut rng);
        let mut t = DenseTemporal::new();
        for _ in 0..FRAMES_PER_PREDICTION {
            t.add(&hv);
        }
        assert!(t.is_full());
        // 256 identical votes swamp the single tie-break vote.
        assert_eq!(t.finish(&tie), hv);
        assert_eq!(t.frames(), 0); // reset
    }

    #[test]
    fn tie_break_decides_exact_ties() {
        let mut rng = Xoshiro256::new(5);
        let tie = Hv::random_half(&mut rng);
        let mut counts = [0u16; DIM];
        counts[0] = 32; // exactly half of 64
        counts[1] = 32;
        let out = majority_with_tie(&counts, 64, &tie);
        assert_eq!(out.get(0), tie.get(0));
        assert_eq!(out.get(1), tie.get(1));
        // Clear majorities are unaffected by the tie bit.
        counts[2] = 40;
        counts[3] = 20;
        let out = majority_with_tie(&counts, 64, &tie);
        assert!(out.get(2));
        assert!(!out.get(3));
    }

    #[test]
    fn tie_break_removes_downward_bias() {
        // Without the tie HV, majority over an even number of fair coins is
        // biased low; with it, density is centred at 0.5.
        let mut rng = Xoshiro256::new(6);
        let im = DenseItemMemory::default_im();
        let mut acc = 0.0;
        let trials = 50;
        for _ in 0..trials {
            let mut counts = [0u16; DIM];
            for _ in 0..CHANNELS {
                let hv = Hv::random_half(&mut rng);
                for i in 0..DIM {
                    counts[i] += hv.get(i) as u16;
                }
            }
            acc += majority_with_tie(&counts, CHANNELS, im.tiebreak(0)).density();
        }
        let mean = acc / trials as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean density {mean}");
    }

    #[test]
    fn classify_prefers_similar() {
        let mut rng = Xoshiro256::new(4);
        let proto = Hv::random_half(&mut rng);
        let other = Hv::random_half(&mut rng);
        // Query = prototype with a few flipped bits.
        let mut query = proto;
        for i in 0..20 {
            query.set(i * 13, !query.get(i * 13));
        }
        let (best, dists) = dense_classify(&query, &[other, proto]);
        assert_eq!(best, 1);
        assert!(dists[1] < dists[0]);
    }
}
