//! 1024-bit packed hypervector.
//!
//! The HV is stored as 16 × u64 words, least-significant-bit first: bit
//! index `i` of the HV lives at word `i / 64`, bit `i % 64`. Segment `s`
//! (for the segmented-shift binding) covers bit indices
//! `[s * SEG_LEN, (s+1) * SEG_LEN)`; with `SEG_LEN = 128` each segment is
//! exactly two words, which the segment ops exploit.

use crate::params::{DIM, SEGMENTS, SEG_LEN};
use crate::rng::Xoshiro256;

/// Number of u64 words backing one HV.
pub const WORDS: usize = DIM / 64;
/// Words per segment (SEG_LEN = 128 → 2 words).
pub const WORDS_PER_SEG: usize = SEG_LEN / 64;

/// A 1024-bit binary hypervector.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Hv {
    pub words: [u64; WORDS],
}

impl Default for Hv {
    fn default() -> Self {
        Self::zero()
    }
}

impl Hv {
    /// The all-zeros HV.
    #[inline]
    pub const fn zero() -> Self {
        Hv { words: [0; WORDS] }
    }

    /// The all-ones HV.
    #[inline]
    pub const fn ones() -> Self {
        Hv {
            words: [u64::MAX; WORDS],
        }
    }

    /// Build from a closure over bit indices.
    pub fn from_fn(mut f: impl FnMut(usize) -> bool) -> Self {
        let mut hv = Hv::zero();
        for i in 0..DIM {
            if f(i) {
                hv.set(i, true);
            }
        }
        hv
    }

    /// Random dense HV where each bit is 1 with probability `p`.
    pub fn random(rng: &mut Xoshiro256, p: f64) -> Self {
        Hv::from_fn(|_| rng.next_bool(p))
    }

    /// Random 50%-density HV drawn word-wise (fast path for dense HDC).
    pub fn random_half(rng: &mut Xoshiro256) -> Self {
        let mut hv = Hv::zero();
        for w in hv.words.iter_mut() {
            *w = rng.next_u64();
        }
        hv
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < DIM);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < DIM);
        let mask = 1u64 << (i % 64);
        if v {
            self.words[i / 64] |= mask;
        } else {
            self.words[i / 64] &= !mask;
        }
    }

    /// Number of 1-bits.
    #[inline]
    pub fn popcount(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Fraction of 1-bits.
    #[inline]
    pub fn density(&self) -> f64 {
        self.popcount() as f64 / DIM as f64
    }

    #[inline]
    pub fn and(&self, other: &Hv) -> Hv {
        let mut out = Hv::zero();
        for i in 0..WORDS {
            out.words[i] = self.words[i] & other.words[i];
        }
        out
    }

    #[inline]
    pub fn or(&self, other: &Hv) -> Hv {
        let mut out = Hv::zero();
        for i in 0..WORDS {
            out.words[i] = self.words[i] | other.words[i];
        }
        out
    }

    #[inline]
    pub fn xor(&self, other: &Hv) -> Hv {
        let mut out = Hv::zero();
        for i in 0..WORDS {
            out.words[i] = self.words[i] ^ other.words[i];
        }
        out
    }

    #[inline]
    pub fn or_assign(&mut self, other: &Hv) {
        for i in 0..WORDS {
            self.words[i] |= other.words[i];
        }
    }

    /// `popcount(self AND other)` — the sparse-HDC similarity metric
    /// (paper §II-D: only 1-bits carry information).
    #[inline]
    pub fn overlap(&self, other: &Hv) -> u32 {
        let mut acc = 0;
        for i in 0..WORDS {
            acc += (self.words[i] & other.words[i]).count_ones();
        }
        acc
    }

    /// Hamming distance — the dense-HDC similarity metric.
    #[inline]
    pub fn hamming(&self, other: &Hv) -> u32 {
        let mut acc = 0;
        for i in 0..WORDS {
            acc += (self.words[i] ^ other.words[i]).count_ones();
        }
        acc
    }

    /// Extract segment `s` as two u64 words (bits `[0, SEG_LEN)` of the
    /// returned pair are the segment, LSB first).
    #[inline]
    pub fn segment(&self, s: usize) -> [u64; WORDS_PER_SEG] {
        debug_assert!(s < SEGMENTS);
        let base = s * WORDS_PER_SEG;
        [self.words[base], self.words[base + 1]]
    }

    #[inline]
    pub fn set_segment(&mut self, s: usize, seg: [u64; WORDS_PER_SEG]) {
        debug_assert!(s < SEGMENTS);
        let base = s * WORDS_PER_SEG;
        self.words[base] = seg[0];
        self.words[base + 1] = seg[1];
    }

    /// Circularly left-shift one 128-bit segment by `sh` positions.
    /// "Left" means a 1-bit at position `p` moves to `(p + sh) % SEG_LEN`,
    /// matching the position-domain binding `(e + d) mod 128`.
    #[inline]
    pub fn rotate_segment(seg: [u64; WORDS_PER_SEG], sh: u32) -> [u64; WORDS_PER_SEG] {
        let sh = (sh as usize) % SEG_LEN;
        if sh == 0 {
            return seg;
        }
        let v = (seg[0] as u128) | ((seg[1] as u128) << 64);
        let r = v.rotate_left(sh as u32);
        [r as u64, (r >> 64) as u64]
    }

    /// Indices of all 1-bits, ascending.
    pub fn one_positions(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.popcount() as usize);
        for (wi, &w) in self.words.iter().enumerate() {
            let mut bits = w;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out.push(wi * 64 + b);
                bits &= bits - 1;
            }
        }
        out
    }

    /// Pack to little-endian bytes (for dataset files / PJRT marshalling).
    pub fn to_bytes(&self) -> [u8; DIM / 8] {
        let mut out = [0u8; DIM / 8];
        for (i, w) in self.words.iter().enumerate() {
            out[i * 8..(i + 1) * 8].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8; DIM / 8]) -> Self {
        let mut hv = Hv::zero();
        for i in 0..WORDS {
            hv.words[i] = u64::from_le_bytes(bytes[i * 8..(i + 1) * 8].try_into().unwrap());
        }
        hv
    }

    /// Expand to one i32 per element (the layout the HLO artifacts use:
    /// JAX-side HVs are `int32[1024]` 0/1 tensors).
    pub fn to_i32s(&self) -> Vec<i32> {
        let mut out = vec![0i32; DIM];
        self.to_i32s_into(&mut out);
        out
    }

    /// Fill a preallocated `[i32; DIM]` buffer word-wise (no per-bit
    /// `get` indexing) — the marshalling hot path of the engine workers.
    pub fn to_i32s_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), DIM);
        for (w, &word) in self.words.iter().enumerate() {
            let chunk = &mut out[w * 64..(w + 1) * 64];
            for (b, v) in chunk.iter_mut().enumerate() {
                *v = ((word >> b) & 1) as i32;
            }
        }
    }

    pub fn from_i32s(v: &[i32]) -> Self {
        assert_eq!(v.len(), DIM);
        Hv::from_fn(|i| v[i] != 0)
    }
}

impl std::fmt::Debug for Hv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Hv(popcount={}, density={:.2}%)",
            self.popcount(),
            self.density() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_and_ones() {
        assert_eq!(Hv::zero().popcount(), 0);
        assert_eq!(Hv::ones().popcount(), DIM as u32);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut hv = Hv::zero();
        for i in [0usize, 1, 63, 64, 127, 128, 511, 1023] {
            hv.set(i, true);
            assert!(hv.get(i), "bit {i}");
        }
        assert_eq!(hv.popcount(), 8);
        hv.set(63, false);
        assert!(!hv.get(63));
        assert_eq!(hv.popcount(), 7);
    }

    #[test]
    fn one_positions_matches_get() {
        let mut rng = Xoshiro256::new(5);
        let hv = Hv::random(&mut rng, 0.1);
        let pos = hv.one_positions();
        assert_eq!(pos.len(), hv.popcount() as usize);
        for &p in &pos {
            assert!(hv.get(p));
        }
        // sorted ascending
        assert!(pos.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn overlap_and_hamming() {
        let mut a = Hv::zero();
        let mut b = Hv::zero();
        a.set(3, true);
        a.set(100, true);
        b.set(100, true);
        b.set(500, true);
        assert_eq!(a.overlap(&b), 1);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn rotate_segment_matches_position_arithmetic() {
        for s in 0..SEGMENTS {
            for p in [0usize, 1, 63, 64, 100, 127] {
                for sh in [0u32, 1, 27, 63, 64, 65, 127] {
                    let mut hv = Hv::zero();
                    hv.set(s * SEG_LEN + p, true);
                    let rot = Hv::rotate_segment(hv.segment(s), sh);
                    let mut out = Hv::zero();
                    out.set_segment(s, rot);
                    let expect = (p + sh as usize) % SEG_LEN;
                    assert_eq!(
                        out.one_positions(),
                        vec![s * SEG_LEN + expect],
                        "seg {s} pos {p} shift {sh}"
                    );
                }
            }
        }
    }

    #[test]
    fn rotate_full_circle_is_identity() {
        let mut rng = Xoshiro256::new(11);
        let hv = Hv::random(&mut rng, 0.3);
        for s in 0..SEGMENTS {
            let seg = hv.segment(s);
            let mut acc = seg;
            for _ in 0..SEG_LEN {
                acc = Hv::rotate_segment(acc, 1);
            }
            assert_eq!(acc, seg);
            assert_eq!(Hv::rotate_segment(seg, SEG_LEN as u32), seg);
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let mut rng = Xoshiro256::new(17);
        let hv = Hv::random_half(&mut rng);
        assert_eq!(Hv::from_bytes(&hv.to_bytes()), hv);
    }

    #[test]
    fn i32s_roundtrip() {
        let mut rng = Xoshiro256::new(23);
        let hv = Hv::random(&mut rng, 0.25);
        assert_eq!(Hv::from_i32s(&hv.to_i32s()), hv);
        // The word-wise fill must agree with per-bit `get`.
        let v = hv.to_i32s();
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, hv.get(i) as i32, "bit {i}");
        }
        let mut buf = vec![7i32; DIM];
        hv.to_i32s_into(&mut buf);
        assert_eq!(buf, v);
    }

    #[test]
    fn random_density_statistics() {
        let mut rng = Xoshiro256::new(31);
        let mut total = 0u32;
        for _ in 0..50 {
            total += Hv::random(&mut rng, 0.5).popcount();
        }
        let mean = total as f64 / 50.0 / DIM as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean density {mean}");
    }
}
