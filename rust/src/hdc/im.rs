//! Item memory (IM) generation.
//!
//! The IM maps, per electrode channel, a 6-bit LBP code to a sparse HV
//! (paper §II-A: 64 channels × 64 codes → 1024-bit HVs with 8 one-bits).
//! A second table holds the electrode-representing HVs used as the other
//! binding operand. Both are "randomly generated at design time"; the
//! reproduction pins the generator (SplitMix64 chained hashing, see
//! [`crate::rng`]) so the Rust golden model, the Python compile path and
//! therefore the HLO artifacts all contain identical tables.
//!
//! Domain separation tags (must match `python/compile/hdc_params.py`):
//!
//! | table                    | chain                                  |
//! |--------------------------|----------------------------------------|
//! | sparse IM position       | `(seed, 1, channel, code, segment)`    |
//! | sparse electrode position| `(seed, 2, channel, segment)`          |
//! | dense IM word            | `(seed, 3, code, word)`                |
//! | dense electrode word     | `(seed, 4, channel, word)`             |

use crate::params::{CHANNELS, IM_SEED, LBP_CODES, SEGMENTS, SEG_LEN};
use crate::rng::hash_chain;

use super::hv::{Hv, WORDS};
use super::sparse::SparseHv;

/// Domain tags for the hash chains.
pub const TAG_SPARSE_IM: u64 = 1;
pub const TAG_SPARSE_ELECTRODE: u64 = 2;
pub const TAG_DENSE_IM: u64 = 3;
pub const TAG_DENSE_ELECTRODE: u64 = 4;
pub const TAG_DENSE_TIEBREAK: u64 = 5;

/// One sparse-IM position: the 1-bit index of segment `seg` of the HV for
/// `(channel, code)`.
#[inline]
pub fn sparse_im_pos(seed: u64, channel: usize, code: usize, seg: usize) -> u8 {
    let h = hash_chain(
        seed,
        &[TAG_SPARSE_IM, channel as u64, code as u64, seg as u64],
    );
    (h % SEG_LEN as u64) as u8
}

/// One electrode-HV position.
#[inline]
pub fn sparse_electrode_pos(seed: u64, channel: usize, seg: usize) -> u8 {
    let h = hash_chain(seed, &[TAG_SPARSE_ELECTRODE, channel as u64, seg as u64]);
    (h % SEG_LEN as u64) as u8
}

/// One 64-bit word of the dense IM HV for `code`.
#[inline]
pub fn dense_im_word(seed: u64, code: usize, word: usize) -> u64 {
    hash_chain(seed, &[TAG_DENSE_IM, code as u64, word as u64])
}

/// One 64-bit word of the dense electrode HV for `channel`.
#[inline]
pub fn dense_electrode_word(seed: u64, channel: usize, word: usize) -> u64 {
    hash_chain(seed, &[TAG_DENSE_ELECTRODE, channel as u64, word as u64])
}

/// One 64-bit word of the dense tie-break HV for bundling stage `stage`
/// (0 = spatial, 1 = temporal). Bundling an *even* number of HVs with a
/// strict majority is biased low; adding a fixed random HV (making the
/// count odd) is the standard dense-HDC fix and what the Burrello'18
/// design does implicitly by bundling 2k+1 items.
#[inline]
pub fn dense_tiebreak_word(seed: u64, stage: usize, word: usize) -> u64 {
    hash_chain(seed, &[TAG_DENSE_TIEBREAK, stage as u64, word as u64])
}

/// The *baseline* sparse item memory: per-channel LUTs from LBP code to a
/// full 1024-bit sparse HV, plus the per-channel electrode HVs.
///
/// The baseline hardware reads the 1024-bit HV out of this table each cycle
/// and one-hot-decodes it inside the binder; the [`super::compim::CompIm`]
/// stores positions directly.
#[derive(Clone)]
pub struct ItemMemory {
    pub seed: u64,
    /// `im[channel][code]` — data-representing sparse HVs.
    im: Vec<[SparseHv; LBP_CODES]>,
    /// `electrodes[channel]` — electrode-representing sparse HVs.
    electrodes: Vec<SparseHv>,
}

impl ItemMemory {
    pub fn generate(seed: u64) -> Self {
        let mut im = Vec::with_capacity(CHANNELS);
        for c in 0..CHANNELS {
            let mut table = [SparseHv::new([0; SEGMENTS]); LBP_CODES];
            for (k, entry) in table.iter_mut().enumerate() {
                let mut pos = [0u8; SEGMENTS];
                for (s, p) in pos.iter_mut().enumerate() {
                    *p = sparse_im_pos(seed, c, k, s);
                }
                *entry = SparseHv::new(pos);
            }
            im.push(table);
        }
        let electrodes = (0..CHANNELS)
            .map(|c| {
                let mut pos = [0u8; SEGMENTS];
                for (s, p) in pos.iter_mut().enumerate() {
                    *p = sparse_electrode_pos(seed, c, s);
                }
                SparseHv::new(pos)
            })
            .collect();
        ItemMemory {
            seed,
            im,
            electrodes,
        }
    }

    /// Default-seed IM shared by every layer of the stack.
    pub fn default_im() -> Self {
        Self::generate(IM_SEED)
    }

    /// Sparse data HV for `(channel, code)` in position space.
    #[inline]
    pub fn lookup(&self, channel: usize, code: u8) -> SparseHv {
        self.im[channel][code as usize]
    }

    /// Sparse data HV expanded to the bit domain (what the baseline IM's
    /// 1024-bit read port produces).
    #[inline]
    pub fn lookup_hv(&self, channel: usize, code: u8) -> Hv {
        self.lookup(channel, code).to_hv()
    }

    #[inline]
    pub fn electrode(&self, channel: usize) -> SparseHv {
        self.electrodes[channel]
    }

    #[inline]
    pub fn electrode_hv(&self, channel: usize) -> Hv {
        self.electrodes[channel].to_hv()
    }

    /// Order-sensitive digest over the IM + electrode position tables.
    /// Mirrors `python/compile/hdc_params.py::im_digest`; equality with
    /// `artifacts/manifest.txt` proves both languages generated identical
    /// item memories (checked by `runtime::Manifest::validate`).
    pub fn digest(&self) -> u64 {
        let mut h = crate::rng::splitmix64_mix(self.seed);
        for c in 0..CHANNELS {
            for k in 0..LBP_CODES {
                for s in 0..SEGMENTS {
                    h = crate::rng::splitmix64_mix(h ^ self.im[c][k].pos[s] as u64);
                }
            }
        }
        for c in 0..CHANNELS {
            for s in 0..SEGMENTS {
                h = crate::rng::splitmix64_mix(h ^ self.electrodes[c].pos[s] as u64);
            }
        }
        h
    }
}

/// The dense item memory of the Burrello'18 baseline: 50%-density HVs,
/// one per LBP code (shared across channels) plus one per electrode.
#[derive(Clone)]
pub struct DenseItemMemory {
    pub seed: u64,
    codes: Vec<Hv>,
    electrodes: Vec<Hv>,
    /// Tie-break HVs for the (even-fan-in) spatial and temporal bundlings.
    tiebreak: [Hv; 2],
}

impl DenseItemMemory {
    pub fn generate(seed: u64) -> Self {
        let codes = (0..LBP_CODES)
            .map(|k| {
                let mut hv = Hv::zero();
                for w in 0..WORDS {
                    hv.words[w] = dense_im_word(seed, k, w);
                }
                hv
            })
            .collect();
        let electrodes = (0..CHANNELS)
            .map(|c| {
                let mut hv = Hv::zero();
                for w in 0..WORDS {
                    hv.words[w] = dense_electrode_word(seed, c, w);
                }
                hv
            })
            .collect();
        let mut tiebreak = [Hv::zero(); 2];
        for (stage, hv) in tiebreak.iter_mut().enumerate() {
            for w in 0..WORDS {
                hv.words[w] = dense_tiebreak_word(seed, stage, w);
            }
        }
        DenseItemMemory {
            seed,
            codes,
            electrodes,
            tiebreak,
        }
    }

    pub fn default_im() -> Self {
        Self::generate(IM_SEED)
    }

    #[inline]
    pub fn lookup(&self, code: u8) -> &Hv {
        &self.codes[code as usize]
    }

    #[inline]
    pub fn electrode(&self, channel: usize) -> &Hv {
        &self.electrodes[channel]
    }

    /// Tie-break HV for bundling stage (0 = spatial, 1 = temporal).
    #[inline]
    pub fn tiebreak(&self, stage: usize) -> &Hv {
        &self.tiebreak[stage]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = ItemMemory::generate(42);
        let b = ItemMemory::generate(42);
        for c in 0..CHANNELS {
            assert_eq!(a.electrode(c), b.electrode(c));
            for k in 0..LBP_CODES {
                assert_eq!(a.lookup(c, k as u8), b.lookup(c, k as u8));
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = ItemMemory::generate(1);
        let b = ItemMemory::generate(2);
        let mut diff = 0;
        for c in 0..CHANNELS {
            for k in 0..LBP_CODES {
                if a.lookup(c, k as u8) != b.lookup(c, k as u8) {
                    diff += 1;
                }
            }
        }
        assert!(diff > CHANNELS * LBP_CODES / 2);
    }

    #[test]
    fn entries_are_valid_sparse_hvs() {
        let im = ItemMemory::default_im();
        for c in 0..CHANNELS {
            for k in 0..LBP_CODES {
                let hv = im.lookup_hv(c, k as u8);
                assert_eq!(hv.popcount(), SEGMENTS as u32);
                assert!(SparseHv::from_hv(&hv).is_some());
            }
        }
    }

    #[test]
    fn positions_look_uniform() {
        // Chi-squared-ish sanity: every position value should occur, and no
        // value should dominate, over the 64*64*8 = 32768 generated entries.
        let im = ItemMemory::default_im();
        let mut hist = [0u32; SEG_LEN];
        for c in 0..CHANNELS {
            for k in 0..LBP_CODES {
                for s in 0..SEGMENTS {
                    hist[im.lookup(c, k as u8).pos[s] as usize] += 1;
                }
            }
        }
        let expected = (CHANNELS * LBP_CODES * SEGMENTS / SEG_LEN) as f64; // 256
        for (v, &h) in hist.iter().enumerate() {
            assert!(h > 0, "position {v} never generated");
            assert!(
                (h as f64) < expected * 1.5 && (h as f64) > expected * 0.5,
                "position {v} count {h} far from expected {expected}"
            );
        }
    }

    #[test]
    fn channel_tables_are_distinct() {
        // Per-channel LUTs must differ (the binder relies on electrode
        // separation, but distinct IM tables additionally decorrelate
        // channels — paper §II-A has one LUT per channel).
        let im = ItemMemory::default_im();
        assert_ne!(im.lookup(0, 0), im.lookup(1, 0));
        assert_ne!(im.electrode(0), im.electrode(1));
    }

    #[test]
    fn dense_im_density_near_half() {
        let im = DenseItemMemory::default_im();
        for k in 0..LBP_CODES {
            let d = im.lookup(k as u8).density();
            assert!((0.38..0.62).contains(&d), "code {k} density {d}");
        }
        for c in 0..CHANNELS {
            let d = im.electrode(c).density();
            assert!((0.38..0.62).contains(&d), "electrode {c} density {d}");
        }
    }

    #[test]
    fn pinned_generator_vectors() {
        // Cross-language contract: python/tests/test_params.py asserts the
        // exact same values. Do not change without changing both.
        let p0 = sparse_im_pos(IM_SEED, 0, 0, 0);
        let p1 = sparse_im_pos(IM_SEED, 11, 42, 3);
        let e0 = sparse_electrode_pos(IM_SEED, 0, 0);
        // Values are pinned by the algorithm; recompute once and freeze.
        let im = ItemMemory::default_im();
        assert_eq!(im.lookup(0, 0).pos[0], p0);
        assert_eq!(im.lookup(11, 42).pos[3], p1);
        assert_eq!(im.electrode(0).pos[0], e0);
    }
}
