//! Process-wide item-memory cache.
//!
//! Item memories are pure functions of their seed (SplitMix64 chained
//! hashing, see [`crate::rng`]), but generating one walks 32k+ hash
//! chains — far too expensive to repeat for every encoder the sweeps and
//! the evaluation pool construct. This cache interns the generated
//! tables behind `Arc`s keyed by seed, so
//! [`SparseEncoder`](super::classifier::SparseEncoder) /
//! [`DenseEncoder`](super::classifier::DenseEncoder) construction is a
//! hash-map hit + two `Arc` clones and encoders become cheap enough to
//! spawn per worker thread.
//!
//! The cache is unbounded but keyed by seed; a run touches a handful of
//! seeds (the shared [`crate::params::IM_SEED`] plus any `--seed`
//! overrides), so entries are retained for the process lifetime.
//! Generation happens *outside* the map lock: concurrent first-time
//! requests for the same seed may generate twice, but both produce the
//! identical table and the first insert wins — no worker ever observes a
//! partially built IM.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::compim::CompIm;
use super::im::{DenseItemMemory, ItemMemory};

/// The sparse tables every sparse design point reads: the baseline
/// 1024-bit item memory and its compressed (CompIM) form, generated from
/// the same seed and equal by construction.
pub struct SparseIms {
    pub im: ItemMemory,
    pub compim: CompIm,
}

static SPARSE: OnceLock<Mutex<HashMap<u64, Arc<SparseIms>>>> = OnceLock::new();
static DENSE: OnceLock<Mutex<HashMap<u64, Arc<DenseItemMemory>>>> = OnceLock::new();

/// Shared sparse IM + CompIM for `seed`, generating on first use.
pub fn sparse(seed: u64) -> Arc<SparseIms> {
    let map = SPARSE.get_or_init(Default::default);
    if let Some(hit) = map.lock().unwrap().get(&seed) {
        return hit.clone();
    }
    let im = ItemMemory::generate(seed);
    let compim = CompIm::from_item_memory(&im);
    let fresh = Arc::new(SparseIms { im, compim });
    let mut map = map.lock().unwrap();
    map.entry(seed).or_insert(fresh).clone()
}

/// Shared dense item memory for `seed`, generating on first use.
pub fn dense(seed: u64) -> Arc<DenseItemMemory> {
    let map = DENSE.get_or_init(Default::default);
    if let Some(hit) = map.lock().unwrap().get(&seed) {
        return hit.clone();
    }
    let fresh = Arc::new(DenseItemMemory::generate(seed));
    let mut map = map.lock().unwrap();
    map.entry(seed).or_insert(fresh).clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{CHANNELS, IM_SEED, LBP_CODES};

    #[test]
    fn same_seed_shares_one_allocation() {
        let a = sparse(IM_SEED);
        let b = sparse(IM_SEED);
        assert!(Arc::ptr_eq(&a, &b));
        let c = dense(IM_SEED);
        let d = dense(IM_SEED);
        assert!(Arc::ptr_eq(&c, &d));
    }

    #[test]
    fn cached_tables_match_direct_generation() {
        let cached = sparse(0xD15C);
        let direct = ItemMemory::generate(0xD15C);
        for c in 0..CHANNELS {
            assert_eq!(cached.im.electrode(c), direct.electrode(c));
            for k in 0..LBP_CODES {
                assert_eq!(cached.im.lookup(c, k as u8), direct.lookup(c, k as u8));
                assert_eq!(cached.compim.lookup(c, k as u8), direct.lookup(c, k as u8));
            }
        }
        assert_eq!(cached.im.digest(), direct.digest());
    }

    #[test]
    fn different_seeds_are_distinct_entries() {
        let a = sparse(1);
        let b = sparse(2);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.im.lookup(0, 0), b.im.lookup(0, 0));
    }

    #[test]
    fn cache_is_thread_safe_under_contention() {
        let seed = 0xC0FFEE;
        let arcs: Vec<Arc<SparseIms>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8).map(|_| s.spawn(move || sparse(seed))).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // All callers converge on one interned table.
        for a in &arcs[1..] {
            assert!(Arc::ptr_eq(&arcs[0], a));
        }
    }
}
