//! Bit-accurate golden models of the dense and sparse HDC classifiers.
//!
//! Module map (paper Fig. 1(b)):
//!
//! * [`hv`] — the 1024-bit packed hypervector type and bit-level ops.
//! * [`sparse`] — sparse HVs in *position space* (8 × 7-bit) and the
//!   segmented-shift binding (paper Fig. 2(a)) in both the bit domain
//!   (baseline hardware) and the position domain (CompIM hardware).
//! * [`dense`] — dense-HDC ops of the Burrello'18 baseline: XOR binding,
//!   bit-wise majority bundling, Hamming-distance similarity.
//! * [`im`] / [`compim`] — item memory and compressed item memory.
//! * [`imcache`] — process-wide `Arc` interning of generated item
//!   memories (seed-keyed), making encoder construction cheap.
//! * [`bitplanes`] — shared bit-sliced counter primitives (carry-save
//!   ripple add, word-level magnitude comparator, transpose).
//! * [`simd`] — runtime-dispatched SIMD tier (AVX2/NEON) over the
//!   bitplanes + scoring kernels, scalar always available.
//! * [`bundling`] — spatial bundling: adder trees + thinning (baseline) and
//!   OR trees (optimized, §III-B).
//! * [`temporal`] — the 256-frame temporal encoder with 8-bit counters.
//! * [`am`] — associative memory and AND-popcount similarity search.
//! * [`train`] — offline one-shot training (§II-D).
//! * [`online`] — iterative online retraining on misclassified windows
//!   (Pale et al., arXiv:2201.09759), deriving new model versions.
//! * [`model`] — the persistent, versioned [`model::ModelBundle`]
//!   artifact (AM + encoder config + provenance) and its binary format.
//! * [`classifier`] — the assembled pipelines for every design variant.

pub mod hv;
pub mod bitplanes;
pub mod simd;
pub mod sparse;
pub mod dense;
pub mod im;
pub mod compim;
pub mod imcache;
pub mod bundling;
pub mod temporal;
pub mod am;
pub mod train;
pub mod online;
pub mod model;
pub mod classifier;
