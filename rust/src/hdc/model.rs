//! Persistent model artifacts: the [`ModelBundle`].
//!
//! A trained model is more than the two class HVs: reproducing its
//! predictions needs the encoder seed and thresholds it was trained
//! against, and operating it over time needs provenance (who trained it,
//! on how many windows, how many online epochs) plus a **monotonically
//! increasing version** so a registry can reject stale publishes. The
//! bundle carries all of that as one first-class, saveable artifact —
//! `repro train --save` writes it, `repro model-info` inspects it,
//! `repro serve --model` deploys it without retraining at startup, and
//! [`crate::hdc::online::OnlineTrainer`] derives new versions from it.
//!
//! ## On-disk format
//!
//! Dependency-free little-endian binary (serde is unavailable offline —
//! DESIGN.md §2), mirroring the hand-rolled approach of
//! [`crate::benchkit`]'s JSON reader and the `.ieeg` dataset format:
//!
//! ```text
//! magic   [u8;4] = b"HDCM"
//! format  u32    = 1 | 2
//! n_sections u32
//! section * n_sections:
//!     tag [u8;4], len u32, payload [u8; len]
//! ```
//!
//! Sections (any order; unknown tags are skipped for forward
//! compatibility, the first four below are required):
//!
//! | tag    | payload                                                        |
//! |--------|----------------------------------------------------------------|
//! | `META` | version u64, variant name (u32 len + utf8)                     |
//! | `CFGS` | seed u64, spatial u16, temporal u16, train_density f64-bits    |
//! | `AMPL` | num_classes u32, dim u32, packed class HVs (dim/8 bytes each)  |
//! | `PROV` | patient u32, epochs u32, parent u64, windows 2×u64, note (str) |
//! | `CNTP` | classes u32, dim u32, windows 2×u64, per-class count planes    |
//! |        | (dim × u32 each) — **format 2, optional**                      |
//!
//! Format 2 is format 1 plus the optional `CNTP` section: the saturating
//! per-class counter planes the model was thinned from, so
//! [`crate::hdc::online::OnlineTrainer`] can resume retraining
//! incrementally from the artifact instead of re-seeding from a record.
//! A bundle without counter planes is still written as format 1 (byte-
//! identical to the format-1 writer), and because `CNTP` is just another
//! length-prefixed section, a format-1 reader that tolerates the header
//! recovers the format-1 content by the unknown-section skip rule.
//! Readers here accept both versions and skip `CNTP` when absent.
//!
//! Every length is validated against the remaining file size before any
//! payload is touched (allocations are fixed-size, never sized by an
//! attacker-controlled length), so truncated, corrupt or bit-flipped
//! files fail with an actionable error instead of a panic or an OOM; a
//! format-version bump beyond what this build reads fails loudly rather
//! than misreading new bytes.

use std::path::Path;

use crate::ensure;
use crate::error::Context;
use crate::params::{DIM, NUM_CLASSES};

use super::am::{AmPlane, AssociativeMemory};
use super::classifier::{ClassifierConfig, Variant};
use super::hv::Hv;

const MAGIC: [u8; 4] = *b"HDCM";

/// Newest on-disk format version this build reads and writes. Bundles
/// without counter planes are still written as format
/// [`BASE_FORMAT_VERSION`] so format-1 readers keep loading them.
pub const FORMAT_VERSION: u32 = 2;

/// The counter-plane-free baseline format (what PR-4 readers understand).
pub const BASE_FORMAT_VERSION: u32 = 1;

/// Where a model came from: training lineage metadata, carried alongside
/// the weights so `repro model-info` can answer "what is this file?".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Provenance {
    /// Patient the model was trained for (0 = unknown / not patient-bound).
    pub patient_id: u32,
    /// Online-retraining epochs behind this version (0 = one-shot).
    pub epochs: u32,
    /// Version this bundle was derived from (0 = freshly trained).
    pub parent_version: u64,
    /// Training windows absorbed per class (interictal, ictal).
    pub train_windows: [u64; NUM_CLASSES],
    /// Free-form note ("one-shot", retrain summary, ...).
    pub note: String,
}

/// The training state behind a thinned AM: the per-class counter planes
/// (saturating accumulators of every absorbed window query) plus the
/// absorbed-window counts. Carrying them in the bundle (format 2,
/// section `CNTP`) lets a retrain resume exactly where the previous
/// training pass left off instead of re-seeding the planes from the raw
/// record — the artifact *is* the training state.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterPlanes {
    /// Per-class accumulators (interictal, ictal), one count per HV
    /// element.
    pub counts: [Box<[u32; DIM]>; NUM_CLASSES],
    /// Windows absorbed into each plane (interictal, ictal).
    pub windows: [u64; NUM_CLASSES],
}

/// A complete, persistent, versioned model artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelBundle {
    /// Monotonically increasing model version (fresh training = 1; each
    /// online retrain derives `version + 1`). Registries reject stale
    /// publishes by comparing this.
    pub version: u64,
    /// Design point the model was trained for.
    pub variant: Variant,
    /// Encoder configuration the AM was trained against (seed,
    /// spatial/temporal thresholds, train density) — serving must encode
    /// with exactly this config to reproduce the training-time function.
    pub config: ClassifierConfig,
    /// The trained associative memory (class-representing HVs).
    pub am: AssociativeMemory,
    pub provenance: Provenance,
    /// Format-2 counter planes ([`CounterPlanes`]): present on bundles
    /// emitted by the training paths, absent on format-1 artifacts.
    /// `None` never blocks serving — only incremental retraining falls
    /// back to re-seeding from a record.
    pub counters: Option<CounterPlanes>,
}

impl ModelBundle {
    /// A freshly trained version-1 bundle.
    pub fn new(
        variant: Variant,
        config: ClassifierConfig,
        am: AssociativeMemory,
        provenance: Provenance,
    ) -> ModelBundle {
        ModelBundle {
            version: 1,
            variant,
            config,
            am,
            provenance,
            counters: None,
        }
    }

    /// The version an artifact derived from this bundle must carry.
    pub fn next_version(&self) -> u64 {
        self.version + 1
    }

    /// The format version this bundle serializes as: counter planes need
    /// format 2, everything else stays at the format-1 baseline so
    /// format-1 readers keep loading counter-less artifacts byte for
    /// byte.
    pub fn wire_format(&self) -> u32 {
        if self.counters.is_some() {
            FORMAT_VERSION
        } else {
            BASE_FORMAT_VERSION
        }
    }

    /// Serialize to the on-disk byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut meta = Vec::new();
        put_u64(&mut meta, self.version);
        put_str(&mut meta, self.variant.name());

        let mut cfgs = Vec::new();
        put_u64(&mut cfgs, self.config.seed);
        cfgs.extend_from_slice(&self.config.spatial_threshold.to_le_bytes());
        cfgs.extend_from_slice(&self.config.temporal_threshold.to_le_bytes());
        put_u64(&mut cfgs, self.config.train_density.to_bits());

        let mut ampl = Vec::new();
        ampl.extend_from_slice(&(NUM_CLASSES as u32).to_le_bytes());
        ampl.extend_from_slice(&(DIM as u32).to_le_bytes());
        for class in &self.am.classes {
            ampl.extend_from_slice(&class.to_bytes());
        }

        let mut prov = Vec::new();
        prov.extend_from_slice(&self.provenance.patient_id.to_le_bytes());
        prov.extend_from_slice(&self.provenance.epochs.to_le_bytes());
        put_u64(&mut prov, self.provenance.parent_version);
        for &w in &self.provenance.train_windows {
            put_u64(&mut prov, w);
        }
        put_str(&mut prov, &self.provenance.note);

        let cntp = self.counters.as_ref().map(|c| {
            let mut cntp = Vec::with_capacity(8 + 16 + NUM_CLASSES * DIM * 4);
            cntp.extend_from_slice(&(NUM_CLASSES as u32).to_le_bytes());
            cntp.extend_from_slice(&(DIM as u32).to_le_bytes());
            for &w in &c.windows {
                put_u64(&mut cntp, w);
            }
            for plane in &c.counts {
                for &count in plane.iter() {
                    cntp.extend_from_slice(&count.to_le_bytes());
                }
            }
            cntp
        });

        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&self.wire_format().to_le_bytes());
        out.extend_from_slice(&(4u32 + cntp.is_some() as u32).to_le_bytes());
        section(&mut out, b"META", &meta);
        section(&mut out, b"CFGS", &cfgs);
        section(&mut out, b"AMPL", &ampl);
        section(&mut out, b"PROV", &prov);
        if let Some(cntp) = &cntp {
            section(&mut out, b"CNTP", cntp);
        }
        out
    }

    /// Parse the on-disk byte format. Rejects bad magic, format-version
    /// mismatches, truncation, length overruns, unknown variants and
    /// architecture mismatches with actionable errors; unknown *sections*
    /// are skipped (forward compatibility within one format version).
    pub fn from_bytes(bytes: &[u8]) -> crate::Result<ModelBundle> {
        let mut r = Reader::new(bytes);
        let magic = r.take(4).context("model bundle header")?;
        ensure!(
            magic == &MAGIC,
            "not a model bundle: magic {:02x?} (expected {:02x?} — is this a `repro train --save` file?)",
            magic,
            MAGIC
        );
        let format = r.u32()?;
        ensure!(
            (BASE_FORMAT_VERSION..=FORMAT_VERSION).contains(&format),
            "model bundle format version {format}, this build reads \
             {BASE_FORMAT_VERSION}..={FORMAT_VERSION} — re-save with a matching build"
        );
        let n_sections = r.u32()?;

        let mut meta: Option<(u64, Variant)> = None;
        let mut cfgs: Option<ClassifierConfig> = None;
        let mut ampl: Option<AssociativeMemory> = None;
        let mut prov: Option<Provenance> = None;
        let mut cntp: Option<CounterPlanes> = None;

        for _ in 0..n_sections {
            let tag: [u8; 4] = r.take(4)?.try_into().expect("4-byte slice");
            let len = r.u32()? as usize;
            let payload = r
                .take(len)
                .with_context(|| format!("section {:?}", tag_name(&tag)))?;
            match &tag {
                b"META" => meta = Some(decode_meta(payload)?),
                b"CFGS" => cfgs = Some(decode_cfgs(payload)?),
                b"AMPL" => ampl = Some(decode_ampl(payload)?),
                b"PROV" => prov = Some(decode_prov(payload)?),
                b"CNTP" => cntp = Some(decode_cntp(payload)?),
                _ => {} // unknown section: skip (forward compatibility)
            }
        }
        ensure!(
            r.remaining() == 0,
            "{} trailing bytes after {} sections",
            r.remaining(),
            n_sections
        );

        let (version, variant) = meta.context("model bundle has no META section")?;
        Ok(ModelBundle {
            version,
            variant,
            config: cfgs.context("model bundle has no CFGS section")?,
            am: ampl.context("model bundle has no AMPL section")?,
            provenance: prov.context("model bundle has no PROV section")?,
            counters: cntp,
        })
    }

    /// Write the bundle to `path`.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write model bundle {}", path.display()))
    }

    /// Load a bundle from `path`.
    pub fn load(path: &Path) -> crate::Result<ModelBundle> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read model bundle {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parse model bundle {}", path.display()))
    }

    /// Human-readable summary (`repro model-info`).
    pub fn describe(&self) -> String {
        let densities = format!(
            "interictal {:.1}% / ictal {:.1}%",
            self.am.classes[0].density() * 100.0,
            self.am.classes[1].density() * 100.0
        );
        describe_parts(
            self.version,
            self.wire_format(),
            self.variant,
            &self.config,
            &densities,
            &self.provenance,
            &counters_text(self.counters.as_ref()),
        )
    }
}

impl AmPlane {
    /// Both engine representations of a bundle's AM — what every engine
    /// (native and PJRT) consumes, pre-decoded so serving never pays a
    /// plane decode (see [`AmPlane::from_memory`]).
    pub fn from_bundle(bundle: &ModelBundle) -> AmPlane {
        AmPlane::from_memory(&bundle.am)
    }
}

// ---------------------------------------------------------------------------
// Per-section payload decoders, shared between the eager [`ModelBundle::
// from_bytes`] path and the lazy [`LazyBundle`] path so the two can never
// drift.

fn decode_meta(payload: &[u8]) -> crate::Result<(u64, Variant)> {
    let mut pr = Reader::new(payload);
    let version = pr.u64()?;
    ensure!(version >= 1, "model version 0 (must be >= 1)");
    let name = pr.string()?;
    let variant = Variant::from_name(&name)
        .with_context(|| format!("unknown variant {name:?} in model bundle"))?;
    pr.finish("META")?;
    Ok((version, variant))
}

fn decode_cfgs(payload: &[u8]) -> crate::Result<ClassifierConfig> {
    let mut pr = Reader::new(payload);
    let seed = pr.u64()?;
    let spatial_threshold = pr.u16()?;
    let temporal_threshold = pr.u16()?;
    let train_density = f64::from_bits(pr.u64()?);
    pr.finish("CFGS")?;
    Ok(ClassifierConfig {
        seed,
        spatial_threshold,
        temporal_threshold,
        train_density,
    })
}

fn decode_ampl(payload: &[u8]) -> crate::Result<AssociativeMemory> {
    let mut pr = Reader::new(payload);
    let classes = pr.u32()? as usize;
    let dim = pr.u32()? as usize;
    ensure!(
        classes == NUM_CLASSES && dim == DIM,
        "model bundle is {classes} classes × {dim} dims, \
         this build expects {NUM_CLASSES} × {DIM}"
    );
    let mut hvs = [Hv::zero(); NUM_CLASSES];
    for hv in hvs.iter_mut() {
        let raw: &[u8; DIM / 8] = pr.take(DIM / 8)?.try_into().expect("fixed-size slice");
        *hv = Hv::from_bytes(raw);
    }
    pr.finish("AMPL")?;
    Ok(AssociativeMemory::new(hvs[0], hvs[1]))
}

fn decode_prov(payload: &[u8]) -> crate::Result<Provenance> {
    let mut pr = Reader::new(payload);
    let patient_id = pr.u32()?;
    let epochs = pr.u32()?;
    let parent_version = pr.u64()?;
    let mut train_windows = [0u64; NUM_CLASSES];
    for w in train_windows.iter_mut() {
        *w = pr.u64()?;
    }
    let note = pr.string()?;
    pr.finish("PROV")?;
    Ok(Provenance {
        patient_id,
        epochs,
        parent_version,
        train_windows,
        note,
    })
}

fn decode_cntp(payload: &[u8]) -> crate::Result<CounterPlanes> {
    let mut pr = Reader::new(payload);
    let classes = pr.u32()? as usize;
    let dim = pr.u32()? as usize;
    ensure!(
        classes == NUM_CLASSES && dim == DIM,
        "counter planes are {classes} classes × {dim} dims, \
         this build expects {NUM_CLASSES} × {DIM}"
    );
    let mut windows = [0u64; NUM_CLASSES];
    for w in windows.iter_mut() {
        *w = pr.u64()?;
    }
    // Fixed-size allocation: the payload length was already bounds-checked
    // against the file, and the planes are DIM × u32 by construction —
    // nothing here allocates from an attacker-controlled length.
    let mut counts = [Box::new([0u32; DIM]), Box::new([0u32; DIM])];
    for plane in counts.iter_mut() {
        let raw = pr.take(DIM * 4)?;
        for (slot, chunk) in plane.iter_mut().zip(raw.chunks_exact(4)) {
            *slot = u32::from_le_bytes(chunk.try_into().expect("4 bytes"));
        }
    }
    pr.finish("CNTP")?;
    Ok(CounterPlanes { counts, windows })
}

fn counters_text(c: Option<&CounterPlanes>) -> String {
    match c {
        Some(c) => format!(
            "present ({}/{} windows — incremental retrain resumes here)",
            c.windows[0], c.windows[1]
        ),
        None => "absent (format-1 artifact — retrains re-seed from a record)".to_string(),
    }
}

fn describe_parts(
    version: u64,
    format: u32,
    variant: Variant,
    config: &ClassifierConfig,
    densities: &str,
    p: &Provenance,
    counters: &str,
) -> String {
    let lineage = if p.parent_version == 0 {
        "freshly trained".to_string()
    } else {
        format!("derived from v{}", p.parent_version)
    };
    format!(
        "model bundle v{version} (format {format})\n\
         \x20 variant            : {}\n\
         \x20 encoder seed       : {:#018x}\n\
         \x20 spatial threshold  : {}\n\
         \x20 temporal threshold : {}\n\
         \x20 train density      : {:.3}\n\
         \x20 class densities    : {densities}\n\
         \x20 provenance         : patient {}, {} online epoch(s), {lineage}, \
         windows {}/{}\n\
         \x20 counter planes     : {counters}\n\
         \x20 note               : {}",
        variant.name(),
        config.seed,
        config.spatial_threshold,
        config.temporal_threshold,
        config.train_density,
        p.patient_id,
        p.epochs,
        p.train_windows[0],
        p.train_windows[1],
        if p.note.is_empty() { "—" } else { &p.note },
    )
}

// ---------------------------------------------------------------------------
// Lazy, section-indexed bundle access.

/// One entry of a bundle's section table: where a section's payload lives,
/// recorded without reading it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SectionSpan {
    /// Section tag (`META`, `CFGS`, `AMPL`, `PROV`, `CNTP`, or unknown).
    pub tag: [u8; 4],
    /// Absolute payload offset from the start of the bundle.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u32,
}

/// The `HDCM` header plus section table of a bundle, built in **one
/// bounds-checked pass that never reads a payload byte**: per section only
/// the 8-byte tag + length header is read and the payload is seeked over.
/// This is the CompIM principle applied to model memory — keep the cheap
/// index resident, regenerate (decode) the expensive part on demand.
#[derive(Clone, Debug)]
pub struct BundleIndex {
    /// On-disk format version (header field; `wire_format()` of the writer).
    pub format: u32,
    /// Sections in file order, unknown tags included.
    pub sections: Vec<SectionSpan>,
}

impl BundleIndex {
    /// Scan the header + section table of `src` (`total` = source length
    /// in bytes). Every section span is validated against `total` before
    /// being recorded, so a span can always be read back with a fixed-size
    /// buffer no larger than the file itself.
    pub fn scan<R: std::io::Read + std::io::Seek>(
        src: &mut R,
        total: u64,
    ) -> crate::Result<BundleIndex> {
        use std::io::SeekFrom;
        src.seek(SeekFrom::Start(0)).context("seek model bundle header")?;
        let mut header = [0u8; 12];
        src.read_exact(&mut header).context("model bundle header")?;
        ensure!(
            header[..4] == MAGIC,
            "not a model bundle: magic {:02x?} (expected {:02x?} — is this a `repro train --save` file?)",
            &header[..4],
            MAGIC
        );
        let format = u32::from_le_bytes(header[4..8].try_into().expect("4 bytes"));
        ensure!(
            (BASE_FORMAT_VERSION..=FORMAT_VERSION).contains(&format),
            "model bundle format version {format}, this build reads \
             {BASE_FORMAT_VERSION}..={FORMAT_VERSION} — re-save with a matching build"
        );
        let n_sections = u32::from_le_bytes(header[8..12].try_into().expect("4 bytes"));
        let mut sections = Vec::new();
        let mut offset = 12u64;
        for i in 0..n_sections {
            ensure!(
                offset + 8 <= total,
                "truncated model bundle: section {i} header at offset {offset}, \
                 file is {total} bytes"
            );
            src.seek(SeekFrom::Start(offset)).context("seek section header")?;
            let mut head = [0u8; 8];
            src.read_exact(&mut head)
                .with_context(|| format!("section {i} header"))?;
            let tag: [u8; 4] = head[..4].try_into().expect("4-byte slice");
            let len = u32::from_le_bytes(head[4..8].try_into().expect("4 bytes"));
            let payload = offset + 8;
            ensure!(
                payload + len as u64 <= total,
                "truncated model bundle: section {} wants {len} bytes at offset \
                 {payload}, file is {total} bytes",
                tag_name(&tag)
            );
            sections.push(SectionSpan { tag, offset: payload, len });
            offset = payload + len as u64;
        }
        ensure!(
            offset == total,
            "{} trailing bytes after {} sections",
            total - offset,
            n_sections
        );
        Ok(BundleIndex { format, sections })
    }

    /// First section with `tag`, if present.
    pub fn find(&self, tag: &[u8; 4]) -> Option<&SectionSpan> {
        self.sections.iter().find(|s| &s.tag == tag)
    }
}

/// Where a [`LazyBundle`] reads payloads back from.
enum LazySource {
    Bytes(Vec<u8>),
    File(std::sync::Mutex<std::fs::File>),
}

impl LazySource {
    fn read_span(&self, span: &SectionSpan) -> crate::Result<Vec<u8>> {
        match self {
            LazySource::Bytes(buf) => {
                // Spans were validated against the buffer length at scan
                // time and the buffer is owned, so this cannot overrun.
                let start = span.offset as usize;
                Ok(buf[start..start + span.len as usize].to_vec())
            }
            LazySource::File(file) => {
                use std::io::{Read, Seek, SeekFrom};
                let mut f = file.lock().unwrap_or_else(|e| e.into_inner());
                f.seek(SeekFrom::Start(span.offset))
                    .with_context(|| format!("seek section {}", tag_name(&span.tag)))?;
                // Bounded by the file size observed at scan time; a file
                // that shrank since then fails the read, never overreads.
                let mut buf = vec![0u8; span.len as usize];
                f.read_exact(&mut buf)
                    .with_context(|| format!("read section {}", tag_name(&span.tag)))?;
                Ok(buf)
            }
        }
    }
}

/// A bundle opened through its [`BundleIndex`]: the small sections
/// (`META`, `CFGS`, `PROV`) are decoded eagerly — they are what listings,
/// recovery validation and lineage walks need — while the heavy sections
/// (`AMPL`, `CNTP`) stay on disk until [`LazyBundle::am`] /
/// [`LazyBundle::counters`] demand them via positioned reads. Peeking a
/// 10k-patient store therefore never materializes a single class HV or
/// counter plane; [`LazyBundle::decode_count`] proves it.
pub struct LazyBundle {
    index: BundleIndex,
    source: LazySource,
    version: u64,
    variant: Variant,
    config: ClassifierConfig,
    provenance: Provenance,
    am: std::sync::OnceLock<AssociativeMemory>,
    counters: std::sync::OnceLock<CounterPlanes>,
    decodes: std::sync::atomic::AtomicUsize,
}

impl LazyBundle {
    /// Open `path` file-backed: scan the section table, decode the small
    /// sections, keep the file handle for on-demand payload reads.
    pub fn open(path: &Path) -> crate::Result<LazyBundle> {
        let mut file = std::fs::File::open(path)
            .with_context(|| format!("open model bundle {}", path.display()))?;
        let total = file
            .metadata()
            .with_context(|| format!("stat model bundle {}", path.display()))?
            .len();
        let index = BundleIndex::scan(&mut file, total)
            .with_context(|| format!("parse model bundle {}", path.display()))?;
        Self::from_parts(index, LazySource::File(std::sync::Mutex::new(file)))
            .with_context(|| format!("parse model bundle {}", path.display()))
    }

    /// Open an in-memory serialization (tests, network payloads).
    pub fn from_vec(bytes: Vec<u8>) -> crate::Result<LazyBundle> {
        let total = bytes.len() as u64;
        let mut cursor = std::io::Cursor::new(&bytes);
        let index = BundleIndex::scan(&mut cursor, total)?;
        Self::from_parts(index, LazySource::Bytes(bytes))
    }

    fn from_parts(index: BundleIndex, source: LazySource) -> crate::Result<LazyBundle> {
        let meta = index.find(b"META").context("model bundle has no META section")?;
        let (version, variant) = decode_meta(&source.read_span(meta)?)?;
        let cfgs = index.find(b"CFGS").context("model bundle has no CFGS section")?;
        let config = decode_cfgs(&source.read_span(cfgs)?)?;
        let prov = index.find(b"PROV").context("model bundle has no PROV section")?;
        let provenance = decode_prov(&source.read_span(prov)?)?;
        // Required even though it stays undecoded: a bundle without an AM
        // can never serve, so reject it at open rather than at first use.
        index.find(b"AMPL").context("model bundle has no AMPL section")?;
        Ok(LazyBundle {
            index,
            source,
            version,
            variant,
            config,
            provenance,
            am: std::sync::OnceLock::new(),
            counters: std::sync::OnceLock::new(),
            decodes: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn config(&self) -> &ClassifierConfig {
        &self.config
    }

    pub fn provenance(&self) -> &Provenance {
        &self.provenance
    }

    /// The on-disk format version (the writer stamps 2 exactly when `CNTP`
    /// is present, so this matches [`ModelBundle::wire_format`]).
    pub fn wire_format(&self) -> u32 {
        self.index.format
    }

    /// Whether a `CNTP` section exists — answered from the index alone,
    /// without decoding it.
    pub fn has_counters(&self) -> bool {
        self.index.find(b"CNTP").is_some()
    }

    /// The section table this bundle was opened through.
    pub fn index(&self) -> &BundleIndex {
        &self.index
    }

    /// Heavy-section decodes performed so far (`AMPL` + `CNTP`). Listing
    /// paths assert this stays 0.
    pub fn decode_count(&self) -> usize {
        self.decodes.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The associative memory, decoded on first use and cached.
    pub fn am(&self) -> crate::Result<&AssociativeMemory> {
        if let Some(am) = self.am.get() {
            return Ok(am);
        }
        let span = self.index.find(b"AMPL").context("model bundle has no AMPL section")?;
        let am = decode_ampl(&self.source.read_span(span)?)?;
        self.decodes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(self.am.get_or_init(|| am))
    }

    /// The counter planes, decoded on first use and cached; `Ok(None)`
    /// when the bundle has no `CNTP` section.
    pub fn counters(&self) -> crate::Result<Option<&CounterPlanes>> {
        let Some(span) = self.index.find(b"CNTP") else {
            return Ok(None);
        };
        if let Some(c) = self.counters.get() {
            return Ok(Some(c));
        }
        let c = decode_cntp(&self.source.read_span(span)?)?;
        self.decodes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Some(self.counters.get_or_init(|| c)))
    }

    /// Materialize the full [`ModelBundle`] (decodes whatever is still
    /// lazy) — the recovery path that actually serves a model ends here.
    pub fn load_full(&self) -> crate::Result<ModelBundle> {
        Ok(ModelBundle {
            version: self.version,
            variant: self.variant,
            config: self.config.clone(),
            am: self.am()?.clone(),
            provenance: self.provenance.clone(),
            counters: self.counters()?.cloned(),
        })
    }

    /// [`ModelBundle::describe`] parity from the small sections alone:
    /// fields that would force a heavy decode report their lazy state
    /// instead (and render identically once decoded).
    pub fn describe(&self) -> String {
        let densities = match self.am.get() {
            Some(am) => format!(
                "interictal {:.1}% / ictal {:.1}%",
                am.classes[0].density() * 100.0,
                am.classes[1].density() * 100.0
            ),
            None => "not decoded (lazy open)".to_string(),
        };
        let counters = if !self.has_counters() {
            counters_text(None)
        } else {
            match self.counters.get() {
                Some(c) => counters_text(Some(c)),
                None => "present (not decoded — lazy open)".to_string(),
            }
        };
        describe_parts(
            self.version,
            self.wire_format(),
            self.variant,
            &self.config,
            &densities,
            &self.provenance,
            &counters,
        )
    }
}

fn tag_name(tag: &[u8; 4]) -> String {
    String::from_utf8_lossy(tag).into_owned()
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Bounds-checked little-endian reader over a byte slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "truncated model bundle: need {n} bytes at offset {}, only {} left",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> crate::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn string(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    /// Assert a known section was consumed exactly (a short or long
    /// payload means corruption, not forward-compatible extension — new
    /// fields get a format-version bump).
    fn finish(&self, tag: &str) -> crate::Result<()> {
        ensure!(
            self.remaining() == 0,
            "section {tag} has {} unread bytes (corrupt or wrong format)",
            self.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn bundle(seed: u64) -> ModelBundle {
        let mut rng = Xoshiro256::new(seed);
        ModelBundle {
            version: 3,
            variant: Variant::Optimized,
            config: ClassifierConfig {
                seed: 0xABCD_EF01_2345_6789,
                spatial_threshold: 1,
                temporal_threshold: 117,
                train_density: 0.37,
            },
            am: AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.2)),
            provenance: Provenance {
                patient_id: 11,
                epochs: 2,
                parent_version: 2,
                train_windows: [120, 40],
                note: "unit-test bundle — µtf8 ✓".to_string(),
            },
            counters: None,
        }
    }

    fn bundle_v2(seed: u64) -> ModelBundle {
        let mut rng = Xoshiro256::new(seed ^ 0xC0DE);
        let mut b = bundle(seed);
        b.counters = Some(crate::testkit::random_counter_planes(&mut rng));
        b
    }

    #[test]
    fn roundtrip_is_identity() {
        let b = bundle(1);
        let back = ModelBundle::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(back, b);
        // Bit-level: re-serializing the parse yields the same bytes.
        assert_eq!(back.to_bytes(), b.to_bytes());
    }

    #[test]
    fn v2_roundtrip_preserves_counter_planes() {
        let b = bundle_v2(21);
        let bytes = b.to_bytes();
        // Counter planes force the format-2 header…
        assert_eq!(bytes[4..8], FORMAT_VERSION.to_le_bytes());
        let back = ModelBundle::from_bytes(&bytes).unwrap();
        assert_eq!(back, b);
        assert_eq!(back.to_bytes(), bytes);
        // …while counter-less bundles stay on the format-1 wire, byte-
        // compatible with readers that predate CNTP.
        assert_eq!(bundle(21).to_bytes()[4..8], BASE_FORMAT_VERSION.to_le_bytes());
    }

    #[test]
    fn v2_truncations_error_without_panicking() {
        let bytes = bundle_v2(22).to_bytes();
        for n in 0..bytes.len() {
            assert!(
                ModelBundle::from_bytes(&bytes[..n]).is_err(),
                "prefix of {n}/{} bytes must be rejected",
                bytes.len()
            );
        }
        assert!(ModelBundle::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn cntp_shape_mismatch_rejected() {
        let b = bundle_v2(23);
        let mut bytes = b.to_bytes();
        // The CNTP payload opens with classes u32 + dim u32; find the
        // section and corrupt its dim field.
        let pos = bytes.windows(4).position(|w| w == b"CNTP".as_slice()).unwrap();
        bytes[pos + 8 + 4..pos + 8 + 8].copy_from_slice(&77u32.to_le_bytes());
        let err = ModelBundle::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("77"), "{err:#}");
    }

    #[test]
    fn save_load_roundtrip_through_disk() {
        let b = bundle(2);
        let path = std::env::temp_dir().join(format!("hdc_model_{}.hdcm", std::process::id()));
        b.save(&path).unwrap();
        let back = ModelBundle::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, b);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = bundle(3).to_bytes();
        bytes[0] = b'X';
        let err = ModelBundle::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
    }

    #[test]
    fn format_version_mismatch_is_actionable() {
        let mut bytes = bundle(4).to_bytes();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = ModelBundle::from_bytes(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("format version 99"), "{msg}");
        assert!(msg.contains(&FORMAT_VERSION.to_string()), "{msg}");
    }

    #[test]
    fn every_truncation_errors_without_panicking() {
        let bytes = bundle(5).to_bytes();
        for n in 0..bytes.len() {
            assert!(
                ModelBundle::from_bytes(&bytes[..n]).is_err(),
                "prefix of {n}/{} bytes must be rejected",
                bytes.len()
            );
        }
        assert!(ModelBundle::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = bundle(6).to_bytes();
        bytes.push(0);
        assert!(ModelBundle::from_bytes(&bytes).is_err());
    }

    #[test]
    fn unknown_sections_are_skipped() {
        // Forward compatibility: a newer writer may append sections this
        // reader does not know; they must parse-skip cleanly.
        let b = bundle(7);
        let mut bytes = b.to_bytes();
        bytes[8..12].copy_from_slice(&5u32.to_le_bytes()); // section count 4 → 5
        section(&mut bytes, b"XTRA", &[1, 2, 3, 4]);
        let back = ModelBundle::from_bytes(&bytes).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn oversized_section_length_rejected() {
        let b = bundle(8);
        let bytes = b.to_bytes();
        // Patch the META section length to overrun the buffer.
        let mut patched = bytes.clone();
        patched[16..20].copy_from_slice(&(bytes.len() as u32).to_le_bytes());
        assert!(ModelBundle::from_bytes(&patched).is_err());
    }

    #[test]
    fn describe_mentions_the_essentials() {
        let d = bundle(9).describe();
        assert!(d.contains("v3"), "{d}");
        assert!(d.contains("sparse-optimized"), "{d}");
        assert!(d.contains("117"), "{d}");
        assert!(d.contains("patient 11"), "{d}");
        assert!(d.contains("derived from v2"), "{d}");
    }

    #[test]
    fn am_plane_from_bundle_never_decodes() {
        let b = bundle(10);
        let plane = AmPlane::from_bundle(&b);
        assert_eq!(plane.memory().classes, b.am.classes);
        assert_eq!(plane.decode_count(), 0);
    }

    #[test]
    fn next_version_is_monotone() {
        let b = bundle(11);
        assert_eq!(b.next_version(), 4);
        assert_eq!(ModelBundle::new(b.variant, b.config, b.am, b.provenance).version, 1);
    }

    #[test]
    fn bundle_index_records_sections_without_reading_payloads() {
        let b = bundle_v2(30);
        let bytes = b.to_bytes();
        let mut cursor = std::io::Cursor::new(&bytes);
        let idx = BundleIndex::scan(&mut cursor, bytes.len() as u64).unwrap();
        assert_eq!(idx.format, FORMAT_VERSION);
        let tags: Vec<&[u8; 4]> = idx.sections.iter().map(|s| &s.tag).collect();
        assert_eq!(tags, [b"META", b"CFGS", b"AMPL", b"PROV", b"CNTP"]);
        // Spans are exactly the written section payloads.
        for span in &idx.sections {
            let start = span.offset as usize;
            assert!(start + span.len as usize <= bytes.len());
            assert_eq!(&bytes[start - 8..start - 4], &span.tag);
        }
    }

    #[test]
    fn bundle_index_rejects_truncation_and_trailing_bytes() {
        let bytes = bundle_v2(31).to_bytes();
        for n in 0..bytes.len() {
            let mut cursor = std::io::Cursor::new(&bytes[..n]);
            assert!(
                BundleIndex::scan(&mut cursor, n as u64).is_err(),
                "prefix of {n}/{} bytes must be rejected",
                bytes.len()
            );
        }
        let mut extended = bytes.clone();
        extended.push(0);
        let mut cursor = std::io::Cursor::new(&extended);
        let err = BundleIndex::scan(&mut cursor, extended.len() as u64).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn lazy_open_decodes_nothing_heavy() {
        let b = bundle_v2(32);
        let path = std::env::temp_dir().join(format!("hdc_lazy_{}.hdcm", std::process::id()));
        b.save(&path).unwrap();
        let lazy = LazyBundle::open(&path).unwrap();
        // Everything a listing needs, straight from META/CFGS/PROV:
        assert_eq!(lazy.version(), b.version);
        assert_eq!(lazy.variant(), b.variant);
        assert_eq!(lazy.config(), &b.config);
        assert_eq!(lazy.provenance(), &b.provenance);
        assert_eq!(lazy.wire_format(), b.wire_format());
        assert!(lazy.has_counters());
        assert!(lazy.describe().contains("not decoded"), "{}", lazy.describe());
        assert_eq!(lazy.decode_count(), 0);
        // Demanding the heavy sections decodes them — once each.
        assert_eq!(lazy.am().unwrap(), &b.am);
        assert_eq!(lazy.counters().unwrap(), b.counters.as_ref());
        assert_eq!(lazy.decode_count(), 2);
        assert_eq!(lazy.am().unwrap(), &b.am);
        assert_eq!(lazy.decode_count(), 2);
        // Fully decoded, describe() matches the eager bundle exactly.
        assert_eq!(lazy.describe(), b.describe());
        assert_eq!(lazy.load_full().unwrap(), b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lazy_format1_bundle_has_no_counters() {
        let b = bundle(33);
        let lazy = LazyBundle::from_vec(b.to_bytes()).unwrap();
        assert_eq!(lazy.wire_format(), BASE_FORMAT_VERSION);
        assert!(!lazy.has_counters());
        assert_eq!(lazy.counters().unwrap(), None);
        assert_eq!(lazy.decode_count(), 0);
        assert_eq!(lazy.load_full().unwrap(), b);
        assert_eq!(lazy.decode_count(), 1); // AMPL only — no CNTP to decode
    }

    #[test]
    fn lazy_rejects_missing_required_sections() {
        let b = bundle(34);
        let mut bytes = b.to_bytes();
        // Rename AMPL so only META/CFGS/PROV remain known.
        let pos = bytes.windows(4).position(|w| w == b"AMPL".as_slice()).unwrap();
        bytes[pos..pos + 4].copy_from_slice(b"XXXX");
        let err = LazyBundle::from_vec(bytes).unwrap_err();
        assert!(format!("{err:#}").contains("AMPL"), "{err:#}");
    }

    #[test]
    fn lazy_corrupt_heavy_section_fails_at_decode_not_open() {
        let b = bundle_v2(35);
        let mut bytes = b.to_bytes();
        // Corrupt the AMPL dim field: the open (index + small sections)
        // must still succeed; the on-demand decode must fail actionably.
        let pos = bytes.windows(4).position(|w| w == b"AMPL".as_slice()).unwrap();
        bytes[pos + 8 + 4..pos + 8 + 8].copy_from_slice(&99u32.to_le_bytes());
        let lazy = LazyBundle::from_vec(bytes).unwrap();
        assert_eq!(lazy.version(), b.version);
        let err = lazy.am().unwrap_err();
        assert!(format!("{err:#}").contains("99"), "{err:#}");
        assert_eq!(lazy.decode_count(), 0);
    }
}
