//! Persistent model artifacts: the [`ModelBundle`].
//!
//! A trained model is more than the two class HVs: reproducing its
//! predictions needs the encoder seed and thresholds it was trained
//! against, and operating it over time needs provenance (who trained it,
//! on how many windows, how many online epochs) plus a **monotonically
//! increasing version** so a registry can reject stale publishes. The
//! bundle carries all of that as one first-class, saveable artifact —
//! `repro train --save` writes it, `repro model-info` inspects it,
//! `repro serve --model` deploys it without retraining at startup, and
//! [`crate::hdc::online::OnlineTrainer`] derives new versions from it.
//!
//! ## On-disk format
//!
//! Dependency-free little-endian binary (serde is unavailable offline —
//! DESIGN.md §2), mirroring the hand-rolled approach of
//! [`crate::benchkit`]'s JSON reader and the `.ieeg` dataset format:
//!
//! ```text
//! magic   [u8;4] = b"HDCM"
//! format  u32    = 1 | 2
//! n_sections u32
//! section * n_sections:
//!     tag [u8;4], len u32, payload [u8; len]
//! ```
//!
//! Sections (any order; unknown tags are skipped for forward
//! compatibility, the first four below are required):
//!
//! | tag    | payload                                                        |
//! |--------|----------------------------------------------------------------|
//! | `META` | version u64, variant name (u32 len + utf8)                     |
//! | `CFGS` | seed u64, spatial u16, temporal u16, train_density f64-bits    |
//! | `AMPL` | num_classes u32, dim u32, packed class HVs (dim/8 bytes each)  |
//! | `PROV` | patient u32, epochs u32, parent u64, windows 2×u64, note (str) |
//! | `CNTP` | classes u32, dim u32, windows 2×u64, per-class count planes    |
//! |        | (dim × u32 each) — **format 2, optional**                      |
//!
//! Format 2 is format 1 plus the optional `CNTP` section: the saturating
//! per-class counter planes the model was thinned from, so
//! [`crate::hdc::online::OnlineTrainer`] can resume retraining
//! incrementally from the artifact instead of re-seeding from a record.
//! A bundle without counter planes is still written as format 1 (byte-
//! identical to the format-1 writer), and because `CNTP` is just another
//! length-prefixed section, a format-1 reader that tolerates the header
//! recovers the format-1 content by the unknown-section skip rule.
//! Readers here accept both versions and skip `CNTP` when absent.
//!
//! Every length is validated against the remaining file size before any
//! payload is touched (allocations are fixed-size, never sized by an
//! attacker-controlled length), so truncated, corrupt or bit-flipped
//! files fail with an actionable error instead of a panic or an OOM; a
//! format-version bump beyond what this build reads fails loudly rather
//! than misreading new bytes.

use std::path::Path;

use crate::ensure;
use crate::error::Context;
use crate::params::{DIM, NUM_CLASSES};

use super::am::{AmPlane, AssociativeMemory};
use super::classifier::{ClassifierConfig, Variant};
use super::hv::Hv;

const MAGIC: [u8; 4] = *b"HDCM";

/// Newest on-disk format version this build reads and writes. Bundles
/// without counter planes are still written as format
/// [`BASE_FORMAT_VERSION`] so format-1 readers keep loading them.
pub const FORMAT_VERSION: u32 = 2;

/// The counter-plane-free baseline format (what PR-4 readers understand).
pub const BASE_FORMAT_VERSION: u32 = 1;

/// Where a model came from: training lineage metadata, carried alongside
/// the weights so `repro model-info` can answer "what is this file?".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Provenance {
    /// Patient the model was trained for (0 = unknown / not patient-bound).
    pub patient_id: u32,
    /// Online-retraining epochs behind this version (0 = one-shot).
    pub epochs: u32,
    /// Version this bundle was derived from (0 = freshly trained).
    pub parent_version: u64,
    /// Training windows absorbed per class (interictal, ictal).
    pub train_windows: [u64; NUM_CLASSES],
    /// Free-form note ("one-shot", retrain summary, ...).
    pub note: String,
}

/// The training state behind a thinned AM: the per-class counter planes
/// (saturating accumulators of every absorbed window query) plus the
/// absorbed-window counts. Carrying them in the bundle (format 2,
/// section `CNTP`) lets a retrain resume exactly where the previous
/// training pass left off instead of re-seeding the planes from the raw
/// record — the artifact *is* the training state.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterPlanes {
    /// Per-class accumulators (interictal, ictal), one count per HV
    /// element.
    pub counts: [Box<[u32; DIM]>; NUM_CLASSES],
    /// Windows absorbed into each plane (interictal, ictal).
    pub windows: [u64; NUM_CLASSES],
}

/// A complete, persistent, versioned model artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelBundle {
    /// Monotonically increasing model version (fresh training = 1; each
    /// online retrain derives `version + 1`). Registries reject stale
    /// publishes by comparing this.
    pub version: u64,
    /// Design point the model was trained for.
    pub variant: Variant,
    /// Encoder configuration the AM was trained against (seed,
    /// spatial/temporal thresholds, train density) — serving must encode
    /// with exactly this config to reproduce the training-time function.
    pub config: ClassifierConfig,
    /// The trained associative memory (class-representing HVs).
    pub am: AssociativeMemory,
    pub provenance: Provenance,
    /// Format-2 counter planes ([`CounterPlanes`]): present on bundles
    /// emitted by the training paths, absent on format-1 artifacts.
    /// `None` never blocks serving — only incremental retraining falls
    /// back to re-seeding from a record.
    pub counters: Option<CounterPlanes>,
}

impl ModelBundle {
    /// A freshly trained version-1 bundle.
    pub fn new(
        variant: Variant,
        config: ClassifierConfig,
        am: AssociativeMemory,
        provenance: Provenance,
    ) -> ModelBundle {
        ModelBundle {
            version: 1,
            variant,
            config,
            am,
            provenance,
            counters: None,
        }
    }

    /// The version an artifact derived from this bundle must carry.
    pub fn next_version(&self) -> u64 {
        self.version + 1
    }

    /// The format version this bundle serializes as: counter planes need
    /// format 2, everything else stays at the format-1 baseline so
    /// format-1 readers keep loading counter-less artifacts byte for
    /// byte.
    pub fn wire_format(&self) -> u32 {
        if self.counters.is_some() {
            FORMAT_VERSION
        } else {
            BASE_FORMAT_VERSION
        }
    }

    /// Serialize to the on-disk byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut meta = Vec::new();
        put_u64(&mut meta, self.version);
        put_str(&mut meta, self.variant.name());

        let mut cfgs = Vec::new();
        put_u64(&mut cfgs, self.config.seed);
        cfgs.extend_from_slice(&self.config.spatial_threshold.to_le_bytes());
        cfgs.extend_from_slice(&self.config.temporal_threshold.to_le_bytes());
        put_u64(&mut cfgs, self.config.train_density.to_bits());

        let mut ampl = Vec::new();
        ampl.extend_from_slice(&(NUM_CLASSES as u32).to_le_bytes());
        ampl.extend_from_slice(&(DIM as u32).to_le_bytes());
        for class in &self.am.classes {
            ampl.extend_from_slice(&class.to_bytes());
        }

        let mut prov = Vec::new();
        prov.extend_from_slice(&self.provenance.patient_id.to_le_bytes());
        prov.extend_from_slice(&self.provenance.epochs.to_le_bytes());
        put_u64(&mut prov, self.provenance.parent_version);
        for &w in &self.provenance.train_windows {
            put_u64(&mut prov, w);
        }
        put_str(&mut prov, &self.provenance.note);

        let cntp = self.counters.as_ref().map(|c| {
            let mut cntp = Vec::with_capacity(8 + 16 + NUM_CLASSES * DIM * 4);
            cntp.extend_from_slice(&(NUM_CLASSES as u32).to_le_bytes());
            cntp.extend_from_slice(&(DIM as u32).to_le_bytes());
            for &w in &c.windows {
                put_u64(&mut cntp, w);
            }
            for plane in &c.counts {
                for &count in plane.iter() {
                    cntp.extend_from_slice(&count.to_le_bytes());
                }
            }
            cntp
        });

        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&self.wire_format().to_le_bytes());
        out.extend_from_slice(&(4u32 + cntp.is_some() as u32).to_le_bytes());
        section(&mut out, b"META", &meta);
        section(&mut out, b"CFGS", &cfgs);
        section(&mut out, b"AMPL", &ampl);
        section(&mut out, b"PROV", &prov);
        if let Some(cntp) = &cntp {
            section(&mut out, b"CNTP", cntp);
        }
        out
    }

    /// Parse the on-disk byte format. Rejects bad magic, format-version
    /// mismatches, truncation, length overruns, unknown variants and
    /// architecture mismatches with actionable errors; unknown *sections*
    /// are skipped (forward compatibility within one format version).
    pub fn from_bytes(bytes: &[u8]) -> crate::Result<ModelBundle> {
        let mut r = Reader::new(bytes);
        let magic = r.take(4).context("model bundle header")?;
        ensure!(
            magic == &MAGIC,
            "not a model bundle: magic {:02x?} (expected {:02x?} — is this a `repro train --save` file?)",
            magic,
            MAGIC
        );
        let format = r.u32()?;
        ensure!(
            (BASE_FORMAT_VERSION..=FORMAT_VERSION).contains(&format),
            "model bundle format version {format}, this build reads \
             {BASE_FORMAT_VERSION}..={FORMAT_VERSION} — re-save with a matching build"
        );
        let n_sections = r.u32()?;

        let mut meta: Option<(u64, Variant)> = None;
        let mut cfgs: Option<ClassifierConfig> = None;
        let mut ampl: Option<AssociativeMemory> = None;
        let mut prov: Option<Provenance> = None;
        let mut cntp: Option<CounterPlanes> = None;

        for _ in 0..n_sections {
            let tag: [u8; 4] = r.take(4)?.try_into().expect("4-byte slice");
            let len = r.u32()? as usize;
            let payload = r
                .take(len)
                .with_context(|| format!("section {:?}", tag_name(&tag)))?;
            let mut pr = Reader::new(payload);
            match &tag {
                b"META" => {
                    let version = pr.u64()?;
                    ensure!(version >= 1, "model version 0 (must be >= 1)");
                    let name = pr.string()?;
                    let variant = Variant::from_name(&name)
                        .with_context(|| format!("unknown variant {name:?} in model bundle"))?;
                    pr.finish("META")?;
                    meta = Some((version, variant));
                }
                b"CFGS" => {
                    let seed = pr.u64()?;
                    let spatial_threshold = pr.u16()?;
                    let temporal_threshold = pr.u16()?;
                    let train_density = f64::from_bits(pr.u64()?);
                    pr.finish("CFGS")?;
                    cfgs = Some(ClassifierConfig {
                        seed,
                        spatial_threshold,
                        temporal_threshold,
                        train_density,
                    });
                }
                b"AMPL" => {
                    let classes = pr.u32()? as usize;
                    let dim = pr.u32()? as usize;
                    ensure!(
                        classes == NUM_CLASSES && dim == DIM,
                        "model bundle is {classes} classes × {dim} dims, \
                         this build expects {NUM_CLASSES} × {DIM}"
                    );
                    let mut hvs = [Hv::zero(); NUM_CLASSES];
                    for hv in hvs.iter_mut() {
                        let raw: &[u8; DIM / 8] =
                            pr.take(DIM / 8)?.try_into().expect("fixed-size slice");
                        *hv = Hv::from_bytes(raw);
                    }
                    pr.finish("AMPL")?;
                    ampl = Some(AssociativeMemory::new(hvs[0], hvs[1]));
                }
                b"PROV" => {
                    let patient_id = pr.u32()?;
                    let epochs = pr.u32()?;
                    let parent_version = pr.u64()?;
                    let mut train_windows = [0u64; NUM_CLASSES];
                    for w in train_windows.iter_mut() {
                        *w = pr.u64()?;
                    }
                    let note = pr.string()?;
                    pr.finish("PROV")?;
                    prov = Some(Provenance {
                        patient_id,
                        epochs,
                        parent_version,
                        train_windows,
                        note,
                    });
                }
                b"CNTP" => {
                    let classes = pr.u32()? as usize;
                    let dim = pr.u32()? as usize;
                    ensure!(
                        classes == NUM_CLASSES && dim == DIM,
                        "counter planes are {classes} classes × {dim} dims, \
                         this build expects {NUM_CLASSES} × {DIM}"
                    );
                    let mut windows = [0u64; NUM_CLASSES];
                    for w in windows.iter_mut() {
                        *w = pr.u64()?;
                    }
                    // Fixed-size allocation: the payload length was
                    // already bounds-checked against the file, and the
                    // planes are DIM × u32 by construction — nothing here
                    // allocates from an attacker-controlled length.
                    let mut counts = [Box::new([0u32; DIM]), Box::new([0u32; DIM])];
                    for plane in counts.iter_mut() {
                        let raw = pr.take(DIM * 4)?;
                        for (slot, chunk) in plane.iter_mut().zip(raw.chunks_exact(4)) {
                            *slot = u32::from_le_bytes(chunk.try_into().expect("4 bytes"));
                        }
                    }
                    pr.finish("CNTP")?;
                    cntp = Some(CounterPlanes { counts, windows });
                }
                _ => {} // unknown section: skip (forward compatibility)
            }
        }
        ensure!(
            r.remaining() == 0,
            "{} trailing bytes after {} sections",
            r.remaining(),
            n_sections
        );

        let (version, variant) = meta.context("model bundle has no META section")?;
        Ok(ModelBundle {
            version,
            variant,
            config: cfgs.context("model bundle has no CFGS section")?,
            am: ampl.context("model bundle has no AMPL section")?,
            provenance: prov.context("model bundle has no PROV section")?,
            counters: cntp,
        })
    }

    /// Write the bundle to `path`.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write model bundle {}", path.display()))
    }

    /// Load a bundle from `path`.
    pub fn load(path: &Path) -> crate::Result<ModelBundle> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read model bundle {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parse model bundle {}", path.display()))
    }

    /// Human-readable summary (`repro model-info`).
    pub fn describe(&self) -> String {
        let p = &self.provenance;
        let lineage = if p.parent_version == 0 {
            "freshly trained".to_string()
        } else {
            format!("derived from v{}", p.parent_version)
        };
        let counters = match &self.counters {
            Some(c) => format!(
                "present ({}/{} windows — incremental retrain resumes here)",
                c.windows[0], c.windows[1]
            ),
            None => "absent (format-1 artifact — retrains re-seed from a record)".to_string(),
        };
        format!(
            "model bundle v{} (format {fmt})\n\
             \x20 variant            : {}\n\
             \x20 encoder seed       : {:#018x}\n\
             \x20 spatial threshold  : {}\n\
             \x20 temporal threshold : {}\n\
             \x20 train density      : {:.3}\n\
             \x20 class densities    : interictal {:.1}% / ictal {:.1}%\n\
             \x20 provenance         : patient {}, {} online epoch(s), {}, \
             windows {}/{}\n\
             \x20 counter planes     : {}\n\
             \x20 note               : {}",
            self.version,
            self.variant.name(),
            self.config.seed,
            self.config.spatial_threshold,
            self.config.temporal_threshold,
            self.config.train_density,
            self.am.classes[0].density() * 100.0,
            self.am.classes[1].density() * 100.0,
            p.patient_id,
            p.epochs,
            lineage,
            p.train_windows[0],
            p.train_windows[1],
            counters,
            if p.note.is_empty() { "—" } else { &p.note },
            fmt = self.wire_format(),
        )
    }
}

impl AmPlane {
    /// Both engine representations of a bundle's AM — what every engine
    /// (native and PJRT) consumes, pre-decoded so serving never pays a
    /// plane decode (see [`AmPlane::from_memory`]).
    pub fn from_bundle(bundle: &ModelBundle) -> AmPlane {
        AmPlane::from_memory(&bundle.am)
    }
}

fn tag_name(tag: &[u8; 4]) -> String {
    String::from_utf8_lossy(tag).into_owned()
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Bounds-checked little-endian reader over a byte slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "truncated model bundle: need {n} bytes at offset {}, only {} left",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> crate::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn string(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    /// Assert a known section was consumed exactly (a short or long
    /// payload means corruption, not forward-compatible extension — new
    /// fields get a format-version bump).
    fn finish(&self, tag: &str) -> crate::Result<()> {
        ensure!(
            self.remaining() == 0,
            "section {tag} has {} unread bytes (corrupt or wrong format)",
            self.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn bundle(seed: u64) -> ModelBundle {
        let mut rng = Xoshiro256::new(seed);
        ModelBundle {
            version: 3,
            variant: Variant::Optimized,
            config: ClassifierConfig {
                seed: 0xABCD_EF01_2345_6789,
                spatial_threshold: 1,
                temporal_threshold: 117,
                train_density: 0.37,
            },
            am: AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.2)),
            provenance: Provenance {
                patient_id: 11,
                epochs: 2,
                parent_version: 2,
                train_windows: [120, 40],
                note: "unit-test bundle — µtf8 ✓".to_string(),
            },
            counters: None,
        }
    }

    fn bundle_v2(seed: u64) -> ModelBundle {
        let mut rng = Xoshiro256::new(seed ^ 0xC0DE);
        let mut b = bundle(seed);
        b.counters = Some(crate::testkit::random_counter_planes(&mut rng));
        b
    }

    #[test]
    fn roundtrip_is_identity() {
        let b = bundle(1);
        let back = ModelBundle::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(back, b);
        // Bit-level: re-serializing the parse yields the same bytes.
        assert_eq!(back.to_bytes(), b.to_bytes());
    }

    #[test]
    fn v2_roundtrip_preserves_counter_planes() {
        let b = bundle_v2(21);
        let bytes = b.to_bytes();
        // Counter planes force the format-2 header…
        assert_eq!(bytes[4..8], FORMAT_VERSION.to_le_bytes());
        let back = ModelBundle::from_bytes(&bytes).unwrap();
        assert_eq!(back, b);
        assert_eq!(back.to_bytes(), bytes);
        // …while counter-less bundles stay on the format-1 wire, byte-
        // compatible with readers that predate CNTP.
        assert_eq!(bundle(21).to_bytes()[4..8], BASE_FORMAT_VERSION.to_le_bytes());
    }

    #[test]
    fn v2_truncations_error_without_panicking() {
        let bytes = bundle_v2(22).to_bytes();
        for n in 0..bytes.len() {
            assert!(
                ModelBundle::from_bytes(&bytes[..n]).is_err(),
                "prefix of {n}/{} bytes must be rejected",
                bytes.len()
            );
        }
        assert!(ModelBundle::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn cntp_shape_mismatch_rejected() {
        let b = bundle_v2(23);
        let mut bytes = b.to_bytes();
        // The CNTP payload opens with classes u32 + dim u32; find the
        // section and corrupt its dim field.
        let pos = bytes.windows(4).position(|w| w == b"CNTP".as_slice()).unwrap();
        bytes[pos + 8 + 4..pos + 8 + 8].copy_from_slice(&77u32.to_le_bytes());
        let err = ModelBundle::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("77"), "{err:#}");
    }

    #[test]
    fn save_load_roundtrip_through_disk() {
        let b = bundle(2);
        let path = std::env::temp_dir().join(format!("hdc_model_{}.hdcm", std::process::id()));
        b.save(&path).unwrap();
        let back = ModelBundle::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, b);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = bundle(3).to_bytes();
        bytes[0] = b'X';
        let err = ModelBundle::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
    }

    #[test]
    fn format_version_mismatch_is_actionable() {
        let mut bytes = bundle(4).to_bytes();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = ModelBundle::from_bytes(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("format version 99"), "{msg}");
        assert!(msg.contains(&FORMAT_VERSION.to_string()), "{msg}");
    }

    #[test]
    fn every_truncation_errors_without_panicking() {
        let bytes = bundle(5).to_bytes();
        for n in 0..bytes.len() {
            assert!(
                ModelBundle::from_bytes(&bytes[..n]).is_err(),
                "prefix of {n}/{} bytes must be rejected",
                bytes.len()
            );
        }
        assert!(ModelBundle::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = bundle(6).to_bytes();
        bytes.push(0);
        assert!(ModelBundle::from_bytes(&bytes).is_err());
    }

    #[test]
    fn unknown_sections_are_skipped() {
        // Forward compatibility: a newer writer may append sections this
        // reader does not know; they must parse-skip cleanly.
        let b = bundle(7);
        let mut bytes = b.to_bytes();
        bytes[8..12].copy_from_slice(&5u32.to_le_bytes()); // section count 4 → 5
        section(&mut bytes, b"XTRA", &[1, 2, 3, 4]);
        let back = ModelBundle::from_bytes(&bytes).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn oversized_section_length_rejected() {
        let b = bundle(8);
        let bytes = b.to_bytes();
        // Patch the META section length to overrun the buffer.
        let mut patched = bytes.clone();
        patched[16..20].copy_from_slice(&(bytes.len() as u32).to_le_bytes());
        assert!(ModelBundle::from_bytes(&patched).is_err());
    }

    #[test]
    fn describe_mentions_the_essentials() {
        let d = bundle(9).describe();
        assert!(d.contains("v3"), "{d}");
        assert!(d.contains("sparse-optimized"), "{d}");
        assert!(d.contains("117"), "{d}");
        assert!(d.contains("patient 11"), "{d}");
        assert!(d.contains("derived from v2"), "{d}");
    }

    #[test]
    fn am_plane_from_bundle_never_decodes() {
        let b = bundle(10);
        let plane = AmPlane::from_bundle(&b);
        assert_eq!(plane.memory().classes, b.am.classes);
        assert_eq!(plane.decode_count(), 0);
    }

    #[test]
    fn next_version_is_monotone() {
        let b = bundle(11);
        assert_eq!(b.next_version(), 4);
        assert_eq!(ModelBundle::new(b.variant, b.config, b.am, b.provenance).version, 1);
    }
}
