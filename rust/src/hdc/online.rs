//! Iterative online retraining — Pale et al. (arXiv:2201.09759) applied
//! to the sparse-HDC classifier.
//!
//! One-shot bundling (§II-D) treats every training window equally; the
//! online HD literature shows that *iterating* on the misclassified
//! windows — add the window's query HV to the correct class accumulator,
//! subtract it from the wrongly-predicted one, re-thin — recovers a
//! large part of the gap to full retraining at a fraction of the cost.
//! [`OnlineTrainer`] implements that loop over the counter planes:
//!
//! 1. seed the per-class counter planes exactly like the one-shot
//!    [`crate::hdc::train::Trainer`] (so zero epochs ≡ one-shot);
//! 2. per epoch: thin the planes to the train-density target
//!    ([`crate::hdc::train::thin_counts_to_density`], the same count-
//!    histogram walk the temporal tuning path uses), classify every
//!    training window against the candidate AM, and re-bundle each
//!    misclassified window (saturating add/subtract on the planes);
//! 3. keep the best-scoring AM seen across all epochs (including the
//!    one-shot starting point), so the result **never scores worse on
//!    the training windows than one-shot training** — the retrain either
//!    improves or preserves, pinned by the tests here and in
//!    `tests/model_lifecycle.rs`.
//!
//! The trainer works on encoded window queries, so it is encoder-
//! agnostic; [`crate::pipeline::online_trainer_for_record`] feeds it a
//! record through the standard streaming encode pass, and
//! [`crate::pipeline::retrain_bundle`] wraps the result into a new
//! [`crate::hdc::model::ModelBundle`] version for registry publication.

use crate::params::{CLASS_ICTAL, CLASS_INTERICTAL, DIM, NUM_CLASSES};

use super::am::AssociativeMemory;
use super::classifier::Variant;
use super::hv::Hv;
use super::model::CounterPlanes;
use super::train::thin_counts_to_density;

/// Knobs of the retraining loop.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Upper bound on retraining epochs (the loop stops early once the
    /// training windows classify cleanly or an epoch makes no update).
    pub max_epochs: usize,
    /// Subtract misclassified queries from the wrongly-predicted class
    /// plane (the full Pale-style update) in addition to adding them to
    /// the correct one. `false` = add-only.
    pub subtract: bool,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            max_epochs: 8,
            subtract: true,
        }
    }
}

/// One epoch's outcome.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    /// Misclassified training windows under the epoch's input AM (these
    /// are the windows that were re-bundled).
    pub errors_before: usize,
    /// Plane updates applied (== `errors_before` by construction).
    pub updates: usize,
    /// Misclassified training windows under the epoch's output AM.
    pub errors_after: usize,
}

/// Full report of one retraining run.
#[derive(Clone, Debug, Default)]
pub struct OnlineReport {
    /// Training windows the trainer iterated over.
    pub windows: usize,
    /// Training-window errors of the one-shot starting point.
    pub initial_errors: usize,
    /// Training-window errors of the returned (best) AM.
    pub best_errors: usize,
    pub epochs: Vec<EpochStats>,
}

impl OnlineReport {
    /// Strictly better than one-shot on the training windows.
    pub fn improved(&self) -> bool {
        self.best_errors < self.initial_errors
    }
}

/// Iterative retrainer over encoded training windows (sparse variants —
/// the accelerator's design points; the dense baseline keeps its
/// majority bundling and is out of scope here).
pub struct OnlineTrainer {
    variant: Variant,
    train_density: f64,
    counts: [Box<[u32; DIM]>; NUM_CLASSES],
    windows: [usize; NUM_CLASSES],
    queries: Vec<(Hv, bool)>,
}

impl OnlineTrainer {
    pub fn new(variant: Variant, train_density: f64) -> Self {
        assert!(
            variant.is_sparse(),
            "online retraining targets the sparse design points"
        );
        OnlineTrainer {
            variant,
            train_density,
            counts: [Box::new([0u32; DIM]), Box::new([0u32; DIM])],
            windows: [0; NUM_CLASSES],
            queries: Vec::new(),
        }
    }

    /// Resume from persisted training state: the counter planes of a
    /// format-2 [`crate::hdc::model::ModelBundle`] become this trainer's
    /// accumulators, exactly as the pass that produced them left them.
    /// The epoch loop still needs the labelled window queries — feed
    /// them through [`Self::attach`] (which does **not** re-seed the
    /// planes). For a one-shot bundle this reconstructs the from-record
    /// trainer state bit for bit; for a retrained bundle it continues
    /// from the post-epoch planes instead of forgetting them.
    pub fn from_counters(variant: Variant, train_density: f64, planes: &CounterPlanes) -> Self {
        let mut t = OnlineTrainer::new(variant, train_density);
        t.counts = planes.counts.clone();
        t.windows = [planes.windows[0] as usize, planes.windows[1] as usize];
        t
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Absorb one labelled training-window query (the one-shot seeding
    /// pass — identical accumulation to `Trainer::add_window`). The
    /// query is retained for the epoch loop.
    pub fn absorb(&mut self, query: Hv, ictal: bool) {
        let class = if ictal { CLASS_ICTAL } else { CLASS_INTERICTAL };
        for p in query.one_positions() {
            self.counts[class][p] += 1;
        }
        self.windows[class] += 1;
        self.queries.push((query, ictal));
    }

    /// Retain a labelled query for the epoch loop **without** touching
    /// the counter planes — the companion of [`Self::from_counters`],
    /// whose planes already contain these windows.
    pub fn attach(&mut self, query: Hv, ictal: bool) {
        self.queries.push((query, ictal));
    }

    /// Training windows absorbed per class (interictal, ictal).
    pub fn windows_per_class(&self) -> [usize; NUM_CLASSES] {
        self.windows
    }

    /// Snapshot the current training state for persistence in a format-2
    /// bundle. Taken after [`Self::run`], the planes are the **best**
    /// epoch's state (see `run`), so they thin to exactly the AM the run
    /// returned and the next retrain resumes from the published model.
    pub fn counters(&self) -> CounterPlanes {
        CounterPlanes {
            counts: self.counts.clone(),
            windows: [self.windows[0] as u64, self.windows[1] as u64],
        }
    }

    /// Thin the current counter planes into a candidate AM.
    pub fn build_am(&self) -> AssociativeMemory {
        AssociativeMemory::new(
            thin_counts_to_density(&self.counts[CLASS_INTERICTAL], self.train_density),
            thin_counts_to_density(&self.counts[CLASS_ICTAL], self.train_density),
        )
    }

    /// Misclassified training windows under `am` (sparse overlap search).
    pub fn errors(&self, am: &AssociativeMemory) -> usize {
        self.queries
            .iter()
            .filter(|(q, ictal)| am.search(q).is_ictal() != *ictal)
            .count()
    }

    /// Run the retraining loop; returns the best AM seen (which is the
    /// one-shot AM when no epoch improves on it) plus the per-epoch
    /// trajectory.
    ///
    /// On return the trainer's counter planes are restored to the state
    /// that produced the **best** AM (not a worse tail epoch's), so
    /// [`Self::counters`] always thins to exactly the returned AM — the
    /// invariant that makes persisted format-2 bundles self-consistent
    /// and chained retrains resume from the state actually published.
    pub fn run(&mut self, cfg: &OnlineConfig) -> (AssociativeMemory, OnlineReport) {
        let mut current = self.build_am();
        let initial_errors = self.errors(&current);
        let mut best = current.clone();
        let mut best_errors = initial_errors;
        let mut best_counts = self.counts.clone();
        // Errors of `current` — carried across epochs so each epoch costs
        // one classification pass (the re-bundle walk) plus one for the
        // freshly thinned AM, not three.
        let mut current_errors = initial_errors;
        let mut epochs = Vec::new();

        for _ in 0..cfg.max_epochs {
            if best_errors == 0 {
                break;
            }
            // Re-bundle every window the current AM misclassifies.
            let mut updates = 0usize;
            let errors_before = current_errors;
            for i in 0..self.queries.len() {
                let (query, ictal) = self.queries[i];
                if current.search(&query).is_ictal() == ictal {
                    continue;
                }
                let (correct, wrong) = if ictal {
                    (CLASS_ICTAL, CLASS_INTERICTAL)
                } else {
                    (CLASS_INTERICTAL, CLASS_ICTAL)
                };
                for p in query.one_positions() {
                    self.counts[correct][p] = self.counts[correct][p].saturating_add(1);
                    if cfg.subtract {
                        self.counts[wrong][p] = self.counts[wrong][p].saturating_sub(1);
                    }
                }
                updates += 1;
            }
            if updates == 0 {
                break;
            }
            current = self.build_am();
            let errors_after = self.errors(&current);
            current_errors = errors_after;
            epochs.push(EpochStats {
                errors_before,
                updates,
                errors_after,
            });
            if errors_after < best_errors {
                best_errors = errors_after;
                best = current.clone();
                best_counts = self.counts.clone();
            }
        }
        self.counts = best_counts;

        let report = OnlineReport {
            windows: self.queries.len(),
            initial_errors,
            best_errors,
            epochs,
        };
        (best, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    /// An HV with 1-bits exactly on the given index ranges.
    fn hv(ranges: &[std::ops::Range<usize>]) -> Hv {
        Hv::from_fn(|i| ranges.iter().any(|r| r.contains(&i)))
    }

    /// A hand-traceable set where one-shot training provably fails on the
    /// "confuser" windows and two Pale-style epochs provably fix them:
    ///
    /// * 8 interictal windows on bits {0..100};
    /// * 8 ictal windows on bits {200..300};
    /// * 4 ictal "confusers" on bits {0..50} ∪ {200..240} — they share
    ///   more support with the interictal prototype than survives the
    ///   10%-density thinning of the ictal class, so the one-shot AM
    ///   scores them 50 (inter) vs 40 (ictal) and misclassifies all 4.
    fn confuser_trainer() -> OnlineTrainer {
        let mut t = OnlineTrainer::new(Variant::Optimized, 0.1);
        for _ in 0..8 {
            t.absorb(hv(&[0..100]), false);
        }
        for _ in 0..8 {
            t.absorb(hv(&[200..300]), true);
        }
        for _ in 0..4 {
            t.absorb(hv(&[0..50, 200..240]), true);
        }
        t
    }

    #[test]
    fn online_retraining_fixes_the_confusers() {
        let mut t = confuser_trainer();
        // One-shot starting point: exactly the 4 confusers fail.
        let one_shot = t.build_am();
        assert_eq!(t.errors(&one_shot), 4);

        let (am, report) = t.run(&OnlineConfig::default());
        assert_eq!(report.windows, 20);
        assert_eq!(report.initial_errors, 4);
        assert_eq!(report.best_errors, 0, "epochs: {:?}", report.epochs);
        assert!(report.improved());
        assert_eq!(t.errors(&am), 0);
        // The traced trajectory: epoch 1 re-shapes the planes but still
        // misses the confusers; epoch 2 classifies everything cleanly.
        assert_eq!(report.epochs.len(), 2);
        assert_eq!(report.epochs[0].errors_after, 4);
        assert_eq!(report.epochs[1].errors_after, 0);
    }

    #[test]
    fn keep_best_never_degrades_vs_one_shot() {
        // Statistical inputs: whatever the epochs do, the returned AM's
        // training error is <= the one-shot error (keep-best guarantee).
        for seed in [1u64, 2, 3, 4] {
            let mut rng = Xoshiro256::new(seed);
            let mut t = OnlineTrainer::new(Variant::Optimized, 0.25);
            for i in 0..30 {
                let ictal = i % 2 == 0;
                // Overlapping class supports so one-shot is imperfect.
                let base = if ictal { 0 } else { 256 };
                let q = Hv::from_fn(|j| {
                    (j >= base && j < base + 512) && rng.next_bool(0.3)
                });
                t.absorb(q, ictal);
            }
            let one_shot_errors = t.errors(&t.build_am());
            let (am, report) = t.run(&OnlineConfig::default());
            assert_eq!(report.initial_errors, one_shot_errors, "seed {seed}");
            assert!(report.best_errors <= one_shot_errors, "seed {seed}");
            assert_eq!(t.errors(&am), report.best_errors, "seed {seed}");
        }
    }

    #[test]
    fn zero_epochs_equals_one_shot_training() {
        // Seeding parity: absorbing the same queries as Trainer::add_window
        // and thinning yields bit-identical class HVs.
        let mut rng = Xoshiro256::new(9);
        let mut online = OnlineTrainer::new(Variant::Optimized, 0.3);
        let mut one_shot = crate::hdc::train::Trainer::new(0.3);
        for i in 0..16 {
            let q = Hv::random(&mut rng, 0.25);
            online.absorb(q, i % 3 == 0);
            one_shot.add_window(&q, i % 3 == 0);
        }
        assert_eq!(
            online.build_am().classes,
            one_shot.finish(Variant::Optimized).classes
        );
        let (am, report) = online.run(&OnlineConfig {
            max_epochs: 0,
            subtract: true,
        });
        assert_eq!(am.classes, one_shot.finish(Variant::Optimized).classes);
        assert_eq!(report.best_errors, report.initial_errors);
        assert!(report.epochs.is_empty());
    }

    #[test]
    fn clean_separation_stops_immediately() {
        let mut t = OnlineTrainer::new(Variant::Optimized, 0.5);
        for _ in 0..4 {
            t.absorb(hv(&[0..100]), false);
            t.absorb(hv(&[500..600]), true);
        }
        let (_, report) = t.run(&OnlineConfig::default());
        assert_eq!(report.initial_errors, 0);
        assert_eq!(report.best_errors, 0);
        assert!(report.epochs.is_empty(), "no epoch runs on a clean set");
    }

    #[test]
    #[should_panic(expected = "sparse")]
    fn dense_variant_rejected() {
        let _ = OnlineTrainer::new(Variant::DenseBaseline, 0.5);
    }

    #[test]
    fn from_counters_resumes_bit_identically() {
        // Reconstructing a trainer from persisted counter planes +
        // attached queries must be indistinguishable from the trainer
        // that produced the planes — same AM, same epoch trajectory.
        let build = || confuser_trainer();
        let mut direct = build();

        let planes = build().counters();
        let mut resumed = OnlineTrainer::from_counters(Variant::Optimized, 0.1, &planes);
        for (q, ictal) in &build().queries {
            resumed.attach(*q, *ictal);
        }

        assert_eq!(resumed.windows_per_class(), direct.windows_per_class());
        assert_eq!(resumed.build_am().classes, direct.build_am().classes);

        let (am_d, rep_d) = direct.run(&OnlineConfig::default());
        let (am_r, rep_r) = resumed.run(&OnlineConfig::default());
        assert_eq!(am_r.classes, am_d.classes);
        assert_eq!(rep_r.initial_errors, rep_d.initial_errors);
        assert_eq!(rep_r.best_errors, rep_d.best_errors);
        assert_eq!(rep_r.epochs.len(), rep_d.epochs.len());
        // And the post-run counters — what a format-2 bundle persists —
        // agree too, so chained retrains stay deterministic.
        assert_eq!(resumed.counters(), direct.counters());
    }

    #[test]
    fn post_run_counters_thin_to_the_returned_am() {
        // The self-consistency invariant of persisted bundles: whatever
        // the epoch trajectory did (including worse tail epochs), the
        // planes left in the trainer thin to exactly the AM `run`
        // returned.
        for seed in [1u64, 5, 9, 13] {
            let mut rng = Xoshiro256::new(seed);
            let mut t = OnlineTrainer::new(Variant::Optimized, 0.25);
            for i in 0..24 {
                let ictal = i % 2 == 0;
                let base = if ictal { 0 } else { 256 };
                let q = Hv::from_fn(|j| (j >= base && j < base + 512) && rng.next_bool(0.3));
                t.absorb(q, ictal);
            }
            let (am, _) = t.run(&OnlineConfig::default());
            assert_eq!(t.build_am().classes, am.classes, "seed {seed}");
        }
    }

    #[test]
    fn attach_leaves_the_planes_alone() {
        let mut t = OnlineTrainer::new(Variant::Optimized, 0.5);
        t.absorb(hv(&[0..100]), false);
        let before = t.counters();
        t.attach(hv(&[0..100]), false);
        let after = t.counters();
        assert_eq!(before, after, "attach must not re-seed the planes");
        assert_eq!(t.windows_per_class(), [1, 0]);
    }
}
