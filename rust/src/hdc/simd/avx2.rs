//! AVX2 kernels: 4 × u64 lanes per `__m256i`, 4 vectors per 1024-bit HV.
//!
//! Everything here is `#[target_feature(enable = "avx2")]` and only
//! reachable through [`super::KernelSet`] values that `mod.rs` hands
//! out *after* `is_x86_feature_detected!("avx2")` returned true — that
//! detection is the safety argument for every `unsafe` block below.
//! All loads/stores are unaligned (`loadu`/`storeu`): `Hv` and the
//! plane arrays are plain `[u64; N]` with no alignment promise.
#![allow(clippy::cast_ptr_alignment)]

use std::arch::x86_64::*;

use crate::params::DIM;

use super::super::hv::{Hv, WORDS};
use super::KernelSet;

pub(super) static SET: KernelSet = KernelSet {
    name: "avx2",
    plane_add,
    plane_add_saturating,
    ge_threshold,
    transpose_counts,
    overlap2,
    hamming2,
};

/// u64 lanes per vector; WORDS = 16 → 4 vectors per HV.
const LANES: usize = 4;
const VECS: usize = WORDS / LANES;

fn plane_add(planes: &mut [[u64; WORDS]], hv: &Hv) -> u64 {
    // SAFETY: SET is only exposed after AVX2 detection (module doc).
    unsafe { plane_add_impl(planes, hv) }
}

fn plane_add_saturating(planes: &mut [[u64; WORDS]], hv: &Hv) {
    // SAFETY: SET is only exposed after AVX2 detection (module doc).
    unsafe { plane_add_saturating_impl(planes, hv) }
}

fn ge_threshold(planes: &[[u64; WORDS]], threshold: u64) -> Hv {
    // SAFETY: SET is only exposed after AVX2 detection (module doc).
    unsafe { ge_threshold_impl(planes, threshold) }
}

fn transpose_counts(planes: &[[u64; WORDS]]) -> Box<[u16; DIM]> {
    // SAFETY: SET is only exposed after AVX2 detection (module doc).
    unsafe { transpose_counts_impl(planes) }
}

fn overlap2(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    // SAFETY: SET is only exposed after AVX2 detection (module doc).
    unsafe { overlap2_impl(q, c0, c1) }
}

fn hamming2(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    // SAFETY: SET is only exposed after AVX2 detection (module doc).
    unsafe { hamming2_impl(q, c0, c1) }
}

#[target_feature(enable = "avx2")]
unsafe fn plane_add_impl(planes: &mut [[u64; WORDS]], hv: &Hv) -> u64 {
    let mut spilled = _mm256_setzero_si256();
    for v in 0..VECS {
        let off = v * LANES;
        let mut carry = _mm256_loadu_si256(hv.words[off..].as_ptr() as *const __m256i);
        for plane in planes.iter_mut() {
            // testz(a, a) == 1 ⇔ every carry lane is already zero.
            if _mm256_testz_si256(carry, carry) != 0 {
                break;
            }
            let p = _mm256_loadu_si256(plane[off..].as_ptr() as *const __m256i);
            _mm256_storeu_si256(
                plane[off..].as_mut_ptr() as *mut __m256i,
                _mm256_xor_si256(p, carry),
            );
            carry = _mm256_and_si256(p, carry);
        }
        spilled = _mm256_or_si256(spilled, carry);
    }
    or_lanes(spilled)
}

#[target_feature(enable = "avx2")]
unsafe fn plane_add_saturating_impl(planes: &mut [[u64; WORDS]], hv: &Hv) {
    for v in 0..VECS {
        let off = v * LANES;
        let mut carry = _mm256_loadu_si256(hv.words[off..].as_ptr() as *const __m256i);
        for plane in planes.iter_mut() {
            if _mm256_testz_si256(carry, carry) != 0 {
                break;
            }
            let p = _mm256_loadu_si256(plane[off..].as_ptr() as *const __m256i);
            _mm256_storeu_si256(
                plane[off..].as_mut_ptr() as *mut __m256i,
                _mm256_xor_si256(p, carry),
            );
            carry = _mm256_and_si256(p, carry);
        }
        // Any lane that carried out wrapped its counters — clamp those
        // columns back to all-ones across every plane.
        if _mm256_testz_si256(carry, carry) == 0 {
            for plane in planes.iter_mut() {
                let p = _mm256_loadu_si256(plane[off..].as_ptr() as *const __m256i);
                _mm256_storeu_si256(
                    plane[off..].as_mut_ptr() as *mut __m256i,
                    _mm256_or_si256(p, carry),
                );
            }
        }
    }
}

#[target_feature(enable = "avx2")]
unsafe fn ge_threshold_impl(planes: &[[u64; WORDS]], threshold: u64) -> Hv {
    debug_assert!(threshold >= 1 && threshold < (1u64 << planes.len()));
    let mut out = Hv::zero();
    for v in 0..VECS {
        let off = v * LANES;
        let mut gt = _mm256_setzero_si256();
        let mut eq = _mm256_set1_epi64x(-1);
        for (b, plane) in planes.iter().enumerate().rev() {
            let p = _mm256_loadu_si256(plane[off..].as_ptr() as *const __m256i);
            if (threshold >> b) & 1 == 1 {
                eq = _mm256_and_si256(eq, p);
            } else {
                gt = _mm256_or_si256(gt, _mm256_and_si256(eq, p));
            }
        }
        _mm256_storeu_si256(
            out.words[off..].as_mut_ptr() as *mut __m256i,
            _mm256_or_si256(gt, eq),
        );
    }
    out
}

/// Per-lane bit masks for the 16 u16 lanes of one vector.
#[rustfmt::skip]
const LANE_BITS: [u16; 16] = [
    0x0001, 0x0002, 0x0004, 0x0008, 0x0010, 0x0020, 0x0040, 0x0080,
    0x0100, 0x0200, 0x0400, 0x0800, 0x1000, 0x2000, 0x4000, 0x8000,
];

#[target_feature(enable = "avx2")]
unsafe fn transpose_counts_impl(planes: &[[u64; WORDS]]) -> Box<[u16; DIM]> {
    let mut out = Box::new([0u16; DIM]);
    let lane_bits = _mm256_loadu_si256(LANE_BITS.as_ptr() as *const __m256i);
    for w in 0..WORDS {
        // 64 elements per word = 4 chunks of 16 u16 lanes. Broadcast
        // each 16-bit chunk of each plane word and test every lane's
        // bit at once — fixed work, unlike the scalar per-set-bit
        // scatter, which is exactly why this pair clears the bench
        // speedup gate on dense accumulators.
        for c in 0..4 {
            let mut acc = _mm256_setzero_si256();
            for (b, plane) in planes.iter().enumerate() {
                let chunk = ((plane[w] >> (c * 16)) & 0xFFFF) as u16;
                let hits = _mm256_cmpeq_epi16(
                    _mm256_and_si256(_mm256_set1_epi16(chunk as i16), lane_bits),
                    lane_bits,
                );
                let weight = _mm256_set1_epi16((1u16 << b) as i16);
                acc = _mm256_or_si256(acc, _mm256_and_si256(hits, weight));
            }
            _mm256_storeu_si256(out[w * 64 + c * 16..].as_mut_ptr() as *mut __m256i, acc);
        }
    }
    out
}

/// Nibble-LUT popcount of each u64 lane (`vpshufb` + `vpsadbw`): per
/// byte, look up the popcount of each nibble, then `sad` against zero
/// sums the 8 bytes of every u64 lane.
#[target_feature(enable = "avx2")]
unsafe fn popcount_epu64(v: __m256i) -> __m256i {
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(v, mask));
    let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(v), mask));
    _mm256_sad_epu8(_mm256_add_epi8(lo, hi), _mm256_setzero_si256())
}

#[target_feature(enable = "avx2")]
unsafe fn overlap2_impl(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    for v in 0..VECS {
        let off = v * LANES;
        let qv = _mm256_loadu_si256(q.words[off..].as_ptr() as *const __m256i);
        let v0 = _mm256_loadu_si256(c0.words[off..].as_ptr() as *const __m256i);
        let v1 = _mm256_loadu_si256(c1.words[off..].as_ptr() as *const __m256i);
        acc0 = _mm256_add_epi64(acc0, popcount_epu64(_mm256_and_si256(qv, v0)));
        acc1 = _mm256_add_epi64(acc1, popcount_epu64(_mm256_and_si256(qv, v1)));
    }
    [sum_lanes(acc0), sum_lanes(acc1)]
}

#[target_feature(enable = "avx2")]
unsafe fn hamming2_impl(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    for v in 0..VECS {
        let off = v * LANES;
        let qv = _mm256_loadu_si256(q.words[off..].as_ptr() as *const __m256i);
        let v0 = _mm256_loadu_si256(c0.words[off..].as_ptr() as *const __m256i);
        let v1 = _mm256_loadu_si256(c1.words[off..].as_ptr() as *const __m256i);
        acc0 = _mm256_add_epi64(acc0, popcount_epu64(_mm256_xor_si256(qv, v0)));
        acc1 = _mm256_add_epi64(acc1, popcount_epu64(_mm256_xor_si256(qv, v1)));
    }
    [sum_lanes(acc0), sum_lanes(acc1)]
}

#[target_feature(enable = "avx2")]
unsafe fn sum_lanes(v: __m256i) -> u32 {
    let mut lanes = [0u64; LANES];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32
}

#[target_feature(enable = "avx2")]
unsafe fn or_lanes(v: __m256i) -> u64 {
    let mut lanes = [0u64; LANES];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes[0] | lanes[1] | lanes[2] | lanes[3]
}
