//! Runtime-dispatched SIMD kernel tier.
//!
//! The bit-sliced hot loops — carry-save spatial bundling, the 8-plane
//! temporal accumulator, the `ge_threshold` magnitude comparator, the
//! count transpose, and the fused AND/XOR-popcount class scoring in
//! [`super::am::AssociativeMemory::search_batch`] — all run through a
//! [`KernelSet`]: a struct of monomorphic function pointers selected
//! once per process. Three sets exist:
//!
//! | set      | arch    | gate                                  |
//! |----------|---------|---------------------------------------|
//! | `scalar` | any     | always available (the reference tier) |
//! | `avx2`   | x86_64  | `is_x86_feature_detected!("avx2")`    |
//! | `neon`   | aarch64 | `is_aarch64_feature_detected!("neon")`|
//!
//! Selection order: an explicit [`select`] (from `--kernels` /
//! `[runtime] kernels` config) wins; otherwise the `HDC_KERNELS` env
//! var (`scalar|avx2|neon|auto`); otherwise [`KernelSet::auto`] picks
//! the widest supported set. The choice is pinned in a `OnceLock` so
//! every hot path pays one relaxed load, not a feature probe.
//!
//! Every non-scalar set is pinned bit-exact against the scalar kernels
//! (which are the `bitplanes.rs` slice functions) by the property fuzz
//! in `tests/kernels.rs`, and the `unsafe` intrinsics below are
//! additionally machine-checked by the scheduled `sanitize` CI job.

use std::sync::OnceLock;

use crate::params::DIM;
use crate::{bail, ensure};

use super::hv::{Hv, WORDS};
use super::bitplanes;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

/// One tier of word-parallel kernels. All entries are plain `fn`
/// pointers (monomorphic, slice-shaped) so a set is a value — benches
/// and tests can run two sets side by side regardless of which one
/// [`active`] pinned.
pub struct KernelSet {
    /// `"scalar"`, `"avx2"` or `"neon"` — stable names used by the
    /// `kernels =` config key, `HDC_KERNELS`, and bench record names.
    pub name: &'static str,
    /// Carry-save add of `hv` into N bit-sliced planes; returns the OR
    /// of per-column carry-outs (`0` unless a counter wrapped).
    pub plane_add: fn(&mut [[u64; WORDS]], &Hv) -> u64,
    /// [`Self::plane_add`] with saturate-to-max semantics on overflow
    /// (the temporal accumulator's clamp at `2^N - 1`).
    pub plane_add_saturating: fn(&mut [[u64; WORDS]], &Hv),
    /// Word-level `count >= threshold`; caller handles the trivial
    /// thresholds (`0`, `>= 2^N`) before dispatching.
    pub ge_threshold: fn(&[[u64; WORDS]], u64) -> Hv,
    /// Bit-sliced planes → per-element `u16` counts.
    pub transpose_counts: fn(&[[u64; WORDS]]) -> Box<[u16; DIM]>,
    /// Fused two-class AND-popcount: `[q·c0, q·c1]` overlaps.
    pub overlap2: fn(&Hv, &Hv, &Hv) -> [u32; 2],
    /// Fused two-class XOR-popcount: `[d(q,c0), d(q,c1)]` Hamming
    /// distances (raw — the AM converts to scores).
    pub hamming2: fn(&Hv, &Hv, &Hv) -> [u32; 2],
}

fn scalar_overlap2(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    let mut s0 = 0u32;
    let mut s1 = 0u32;
    for w in 0..WORDS {
        let qw = q.words[w];
        s0 += (qw & c0.words[w]).count_ones();
        s1 += (qw & c1.words[w]).count_ones();
    }
    [s0, s1]
}

fn scalar_hamming2(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    let mut s0 = 0u32;
    let mut s1 = 0u32;
    for w in 0..WORDS {
        let qw = q.words[w];
        s0 += (qw ^ c0.words[w]).count_ones();
        s1 += (qw ^ c1.words[w]).count_ones();
    }
    [s0, s1]
}

static SCALAR: KernelSet = KernelSet {
    name: "scalar",
    plane_add: bitplanes::plane_add,
    plane_add_saturating: bitplanes::plane_add_saturating,
    ge_threshold: bitplanes::ge_threshold_planes,
    transpose_counts: bitplanes::transpose_counts_planes,
    overlap2: scalar_overlap2,
    hamming2: scalar_hamming2,
};

impl KernelSet {
    /// The always-available scalar reference tier.
    pub fn scalar() -> &'static KernelSet {
        &SCALAR
    }

    /// The widest set this CPU supports (what `kernels = auto` picks).
    pub fn auto() -> &'static KernelSet {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            return &avx2::SET;
        }
        #[cfg(target_arch = "aarch64")]
        if std::arch::is_aarch64_feature_detected!("neon") {
            return &neon::SET;
        }
        &SCALAR
    }

    /// Every set this CPU supports, scalar first. Tests iterate this to
    /// pin each available tier against scalar.
    pub fn supported() -> Vec<&'static KernelSet> {
        let mut sets = vec![&SCALAR];
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            sets.push(&avx2::SET);
        }
        #[cfg(target_arch = "aarch64")]
        if std::arch::is_aarch64_feature_detected!("neon") {
            sets.push(&neon::SET);
        }
        sets
    }

    /// Resolve a config/env name. Errors on unknown names and on sets
    /// the running CPU (or this build's target arch) cannot execute —
    /// never silently falls back, so a CI leg forcing `avx2` cannot
    /// fake-pass on scalar hardware.
    pub fn by_name(name: &str) -> crate::Result<&'static KernelSet> {
        match name {
            "auto" => Ok(Self::auto()),
            "scalar" => Ok(&SCALAR),
            "avx2" => by_name_avx2(),
            "neon" => by_name_neon(),
            other => bail!("unknown kernel set {other:?} (known: scalar, avx2, neon, auto)"),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn by_name_avx2() -> crate::Result<&'static KernelSet> {
    ensure!(
        is_x86_feature_detected!("avx2"),
        "kernels = avx2: this CPU does not report AVX2 (use scalar or auto)"
    );
    Ok(&avx2::SET)
}

#[cfg(not(target_arch = "x86_64"))]
fn by_name_avx2() -> crate::Result<&'static KernelSet> {
    bail!(
        "kernels = avx2 requires an x86_64 build (this target is {})",
        std::env::consts::ARCH
    )
}

#[cfg(target_arch = "aarch64")]
fn by_name_neon() -> crate::Result<&'static KernelSet> {
    ensure!(
        std::arch::is_aarch64_feature_detected!("neon"),
        "kernels = neon: this CPU does not report NEON (use scalar or auto)"
    );
    Ok(&neon::SET)
}

#[cfg(not(target_arch = "aarch64"))]
fn by_name_neon() -> crate::Result<&'static KernelSet> {
    bail!(
        "kernels = neon requires an aarch64 build (this target is {})",
        std::env::consts::ARCH
    )
}

static ACTIVE: OnceLock<&'static KernelSet> = OnceLock::new();

/// The process-wide kernel set. First use pins it: `HDC_KERNELS` if
/// set (a bad value panics loudly rather than silently downgrading a
/// forced-SIMD test run), else [`KernelSet::auto`].
pub fn active() -> &'static KernelSet {
    ACTIVE.get_or_init(|| match std::env::var("HDC_KERNELS") {
        Ok(name) => match KernelSet::by_name(name.trim()) {
            Ok(set) => set,
            Err(e) => panic!("HDC_KERNELS={}: {e}", name.trim()),
        },
        Err(_) => KernelSet::auto(),
    })
}

/// Pin the process-wide set by name (CLI `--kernels` / config
/// `[runtime] kernels`). Explicit selection outranks `HDC_KERNELS`
/// when it gets there first; if something already pinned a *different*
/// set this errors instead of switching mid-flight (published models
/// and benches assume one set per process).
pub fn select(name: &str) -> crate::Result<&'static KernelSet> {
    let want = KernelSet::by_name(name)?;
    let got = ACTIVE.get_or_init(|| want);
    ensure!(
        got.name == want.name,
        "kernel set already pinned to {} for this process; cannot re-select {} \
         (set it once, before first use)",
        got.name,
        want.name
    );
    Ok(got)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_supported_and_auto_is_one_of_them() {
        let names: Vec<&str> = KernelSet::supported().iter().map(|s| s.name).collect();
        assert_eq!(names[0], "scalar");
        assert!(names.contains(&KernelSet::auto().name));
        // by_name round-trips every supported set.
        for set in KernelSet::supported() {
            assert_eq!(KernelSet::by_name(set.name).unwrap().name, set.name);
        }
        assert_eq!(KernelSet::by_name("auto").unwrap().name, KernelSet::auto().name);
        assert!(KernelSet::by_name("avx512").is_err());
    }

    #[test]
    fn select_is_sticky() {
        // Whatever pinned the set first (env or another test), re-selecting
        // the same name is idempotent and a *different* supported name errors.
        let current = active();
        assert_eq!(select(current.name).unwrap().name, current.name);
        if let Some(other) = KernelSet::supported()
            .into_iter()
            .find(|s| s.name != current.name)
        {
            assert!(select(other.name).is_err());
        }
    }

    #[test]
    fn fused_two_class_scoring_matches_hv_methods() {
        let mut q = Hv::zero();
        let mut c0 = Hv::zero();
        let mut c1 = Hv::zero();
        for w in 0..WORDS {
            let w64 = w as u64;
            q.words[w] = 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(w64 | 1);
            c0.words[w] = 0xbf58_476d_1ce4_e5b9u64.rotate_left(w as u32) ^ w64;
            c1.words[w] = 0x94d0_49bb_1331_11ebu64.wrapping_add(w64 << 7);
        }
        for set in KernelSet::supported() {
            assert_eq!(
                (set.overlap2)(&q, &c0, &c1),
                [q.overlap(&c0), q.overlap(&c1)],
                "overlap2 set {}",
                set.name
            );
            assert_eq!(
                (set.hamming2)(&q, &c0, &c1),
                [q.hamming(&c0), q.hamming(&c1)],
                "hamming2 set {}",
                set.name
            );
        }
    }
}
