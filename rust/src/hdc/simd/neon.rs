//! NEON kernels: 2 × u64 lanes per `uint64x2_t`, 8 vectors per HV.
//!
//! NEON is mandatory on aarch64, but this set still flows through the
//! same detection gate as AVX2 (`is_aarch64_feature_detected!`) so the
//! dispatch story is uniform. Every function is
//! `#[target_feature(enable = "neon")]` and only reachable through
//! [`super::KernelSet`] values handed out after that detection —
//! that is the safety argument for the `unsafe` blocks in the
//! wrappers. Popcount uses `vcnt` (per-byte population count), the
//! instruction the ISSUE's "vcnt-based vectorized popcount" names.
//!
//! This file only compiles on aarch64 (`#[cfg]` in `mod.rs`); x86 CI
//! covers it for format/review only, so keep it conservative.

use std::arch::aarch64::*;

use crate::params::DIM;

use super::super::hv::{Hv, WORDS};
use super::KernelSet;

pub(super) static SET: KernelSet = KernelSet {
    name: "neon",
    plane_add,
    plane_add_saturating,
    ge_threshold,
    transpose_counts,
    overlap2,
    hamming2,
};

/// u64 lanes per vector; WORDS = 16 → 8 vectors per HV.
const LANES: usize = 2;
const VECS: usize = WORDS / LANES;

fn plane_add(planes: &mut [[u64; WORDS]], hv: &Hv) -> u64 {
    // SAFETY: SET is only exposed after NEON detection (module doc).
    unsafe { plane_add_impl(planes, hv) }
}

fn plane_add_saturating(planes: &mut [[u64; WORDS]], hv: &Hv) {
    // SAFETY: SET is only exposed after NEON detection (module doc).
    unsafe { plane_add_saturating_impl(planes, hv) }
}

fn ge_threshold(planes: &[[u64; WORDS]], threshold: u64) -> Hv {
    // SAFETY: SET is only exposed after NEON detection (module doc).
    unsafe { ge_threshold_impl(planes, threshold) }
}

fn transpose_counts(planes: &[[u64; WORDS]]) -> Box<[u16; DIM]> {
    // SAFETY: SET is only exposed after NEON detection (module doc).
    unsafe { transpose_counts_impl(planes) }
}

fn overlap2(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    // SAFETY: SET is only exposed after NEON detection (module doc).
    unsafe { overlap2_impl(q, c0, c1) }
}

fn hamming2(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    // SAFETY: SET is only exposed after NEON detection (module doc).
    unsafe { hamming2_impl(q, c0, c1) }
}

#[target_feature(enable = "neon")]
unsafe fn is_zero(v: uint64x2_t) -> bool {
    (vgetq_lane_u64::<0>(v) | vgetq_lane_u64::<1>(v)) == 0
}

#[target_feature(enable = "neon")]
unsafe fn plane_add_impl(planes: &mut [[u64; WORDS]], hv: &Hv) -> u64 {
    let mut spilled = 0u64;
    for v in 0..VECS {
        let off = v * LANES;
        let mut carry = vld1q_u64(hv.words[off..].as_ptr());
        for plane in planes.iter_mut() {
            if is_zero(carry) {
                break;
            }
            let p = vld1q_u64(plane[off..].as_ptr());
            vst1q_u64(plane[off..].as_mut_ptr(), veorq_u64(p, carry));
            carry = vandq_u64(p, carry);
        }
        spilled |= vgetq_lane_u64::<0>(carry) | vgetq_lane_u64::<1>(carry);
    }
    spilled
}

#[target_feature(enable = "neon")]
unsafe fn plane_add_saturating_impl(planes: &mut [[u64; WORDS]], hv: &Hv) {
    for v in 0..VECS {
        let off = v * LANES;
        let mut carry = vld1q_u64(hv.words[off..].as_ptr());
        for plane in planes.iter_mut() {
            if is_zero(carry) {
                break;
            }
            let p = vld1q_u64(plane[off..].as_ptr());
            vst1q_u64(plane[off..].as_mut_ptr(), veorq_u64(p, carry));
            carry = vandq_u64(p, carry);
        }
        // Clamp wrapped columns back to all-ones across every plane.
        if !is_zero(carry) {
            for plane in planes.iter_mut() {
                let p = vld1q_u64(plane[off..].as_ptr());
                vst1q_u64(plane[off..].as_mut_ptr(), vorrq_u64(p, carry));
            }
        }
    }
}

#[target_feature(enable = "neon")]
unsafe fn ge_threshold_impl(planes: &[[u64; WORDS]], threshold: u64) -> Hv {
    debug_assert!(threshold >= 1 && threshold < (1u64 << planes.len()));
    let mut out = Hv::zero();
    for v in 0..VECS {
        let off = v * LANES;
        let mut gt = vdupq_n_u64(0);
        let mut eq = vdupq_n_u64(u64::MAX);
        for (b, plane) in planes.iter().enumerate().rev() {
            let p = vld1q_u64(plane[off..].as_ptr());
            if (threshold >> b) & 1 == 1 {
                eq = vandq_u64(eq, p);
            } else {
                gt = vorrq_u64(gt, vandq_u64(eq, p));
            }
        }
        vst1q_u64(out.words[off..].as_mut_ptr(), vorrq_u64(gt, eq));
    }
    out
}

/// Per-lane bit masks for the 8 u16 lanes of one vector.
#[rustfmt::skip]
const LANE_BITS: [u16; 8] = [
    0x0001, 0x0002, 0x0004, 0x0008, 0x0010, 0x0020, 0x0040, 0x0080,
];

#[target_feature(enable = "neon")]
unsafe fn transpose_counts_impl(planes: &[[u64; WORDS]]) -> Box<[u16; DIM]> {
    let mut out = Box::new([0u16; DIM]);
    let lane_bits = vld1q_u16(LANE_BITS.as_ptr());
    for w in 0..WORDS {
        // 64 elements per word = 8 chunks of 8 u16 lanes: broadcast
        // each 8-bit chunk, `vtst` every lane's bit, weight by 1 << b.
        for c in 0..8 {
            let mut acc = vdupq_n_u16(0);
            for (b, plane) in planes.iter().enumerate() {
                let chunk = ((plane[w] >> (c * 8)) & 0xFF) as u16;
                let hits = vtstq_u16(vdupq_n_u16(chunk), lane_bits);
                acc = vorrq_u16(acc, vandq_u16(hits, vdupq_n_u16(1 << b)));
            }
            vst1q_u16(out[w * 64 + c * 8..].as_mut_ptr(), acc);
        }
    }
    out
}

/// `vcnt` popcount of one 128-bit vector, summed to a scalar (≤ 128,
/// so the byte-sum `vaddvq_u8` cannot overflow).
#[target_feature(enable = "neon")]
unsafe fn popcount128(v: uint64x2_t) -> u32 {
    vaddvq_u8(vcntq_u8(vreinterpretq_u8_u64(v))) as u32
}

#[target_feature(enable = "neon")]
unsafe fn overlap2_impl(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    let mut s0 = 0u32;
    let mut s1 = 0u32;
    for v in 0..VECS {
        let off = v * LANES;
        let qv = vld1q_u64(q.words[off..].as_ptr());
        s0 += popcount128(vandq_u64(qv, vld1q_u64(c0.words[off..].as_ptr())));
        s1 += popcount128(vandq_u64(qv, vld1q_u64(c1.words[off..].as_ptr())));
    }
    [s0, s1]
}

#[target_feature(enable = "neon")]
unsafe fn hamming2_impl(q: &Hv, c0: &Hv, c1: &Hv) -> [u32; 2] {
    let mut s0 = 0u32;
    let mut s1 = 0u32;
    for v in 0..VECS {
        let off = v * LANES;
        let qv = vld1q_u64(q.words[off..].as_ptr());
        s0 += popcount128(veorq_u64(qv, vld1q_u64(c0.words[off..].as_ptr())));
        s1 += popcount128(veorq_u64(qv, vld1q_u64(c1.words[off..].as_ptr())));
    }
    [s0, s1]
}
