//! Sparse hypervectors in position space and the segmented-shift binding.
//!
//! A *sparse* HV in this system has exactly one 1-bit per 128-bit segment
//! (density 8/1024 ≈ 0.78%). It is fully described by eight 7-bit
//! positions — the representation the CompIM stores (paper §III-A,
//! 8 × 7 = 56 bits instead of 1024).
//!
//! The segmented-shift binding (paper Fig. 2(a)) circularly shifts each
//! segment of the electrode HV by the position of the 1-bit in the
//! corresponding segment of the data HV. For single-1-bit segments this is
//! exactly a modular add of positions:
//!
//! ```text
//! bound.pos[s] = (electrode.pos[s] + data.pos[s]) mod 128
//! ```
//!
//! Both the bit-domain implementation (what the baseline hardware does:
//! one-hot decode + barrel shift) and the position-domain implementation
//! (what the CompIM hardware does: 7-bit add) are provided and tested for
//! equivalence — that equivalence *is* the CompIM correctness argument.

use crate::params::{SEGMENTS, SEG_LEN};
use crate::rng::Xoshiro256;

use super::hv::Hv;

/// A sparse HV: one 1-bit position per segment.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct SparseHv {
    /// `pos[s]` ∈ [0, SEG_LEN) is the index of the 1-bit within segment `s`.
    pub pos: [u8; SEGMENTS],
}

impl SparseHv {
    pub const fn new(pos: [u8; SEGMENTS]) -> Self {
        SparseHv { pos }
    }

    /// Uniformly random sparse HV.
    pub fn random(rng: &mut Xoshiro256) -> Self {
        let mut pos = [0u8; SEGMENTS];
        for p in pos.iter_mut() {
            *p = rng.next_below(SEG_LEN as u64) as u8;
        }
        SparseHv { pos }
    }

    /// Expand to the 1024-bit domain (one-hot per segment).
    pub fn to_hv(&self) -> Hv {
        let mut hv = Hv::zero();
        for (s, &p) in self.pos.iter().enumerate() {
            hv.set(s * SEG_LEN + p as usize, true);
        }
        hv
    }

    /// Compress a bit-domain HV that has exactly one 1-bit per segment.
    /// Returns `None` if any segment's popcount ≠ 1 (the one-hot decoder in
    /// the baseline hardware would produce garbage for such inputs).
    pub fn from_hv(hv: &Hv) -> Option<Self> {
        let mut pos = [0u8; SEGMENTS];
        for s in 0..SEGMENTS {
            let seg = hv.segment(s);
            let count = seg[0].count_ones() + seg[1].count_ones();
            if count != 1 {
                return None;
            }
            let p = if seg[0] != 0 {
                seg[0].trailing_zeros()
            } else {
                64 + seg[1].trailing_zeros()
            };
            pos[s] = p as u8;
        }
        Some(SparseHv { pos })
    }

    /// Position-domain segmented-shift binding: 8 parallel 7-bit modular
    /// adds. This is the operation the CompIM datapath performs.
    #[inline]
    pub fn bind(&self, data: &SparseHv) -> SparseHv {
        let mut pos = [0u8; SEGMENTS];
        for s in 0..SEGMENTS {
            pos[s] = ((self.pos[s] as usize + data.pos[s] as usize) % SEG_LEN) as u8;
        }
        SparseHv { pos }
    }

    /// Inverse binding (for unbinding / diagnostics): subtract positions.
    #[inline]
    pub fn unbind(&self, data: &SparseHv) -> SparseHv {
        let mut pos = [0u8; SEGMENTS];
        for s in 0..SEGMENTS {
            pos[s] = ((self.pos[s] as usize + SEG_LEN - data.pos[s] as usize) % SEG_LEN) as u8;
        }
        SparseHv { pos }
    }

    /// Density of the expanded HV (constant: SEGMENTS / DIM).
    pub fn density() -> f64 {
        SEGMENTS as f64 / (SEGMENTS * SEG_LEN) as f64
    }
}

/// Bit-domain segmented-shift binding, exactly as the *baseline* hardware
/// implements it (paper Fig. 3(a)):
///
/// 1. a one-hot→binary decoder extracts, per segment, the position of the
///    1-bit in the data HV;
/// 2. a barrel shifter circularly shifts the corresponding segment of the
///    electrode HV by that amount.
///
/// `electrode` may be *any* 1024-bit HV (the shift is well defined even for
/// non-sparse inputs); `data` must be sparse (one 1-bit per segment).
pub fn bind_bitdomain(electrode: &Hv, data: &Hv) -> Option<Hv> {
    let data_pos = SparseHv::from_hv(data)?;
    let mut out = Hv::zero();
    for s in 0..SEGMENTS {
        let rotated = Hv::rotate_segment(electrode.segment(s), data_pos.pos[s] as u32);
        out.set_segment(s, rotated);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_from_hv_roundtrip() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..100 {
            let s = SparseHv::random(&mut rng);
            let hv = s.to_hv();
            assert_eq!(hv.popcount(), SEGMENTS as u32);
            assert_eq!(SparseHv::from_hv(&hv), Some(s));
        }
    }

    #[test]
    fn from_hv_rejects_non_sparse() {
        let mut hv = Hv::zero();
        assert_eq!(SparseHv::from_hv(&hv), None); // empty segment
        hv.set(0, true);
        hv.set(1, true); // two bits in segment 0
        for s in 1..SEGMENTS {
            hv.set(s * SEG_LEN, true);
        }
        assert_eq!(SparseHv::from_hv(&hv), None);
    }

    #[test]
    fn bind_position_vs_bit_domain_equivalence() {
        // The CompIM correctness argument: position-domain modular add ==
        // one-hot decode + barrel shift in the bit domain.
        let mut rng = Xoshiro256::new(2);
        for _ in 0..500 {
            let e = SparseHv::random(&mut rng);
            let d = SparseHv::random(&mut rng);
            let pos_domain = e.bind(&d).to_hv();
            let bit_domain = bind_bitdomain(&e.to_hv(), &d.to_hv()).unwrap();
            assert_eq!(pos_domain, bit_domain);
        }
    }

    #[test]
    fn bind_unbind_inverse() {
        let mut rng = Xoshiro256::new(3);
        for _ in 0..100 {
            let e = SparseHv::random(&mut rng);
            let d = SparseHv::random(&mut rng);
            assert_eq!(e.bind(&d).unbind(&d), e);
        }
    }

    #[test]
    fn bind_preserves_sparsity() {
        let mut rng = Xoshiro256::new(4);
        let e = SparseHv::random(&mut rng);
        let d = SparseHv::random(&mut rng);
        assert_eq!(e.bind(&d).to_hv().popcount(), SEGMENTS as u32);
    }

    #[test]
    fn bind_zero_is_identity() {
        let mut rng = Xoshiro256::new(5);
        let e = SparseHv::random(&mut rng);
        let zero = SparseHv::new([0; SEGMENTS]);
        assert_eq!(e.bind(&zero), e);
    }

    #[test]
    fn bind_is_commutative_in_position_sum() {
        // (e + d) mod 128 == (d + e) mod 128 — segmented shift binding of
        // two sparse HVs is commutative.
        let mut rng = Xoshiro256::new(6);
        for _ in 0..50 {
            let a = SparseHv::random(&mut rng);
            let b = SparseHv::random(&mut rng);
            assert_eq!(a.bind(&b), b.bind(&a));
        }
    }

    #[test]
    fn bind_distributes_quasi_orthogonally() {
        // Binding with different data HVs should produce (near-)orthogonal
        // outputs: expected overlap of two random sparse HVs is
        // SEGMENTS * 1/SEG_LEN = 8/128 = 0.0625 bits.
        let mut rng = Xoshiro256::new(7);
        let e = SparseHv::random(&mut rng);
        let mut total_overlap = 0u32;
        let n = 2000;
        for _ in 0..n {
            let d1 = SparseHv::random(&mut rng);
            let d2 = SparseHv::random(&mut rng);
            total_overlap += e.bind(&d1).to_hv().overlap(&e.bind(&d2).to_hv());
        }
        let mean = total_overlap as f64 / n as f64;
        assert!(mean < 0.2, "bound HVs should be near-orthogonal, got {mean}");
    }

    #[test]
    fn bitdomain_bind_supports_dense_electrode() {
        // The barrel shifter shifts whatever electrode pattern it is given —
        // check against a manual rotation for a dense electrode HV.
        let mut rng = Xoshiro256::new(8);
        let e = Hv::random(&mut rng, 0.5);
        let d = SparseHv::random(&mut rng);
        let out = bind_bitdomain(&e, &d.to_hv()).unwrap();
        for s in 0..SEGMENTS {
            let sh = d.pos[s] as usize;
            for p in 0..SEG_LEN {
                assert_eq!(
                    out.get(s * SEG_LEN + (p + sh) % SEG_LEN),
                    e.get(s * SEG_LEN + p)
                );
            }
        }
    }
}
