//! Temporal bundling — paper §II-C (second half).
//!
//! The temporal encoder accumulates the 256 sequential spatial-encoder
//! outputs of one prediction window into per-element counters (8 bits per
//! element in hardware → the "large 8192-bit register"), then thins with a
//! threshold to produce the query HV. The paper's operating point is
//! threshold 130, keeping the query density in 20–30%.

use crate::params::{DIM, FRAMES_PER_PREDICTION, TEMPORAL_COUNTER_MAX};

use super::hv::Hv;

/// Streaming temporal accumulator with hardware-faithful 8-bit saturating
/// counters.
#[derive(Clone)]
pub struct TemporalAccumulator {
    counts: Box<[u16; DIM]>,
    frames: usize,
}

impl Default for TemporalAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl TemporalAccumulator {
    pub fn new() -> Self {
        TemporalAccumulator {
            counts: Box::new([0u16; DIM]),
            frames: 0,
        }
    }

    /// Add one spatial-encoder output frame. Counters saturate at 255
    /// exactly like the 8-bit hardware registers. Word-iterated without
    /// intermediate allocation — this runs once per clock cycle on the
    /// serving hot path (§Perf L3-1).
    pub fn add(&mut self, frame: &Hv) {
        for (w, &word) in frame.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                let c = &mut self.counts[w * 64 + b];
                *c += (*c < TEMPORAL_COUNTER_MAX) as u16;
                bits &= bits - 1;
            }
        }
        self.frames += 1;
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    /// One prediction window's worth of frames accumulated?
    pub fn is_full(&self) -> bool {
        self.frames >= FRAMES_PER_PREDICTION
    }

    pub fn counts(&self) -> &[u16; DIM] {
        &self.counts
    }

    /// Thin to a binary query HV (`count >= threshold`) and reset for the
    /// next window.
    pub fn finish(&mut self, threshold: u16) -> Hv {
        let out = self.peek(threshold);
        self.reset();
        out
    }

    /// Thin without resetting (used by training, which inspects several
    /// candidate thresholds over the same window). Word-wise assembly —
    /// this is on the per-window hot path (§Perf L3-2).
    pub fn peek(&self, threshold: u16) -> Hv {
        let mut hv = Hv::zero();
        for (w, word) in hv.words.iter_mut().enumerate() {
            let base = w * 64;
            let mut bits = 0u64;
            for b in 0..64 {
                bits |= ((self.counts[base + b] >= threshold) as u64) << b;
            }
            *word = bits;
        }
        hv
    }

    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.frames = 0;
    }
}

/// Find the smallest threshold such that the thinned density of `counts`
/// does not exceed `max_density`. This is how the max-HV-density
/// hyperparameter (paper Fig. 4's x-axis) maps to a hardware threshold:
/// sweep the count histogram from above.
pub fn threshold_for_max_density(counts: &[u16; DIM], max_density: f64) -> u16 {
    let max_ones = (max_density * DIM as f64).floor() as usize;
    // Histogram of counter values (bounded by TEMPORAL_COUNTER_MAX).
    let mut hist = [0usize; TEMPORAL_COUNTER_MAX as usize + 1];
    for &c in counts.iter() {
        hist[c as usize] += 1;
    }
    // Walk thresholds downward from max+1; ones(t) = #elements with count >= t.
    let mut ones = 0usize;
    let mut t = TEMPORAL_COUNTER_MAX as usize + 1;
    while t > 1 {
        let next_ones = ones + hist[t - 1];
        if next_ones > max_ones {
            break;
        }
        ones = next_ones;
        t -= 1;
    }
    t as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn accumulate_and_thin() {
        let mut acc = TemporalAccumulator::new();
        let mut frame = Hv::zero();
        frame.set(10, true);
        frame.set(20, true);
        for _ in 0..100 {
            acc.add(&frame);
        }
        let mut frame2 = Hv::zero();
        frame2.set(20, true);
        frame2.set(30, true);
        for _ in 0..50 {
            acc.add(&frame2);
        }
        assert_eq!(acc.counts()[10], 100);
        assert_eq!(acc.counts()[20], 150);
        assert_eq!(acc.counts()[30], 50);
        let hv = acc.peek(100);
        assert!(hv.get(10) && hv.get(20) && !hv.get(30));
        let hv = acc.finish(130);
        assert!(!hv.get(10) && hv.get(20) && !hv.get(30));
        assert_eq!(acc.frames(), 0);
        assert_eq!(acc.counts()[20], 0);
    }

    #[test]
    fn counters_saturate_at_8_bits() {
        let mut acc = TemporalAccumulator::new();
        let mut frame = Hv::zero();
        frame.set(0, true);
        for _ in 0..300 {
            acc.add(&frame);
        }
        assert_eq!(acc.counts()[0], TEMPORAL_COUNTER_MAX);
    }

    #[test]
    fn is_full_after_window() {
        let mut acc = TemporalAccumulator::new();
        let frame = Hv::zero();
        for _ in 0..FRAMES_PER_PREDICTION - 1 {
            acc.add(&frame);
            assert!(!acc.is_full());
        }
        acc.add(&frame);
        assert!(acc.is_full());
    }

    #[test]
    fn threshold_for_max_density_respects_bound() {
        let mut rng = Xoshiro256::new(9);
        let mut acc = TemporalAccumulator::new();
        // Random-ish frames with ~40% density to emulate spatial outputs.
        for _ in 0..FRAMES_PER_PREDICTION {
            acc.add(&Hv::random(&mut rng, 0.4));
        }
        for max_d in [0.05, 0.1, 0.2, 0.3, 0.5] {
            let t = threshold_for_max_density(acc.counts(), max_d);
            let d = acc.peek(t).density();
            assert!(d <= max_d + 1e-12, "max_d {max_d}: got {d} at t {t}");
            // And it is the *smallest* such threshold (t-1 would overflow
            // the bound), unless t == 1 already.
            if t > 1 {
                let d_prev = acc.peek(t - 1).density();
                assert!(d_prev > max_d, "t {t} not minimal for {max_d}");
            }
        }
    }

    #[test]
    fn threshold_one_when_everything_fits() {
        let counts = Box::new([0u16; DIM]);
        assert_eq!(threshold_for_max_density(&counts, 0.5), 1);
    }
}
