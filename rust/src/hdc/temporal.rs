//! Temporal bundling — paper §II-C (second half).
//!
//! The temporal encoder accumulates the 256 sequential spatial-encoder
//! outputs of one prediction window into per-element counters (8 bits per
//! element in hardware → the "large 8192-bit register"), then thins with a
//! threshold to produce the query HV. The paper's operating point is
//! threshold 130, keeping the query density in 20–30%.
//!
//! ## Word-parallel hot path
//!
//! [`TemporalAccumulator`] stores the 1024 × 8-bit counters *bit-sliced*:
//! 8 bit planes of 16 u64 words. Adding one frame is a word-wise
//! carry-save ripple (64 counters advance per u64 op) with a saturating
//! fix-up on the carry out of the top plane — exactly the hardware's
//! 8-bit saturating registers, but 64 at a time. Thinning walks the
//! planes MSB→LSB with a branchless magnitude comparator. The original
//! per-element u16 implementation is retained as
//! [`TemporalAccumulatorReference`]; `tests/kernels.rs` pins the two
//! bit-exactly against each other.

use crate::params::{DIM, FRAMES_PER_PREDICTION, TEMPORAL_COUNTER_BITS, TEMPORAL_COUNTER_MAX};

use super::hv::{Hv, WORDS};
use super::simd::{self, KernelSet};

/// Bit planes of the temporal counters (8 in hardware).
pub const TEMPORAL_PLANES: usize = TEMPORAL_COUNTER_BITS;

/// Streaming temporal accumulator with hardware-faithful 8-bit saturating
/// counters, stored bit-sliced for word-parallel accumulate/thin.
#[derive(Clone)]
pub struct TemporalAccumulator {
    /// `planes[b][w]` = bit `b` of the counters of elements
    /// `w*64..w*64+64`.
    planes: [[u64; WORDS]; TEMPORAL_PLANES],
    frames: usize,
}

impl Default for TemporalAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl TemporalAccumulator {
    pub fn new() -> Self {
        TemporalAccumulator {
            planes: [[0u64; WORDS]; TEMPORAL_PLANES],
            frames: 0,
        }
    }

    /// Add one spatial-encoder output frame. Counters saturate at 255
    /// exactly like the 8-bit hardware registers. Word-parallel
    /// carry-save ripple through the process-wide [`simd::active`]
    /// kernel set — this runs once per clock cycle on the serving hot
    /// path (§Perf L3-1).
    pub fn add(&mut self, frame: &Hv) {
        self.add_with(frame, simd::active());
    }

    /// [`Self::add`] with an explicit kernel set (benches and the
    /// bit-exactness fuzz run scalar and SIMD side by side).
    pub fn add_with(&mut self, frame: &Hv, ks: &KernelSet) {
        (ks.plane_add_saturating)(&mut self.planes, frame);
        self.frames += 1;
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    /// One prediction window's worth of frames accumulated?
    pub fn is_full(&self) -> bool {
        self.frames >= FRAMES_PER_PREDICTION
    }

    /// Per-element counter values, transposed out of the bit planes.
    /// Diagnostic / tuning path only — the hot path never materializes
    /// this (thinning reads the planes directly).
    pub fn counts(&self) -> Box<[u16; DIM]> {
        self.counts_with(simd::active())
    }

    /// [`Self::counts`] with an explicit kernel set.
    pub fn counts_with(&self, ks: &KernelSet) -> Box<[u16; DIM]> {
        (ks.transpose_counts)(&self.planes)
    }

    /// Thin to a binary query HV (`count >= threshold`) and reset for the
    /// next window.
    pub fn finish(&mut self, threshold: u16) -> Hv {
        let out = self.peek(threshold);
        self.reset();
        out
    }

    /// Thin without resetting (used by training, which inspects several
    /// candidate thresholds over the same window). Branchless word-level
    /// magnitude comparator — this is on the per-window hot path
    /// (§Perf L3-2).
    pub fn peek(&self, threshold: u16) -> Hv {
        self.peek_with(threshold, simd::active())
    }

    /// [`Self::peek`] with an explicit kernel set.
    pub fn peek_with(&self, threshold: u16, ks: &KernelSet) -> Hv {
        if threshold == 0 {
            return Hv::ones();
        }
        if threshold > TEMPORAL_COUNTER_MAX {
            return Hv::zero();
        }
        (ks.ge_threshold)(&self.planes, threshold as u64)
    }

    pub fn reset(&mut self) {
        self.planes = [[0u64; WORDS]; TEMPORAL_PLANES];
        self.frames = 0;
    }
}

/// Scalar reference implementation of the temporal accumulator: one u16
/// per element, per-bit scatter on add, per-element compare on peek.
/// Kept as the equivalence oracle for [`TemporalAccumulator`].
#[derive(Clone)]
pub struct TemporalAccumulatorReference {
    counts: Box<[u16; DIM]>,
    frames: usize,
}

impl Default for TemporalAccumulatorReference {
    fn default() -> Self {
        Self::new()
    }
}

impl TemporalAccumulatorReference {
    pub fn new() -> Self {
        TemporalAccumulatorReference {
            counts: Box::new([0u16; DIM]),
            frames: 0,
        }
    }

    pub fn add(&mut self, frame: &Hv) {
        for (w, &word) in frame.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                let c = &mut self.counts[w * 64 + b];
                *c += (*c < TEMPORAL_COUNTER_MAX) as u16;
                bits &= bits - 1;
            }
        }
        self.frames += 1;
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn counts(&self) -> &[u16; DIM] {
        &self.counts
    }

    pub fn peek(&self, threshold: u16) -> Hv {
        Hv::from_fn(|i| self.counts[i] >= threshold)
    }

    pub fn finish(&mut self, threshold: u16) -> Hv {
        let out = self.peek(threshold);
        self.counts.fill(0);
        self.frames = 0;
        out
    }
}

/// Histogram of counter values (index = value; counters saturate at
/// [`TEMPORAL_COUNTER_MAX`]). Build it once per window and derive every
/// candidate density's threshold from it
/// ([`threshold_for_max_density_hist`]) — the single-pass multi-density
/// tuning path (`pipeline::tune_temporal_thresholds`).
pub fn count_histogram(counts: &[u16; DIM]) -> [usize; TEMPORAL_COUNTER_MAX as usize + 1] {
    let mut hist = [0usize; TEMPORAL_COUNTER_MAX as usize + 1];
    for &c in counts.iter() {
        hist[c as usize] += 1;
    }
    hist
}

/// [`threshold_for_max_density`] over a prebuilt count histogram: walk
/// thresholds downward from max+1; `ones(t)` = #elements with count >= t.
pub fn threshold_for_max_density_hist(
    hist: &[usize; TEMPORAL_COUNTER_MAX as usize + 1],
    max_density: f64,
) -> u16 {
    let max_ones = (max_density * DIM as f64).floor() as usize;
    let mut ones = 0usize;
    let mut t = TEMPORAL_COUNTER_MAX as usize + 1;
    while t > 1 {
        let next_ones = ones + hist[t - 1];
        if next_ones > max_ones {
            break;
        }
        ones = next_ones;
        t -= 1;
    }
    t as u16
}

/// Find the smallest threshold such that the thinned density of `counts`
/// does not exceed `max_density`. This is how the max-HV-density
/// hyperparameter (paper Fig. 4's x-axis) maps to a hardware threshold:
/// sweep the count histogram from above.
pub fn threshold_for_max_density(counts: &[u16; DIM], max_density: f64) -> u16 {
    threshold_for_max_density_hist(&count_histogram(counts), max_density)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn accumulate_and_thin() {
        let mut acc = TemporalAccumulator::new();
        let mut frame = Hv::zero();
        frame.set(10, true);
        frame.set(20, true);
        for _ in 0..100 {
            acc.add(&frame);
        }
        let mut frame2 = Hv::zero();
        frame2.set(20, true);
        frame2.set(30, true);
        for _ in 0..50 {
            acc.add(&frame2);
        }
        assert_eq!(acc.counts()[10], 100);
        assert_eq!(acc.counts()[20], 150);
        assert_eq!(acc.counts()[30], 50);
        let hv = acc.peek(100);
        assert!(hv.get(10) && hv.get(20) && !hv.get(30));
        let hv = acc.finish(130);
        assert!(!hv.get(10) && hv.get(20) && !hv.get(30));
        assert_eq!(acc.frames(), 0);
        assert_eq!(acc.counts()[20], 0);
    }

    #[test]
    fn counters_saturate_at_8_bits() {
        let mut acc = TemporalAccumulator::new();
        let mut frame = Hv::zero();
        frame.set(0, true);
        for _ in 0..300 {
            acc.add(&frame);
        }
        assert_eq!(acc.counts()[0], TEMPORAL_COUNTER_MAX);
        // Saturation must not disturb neighbouring columns.
        assert_eq!(acc.counts()[1], 0);
        assert_eq!(acc.peek(TEMPORAL_COUNTER_MAX).popcount(), 1);
    }

    #[test]
    fn matches_reference_with_saturation() {
        let mut rng = Xoshiro256::new(13);
        let mut fast = TemporalAccumulator::new();
        let mut slow = TemporalAccumulatorReference::new();
        // Enough dense-ish frames to drive many counters into saturation.
        for _ in 0..300 {
            let f = Hv::random(&mut rng, 0.7);
            fast.add(&f);
            slow.add(&f);
        }
        assert_eq!(*fast.counts(), *slow.counts());
        for t in [0u16, 1, 64, 130, 255, 256, 1000] {
            assert_eq!(fast.peek(t), slow.peek(t), "threshold {t}");
        }
        assert_eq!(fast.finish(130), slow.finish(130));
        assert_eq!(*fast.counts(), *slow.counts());
    }

    #[test]
    fn is_full_after_window() {
        let mut acc = TemporalAccumulator::new();
        let frame = Hv::zero();
        for _ in 0..FRAMES_PER_PREDICTION - 1 {
            acc.add(&frame);
            assert!(!acc.is_full());
        }
        acc.add(&frame);
        assert!(acc.is_full());
    }

    #[test]
    fn threshold_for_max_density_respects_bound() {
        let mut rng = Xoshiro256::new(9);
        let mut acc = TemporalAccumulator::new();
        // Random-ish frames with ~40% density to emulate spatial outputs.
        for _ in 0..FRAMES_PER_PREDICTION {
            acc.add(&Hv::random(&mut rng, 0.4));
        }
        for max_d in [0.05, 0.1, 0.2, 0.3, 0.5] {
            let t = threshold_for_max_density(&acc.counts(), max_d);
            let d = acc.peek(t).density();
            assert!(d <= max_d + 1e-12, "max_d {max_d}: got {d} at t {t}");
            // And it is the *smallest* such threshold (t-1 would overflow
            // the bound), unless t == 1 already.
            if t > 1 {
                let d_prev = acc.peek(t - 1).density();
                assert!(d_prev > max_d, "t {t} not minimal for {max_d}");
            }
        }
    }

    #[test]
    fn threshold_one_when_everything_fits() {
        let counts = Box::new([0u16; DIM]);
        assert_eq!(threshold_for_max_density(&counts, 0.5), 1);
    }

    #[test]
    fn histogram_covers_every_element() {
        let mut rng = Xoshiro256::new(21);
        let mut acc = TemporalAccumulator::new();
        for _ in 0..FRAMES_PER_PREDICTION {
            acc.add(&Hv::random(&mut rng, 0.3));
        }
        let counts = acc.counts();
        let hist = count_histogram(&counts);
        assert_eq!(hist.iter().sum::<usize>(), DIM);
        // Deriving from the histogram equals deriving from the counts.
        for d in [0.05, 0.2, 0.5] {
            assert_eq!(
                threshold_for_max_density_hist(&hist, d),
                threshold_for_max_density(&counts, d)
            );
        }
    }
}
