//! Offline one-shot training — paper §II-D.
//!
//! Class-representing HVs are computed "through the same sparse HDC
//! classifier as the inference but with labeled data from one seizure":
//! every prediction-window query HV of the training record is accumulated
//! into a per-class counter plane, and each class plane is thinned to the
//! configured density (50% in the paper) to form the AM entry. The dense
//! design point bundles with a bit-wise majority instead.
//!
//! Training runs offline (design-/fit-time); only the resulting AM is
//! deployed on the accelerator. Deployment-facing entry points emit a
//! persistent [`ModelBundle`] (AM + encoder config + provenance +
//! version) rather than a bare [`AssociativeMemory`]; the thinning
//! helper ([`thin_counts_to_density`]) is shared with the iterative
//! retrainer ([`crate::hdc::online`]).

use crate::params::{CLASS_ICTAL, CLASS_INTERICTAL, DIM, NUM_CLASSES};

use super::am::AssociativeMemory;
use super::classifier::{ClassifierConfig, Encoder, Frame, Variant};
use super::dense::majority_from_counts;
use super::hv::Hv;
use super::model::{CounterPlanes, ModelBundle, Provenance};

/// A labelled frame stream: the LBP codes of one frame plus whether the
/// frame lies inside the expert-annotated ictal interval.
pub type LabelledFrame = (Frame, bool);

/// Thin a class counter plane to at most `max_density` ones (sparse
/// bundling with thinning, §II-D): pick the smallest threshold `t >= 1`
/// with `|{i : plane[i] >= t}| <= max_density * DIM`, via a count
/// histogram — the class-plane analogue of the temporal tuning path
/// ([`crate::hdc::temporal::count_histogram`] /
/// [`crate::hdc::temporal::threshold_for_max_density_hist`], which are
/// fixed to the 8-bit hardware counters; class counts are unbounded, so
/// the histogram here is sized by the observed maximum).
pub fn thin_counts_to_density(plane: &[u32; DIM], max_density: f64) -> Hv {
    let max_count = plane.iter().copied().max().unwrap_or(0);
    if max_count == 0 {
        return Hv::zero();
    }
    let max_ones = (max_density * DIM as f64).floor() as usize;
    let mut hist = vec![0usize; max_count as usize + 2];
    for &c in plane.iter() {
        hist[c as usize] += 1;
    }
    // Smallest threshold t >= 1 with |{i : plane[i] >= t}| <= max_ones.
    let mut ones = 0usize;
    let mut t = max_count as usize + 1;
    while t > 1 {
        let next = ones + hist[t - 1];
        if next > max_ones {
            break;
        }
        ones = next;
        t -= 1;
    }
    Hv::from_fn(|i| plane[i] >= t as u32)
}

/// Accumulates query HVs per class and produces the AM.
pub struct Trainer {
    counts: [Box<[u32; DIM]>; NUM_CLASSES],
    windows: [usize; NUM_CLASSES],
    /// Density target for the thinned class HVs (sparse variants).
    pub train_density: f64,
}

impl Trainer {
    pub fn new(train_density: f64) -> Self {
        Trainer {
            counts: [Box::new([0u32; DIM]), Box::new([0u32; DIM])],
            windows: [0; NUM_CLASSES],
            train_density,
        }
    }

    /// Add one query HV with its window label.
    pub fn add_window(&mut self, query: &Hv, ictal: bool) {
        let class = if ictal { CLASS_ICTAL } else { CLASS_INTERICTAL };
        let plane = &mut self.counts[class];
        for p in query.one_positions() {
            plane[p] += 1;
        }
        self.windows[class] += 1;
    }

    pub fn windows(&self) -> [usize; NUM_CLASSES] {
        self.windows
    }

    /// Snapshot the accumulated counter planes — the training state a
    /// format-2 [`ModelBundle`] persists so retraining can resume from
    /// the artifact ([`crate::hdc::online::OnlineTrainer::from_counters`])
    /// instead of re-seeding from the record.
    pub fn counter_planes(&self) -> CounterPlanes {
        CounterPlanes {
            counts: self.counts.clone(),
            windows: [
                self.windows[CLASS_INTERICTAL] as u64,
                self.windows[CLASS_ICTAL] as u64,
            ],
        }
    }

    /// Majority bundling for the dense design point.
    fn majority_class(&self, class: usize) -> Hv {
        let n = self.windows[class];
        if n == 0 {
            return Hv::zero();
        }
        let mut c16 = [0u16; DIM];
        for (i, &c) in self.counts[class].iter().enumerate() {
            c16[i] = c.min(u16::MAX as u32) as u16;
        }
        majority_from_counts(&c16, n)
    }

    /// Produce the associative memory for the given design variant.
    pub fn finish(&self, variant: Variant) -> AssociativeMemory {
        let (inter, ictal) = if variant.is_sparse() {
            (
                thin_counts_to_density(&self.counts[CLASS_INTERICTAL], self.train_density),
                thin_counts_to_density(&self.counts[CLASS_ICTAL], self.train_density),
            )
        } else {
            (
                self.majority_class(CLASS_INTERICTAL),
                self.majority_class(CLASS_ICTAL),
            )
        };
        AssociativeMemory::new(inter, ictal)
    }

    /// Produce a persistent, versioned model artifact: the AM plus the
    /// encoder config it was trained against and this trainer's window
    /// provenance. Fresh one-shot training always yields version 1.
    pub fn finish_bundle(
        &self,
        variant: Variant,
        cfg: &ClassifierConfig,
        mut provenance: Provenance,
    ) -> ModelBundle {
        provenance.train_windows = [
            self.windows[CLASS_INTERICTAL] as u64,
            self.windows[CLASS_ICTAL] as u64,
        ];
        if provenance.note.is_empty() {
            provenance.note = "one-shot training".to_string();
        }
        let mut bundle = ModelBundle::new(variant, cfg.clone(), self.finish(variant), provenance);
        // Persist the training state alongside the thinned AM (format 2)
        // for the sparse design points — dense majority bundling has no
        // online-retraining path to resume.
        if variant.is_sparse() {
            bundle.counters = Some(self.counter_planes());
        }
        bundle
    }
}

/// Stream labelled frames through an encoder, invoking `add` once per
/// completed prediction window with the window's query HV and its
/// **majority label**: an expert-marked onset mid-window labels that
/// window ictal only if most of it is ictal — conservative, mirrors
/// [1]'s windowing. This is *the* window-labelling rule; one-shot
/// training, the explicit-trainer path and online retraining all label
/// through this one function so they can never drift apart.
pub fn label_windows(
    encoder: &mut dyn Encoder,
    frames: impl IntoIterator<Item = LabelledFrame>,
    mut add: impl FnMut(Hv, bool),
) {
    encoder.reset();
    let mut ictal_frames = 0usize;
    let mut total_frames = 0usize;
    for (codes, ictal) in frames {
        ictal_frames += ictal as usize;
        total_frames += 1;
        if let Some(query) = encoder.push_frame(&codes) {
            add(query, ictal_frames * 2 > total_frames);
            ictal_frames = 0;
            total_frames = 0;
        }
    }
    encoder.reset();
}

/// One-shot training over a labelled frame stream, yielding a
/// version-1 [`ModelBundle`] that carries the encoder config alongside
/// the AM (the artifact every downstream layer consumes). Windows are
/// labelled by [`label_windows`].
pub fn train_from_frames(
    encoder: &mut dyn Encoder,
    frames: impl IntoIterator<Item = LabelledFrame>,
    cfg: &ClassifierConfig,
) -> ModelBundle {
    let variant = encoder.variant();
    let mut trainer = Trainer::new(cfg.train_density);
    label_windows(encoder, frames, |query, ictal| {
        trainer.add_window(&query, ictal)
    });
    trainer.finish_bundle(variant, cfg, Provenance::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::classifier::{ClassifierConfig, SparseEncoder};
    use crate::params::{CHANNELS, FRAMES_PER_PREDICTION, LBP_CODES};
    use crate::rng::Xoshiro256;

    /// Synthetic frame streams where ictal frames draw codes from a biased
    /// alphabet — a stand-in for the LBP statistics shift of a seizure.
    fn frame(rng: &mut Xoshiro256, ictal: bool) -> Frame {
        let mut f = [0u8; CHANNELS];
        for c in f.iter_mut() {
            *c = if ictal {
                // seizures: rhythmic, concentrated codes
                rng.next_below(8) as u8
            } else {
                // background: broad alphabet, disjoint from the ictal one so
                // the toy problem is cleanly separable
                8 + rng.next_below(LBP_CODES as u64 - 8) as u8
            };
        }
        f
    }

    #[test]
    fn trained_am_separates_classes() {
        let mut rng = Xoshiro256::new(42);
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());

        // Train: 8 interictal windows then 8 ictal windows.
        let mut frames = Vec::new();
        for _ in 0..8 * FRAMES_PER_PREDICTION {
            frames.push((frame(&mut rng, false), false));
        }
        for _ in 0..8 * FRAMES_PER_PREDICTION {
            frames.push((frame(&mut rng, true), true));
        }
        let bundle = train_from_frames(&mut enc, frames, &cfg);
        let am = &bundle.am;

        // Class HVs should be near the density target and distinct.
        let d0 = am.classes[CLASS_INTERICTAL].density();
        let d1 = am.classes[CLASS_ICTAL].density();
        assert!(d0 > 0.05 && d0 <= 0.5 + 1e-9, "interictal density {d0}");
        assert!(d1 > 0.05 && d1 <= 0.5 + 1e-9, "ictal density {d1}");
        assert_ne!(am.classes[0], am.classes[1]);

        // The bundle records what it was trained with.
        assert_eq!(bundle.version, 1);
        assert_eq!(bundle.variant, Variant::Optimized);
        assert_eq!(bundle.config, cfg);
        assert_eq!(bundle.provenance.train_windows, [8, 8]);
        assert_eq!(bundle.provenance.epochs, 0);

        // Test: fresh windows classify correctly.
        let mut correct = 0;
        for &ictal in &[false, true, false, true] {
            enc.reset();
            let mut out = None;
            for _ in 0..FRAMES_PER_PREDICTION {
                out = out.or(enc.push_frame(&frame(&mut rng, ictal)));
            }
            let q = out.unwrap();
            let r = am.search(&q);
            if r.is_ictal() == ictal {
                correct += 1;
            }
        }
        assert_eq!(correct, 4, "one-shot training should separate the toy classes");
    }

    #[test]
    fn empty_class_yields_zero_hv() {
        let trainer = Trainer::new(0.5);
        let am = trainer.finish(Variant::Optimized);
        assert_eq!(am.classes[0].popcount(), 0);
        assert_eq!(am.classes[1].popcount(), 0);
    }

    #[test]
    fn thinning_respects_density_target() {
        let mut rng = Xoshiro256::new(7);
        let mut trainer = Trainer::new(0.3);
        for _ in 0..20 {
            trainer.add_window(&Hv::random(&mut rng, 0.25), true);
        }
        let am = trainer.finish(Variant::Optimized);
        assert!(am.classes[CLASS_ICTAL].density() <= 0.3 + 1e-12);
        assert!(am.classes[CLASS_ICTAL].density() > 0.0);
    }

    #[test]
    fn thin_helper_picks_minimal_threshold() {
        let mut rng = Xoshiro256::new(77);
        let mut plane = Box::new([0u32; DIM]);
        for _ in 0..40 {
            for p in Hv::random(&mut rng, 0.3).one_positions() {
                plane[p] += 1;
            }
        }
        for max_d in [0.05, 0.2, 0.5] {
            let max_ones = (max_d * DIM as f64).floor() as usize;
            let hv = thin_counts_to_density(&plane, max_d);
            assert!(hv.density() <= max_d + 1e-12, "density {} > {max_d}", hv.density());
            // Minimality: loosening the threshold far enough to admit the
            // highest-count *excluded* element must overflow the cap
            // (otherwise the helper should have kept it).
            let excluded_max = plane
                .iter()
                .enumerate()
                .filter(|&(i, &c)| !hv.get(i) && c > 0)
                .map(|(_, &c)| c)
                .max();
            if let Some(s) = excluded_max {
                let looser = plane.iter().filter(|&&c| c >= s).count();
                assert!(looser > max_ones, "count-{s} elements wrongly excluded at {max_d}");
            }
        }
    }

    #[test]
    fn window_labels_use_majority() {
        // A window with less than half ictal frames counts interictal.
        let mut rng = Xoshiro256::new(8);
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let mut frames = Vec::new();
        for i in 0..FRAMES_PER_PREDICTION {
            // 25% of frames labelled ictal.
            frames.push((frame(&mut rng, false), i % 4 == 0));
        }
        let bundle = train_from_frames(&mut enc, frames, &cfg);
        // Everything went to interictal; the ictal class stays empty.
        assert_eq!(bundle.am.classes[CLASS_ICTAL].popcount(), 0);
        assert!(bundle.am.classes[CLASS_INTERICTAL].popcount() > 0);
        assert_eq!(bundle.provenance.train_windows, [1, 0]);
    }

    #[test]
    fn bundles_carry_the_counter_planes() {
        let mut rng = Xoshiro256::new(31);
        let mut trainer = Trainer::new(0.4);
        let queries: Vec<(Hv, bool)> = (0..12)
            .map(|i| (Hv::random(&mut rng, 0.2), i % 3 == 0))
            .collect();
        for (q, ictal) in &queries {
            trainer.add_window(q, *ictal);
        }
        let bundle = trainer.finish_bundle(
            Variant::Optimized,
            &ClassifierConfig::optimized(),
            Provenance::default(),
        );
        let planes = bundle.counters.expect("sparse bundles persist their planes");
        assert_eq!(planes.windows, [8, 4]);
        // The planes really are the accumulation of the queries: thinning
        // them reproduces the bundle's AM exactly.
        assert_eq!(
            AssociativeMemory::new(
                thin_counts_to_density(&planes.counts[CLASS_INTERICTAL], 0.4),
                thin_counts_to_density(&planes.counts[CLASS_ICTAL], 0.4),
            )
            .classes,
            bundle.am.classes
        );
        // Dense bundles stay format 1 (no online path to resume).
        let dense = Trainer::new(0.5).finish_bundle(
            Variant::DenseBaseline,
            &ClassifierConfig::default(),
            Provenance::default(),
        );
        assert!(dense.counters.is_none());
    }

    #[test]
    fn dense_training_majority() {
        let mut rng = Xoshiro256::new(9);
        let mut trainer = Trainer::new(0.5);
        let proto = Hv::random_half(&mut rng);
        for _ in 0..9 {
            trainer.add_window(&proto, true);
        }
        // one dissenting window
        trainer.add_window(&Hv::random_half(&mut rng), true);
        let am = trainer.finish(Variant::DenseBaseline);
        // Majority of 10 windows, 9 identical → equals proto.
        assert_eq!(am.classes[CLASS_ICTAL], proto);
    }
}
