//! Offline one-shot training — paper §II-D.
//!
//! Class-representing HVs are computed "through the same sparse HDC
//! classifier as the inference but with labeled data from one seizure":
//! every prediction-window query HV of the training record is accumulated
//! into a per-class counter plane, and each class plane is thinned to the
//! configured density (50% in the paper) to form the AM entry. The dense
//! design point bundles with a bit-wise majority instead.
//!
//! Training runs offline (design-/fit-time); only the resulting AM is
//! deployed on the accelerator.

use crate::params::{CLASS_ICTAL, CLASS_INTERICTAL, DIM, NUM_CLASSES};

use super::am::AssociativeMemory;
use super::classifier::{Encoder, Frame, Variant};
use super::dense::majority_from_counts;
use super::hv::Hv;

/// A labelled frame stream: the LBP codes of one frame plus whether the
/// frame lies inside the expert-annotated ictal interval.
pub type LabelledFrame = (Frame, bool);

/// Accumulates query HVs per class and produces the AM.
pub struct Trainer {
    counts: [Box<[u32; DIM]>; NUM_CLASSES],
    windows: [usize; NUM_CLASSES],
    /// Density target for the thinned class HVs (sparse variants).
    pub train_density: f64,
}

impl Trainer {
    pub fn new(train_density: f64) -> Self {
        Trainer {
            counts: [Box::new([0u32; DIM]), Box::new([0u32; DIM])],
            windows: [0; NUM_CLASSES],
            train_density,
        }
    }

    /// Add one query HV with its window label.
    pub fn add_window(&mut self, query: &Hv, ictal: bool) {
        let class = if ictal { CLASS_ICTAL } else { CLASS_INTERICTAL };
        let plane = &mut self.counts[class];
        for p in query.one_positions() {
            plane[p] += 1;
        }
        self.windows[class] += 1;
    }

    pub fn windows(&self) -> [usize; NUM_CLASSES] {
        self.windows
    }

    /// Thin one class plane to at most `train_density` (sparse bundling
    /// with thinning, §II-D).
    fn thin_class(&self, class: usize) -> Hv {
        let plane = &self.counts[class];
        let max_ones = (self.train_density * DIM as f64).floor() as usize;
        // Count histogram over window counts (bounded by windows seen).
        let max_count = self.windows[class] as u32;
        if max_count == 0 {
            return Hv::zero();
        }
        let mut hist = vec![0usize; max_count as usize + 2];
        for &c in plane.iter() {
            hist[c as usize] += 1;
        }
        // Smallest threshold t >= 1 with |{i : plane[i] >= t}| <= max_ones.
        let mut ones = 0usize;
        let mut t = max_count as usize + 1;
        while t > 1 {
            let next = ones + hist[t - 1];
            if next > max_ones {
                break;
            }
            ones = next;
            t -= 1;
        }
        Hv::from_fn(|i| plane[i] >= t as u32)
    }

    /// Majority bundling for the dense design point.
    fn majority_class(&self, class: usize) -> Hv {
        let n = self.windows[class];
        if n == 0 {
            return Hv::zero();
        }
        let mut c16 = [0u16; DIM];
        for (i, &c) in self.counts[class].iter().enumerate() {
            c16[i] = c.min(u16::MAX as u32) as u16;
        }
        majority_from_counts(&c16, n)
    }

    /// Produce the associative memory for the given design variant.
    pub fn finish(&self, variant: Variant) -> AssociativeMemory {
        let (inter, ictal) = if variant.is_sparse() {
            (
                self.thin_class(CLASS_INTERICTAL),
                self.thin_class(CLASS_ICTAL),
            )
        } else {
            (
                self.majority_class(CLASS_INTERICTAL),
                self.majority_class(CLASS_ICTAL),
            )
        };
        AssociativeMemory::new(inter, ictal)
    }
}

/// One-shot training over a labelled frame stream.
///
/// Windows are labelled by *majority of frame labels* within the window
/// (an expert-marked onset mid-window labels that window ictal only if
/// most of it is ictal — conservative, mirrors [1]'s windowing).
pub fn train_from_frames(
    encoder: &mut dyn Encoder,
    frames: impl IntoIterator<Item = LabelledFrame>,
    train_density: f64,
) -> AssociativeMemory {
    let variant = encoder.variant();
    let mut trainer = Trainer::new(train_density);
    encoder.reset();
    let mut ictal_frames = 0usize;
    let mut total_frames = 0usize;
    for (codes, ictal) in frames {
        ictal_frames += ictal as usize;
        total_frames += 1;
        if let Some(query) = encoder.push_frame(&codes) {
            trainer.add_window(&query, ictal_frames * 2 > total_frames);
            ictal_frames = 0;
            total_frames = 0;
        }
    }
    encoder.reset();
    trainer.finish(variant)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::classifier::{ClassifierConfig, SparseEncoder};
    use crate::params::{CHANNELS, FRAMES_PER_PREDICTION, LBP_CODES};
    use crate::rng::Xoshiro256;

    /// Synthetic frame streams where ictal frames draw codes from a biased
    /// alphabet — a stand-in for the LBP statistics shift of a seizure.
    fn frame(rng: &mut Xoshiro256, ictal: bool) -> Frame {
        let mut f = [0u8; CHANNELS];
        for c in f.iter_mut() {
            *c = if ictal {
                // seizures: rhythmic, concentrated codes
                rng.next_below(8) as u8
            } else {
                // background: broad alphabet, disjoint from the ictal one so
                // the toy problem is cleanly separable
                8 + rng.next_below(LBP_CODES as u64 - 8) as u8
            };
        }
        f
    }

    #[test]
    fn trained_am_separates_classes() {
        let mut rng = Xoshiro256::new(42);
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());

        // Train: 8 interictal windows then 8 ictal windows.
        let mut frames = Vec::new();
        for _ in 0..8 * FRAMES_PER_PREDICTION {
            frames.push((frame(&mut rng, false), false));
        }
        for _ in 0..8 * FRAMES_PER_PREDICTION {
            frames.push((frame(&mut rng, true), true));
        }
        let am = train_from_frames(&mut enc, frames, cfg.train_density);

        // Class HVs should be near the density target and distinct.
        let d0 = am.classes[CLASS_INTERICTAL].density();
        let d1 = am.classes[CLASS_ICTAL].density();
        assert!(d0 > 0.05 && d0 <= 0.5 + 1e-9, "interictal density {d0}");
        assert!(d1 > 0.05 && d1 <= 0.5 + 1e-9, "ictal density {d1}");
        assert_ne!(am.classes[0], am.classes[1]);

        // Test: fresh windows classify correctly.
        let mut correct = 0;
        for &ictal in &[false, true, false, true] {
            enc.reset();
            let mut out = None;
            for _ in 0..FRAMES_PER_PREDICTION {
                out = out.or(enc.push_frame(&frame(&mut rng, ictal)));
            }
            let q = out.unwrap();
            let r = am.search(&q);
            if r.is_ictal() == ictal {
                correct += 1;
            }
        }
        assert_eq!(correct, 4, "one-shot training should separate the toy classes");
    }

    #[test]
    fn empty_class_yields_zero_hv() {
        let trainer = Trainer::new(0.5);
        let am = trainer.finish(Variant::Optimized);
        assert_eq!(am.classes[0].popcount(), 0);
        assert_eq!(am.classes[1].popcount(), 0);
    }

    #[test]
    fn thinning_respects_density_target() {
        let mut rng = Xoshiro256::new(7);
        let mut trainer = Trainer::new(0.3);
        for _ in 0..20 {
            trainer.add_window(&Hv::random(&mut rng, 0.25), true);
        }
        let am = trainer.finish(Variant::Optimized);
        assert!(am.classes[CLASS_ICTAL].density() <= 0.3 + 1e-12);
        assert!(am.classes[CLASS_ICTAL].density() > 0.0);
    }

    #[test]
    fn window_labels_use_majority() {
        // A window with less than half ictal frames counts interictal.
        let mut rng = Xoshiro256::new(8);
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let mut frames = Vec::new();
        for i in 0..FRAMES_PER_PREDICTION {
            // 25% of frames labelled ictal.
            frames.push((frame(&mut rng, false), i % 4 == 0));
        }
        let am = train_from_frames(&mut enc, frames, cfg.train_density);
        // Everything went to interictal; the ictal class stays empty.
        assert_eq!(am.classes[CLASS_ICTAL].popcount(), 0);
        assert!(am.classes[CLASS_INTERICTAL].popcount() > 0);
    }

    #[test]
    fn dense_training_majority() {
        let mut rng = Xoshiro256::new(9);
        let mut trainer = Trainer::new(0.5);
        let proto = Hv::random_half(&mut rng);
        for _ in 0..9 {
            trainer.add_window(&proto, true);
        }
        // one dissenting window
        trainer.add_window(&Hv::random_half(&mut rng), true);
        let am = trainer.finish(Variant::DenseBaseline);
        // Majority of 10 windows, 9 identical → equals proto.
        assert_eq!(am.classes[CLASS_ICTAL], proto);
    }
}
