//! Switching-activity collection — the PrimeTime-PX substitution.
//!
//! Runs the bit-accurate design simulators over real stimuli (LBP frames
//! from a synthetic patient record) and counts, cycle by cycle, the events
//! that burn dynamic energy:
//!
//! * bit toggles on every inter-module HV bus (value at cycle *t* XORed
//!   with cycle *t−1* — exactly what switching annotation measures),
//! * ones flowing into adder/OR trees (internal compressor activity),
//! * flip-flop bit flips in the temporal counters (carry chains included),
//! * AM events per prediction.
//!
//! The same stimuli drive all four design points, so differences in the
//! resulting energies come only from architecture — the paper's Fig. 5
//! methodology ("energy analysis … with switching annotations", §IV).

use std::collections::BTreeMap;

use crate::hdc::bundling;
use crate::hdc::classifier::{ClassifierConfig, Frame, Variant};
use crate::hdc::compim::CompIm;
use crate::hdc::dense::{self};
use crate::hdc::hv::Hv;
use crate::hdc::im::{DenseItemMemory, ItemMemory};
use crate::hdc::sparse::{bind_bitdomain, SparseHv};
use crate::params::{
    CHANNELS, DIM, FRAMES_PER_PREDICTION, SEGMENTS, TEMPORAL_COUNTER_MAX,
};

/// Named event counters accumulated over a simulation.
#[derive(Clone, Debug, Default)]
pub struct Activity {
    counters: BTreeMap<&'static str, f64>,
    pub cycles: u64,
    pub predictions: u64,
}

impl Activity {
    pub fn add(&mut self, key: &'static str, v: f64) {
        *self.counters.entry(key).or_insert(0.0) += v;
    }

    pub fn get(&self, key: &'static str) -> f64 {
        self.counters.get(key).copied().unwrap_or(0.0)
    }

    /// Events per prediction window (the paper's energy unit).
    pub fn per_prediction(&self, key: &'static str) -> f64 {
        if self.predictions == 0 {
            return 0.0;
        }
        self.get(key) / self.predictions as f64
    }

    pub fn keys(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.counters.keys().copied()
    }
}

fn hamming(a: &Hv, b: &Hv) -> f64 {
    a.hamming(b) as f64
}

/// Toggles between two 7-bit positions.
fn pos_toggles(a: u8, b: u8) -> f64 {
    ((a ^ b).count_ones()) as f64
}

/// Bit flips when an 8-bit saturating counter increments (carry chain).
fn counter_inc_toggles(old: u16) -> f64 {
    if old >= TEMPORAL_COUNTER_MAX {
        return 0.0;
    }
    ((old ^ (old + 1)).count_ones()) as f64
}

/// Collect activity for one design point over a frame stream.
///
/// Only whole prediction windows are simulated; a trailing partial window
/// is dropped so per-prediction numbers are exact.
pub fn collect_activity(variant: Variant, cfg: &ClassifierConfig, frames: &[Frame]) -> Activity {
    match variant {
        Variant::DenseBaseline => collect_dense(cfg, frames),
        Variant::SparseBaseline => collect_sparse(cfg, frames, SparseStyle::Baseline),
        Variant::SparseCompIm => collect_sparse(cfg, frames, SparseStyle::CompImAdder),
        Variant::Optimized => collect_sparse(cfg, frames, SparseStyle::CompImOr),
    }
}

#[derive(Clone, Copy, PartialEq)]
enum SparseStyle {
    Baseline,
    CompImAdder,
    CompImOr,
}

fn collect_sparse(cfg: &ClassifierConfig, frames: &[Frame], style: SparseStyle) -> Activity {
    let im = ItemMemory::generate(cfg.seed);
    let compim = CompIm::from_item_memory(&im);
    let mut act = Activity::default();

    let windows = frames.len() / FRAMES_PER_PREDICTION;
    let n = windows * FRAMES_PER_PREDICTION;

    // Previous-cycle state per channel.
    let mut prev_im_hv = vec![Hv::zero(); CHANNELS]; // 1024-bit IM bus (baseline)
    let mut prev_im_pos = vec![SparseHv::new([0; SEGMENTS]); CHANNELS]; // 56-bit CompIM bus
    let mut prev_bound = vec![Hv::zero(); CHANNELS]; // binder output (one-hot domain)
    let mut prev_bound_pos = vec![SparseHv::new([0; SEGMENTS]); CHANNELS];
    let mut prev_spatial = Hv::zero();
    let mut prev_query = Hv::zero();
    let mut counters = vec![0u16; DIM];
    let mut frames_in_window = 0usize;

    let mut bound_bits: Vec<Hv> = Vec::with_capacity(CHANNELS);
    let mut bound_pos: Vec<SparseHv> = Vec::with_capacity(CHANNELS);

    for frame in &frames[..n] {
        bound_bits.clear();
        bound_pos.clear();
        for (c, &code) in frame.iter().enumerate() {
            match style {
                SparseStyle::Baseline => {
                    // IM 1024-bit read port.
                    let data_hv = im.lookup_hv(c, code);
                    act.add("im.read_bits", DIM as f64);
                    act.add("im.read_ones", SEGMENTS as f64);
                    act.add("im.out_toggles", hamming(&data_hv, &prev_im_hv[c]));
                    prev_im_hv[c] = data_hv;
                    // One-hot → binary decoder.
                    let data_pos = im.lookup(c, code);
                    for s in 0..SEGMENTS {
                        act.add(
                            "dec.out_toggles",
                            pos_toggles(data_pos.pos[s], prev_im_pos[c].pos[s]),
                        );
                    }
                    prev_im_pos[c] = data_pos;
                    // Barrel shifter output bus.
                    let bound = bind_bitdomain(&im.electrode_hv(c), &data_hv).unwrap();
                    act.add("bind.out_toggles", hamming(&bound, &prev_bound[c]));
                    // Internal shifter activity: each stage re-routes the
                    // full 128-bit segment when its shift bit differs.
                    let shift_bit_flips: f64 = (0..SEGMENTS)
                        .map(|s| pos_toggles(data_pos.pos[s], prev_bound_pos[c].pos[s]))
                        .sum();
                    act.add("bind.internal_events", shift_bit_flips * 2.0);
                    prev_bound_pos[c] = data_pos;
                    prev_bound[c] = bound;
                    bound_bits.push(bound);
                }
                SparseStyle::CompImAdder | SparseStyle::CompImOr => {
                    // CompIM 56-bit read port.
                    let data_pos = compim.lookup(c, code);
                    act.add("im.read_bits", CompIm::ENTRY_BITS as f64);
                    act.add(
                        "im.read_ones",
                        compim.lookup_packed(c, code).count_ones() as f64,
                    );
                    for s in 0..SEGMENTS {
                        act.add(
                            "im.out_toggles",
                            pos_toggles(data_pos.pos[s], prev_im_pos[c].pos[s]),
                        );
                    }
                    prev_im_pos[c] = data_pos;
                    // 7-bit adders (+ carry activity ≈ output toggles) and
                    // the 7→128 decoder feeding the bundling.
                    let bpos = compim.bind(c, code);
                    for s in 0..SEGMENTS {
                        act.add(
                            "bind.add_toggles",
                            pos_toggles(bpos.pos[s], prev_bound_pos[c].pos[s]),
                        );
                    }
                    let bound = bpos.to_hv();
                    act.add("bind.out_toggles", hamming(&bound, &prev_bound[c]));
                    prev_bound_pos[c] = bpos;
                    prev_bound[c] = bound;
                    bound_pos.push(bpos);
                    bound_bits.push(bound);
                }
            }
        }

        // Spatial bundling.
        let ones: f64 = bound_bits.iter().map(|h| h.popcount() as f64).sum();
        act.add("spatial.input_ones", ones);
        let spatial = match style {
            SparseStyle::Baseline => {
                bundling::bundle_adder_thin(&bound_bits, cfg.spatial_threshold)
            }
            SparseStyle::CompImAdder => {
                bundling::bundle_adder_thin_pos(&bound_pos, cfg.spatial_threshold)
            }
            SparseStyle::CompImOr => bundling::bundle_or_pos(&bound_pos),
        };
        act.add("spatial.out_toggles", hamming(&spatial, &prev_spatial));
        prev_spatial = spatial;

        // Temporal counters (8-bit, saturating).
        // Clock-gated counters: only elements whose spatial bit is 1 see
        // a clock edge this cycle.
        act.add("temporal.clocked_bits", spatial.popcount() as f64 * 8.0);
        for p in spatial.one_positions() {
            act.add("temporal.ff_bit_toggles", counter_inc_toggles(counters[p]));
            if counters[p] < TEMPORAL_COUNTER_MAX {
                counters[p] += 1;
            }
        }
        frames_in_window += 1;
        act.cycles += 1;

        if frames_in_window == FRAMES_PER_PREDICTION {
            // Thin + similarity search.
            let query = Hv::from_fn(|i| counters[i] >= cfg.temporal_threshold);
            act.add("query.out_toggles", hamming(&query, &prev_query));
            act.add("am.query_ones", query.popcount() as f64);
            // Two sequential class comparisons load the AM AND plane.
            act.add("am.compare_events", 2.0 * query.popcount() as f64);
            prev_query = query;
            // Counter reset: every set bit flips to 0.
            let reset_toggles: f64 = counters.iter().map(|&c| c.count_ones() as f64).sum();
            act.add("temporal.ff_bit_toggles", reset_toggles);
            counters.fill(0);
            frames_in_window = 0;
            act.predictions += 1;
        }
    }
    act
}

fn collect_dense(cfg: &ClassifierConfig, frames: &[Frame]) -> Activity {
    let im = DenseItemMemory::generate(cfg.seed);
    let mut act = Activity::default();

    let windows = frames.len() / FRAMES_PER_PREDICTION;
    let n = windows * FRAMES_PER_PREDICTION;

    let mut prev_im_hv = vec![Hv::zero(); CHANNELS];
    let mut prev_bound = vec![Hv::zero(); CHANNELS];
    let mut prev_spatial = Hv::zero();
    let mut prev_query = Hv::zero();
    let mut counters = vec![0u16; DIM];
    let mut frames_in_window = 0usize;

    for frame in &frames[..n] {
        let mut bound_all: Vec<Hv> = Vec::with_capacity(CHANNELS);
        for (c, &code) in frame.iter().enumerate() {
            let data = *im.lookup(code);
            act.add("im.read_bits", DIM as f64);
            act.add("im.read_ones", data.popcount() as f64);
            act.add("im.out_toggles", hamming(&data, &prev_im_hv[c]));
            prev_im_hv[c] = data;
            let bound = dense::bind(&data, im.electrode(c));
            act.add("bind.out_toggles", hamming(&bound, &prev_bound[c]));
            // XOR array internal = output toggles (one gate per bit).
            act.add("bind.internal_events", hamming(&bound, &prev_bound[c]));
            prev_bound[c] = bound;
            bound_all.push(bound);
        }

        let ones: f64 = bound_all.iter().map(|h| h.popcount() as f64).sum();
        act.add("spatial.input_ones", ones);
        let (spatial, _counts) = {
            let mut codes_arr = [0u8; CHANNELS];
            codes_arr.copy_from_slice(frame);
            dense::dense_spatial_encode(&im, &codes_arr)
        };
        act.add("spatial.out_toggles", hamming(&spatial, &prev_spatial));
        prev_spatial = spatial;

        // Clock-gated counters: only elements whose spatial bit is 1 see
        // a clock edge this cycle.
        act.add("temporal.clocked_bits", spatial.popcount() as f64 * 8.0);
        for p in spatial.one_positions() {
            act.add("temporal.ff_bit_toggles", counter_inc_toggles(counters[p]));
            if counters[p] < TEMPORAL_COUNTER_MAX {
                counters[p] += 1;
            }
        }
        frames_in_window += 1;
        act.cycles += 1;

        if frames_in_window == FRAMES_PER_PREDICTION {
            let mut c16 = [0u16; DIM];
            c16.copy_from_slice(&counters);
            let query = dense::majority_with_tie(&c16, FRAMES_PER_PREDICTION, im.tiebreak(1));
            act.add("query.out_toggles", hamming(&query, &prev_query));
            act.add("am.query_ones", query.popcount() as f64);
            // Hamming search: XOR plane + popcount, two classes; activity
            // scales with the full dimension for dense.
            act.add("am.compare_events", 2.0 * DIM as f64);
            prev_query = query;
            let reset_toggles: f64 = counters.iter().map(|&c| c.count_ones() as f64).sum();
            act.add("temporal.ff_bit_toggles", reset_toggles);
            counters.fill(0);
            frames_in_window = 0;
            act.predictions += 1;
        }
    }
    act
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_frames(n: usize, seed: u64) -> Vec<Frame> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let mut f = [0u8; CHANNELS];
                for c in f.iter_mut() {
                    *c = rng.next_below(crate::params::LBP_CODES as u64) as u8;
                }
                f
            })
            .collect()
    }

    #[test]
    fn whole_windows_only() {
        let frames = random_frames(FRAMES_PER_PREDICTION + 100, 1);
        let cfg = ClassifierConfig::optimized();
        let act = collect_activity(Variant::Optimized, &cfg, &frames);
        assert_eq!(act.predictions, 1);
        assert_eq!(act.cycles, FRAMES_PER_PREDICTION as u64);
    }

    #[test]
    fn sparse_bus_toggles_far_below_dense() {
        // The paper's core claim: sparse HVs switch ~2% of what dense HVs
        // switch on the binder output buses.
        let frames = random_frames(FRAMES_PER_PREDICTION, 2);
        let sparse = collect_activity(
            Variant::Optimized,
            &ClassifierConfig::optimized(),
            &frames,
        );
        let dense = collect_activity(
            Variant::DenseBaseline,
            &ClassifierConfig::default(),
            &frames,
        );
        let s = sparse.per_prediction("bind.out_toggles");
        let d = dense.per_prediction("bind.out_toggles");
        assert!(s > 0.0 && d > 0.0);
        let ratio = s / d;
        assert!(
            ratio < 0.08,
            "sparse/dense binder toggle ratio {ratio} should be ≈ 2·p ≈ 3%"
        );
    }

    #[test]
    fn compim_im_bus_cheaper_than_baseline() {
        let frames = random_frames(FRAMES_PER_PREDICTION, 3);
        let base = collect_activity(
            Variant::SparseBaseline,
            &ClassifierConfig::default(),
            &frames,
        );
        let opt = collect_activity(Variant::Optimized, &ClassifierConfig::optimized(), &frames);
        // 56-bit read port vs 1024-bit read port.
        assert!(opt.per_prediction("im.read_bits") < base.per_prediction("im.read_bits") / 10.0);
        // Note: binary position buses toggle slightly *more* bits than the
        // one-hot bus (≈3.5 vs 2 per changed segment) — the CompIM win is
        // the removed decoder + narrow ROM/bus, not the toggle count.
        assert!(
            opt.per_prediction("im.out_toggles") < 3.0 * base.per_prediction("im.out_toggles")
        );
    }

    #[test]
    fn baseline_and_compim_same_bound_output() {
        // Same architecture-level signal → identical bound-bus toggles.
        let frames = random_frames(FRAMES_PER_PREDICTION, 4);
        let cfg1 = ClassifierConfig {
            spatial_threshold: 1,
            ..Default::default()
        };
        let base = collect_activity(Variant::SparseBaseline, &cfg1, &frames);
        let comp = collect_activity(Variant::SparseCompIm, &cfg1, &frames);
        assert_eq!(
            base.get("bind.out_toggles"),
            comp.get("bind.out_toggles")
        );
        assert_eq!(
            base.get("spatial.input_ones"),
            comp.get("spatial.input_ones")
        );
    }

    #[test]
    fn spatial_input_ones_constant_for_sparse() {
        // Every bound sparse HV has exactly 8 ones → 512 per cycle.
        let frames = random_frames(FRAMES_PER_PREDICTION, 5);
        let act = collect_activity(Variant::Optimized, &ClassifierConfig::optimized(), &frames);
        let per_cycle = act.get("spatial.input_ones") / act.cycles as f64;
        assert!((per_cycle - (CHANNELS * SEGMENTS) as f64).abs() < 1e-9);
    }

    #[test]
    fn am_events_only_on_predictions() {
        let frames = random_frames(FRAMES_PER_PREDICTION * 3, 6);
        let act = collect_activity(Variant::Optimized, &ClassifierConfig::optimized(), &frames);
        assert_eq!(act.predictions, 3);
        assert!(act.get("am.query_ones") > 0.0);
        // query ones bounded by DIM per prediction
        assert!(act.per_prediction("am.query_ones") <= DIM as f64);
    }
}
