//! Report formatting: the textual reproductions of Fig. 1(c), Fig. 5 and
//! Table I.

use crate::hdc::classifier::Variant;
use crate::params::CHANNELS;

use super::designs::DesignReport;

fn bar(frac: f64, width: usize) -> String {
    let filled = (frac * width as f64).round() as usize;
    let mut s = String::new();
    for i in 0..width {
        s.push(if i < filled { '█' } else { '·' });
    }
    s
}

/// Fig. 1(c): per-module area and energy breakdown of one design.
pub fn format_breakdown(rep: &DesignReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "design: {:<18} area {:.4} mm²  energy/predict {:.2} nJ  (dyn {:.2} + leak {:.2})\n",
        rep.variant.name(),
        rep.area_mm2(),
        rep.energy_nj_per_pred(),
        rep.dyn_nj_per_pred(),
        rep.leak_nj_per_pred(),
    ));
    out.push_str(&format!(
        "{:<18} {:>8}  {:<26} {:>8}  {:<26}\n",
        "module", "area%", "", "energy%", ""
    ));
    for (name, a, e) in rep.shares() {
        out.push_str(&format!(
            "{:<18} {:>7.1}%  {:<26} {:>7.1}%  {:<26}\n",
            name,
            a * 100.0,
            bar(a, 26),
            e * 100.0,
            bar(e, 26)
        ));
    }
    out
}

/// Fig. 5: the four designs side by side with ratios vs. the optimized
/// design.
pub fn format_comparison(reports: &[DesignReport]) -> String {
    let opt = reports
        .iter()
        .find(|r| r.variant == Variant::Optimized)
        .expect("optimized design present");
    let mut out = String::new();
    out.push_str(&format!(
        "{:<18} {:>10} {:>12} {:>10} {:>10} {:>10}\n",
        "design", "area mm²", "energy nJ", "power µW", "area ×", "energy ×"
    ));
    for r in reports {
        out.push_str(&format!(
            "{:<18} {:>10.4} {:>12.2} {:>10.1} {:>9.2}x {:>9.2}x\n",
            r.variant.name(),
            r.area_mm2(),
            r.energy_nj_per_pred(),
            r.power_uw(),
            r.area_mm2() / opt.area_mm2(),
            r.energy_nj_per_pred() / opt.energy_nj_per_pred(),
        ));
    }
    out.push('\n');
    for r in reports {
        out.push_str(&format_breakdown(r));
        out.push('\n');
    }
    out
}

/// One row of Table I.
#[derive(Clone, Debug)]
pub struct SotaRow {
    pub label: &'static str,
    pub application: &'static str,
    pub kind: &'static str,
    pub tech_nm: u32,
    pub voltage_v: Option<f64>,
    pub freq_mhz: Option<f64>,
    pub hv_dim: Option<u32>,
    pub channels: u32,
    pub area_mm2: f64,
    pub latency: &'static str,
    pub energy_per_predict_nj: f64,
}

impl SotaRow {
    pub fn energy_per_channel_nj(&self) -> f64 {
        self.energy_per_predict_nj / self.channels as f64
    }
}

/// Literature rows of Table I ([10] Elhosary'19 SVM, [11] O'Leary'20
/// decision tree, [3] Menon'22 dense HDC) — published numbers, reproduced
/// verbatim from the paper's table.
pub fn literature_rows() -> Vec<SotaRow> {
    vec![
        SotaRow {
            label: "[10] SVM",
            application: "EEG seizure det.",
            kind: "SVM",
            tech_nm: 65,
            voltage_v: None,
            freq_mhz: Some(100.0),
            hv_dim: None,
            channels: 23,
            area_mm2: 0.09,
            latency: "160 ns",
            energy_per_predict_nj: 841.6,
        },
        SotaRow {
            label: "[11] DT",
            application: "iEEG brain state",
            kind: "Decision Tree",
            tech_nm: 65,
            voltage_v: Some(1.2),
            freq_mhz: None,
            hv_dim: None,
            channels: 8,
            area_mm2: 1.95,
            latency: "-",
            energy_per_predict_nj: 36.0,
        },
        SotaRow {
            label: "[3] dense HDC",
            application: "Emotion recog.",
            kind: "Dense HDC",
            tech_nm: 28,
            voltage_v: Some(0.8),
            freq_mhz: Some(0.909),
            hv_dim: Some(2000),
            channels: 214,
            area_mm2: 0.068,
            latency: "1 ms",
            energy_per_predict_nj: 39.1,
        },
    ]
}

/// Our measured row from the optimized design report.
pub fn ours_row(rep: &DesignReport) -> SotaRow {
    assert_eq!(rep.variant, Variant::Optimized);
    SotaRow {
        label: "Ours*",
        application: "iEEG seizure det.",
        kind: "Sparse HDC",
        tech_nm: 16,
        voltage_v: Some(rep.tech.vdd),
        freq_mhz: Some(rep.clock_mhz()),
        hv_dim: Some(crate::params::DIM as u32),
        channels: CHANNELS as u32,
        area_mm2: rep.area_mm2(),
        latency: "25.6 µs",
        energy_per_predict_nj: rep.energy_nj_per_pred(),
    }
}

/// Table I, formatted.
pub fn format_table1(rep: &DesignReport) -> String {
    let mut rows = vec![ours_row(rep)];
    rows.extend(literature_rows());
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<18} {:<14} {:>5} {:>6} {:>8} {:>6} {:>4} {:>9} {:>10} {:>10} {:>8}\n",
        "spec",
        "application",
        "type",
        "tech",
        "V",
        "f MHz",
        "D",
        "ch",
        "area mm²",
        "latency",
        "E/pred nJ",
        "E/ch nJ"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<14} {:<18} {:<14} {:>5} {:>6} {:>8} {:>6} {:>4} {:>9.3} {:>10} {:>10.1} {:>8.3}\n",
            r.label,
            r.application,
            r.kind,
            r.tech_nm,
            r.voltage_v.map(|v| format!("{v:.2}")).unwrap_or("-".into()),
            r.freq_mhz.map(|f| format!("{f}")).unwrap_or("-".into()),
            r.hv_dim.map(|d| d.to_string()).unwrap_or("-".into()),
            r.channels,
            r.area_mm2,
            r.latency,
            r.energy_per_predict_nj,
            r.energy_per_channel_nj(),
        ));
    }
    out.push_str("* synthesized-model results (gate-level cost model, see DESIGN.md)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::classifier::ClassifierConfig;
    use crate::hwmodel::designs::analyze_all;

    #[test]
    fn formatting_smoke() {
        let reports = analyze_all(&ClassifierConfig::default(), 1);
        let cmp = format_comparison(&reports);
        assert!(cmp.contains("sparse-optimized"));
        assert!(cmp.contains("dense-baseline"));
        let t1 = format_table1(&reports[3]);
        assert!(t1.contains("Ours*"));
        assert!(t1.contains("[10] SVM"));
        assert!(t1.contains("[3] dense HDC"));
    }

    #[test]
    fn ours_beats_sota_on_energy_per_predict() {
        // Table I claim: most energy-efficient per prediction.
        let reports = analyze_all(&ClassifierConfig::default(), 1);
        let ours = ours_row(&reports[3]);
        for r in literature_rows() {
            assert!(
                ours.energy_per_predict_nj < r.energy_per_predict_nj,
                "ours {} vs {} {}",
                ours.energy_per_predict_nj,
                r.label,
                r.energy_per_predict_nj
            );
        }
    }

    #[test]
    fn literature_rows_pin_paper_values() {
        let rows = literature_rows();
        assert_eq!(rows[0].energy_per_predict_nj, 841.6);
        assert_eq!(rows[1].area_mm2, 1.95);
        assert_eq!(rows[2].channels, 214);
        assert!((rows[2].energy_per_channel_nj() - 0.183).abs() < 0.01);
        assert!((rows[0].energy_per_channel_nj() - 36.59).abs() < 0.05);
    }

    #[test]
    fn bar_rendering() {
        assert_eq!(bar(0.0, 4), "····");
        assert_eq!(bar(1.0, 4), "████");
        assert_eq!(bar(0.5, 4), "██··");
    }
}
