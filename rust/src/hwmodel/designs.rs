//! The four design points and their full cost reports.

use crate::data::synth::{SynthConfig, SynthPatient};
use crate::hdc::classifier::{ClassifierConfig, Frame, Variant};
use crate::params::{CLOCK_HZ, FRAMES_PER_PREDICTION, PREDICT_LATENCY_S};
use crate::pipeline::record_frames;

use super::activity::{collect_activity, Activity};
use super::gates::{Tech, TSMC16};
use super::modules::{self, ModuleCost};

/// A complete area/energy report for one design point.
#[derive(Clone, Debug)]
pub struct DesignReport {
    pub variant: Variant,
    pub tech: Tech,
    pub modules: Vec<ModuleCost>,
    pub activity: Activity,
}

impl DesignReport {
    pub fn area_ge(&self) -> f64 {
        self.modules.iter().map(|m| m.area_ge).sum()
    }

    pub fn area_mm2(&self) -> f64 {
        self.area_ge() * self.tech.ge_area_um2 * 1e-6
    }

    /// Dynamic energy per prediction (nJ).
    pub fn dyn_nj_per_pred(&self) -> f64 {
        self.modules.iter().map(|m| m.dyn_fj_per_pred).sum::<f64>() * 1e-6
    }

    /// Leakage energy per prediction (nJ): leak power × 25.6 µs.
    pub fn leak_nj_per_pred(&self) -> f64 {
        self.area_ge() * self.tech.leak_nw_per_ge * 1e-9 * PREDICT_LATENCY_S * 1e9
    }

    /// Total energy per prediction (nJ) — the paper's "Energy per predict".
    pub fn energy_nj_per_pred(&self) -> f64 {
        self.dyn_nj_per_pred() + self.leak_nj_per_pred()
    }

    /// Average power at the paper's duty (one prediction per 256 cycles,
    /// µW).
    pub fn power_uw(&self) -> f64 {
        self.energy_nj_per_pred() * 1e-9 / PREDICT_LATENCY_S * 1e6
    }

    pub fn energy_per_channel_nj(&self) -> f64 {
        self.energy_nj_per_pred() / crate::params::CHANNELS as f64
    }

    pub fn latency_us(&self) -> f64 {
        PREDICT_LATENCY_S * 1e6
    }

    pub fn clock_mhz(&self) -> f64 {
        CLOCK_HZ / 1e6
    }

    /// Per-module (name, area share, energy share) with leakage folded
    /// into each module proportionally to its area.
    pub fn shares(&self) -> Vec<(&'static str, f64, f64)> {
        let total_area = self.area_ge();
        let leak_total_fj = self.leak_nj_per_pred() * 1e6;
        let total_energy_fj: f64 =
            self.modules.iter().map(|m| m.dyn_fj_per_pred).sum::<f64>() + leak_total_fj;
        self.modules
            .iter()
            .map(|m| {
                let module_leak = leak_total_fj * m.area_ge / total_area;
                (
                    m.name,
                    m.area_ge / total_area,
                    (m.dyn_fj_per_pred + module_leak) / total_energy_fj,
                )
            })
            .collect()
    }

    /// Energy (nJ, leakage included) of one module group by names.
    pub fn group_energy_nj(&self, names: &[&str]) -> f64 {
        let total = self.energy_nj_per_pred();
        self.shares()
            .iter()
            .filter(|(n, _, _)| names.contains(n))
            .map(|(_, _, e)| e * total)
            .sum()
    }

    pub fn group_area_mm2(&self, names: &[&str]) -> f64 {
        let total = self.area_mm2();
        self.shares()
            .iter()
            .filter(|(n, _, _)| names.contains(n))
            .map(|(_, a, _)| a * total)
            .sum()
    }
}

/// Analyze one design point under the given stimulus frames.
pub fn analyze(variant: Variant, cfg: &ClassifierConfig, frames: &[Frame]) -> DesignReport {
    let tech = TSMC16.clone();
    let act = collect_activity(variant, cfg, frames);
    let modules: Vec<ModuleCost> = match variant {
        Variant::SparseBaseline => vec![
            modules::im_baseline(&tech, &act),
            modules::onehot_decoder(&tech, &act),
            modules::binding_baseline(&tech, &act),
            modules::spatial_adder(&tech, &act),
            modules::temporal(&tech, &act),
            modules::am_sparse(&tech, &act),
        ],
        Variant::SparseCompIm => vec![
            modules::im_compressed(&tech, &act),
            modules::binding_compim(&tech, &act),
            modules::spatial_adder(&tech, &act),
            modules::temporal(&tech, &act),
            modules::am_sparse(&tech, &act),
        ],
        Variant::Optimized => vec![
            modules::im_compressed(&tech, &act),
            modules::binding_compim(&tech, &act),
            modules::spatial_or(&tech, &act),
            modules::temporal(&tech, &act),
            modules::am_sparse(&tech, &act),
        ],
        Variant::DenseBaseline => vec![
            modules::im_dense(&tech, &act),
            modules::binding_dense(&tech, &act),
            modules::spatial_dense(&tech, &act),
            modules::temporal_dense(&tech, &act),
            modules::am_dense(&tech, &act),
        ],
    };
    DesignReport {
        variant,
        tech,
        modules,
        activity: act,
    }
}

/// The paper's stimulus: "energy and area analysis were carried out on
/// seizure data from patient 11" (§IV). We use the synthetic patient 11's
/// seizure record.
pub fn patient11_stimulus(windows: usize) -> Vec<Frame> {
    let synth = SynthConfig {
        records_per_patient: 1,
        // Center the stimulus on the seizure: lead-in + ictal covering the
        // requested number of prediction windows.
        pre_s: 8.0,
        ictal_s: (windows as f64) * FRAMES_PER_PREDICTION as f64
            / crate::params::SAMPLE_RATE_HZ,
        post_s: 2.0,
        ..Default::default()
    };
    let patient = SynthPatient::generate(&synth, 11);
    let rec = &patient.records[0];
    let frames: Vec<Frame> = record_frames(rec).map(|(f, _)| f).collect();
    // Skip the interictal lead-in so the windows cover seizure activity,
    // keeping one pre-ictal window for realistic bus-toggle warm-up.
    let start = ((8.0 - 0.5) * crate::params::SAMPLE_RATE_HZ) as usize
        / FRAMES_PER_PREDICTION
        * FRAMES_PER_PREDICTION;
    frames[start..].to_vec()
}

/// Analyze every design point under the same stimulus. The four designs
/// are independent switching-activity simulations, so they shard over
/// the [`crate::evalpool`] (deterministic variant order preserved).
pub fn analyze_all(cfg_sparse_baseline: &ClassifierConfig, windows: usize) -> Vec<DesignReport> {
    let frames = patient11_stimulus(windows);
    // All designs are evaluated with spatial threshold 1, i.e. with the
    // function the paper shows to be equivalent across the design points
    // (§III-B: removing the thinning is lossless), so the Fig. 5 deltas
    // isolate *hardware* differences.
    let cfg = ClassifierConfig {
        spatial_threshold: 1,
        ..cfg_sparse_baseline.clone()
    };
    crate::evalpool::map(&Variant::ALL, |&variant| analyze(variant, &cfg, &frames))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reports() -> Vec<DesignReport> {
        analyze_all(&ClassifierConfig::default(), 2)
    }

    #[test]
    fn headline_ratios_have_paper_shape() {
        let r = reports();
        let dense = &r[0];
        let base = &r[1];
        let opt = &r[3];

        let e_ratio_base = base.energy_nj_per_pred() / opt.energy_nj_per_pred();
        let a_ratio_base = base.area_mm2() / opt.area_mm2();
        let e_ratio_dense = dense.energy_nj_per_pred() / opt.energy_nj_per_pred();
        let a_ratio_dense = dense.area_mm2() / opt.area_mm2();

        // Paper: 1.72× / 2.20× vs sparse baseline, 7.50× / 3.24× vs dense.
        // The reproduction must preserve the *shape*: optimized wins on
        // both axes against both baselines, with dense-energy the largest
        // gap.
        assert!(
            (1.4..2.1).contains(&e_ratio_base),
            "energy vs sparse baseline {e_ratio_base} (paper 1.72)"
        );
        assert!(
            (1.8..2.9).contains(&a_ratio_base),
            "area vs sparse baseline {a_ratio_base} (paper 2.20)"
        );
        assert!(
            (5.5..11.0).contains(&e_ratio_dense),
            "energy vs dense {e_ratio_dense} (paper 7.50)"
        );
        assert!(
            (2.4..4.8).contains(&a_ratio_dense),
            "area vs dense {a_ratio_dense} (paper 3.24)"
        );
        assert!(
            e_ratio_dense > e_ratio_base,
            "dense energy gap must exceed sparse-baseline gap"
        );
    }

    #[test]
    fn optimized_absolute_point_near_paper() {
        let r = reports();
        let opt = &r[3];
        let area = opt.area_mm2();
        let energy = opt.energy_nj_per_pred();
        // Paper: 0.059 mm², 12.5 nJ. Calibration tolerance: ±40%.
        assert!(
            (0.035..0.095).contains(&area),
            "optimized area {area} mm² too far from 0.059"
        );
        assert!(
            (7.0..20.0).contains(&energy),
            "optimized energy {energy} nJ too far from 12.5"
        );
    }

    #[test]
    fn baseline_breakdown_matches_fig1c_shape() {
        let r = reports();
        let base = &r[1];
        let shares = base.shares();
        let share = |name: &str| -> (f64, f64) {
            shares
                .iter()
                .find(|(n, _, _)| *n == name)
                .map(|(_, a, e)| (*a, *e))
                .unwrap()
        };
        let (a_bind, e_bind) = share("binding");
        let (a_dec, e_dec) = share("one-hot-decoder");
        let (a_spatial, _) = share("spatial-bundling");
        // Fig 1(c): binding + decoder ≈ 51.3% energy / 38% area; spatial
        // bundling ≈ 44.9% area. Accept generous bands.
        let bind_energy = e_bind + e_dec;
        let bind_area = a_bind + a_dec;
        assert!(
            (0.30..0.70).contains(&bind_energy),
            "binding+decoder energy share {bind_energy}"
        );
        assert!(
            (0.20..0.55).contains(&bind_area),
            "binding+decoder area share {bind_area}"
        );
        assert!(
            (0.25..0.60).contains(&a_spatial),
            "spatial bundling area share {a_spatial}"
        );
    }

    #[test]
    fn latency_is_25_6_us() {
        let r = reports();
        assert!((r[3].latency_us() - 25.6).abs() < 1e-9);
    }

    #[test]
    fn shares_sum_to_one() {
        for rep in reports() {
            let (a, e): (f64, f64) = rep
                .shares()
                .iter()
                .fold((0.0, 0.0), |(a, e), (_, sa, se)| (a + sa, e + se));
            assert!((a - 1.0).abs() < 1e-9, "{:?} area shares {a}", rep.variant);
            assert!((e - 1.0).abs() < 1e-9, "{:?} energy shares {e}", rep.variant);
        }
    }
}
