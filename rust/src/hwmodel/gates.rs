//! 16nm-class standard-cell primitives: gate-equivalent areas and
//! per-event energies.
//!
//! A *gate equivalent* (GE) is one NAND2. Absolute constants are
//! calibrated to land the optimized design at the paper's reported point
//! (0.059 mm², 12.5 nJ/prediction at 10 MHz / 0.75 V); all *relative*
//! results (Fig. 1(c) shares, Fig. 5 ratios) follow from structure and
//! measured switching activity, not from calibration.

/// Technology corner.
#[derive(Clone, Debug)]
pub struct Tech {
    pub name: &'static str,
    /// Area of one GE (NAND2) in µm², including placement overhead /
    /// utilisation (raw TSMC16 NAND2 ≈ 0.08 µm²; post-P&R effective
    /// density is lower).
    pub ge_area_um2: f64,
    /// Dynamic energy per gate-equivalent output toggle at VDD (fJ).
    pub e_gate_toggle_fj: f64,
    /// Dynamic energy per long-wire/bus bit toggle (fJ) — interconnect
    /// dominates for the 1024-bit HV buses.
    pub e_wire_toggle_fj: f64,
    /// Flip-flop: clock energy per cycle (fJ, clock tree included).
    pub e_ff_clock_fj: f64,
    /// Flip-flop: extra energy when the stored bit toggles (fJ).
    pub e_ff_toggle_fj: f64,
    /// ROM/LUT internal switching per *output-bit toggle* (fJ) — a LUT
    /// whose output does not change burns (almost) nothing, which is why
    /// slowly-changing LBP codes and sparse HVs are cheap.
    pub e_rom_toggle_fj: f64,
    /// Ungated clock-tree trunk energy per FF bit per cycle (fJ).
    pub e_clk_trunk_fj: f64,
    /// Leakage per GE (nW).
    pub leak_nw_per_ge: f64,
    pub vdd: f64,
}

/// TSMC16-class corner at 0.75 V (paper §IV / Table I).
///
/// Calibration note (DESIGN.md §2): the absolute per-event energies and
/// the effective GE area are fitted once so that the *optimized* design
/// lands on the paper's reported point (0.059 mm², 12.5 nJ/predict);
/// every other number in Fig. 1(c)/Fig. 5/Table I is then produced by
/// structure + measured switching activity with these same constants.
pub const TSMC16: Tech = Tech {
    name: "tsmc16-0.75V",
    ge_area_um2: 0.186,
    e_gate_toggle_fj: 0.8,
    e_wire_toggle_fj: 1.2,
    e_ff_clock_fj: 4.0,
    e_ff_toggle_fj: 1.1,
    e_rom_toggle_fj: 1.0,
    e_clk_trunk_fj: 2.5,
    leak_nw_per_ge: 0.02,
    vdd: 0.75,
};

// ---------------------------------------------------------------------
// Gate-equivalent counts of the datapath primitives (structural, with a
// light synthesis-sharing discount where trees share subterms).
// ---------------------------------------------------------------------

/// One ROM/LUT bit synthesized as random logic (sparse content lets the
/// tools minimise heavily — paper §II-A: "the IM can be heavily optimized
/// by the design tools").
pub const GE_ROM_BIT: f64 = 0.165;

/// 7-bit → 128 one-hot decoder (2-level predecode).
pub const GE_DEC_7_128: f64 = 212.0;

/// 128 one-hot → 7-bit binary encoder (7 shared 64-input OR planes).
pub const GE_ENC_128_7: f64 = 309.0;

/// 7-bit ripple adder (mod-128 wrap is free: drop the carry).
pub const GE_ADD7: f64 = 35.0;

/// Full adder / half adder / 2-input gates / mux / flip-flop.
pub const GE_FA: f64 = 5.0;
pub const GE_HA: f64 = 2.5;
pub const GE_OR2: f64 = 1.0;
pub const GE_AND2: f64 = 1.0;
pub const GE_XOR2: f64 = 2.5;
pub const GE_MUX2: f64 = 2.2;
pub const GE_FF: f64 = 4.5;

/// n-input OR tree (n-1 OR2s).
pub fn ge_or_tree(n: usize) -> f64 {
    (n.saturating_sub(1)) as f64 * GE_OR2
}

/// n-input AND tree.
pub fn ge_and_tree(n: usize) -> f64 {
    (n.saturating_sub(1)) as f64 * GE_AND2
}

/// Population-count adder tree over n 1-bit inputs (n-1 FA-equivalents,
/// standard compressor-tree sizing).
pub fn ge_popcount_tree(n: usize) -> f64 {
    (n.saturating_sub(1)) as f64 * GE_FA
}

/// b-bit magnitude comparator.
pub fn ge_comparator(bits: usize) -> f64 {
    bits as f64 * 2.0
}

/// b-bit incrementer (half-adder chain).
pub fn ge_incrementer(bits: usize) -> f64 {
    bits as f64 * GE_HA
}

/// b-bit register.
pub fn ge_register(bits: usize) -> f64 {
    bits as f64 * GE_FF
}

/// Depth of a balanced binary tree over n inputs (levels a toggle ripples
/// through — used by the activity→energy conversion).
pub fn tree_depth(n: usize) -> f64 {
    (n.max(2) as f64).log2().ceil()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_sizes() {
        assert_eq!(ge_or_tree(64), 63.0);
        assert_eq!(ge_popcount_tree(1024), 1023.0 * GE_FA);
        assert_eq!(ge_or_tree(1), 0.0);
    }

    #[test]
    fn depth_monotone() {
        assert_eq!(tree_depth(64), 6.0);
        assert_eq!(tree_depth(256), 8.0);
        assert!(tree_depth(1024) > tree_depth(64));
    }

    #[test]
    fn tech_constants_positive() {
        for v in [
            TSMC16.ge_area_um2,
            TSMC16.e_gate_toggle_fj,
            TSMC16.e_wire_toggle_fj,
            TSMC16.e_ff_clock_fj,
            TSMC16.e_rom_toggle_fj,
            TSMC16.e_clk_trunk_fj,
            TSMC16.leak_nw_per_ge,
        ] {
            assert!(v > 0.0);
        }
    }

    #[test]
    fn adder_tree_costs_more_than_or_tree() {
        // The §III-B area argument in one line.
        assert!(ge_popcount_tree(64) > 4.0 * ge_or_tree(64));
    }
}
