//! Gate-level hardware cost model — the synthesis-flow substitution
//! (DESIGN.md §2).
//!
//! The paper reports synthesis results (TSMC 16nm FinFET, Synopsys DC +
//! PrimeTime PX with switching annotations). We cannot run that flow, so
//! this module rebuilds its two ingredients in the Accelergy/CACTI
//! tradition:
//!
//! 1. **Structural area model** ([`gates`], [`modules`]): every datapath
//!    block of every design point is decomposed into standard-cell
//!    primitives (ROM bits, decoders, one-hot encoders, adders, barrel
//!    muxes, OR/adder trees, flip-flops) with 16nm-class gate-equivalent
//!    counts.
//! 2. **Switching-activity-annotated energy model** ([`activity`]): the
//!    bit-accurate simulators from [`crate::hdc`] run real (synthetic-
//!    patient) stimuli through each design and count actual bit toggles on
//!    every bus and tree; per-toggle energies then produce nJ/prediction.
//!    This preserves the paper's central mechanism — sparse HVs toggle ~2%
//!    of the bits dense HVs do — rather than assuming it.
//!
//! [`designs`] assembles the four design points and [`breakdown`] formats
//! the Fig. 1(c) / Fig. 5 / Table I reproductions.

pub mod gates;
pub mod activity;
pub mod modules;
pub mod designs;
pub mod breakdown;
