//! Costed datapath modules for every design point.
//!
//! Each function sizes one module structurally (GE counts from
//! [`super::gates`]) and converts the simulator's measured switching
//! activity into dynamic energy per prediction. Modules are named so that
//! the grouping of the paper's breakdowns can be reproduced:
//! Fig. 1(c)/Fig. 5 groups `one-hot-decoder` with `binding`.

use crate::hdc::compim::CompIm;
use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION, LBP_CODES, NUM_CLASSES, SEGMENTS};

use super::activity::Activity;
use super::gates::*;

/// Dense-HDC hardware dimensionality. The dense baseline follows [1]
/// (Burrello'18), which requires a larger D than segment-sparse HDC for
/// equal representational power; the comparable dense biosignal processor
/// [3] (Menon'22) uses D = 2000. We model the dense design at 2048 and
/// scale the per-element activity measured by the D=1024 simulator
/// linearly (per-bit statistics are dimension-independent).
pub const DENSE_DIM: usize = 2048;

/// One sized + energy-annotated module.
#[derive(Clone, Debug)]
pub struct ModuleCost {
    pub name: &'static str,
    pub area_ge: f64,
    /// Dynamic energy per prediction window (fJ).
    pub dyn_fj_per_pred: f64,
}

/// Average internal toggles per arriving `1` in a compressor (adder) tree
/// level, and the OR-tree equivalent (ORs saturate, so fewer nodes flip).
const W_FA: f64 = 0.125;
const W_OR: f64 = 0.08;
/// Barrel-shifter / decoder internal amplification per control-bit flip.
const W_SHIFT: f64 = 11.0;
/// Adder internal toggles per output-bit flip.
const W_ADD: f64 = 1.4;
/// ROM/LUT internal amplification per output-bit toggle.
const W_ROM: f64 = 1.0;
/// One-hot→binary OR-plane amplification per input-bit toggle.
const W_DEC: f64 = 4.0;

/// Cycles per prediction (for clock energy).
const CYCLES: f64 = FRAMES_PER_PREDICTION as f64;

// ---------------------------------------------------------------------
// Sparse designs
// ---------------------------------------------------------------------

/// Baseline sparse IM: per channel/segment a 64×7-bit position ROM *plus*
/// the 7→128 expansion producing the 1024-bit read port (paper Fig. 3(a):
/// the IM hands full HVs to the binder).
pub fn im_baseline(t: &Tech, act: &Activity) -> ModuleCost {
    let insts = (CHANNELS * SEGMENTS) as f64;
    // Synthesis maps the 6-bit code → 128-bit one-hot segment directly to
    // minimized random logic (~the same literal count as the position
    // ROM); no explicit decoder instance survives in the netlist.
    let area = insts * (64.0 * 7.0 * GE_ROM_BIT);
    let dyn_fj = act.per_prediction("im.out_toggles")
        * (W_ROM * t.e_rom_toggle_fj + t.e_wire_toggle_fj);
    ModuleCost {
        name: "item-memory",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Compressed IM (§III-A): the position ROM alone; the 56-bit read port
/// replaces the 1024-bit one.
pub fn im_compressed(t: &Tech, act: &Activity) -> ModuleCost {
    let insts = (CHANNELS * SEGMENTS) as f64;
    let area = insts * (64.0 * 7.0 * GE_ROM_BIT);
    let dyn_fj = act.per_prediction("im.out_toggles")
        * (W_ROM * t.e_rom_toggle_fj + t.e_wire_toggle_fj);
    ModuleCost {
        name: "comp-im",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// One-hot → binary decoder of the baseline binder (per channel/segment a
/// 128→7 encoder). Internal activity follows the 1024-bit input bus.
pub fn onehot_decoder(t: &Tech, act: &Activity) -> ModuleCost {
    let insts = (CHANNELS * SEGMENTS) as f64;
    let area = insts * GE_ENC_128_7;
    let dyn_fj = act.per_prediction("im.out_toggles") * W_DEC * t.e_gate_toggle_fj
        + act.per_prediction("dec.out_toggles") * t.e_wire_toggle_fj;
    ModuleCost {
        name: "one-hot-decoder",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Baseline binding: the segment barrel shifter (synthesis reduces the
/// constant-electrode rotate to position-add + 7→128 re-decode, which is
/// exactly how we size it).
pub fn binding_baseline(t: &Tech, act: &Activity) -> ModuleCost {
    let insts = (CHANNELS * SEGMENTS) as f64;
    let area = insts * (GE_ADD7 + GE_DEC_7_128);
    let dyn_fj = act.per_prediction("bind.internal_events") * W_SHIFT * t.e_gate_toggle_fj
        + act.per_prediction("bind.out_toggles") * t.e_wire_toggle_fj;
    ModuleCost {
        name: "binding",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Optimized binding (§III-A): eight 7-bit modular adders per channel plus
/// the single 7→128 decode feeding the bundling.
pub fn binding_compim(t: &Tech, act: &Activity) -> ModuleCost {
    let insts = (CHANNELS * SEGMENTS) as f64;
    let area = insts * (GE_ADD7 + GE_DEC_7_128);
    let dyn_fj = act.per_prediction("bind.add_toggles") * W_ADD * t.e_gate_toggle_fj
        + act.per_prediction("bind.out_toggles") * t.e_wire_toggle_fj;
    ModuleCost {
        name: "binding",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Baseline spatial bundling: a 64-input adder tree + thinning comparator
/// per HV element (§II-C).
pub fn spatial_adder(t: &Tech, act: &Activity) -> ModuleCost {
    let area = DIM as f64 * (ge_popcount_tree(CHANNELS) + ge_comparator(6));
    let dyn_fj = act.per_prediction("bind.out_toggles")
        * tree_depth(CHANNELS)
        * W_FA
        * t.e_gate_toggle_fj
        + act.per_prediction("spatial.out_toggles") * t.e_wire_toggle_fj;
    ModuleCost {
        name: "spatial-bundling",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Optimized spatial bundling: OR tree, no thinning (§III-B).
pub fn spatial_or(t: &Tech, act: &Activity) -> ModuleCost {
    let area = DIM as f64 * ge_or_tree(CHANNELS);
    let dyn_fj = act.per_prediction("bind.out_toggles")
        * tree_depth(CHANNELS)
        * W_OR
        * t.e_gate_toggle_fj
        + act.per_prediction("spatial.out_toggles") * t.e_wire_toggle_fj;
    ModuleCost {
        name: "spatial-bundling",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Temporal bundling: 1024 saturating 8-bit counters (the paper's
/// "large 8192-bit register"), incrementers and the thinning comparators.
pub fn temporal(t: &Tech, act: &Activity) -> ModuleCost {
    let ff_bits = (DIM * 8) as f64;
    let area = DIM as f64 * (ge_register(8) + ge_incrementer(8) + ge_comparator(8));
    let dyn_fj = act.per_prediction("temporal.clocked_bits") * t.e_ff_clock_fj
        + ff_bits * CYCLES * t.e_clk_trunk_fj
        + act.per_prediction("temporal.ff_bit_toggles") * t.e_ff_toggle_fj
        + act.per_prediction("query.out_toggles") * t.e_wire_toggle_fj;
    ModuleCost {
        name: "temporal-bundling",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Associative memory: class storage, AND plane, popcount tree, compare.
pub fn am_sparse(t: &Tech, act: &Activity) -> ModuleCost {
    let area = (NUM_CLASSES * DIM) as f64 * GE_FF
        + DIM as f64 * GE_AND2
        + ge_popcount_tree(DIM)
        + ge_comparator(11);
    let dyn_fj = (NUM_CLASSES * DIM) as f64 * CYCLES * t.e_clk_trunk_fj // gated class regs
        + act.per_prediction("am.compare_events") * tree_depth(DIM) * W_FA * t.e_gate_toggle_fj;
    ModuleCost {
        name: "assoc-memory",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

// ---------------------------------------------------------------------
// Dense design (per-element structures scale with DENSE_DIM)
// ---------------------------------------------------------------------

/// Dimension scaling from the D=1024 simulation to the dense hardware.
fn kd() -> f64 {
    DENSE_DIM as f64 / DIM as f64
}

pub fn im_dense(t: &Tech, act: &Activity) -> ModuleCost {
    // Code ROM (shared) + electrode ROM, both DENSE_DIM wide.
    let area = ((LBP_CODES + CHANNELS) * DENSE_DIM) as f64 * GE_ROM_BIT;
    let dyn_fj = act.per_prediction("im.out_toggles")
        * kd()
        * (W_ROM * t.e_rom_toggle_fj + t.e_wire_toggle_fj);
    ModuleCost {
        name: "item-memory",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

pub fn binding_dense(t: &Tech, act: &Activity) -> ModuleCost {
    let area = (CHANNELS * DENSE_DIM) as f64 * GE_XOR2;
    // XOR with the constant electrode HV synthesizes to wires/inverters;
    // only the bus toggle cost remains significant.
    let dyn_fj = act.per_prediction("bind.out_toggles")
        * kd()
        * (t.e_gate_toggle_fj + t.e_wire_toggle_fj);
    ModuleCost {
        name: "binding",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

pub fn spatial_dense(t: &Tech, act: &Activity) -> ModuleCost {
    let area = DENSE_DIM as f64 * (ge_popcount_tree(CHANNELS) + ge_comparator(6));
    let dyn_fj = act.per_prediction("bind.out_toggles")
        * kd()
        * tree_depth(CHANNELS)
        * W_FA
        * t.e_gate_toggle_fj
        + act.per_prediction("spatial.out_toggles") * kd() * t.e_wire_toggle_fj;
    ModuleCost {
        name: "spatial-bundling",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

pub fn temporal_dense(t: &Tech, act: &Activity) -> ModuleCost {
    let ff_bits = (DENSE_DIM * 8) as f64;
    let area = DENSE_DIM as f64 * (ge_register(8) + ge_incrementer(8) + ge_comparator(8));
    let dyn_fj = act.per_prediction("temporal.clocked_bits") * kd() * t.e_ff_clock_fj
        + ff_bits * CYCLES * t.e_clk_trunk_fj
        + act.per_prediction("temporal.ff_bit_toggles") * kd() * t.e_ff_toggle_fj
        + act.per_prediction("query.out_toggles") * kd() * t.e_wire_toggle_fj;
    ModuleCost {
        name: "temporal-bundling",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

pub fn am_dense(t: &Tech, act: &Activity) -> ModuleCost {
    let area = (NUM_CLASSES * DENSE_DIM) as f64 * GE_FF
        + DENSE_DIM as f64 * GE_XOR2
        + ge_popcount_tree(DENSE_DIM)
        + ge_comparator(12);
    let dyn_fj = (NUM_CLASSES * DENSE_DIM) as f64 * CYCLES * t.e_clk_trunk_fj
        + act.per_prediction("am.compare_events")
            * kd()
            * tree_depth(DENSE_DIM)
            * W_FA
            * t.e_gate_toggle_fj;
    ModuleCost {
        name: "assoc-memory",
        area_ge: area,
        dyn_fj_per_pred: dyn_fj,
    }
}

/// Sanity: the 56-bit CompIM entry the area model assumes matches the
/// functional model.
pub fn compim_entry_bits() -> usize {
    CompIm::ENTRY_BITS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::classifier::{ClassifierConfig, Variant};
    use crate::hwmodel::activity::collect_activity;
    use crate::rng::Xoshiro256;

    fn frames(n: usize) -> Vec<[u8; CHANNELS]> {
        let mut rng = Xoshiro256::new(1);
        (0..n)
            .map(|_| {
                let mut f = [0u8; CHANNELS];
                for c in f.iter_mut() {
                    *c = rng.next_below(LBP_CODES as u64) as u8;
                }
                f
            })
            .collect()
    }

    #[test]
    fn or_tree_smaller_than_adder_tree() {
        let fr = frames(FRAMES_PER_PREDICTION);
        let cfg = ClassifierConfig::optimized();
        let act = collect_activity(Variant::Optimized, &cfg, &fr);
        let or = spatial_or(&TSMC16, &act);
        let add = spatial_adder(&TSMC16, &act);
        assert!(add.area_ge / or.area_ge > 4.0, "paper §III-B area argument");
        assert!(add.dyn_fj_per_pred > or.dyn_fj_per_pred);
    }

    #[test]
    fn compim_smaller_than_baseline_im_plus_decoder() {
        let fr = frames(FRAMES_PER_PREDICTION);
        let base_act = collect_activity(
            Variant::SparseBaseline,
            &ClassifierConfig::default(),
            &fr,
        );
        let opt_act = collect_activity(Variant::Optimized, &ClassifierConfig::optimized(), &fr);
        let base = im_baseline(&TSMC16, &base_act).area_ge
            + onehot_decoder(&TSMC16, &base_act).area_ge
            + binding_baseline(&TSMC16, &base_act).area_ge;
        let opt = im_compressed(&TSMC16, &opt_act).area_ge + binding_compim(&TSMC16, &opt_act).area_ge;
        assert!(base / opt > 1.5, "CompIM area win: {base} vs {opt}");
    }

    #[test]
    fn all_modules_positive() {
        let fr = frames(FRAMES_PER_PREDICTION);
        let act = collect_activity(Variant::Optimized, &ClassifierConfig::optimized(), &fr);
        for m in [
            im_compressed(&TSMC16, &act),
            binding_compim(&TSMC16, &act),
            spatial_or(&TSMC16, &act),
            temporal(&TSMC16, &act),
            am_sparse(&TSMC16, &act),
        ] {
            assert!(m.area_ge > 0.0, "{}", m.name);
            assert!(m.dyn_fj_per_pred > 0.0, "{}", m.name);
        }
    }

    #[test]
    fn entry_bits_contract() {
        assert_eq!(compim_entry_bits(), 56);
    }
}
