//! Local binary pattern (LBP) preprocessing — the front-end of the
//! Burrello'18 pipeline the paper inherits (§II: "the electrode data is
//! preprocessed into 6-bit local binary pattern codes, which capture the
//! relation between consecutive values").
//!
//! For channel samples `x[t]`, the 6-bit code at time `t` is
//!
//! ```text
//! bit i = 1  iff  x[t - 5 + i] > x[t - 6 + i],   i = 0..5
//! ```
//!
//! i.e. the signs of the last six first-order differences, oldest
//! difference in the LSB. Until six differences are available the encoder
//! emits code 0 (hardware reset state).

use crate::params::{CHANNELS, LBP_BITS};

/// Streaming LBP encoder for a single channel.
#[derive(Clone, Debug)]
pub struct LbpChannel {
    last: Option<f32>,
    code: u8,
    diffs_seen: u32,
}

impl Default for LbpChannel {
    fn default() -> Self {
        Self::new()
    }
}

impl LbpChannel {
    pub fn new() -> Self {
        LbpChannel {
            last: None,
            code: 0,
            diffs_seen: 0,
        }
    }

    /// Push one sample, get the current 6-bit code.
    #[inline]
    pub fn push(&mut self, x: f32) -> u8 {
        if let Some(prev) = self.last {
            let up = (x > prev) as u8;
            // Shift the new difference sign into the MSB of the 6-bit code;
            // the oldest sign falls off the LSB side.
            self.code = (self.code >> 1) | (up << (LBP_BITS - 1));
            self.diffs_seen = self.diffs_seen.saturating_add(1);
        }
        self.last = Some(x);
        self.current()
    }

    /// Current code (0 during warm-up).
    #[inline]
    pub fn current(&self) -> u8 {
        if self.diffs_seen >= LBP_BITS as u32 {
            self.code
        } else {
            0
        }
    }

    /// Warm-up complete (six differences observed)?
    pub fn ready(&self) -> bool {
        self.diffs_seen >= LBP_BITS as u32
    }

    pub fn reset(&mut self) {
        *self = LbpChannel::new();
    }
}

/// Streaming LBP encoder for the full 64-channel array.
#[derive(Clone, Debug)]
pub struct LbpFrontend {
    channels: Vec<LbpChannel>,
}

impl Default for LbpFrontend {
    fn default() -> Self {
        Self::new()
    }
}

impl LbpFrontend {
    pub fn new() -> Self {
        LbpFrontend {
            channels: vec![LbpChannel::new(); CHANNELS],
        }
    }

    /// Push one multichannel sample, get the frame of codes.
    pub fn push(&mut self, samples: &[f32; CHANNELS]) -> [u8; CHANNELS] {
        let mut codes = [0u8; CHANNELS];
        for (c, (enc, &x)) in self.channels.iter_mut().zip(samples.iter()).enumerate() {
            codes[c] = enc.push(x);
        }
        codes
    }

    pub fn ready(&self) -> bool {
        self.channels.iter().all(|c| c.ready())
    }

    pub fn reset(&mut self) {
        for c in &mut self.channels {
            c.reset();
        }
    }
}

/// Batch helper: LBP codes for a whole single-channel signal.
pub fn lbp_codes(signal: &[f32]) -> Vec<u8> {
    let mut enc = LbpChannel::new();
    signal.iter().map(|&x| enc.push(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_rising_gives_all_ones() {
        let signal: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let codes = lbp_codes(&signal);
        // After warm-up (6 diffs = 7 samples) the code is 0b111111 = 63.
        assert_eq!(codes[19], 0b11_1111);
        assert!(codes[..6].iter().all(|&c| c == 0), "warm-up emits 0");
    }

    #[test]
    fn monotonic_falling_gives_zero() {
        let signal: Vec<f32> = (0..20).map(|i| -(i as f32)).collect();
        let codes = lbp_codes(&signal);
        assert_eq!(codes[19], 0);
    }

    #[test]
    fn alternating_signal_alternates_codes() {
        // x = +1, -1, +1, ... → diffs alternate down/up.
        let signal: Vec<f32> = (0..30).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let codes = lbp_codes(&signal);
        let a = codes[20];
        let b = codes[21];
        assert_ne!(a, b);
        assert_eq!(codes[22], a, "period-2 signal gives period-2 codes");
        // Exactly 3 ups in any window of 6 alternating diffs.
        assert_eq!(a.count_ones(), 3);
        assert_eq!(b.count_ones(), 3);
    }

    #[test]
    fn newest_diff_in_msb() {
        // Five falling samples then one rise → only the MSB set.
        let signal = [10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 6.0];
        let codes = lbp_codes(&signal);
        assert_eq!(*codes.last().unwrap(), 1 << (LBP_BITS - 1));
    }

    #[test]
    fn codes_fit_six_bits() {
        let signal: Vec<f32> = (0..200).map(|i| ((i * 37) % 17) as f32).collect();
        for c in lbp_codes(&signal) {
            assert!(c < 64);
        }
    }

    #[test]
    fn equal_samples_count_as_not_greater() {
        let signal = [1.0f32; 20];
        let codes = lbp_codes(&signal);
        assert_eq!(codes[19], 0);
    }

    #[test]
    fn frontend_matches_per_channel() {
        let mut fe = LbpFrontend::new();
        let mut per_channel: Vec<LbpChannel> = vec![LbpChannel::new(); CHANNELS];
        for t in 0..50 {
            let mut sample = [0f32; CHANNELS];
            for (c, s) in sample.iter_mut().enumerate() {
                *s = ((t * (c + 1)) % 7) as f32;
            }
            let frame = fe.push(&sample);
            for c in 0..CHANNELS {
                assert_eq!(frame[c], per_channel[c].push(sample[c]));
            }
        }
        assert!(fe.ready());
    }
}
