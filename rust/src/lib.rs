//! # sparse-hdc-ieeg
//!
//! Full-system reproduction of *"iEEG Seizure Detection with a Sparse
//! Hyperdimensional Computing Accelerator"* (Cuyckens et al., PRIME 2025).
//!
//! The crate is organised as the Layer-3 (Rust) half of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`hdc`] — bit-accurate golden-model simulators of the dense and sparse
//!   HDC classifiers (item memory, segmented-shift binding, bundling with and
//!   without thinning, temporal encoding, associative memory, one-shot
//!   training). These are the reference semantics every other layer
//!   (Pallas kernels, JAX graphs, the PJRT-loaded HLO executables and the
//!   hardware cost model) must agree with bit-exactly.
//! * [`lbp`] — the 6-bit local-binary-pattern front-end (Burrello'18).
//! * [`data`] — the synthetic iEEG substrate (patients, seizures,
//!   annotations), dataset containers and detection metrics.
//! * [`hwmodel`] — the gate-level area/energy cost model (16nm-class
//!   constants + switching-activity annotation from the simulators) that
//!   regenerates the paper's Fig. 1(c), Fig. 5 and Table I.
//! * [`runtime`] — the PJRT client wrapper that loads the AOT-compiled
//!   `artifacts/*.hlo.txt` produced by `python/compile/aot.py` and executes
//!   them on the request path.
//! * [`coordinator`] — the streaming serving layer: per-patient sessions,
//!   frame batching, routing, detector post-processing, metrics,
//!   backpressure, and the versioned model registry (hot-swappable
//!   [`hdc::model::ModelBundle`] artifacts, online retraining via
//!   [`hdc::online`]), and the wire server ([`coordinator::wire`]).
//! * [`transport`] — the wire layer beneath the coordinator: a versioned
//!   binary frame codec (same magic + length-prefix discipline as the
//!   model-bundle format), a [`transport::Transport`] trait with
//!   in-memory duplex and framed-TCP implementations, the streaming
//!   client, and the load generator behind `repro loadgen`.
//! * [`evalpool`] — the sharded evaluation pool: deterministic-order
//!   parallel map over (variant × density × patient) jobs, used by the
//!   sweep commands and the coordinator's session setup.
//! * [`bench`]-support ([`benchkit`]) and property-testing ([`testkit`])
//!   substrates, plus a dependency-free CLI parser ([`cli`]), config
//!   system ([`config`]) and error type ([`error`]) — the offline build
//!   environment has no criterion / proptest / clap / serde / anyhow, so
//!   these are built in-repo (see DESIGN.md §2).
//!
//! ## Feature matrix
//!
//! | feature   | default | effect                                          |
//! |-----------|---------|-------------------------------------------------|
//! | *(none)*  | yes     | everything above with the **native** window     |
//! |           |         | engine (golden model) on the serving path —     |
//! |           |         | no artifacts, no external crates                |
//! | `pjrt`    | no      | compiles [`runtime`]'s PJRT path (`Runtime`,    |
//! |           |         | `WindowEngine`) against the `xla` crate; needs  |
//! |           |         | `artifacts/` from `python/compile/aot.py`       |
//!
//! The default build is what the tier-1 verify exercises:
//! `cargo build --release && cargo test -q`.
//!
//! ## Quick start
//!
//! ```no_run
//! use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
//! use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
//! use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
//! use sparse_hdc_ieeg::pipeline;
//!
//! let patient = SynthPatient::generate(&SynthConfig::default(), 11);
//! let eval = pipeline::evaluate_patient(
//!     Variant::Optimized,
//!     &ClassifierConfig::optimized(),
//!     &patient,
//!     Some(0.25), // max HV density after thinning (Fig. 4 hyperparameter)
//!     AlarmPolicy::default(),
//! );
//! println!("detected {}/{}", eval.summary.detected, eval.summary.seizures);
//! ```

pub mod error;
pub mod params;
pub mod rng;
pub mod hdc;
pub mod lbp;
pub mod pipeline;
pub mod evalpool;
pub mod data;
pub mod hwmodel;
pub mod runtime;
pub mod transport;
pub mod coordinator;
pub mod cli;
pub mod config;
pub mod benchkit;
pub mod testkit;

pub use error::{Context, Error};

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
