//! `repro` — the leader binary of the sparse-HDC iEEG reproduction.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §4):
//!
//! * `gen-data`  — write synthetic patient datasets to disk
//! * `train`     — one-shot-train a patient, save a model bundle
//! * `detect`    — run a trained classifier over records
//! * `serve`     — start the streaming coordinator (end-to-end system)
//! * `model-info` — inspect a saved model bundle
//! * `fig1c`     — naive-sparse area/energy breakdown (paper Fig. 1(c))
//! * `fig4`      — delay/accuracy vs max-density sweep (paper Fig. 4)
//! * `fig5`      — four-design breakdown comparison (paper Fig. 5)
//! * `table1`    — SotA comparison (paper Table I)

use sparse_hdc_ieeg::bail;
use sparse_hdc_ieeg::cli::Args;

mod commands;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> sparse_hdc_ieeg::Result<()> {
    match args.subcommand.as_deref() {
        Some("gen-data") => commands::gen_data(args),
        Some("train") => commands::train(args),
        Some("detect") => commands::detect(args),
        Some("serve") => commands::serve(args),
        Some("model-info") => commands::model_info(args),
        Some("fig1c") => commands::fig1c(args),
        Some("fig4") => commands::fig4(args),
        Some("fig5") => commands::fig5(args),
        Some("table1") => commands::table1(args),
        Some("ablate-thinning") => commands::ablate_thinning(args),
        Some("bench-diff") => commands::bench_diff(args),
        Some("bench-speedup") => commands::bench_speedup(args),
        Some("dispatch") => commands::dispatch(args),
        Some("loadgen") => commands::loadgen(args),
        Some("loadgen-diff") => commands::loadgen_diff(args),
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        r#"repro — sparse-HDC iEEG seizure detection (PRIME'25 reproduction)

USAGE: repro <subcommand> [options]

data / model:
  gen-data  --out DIR [--patients N] [--records N] [--seed S]
  train     --data DIR --patient ID [--variant V] [--max-density D]
            [--save FILE] [--retrain-epochs N] [--out FILE] [--kernels SET]
  model-info <bundle.hdcm | models-dir>   inspect a bundle / list a store
  detect    --data DIR --patient ID [--variant V] [--max-density D]
  serve     --data DIR [--config FILE] [--patients LIST] [--model FILE]
            [--models-dir DIR] [--retrain-epochs N] [--retrain-fa-rate R]
            [--feedback-window N]  retrain from the last N labelled serving windows
            [--use-pjrt] [--realtime] [--batch N] [--chunk N]
            [--kernels SET]     pin the compute kernel set (scalar|avx2|neon|auto)
            [--listen ADDR]     serve framed TCP instead of in-process replay
            [--shard-of K/N]    declare this server shard K of an N-shard fleet
  serve     --status HOST:PORT  scrape a wire server's telemetry (FA rates,
            retrains, drift triggers, feedback depth, plane-cache stats)
  dispatch  --shards ADDR,ADDR[,...] [--listen ADDR] [--place "P=S,..."]
            [--lease-ms N] [--reap-ms N] [--wait-shards-s N] [--config FILE]
            fleet dispatcher: place patients across shards, lease + re-lease

paper experiments:
  fig1c     [--windows N]                 naive sparse breakdown (Fig. 1c)
  fig4      [--patients N] [--densities LIST] [--variant V]  (Fig. 4)
  fig5      [--windows N]                 design comparison (Fig. 5)
  table1    [--windows N]                 SotA comparison (Table I)
  ablate-thinning [--patients N] [--max-density D]   §III-B ablation

tooling:
  bench-diff <current.json> <baseline.json> [--threshold FRAC]
            compare two benchkit/v1 runs; fail on kernel/* median regressions
            (an empty/stub baseline is an error — promote a real run first)
  bench-speedup <run.json>... [--min-speedup X]
            within-run SIMD gate: best kernel/*/scalar vs /simd pair must
            show at least X speedup (default 2.0)
  loadgen   --addr HOST:PORT --data DIR [--patients LIST] [--sessions N]
            [--concurrency N] [--record K] [--chunk N] [--retries N]
            [--report FILE] [--allow-drops]
            [--hostile SPEC --seed N]  fault-inject every stream (spec items:
            dropout, stuck, drift, label-noise, jitter — comma-separated)
            replay concurrent wire sessions, report loadgen/v1
  loadgen-diff <current.json> <baseline.json> [--threshold FRAC]
            compare two loadgen/v1 reports (stub baseline = error)

kernel sets: scalar | avx2 | neon | auto   (also: HDC_KERNELS env,
            [runtime] kernels in the config file)

variants: dense-baseline | sparse-baseline | sparse-compim | sparse-optimized
"#
    );
}
