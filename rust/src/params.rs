//! Global architecture parameters of the iEEG sparse-HDC system.
//!
//! All values follow the paper (PRIME'25) and its dense-HDC ancestor
//! (Burrello et al., BioCAS'18). They are compile-time constants because
//! the hardware they model is fixed-function; the Python compile path
//! (`python/compile/hdc_params.py`) mirrors them and `make artifacts`
//! bakes them into the HLO artifacts.

/// Hypervector dimensionality `D`.
pub const DIM: usize = 1024;

/// Number of segments for the segmented-shift binding.
pub const SEGMENTS: usize = 8;

/// Length of one segment (`DIM / SEGMENTS`); each sparse HV carries exactly
/// one 1-bit per segment, so the base density is `SEGMENTS / DIM ≈ 0.78%`.
pub const SEG_LEN: usize = DIM / SEGMENTS;

/// Bits needed to encode a position inside a segment (log2(SEG_LEN)).
pub const SEG_POS_BITS: usize = 7;

/// Number of iEEG electrodes / input channels.
pub const CHANNELS: usize = 64;

/// Local-binary-pattern code width (bits) and alphabet size.
pub const LBP_BITS: usize = 6;
pub const LBP_CODES: usize = 1 << LBP_BITS;

/// Frames (clock cycles / samples) accumulated by the temporal encoder per
/// prediction — the paper's "time frame".
pub const FRAMES_PER_PREDICTION: usize = 256;

/// iEEG sampling rate (SWEC-ETHZ short-term dataset rate).
pub const SAMPLE_RATE_HZ: f64 = 512.0;

/// Seconds covered by one prediction window.
pub const PREDICTION_PERIOD_S: f64 = FRAMES_PER_PREDICTION as f64 / SAMPLE_RATE_HZ;

/// Accelerator clock (paper §IV-B).
pub const CLOCK_HZ: f64 = 10.0e6;

/// Latency of one prediction at `CLOCK_HZ` (256 cycles = 25.6 µs).
pub const PREDICT_LATENCY_S: f64 = FRAMES_PER_PREDICTION as f64 / CLOCK_HZ;

/// Paper's temporal-thinning threshold keeping max density in 20–30%.
pub const TEMPORAL_THRESHOLD_DEFAULT: u16 = 130;

/// Width of the temporal accumulator counters (8-bit in hardware; counts
/// saturate at 255).
pub const TEMPORAL_COUNTER_BITS: usize = 8;
pub const TEMPORAL_COUNTER_MAX: u16 = (1 << TEMPORAL_COUNTER_BITS) - 1;

/// Number of classes in the associative memory (interictal / ictal).
pub const NUM_CLASSES: usize = 2;
pub const CLASS_INTERICTAL: usize = 0;
pub const CLASS_ICTAL: usize = 1;

/// Default RNG seed for item-memory generation. Shared with the Python
/// compile path so every layer generates identical item memories.
pub const IM_SEED: u64 = 0x5EED_1EE6_0000_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_constants_consistent() {
        assert_eq!(SEG_LEN, 128);
        assert_eq!(1 << SEG_POS_BITS, SEG_LEN);
        assert_eq!(LBP_CODES, 64);
        assert!((PREDICTION_PERIOD_S - 0.5).abs() < 1e-12);
        assert!((PREDICT_LATENCY_S - 25.6e-6).abs() < 1e-12);
    }
}
