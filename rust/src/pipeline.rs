//! End-to-end algorithmic pipeline: records → LBP frames → encoder →
//! training / evaluation. This is the offline counterpart of the
//! [`crate::coordinator`] streaming path and the engine behind the Fig. 4
//! reproduction (`repro fig4`).

use crate::data::metrics::{evaluate_record, AlarmPolicy, EvalSummary, WindowPrediction};
use crate::data::synth::{Record, SynthPatient};
use crate::hdc::classifier::{
    Classifier, ClassifierConfig, Encoder, Frame, SparseEncoder, Variant,
};
use crate::hdc::hv::Hv;
use crate::hdc::model::{ModelBundle, Provenance};
use crate::hdc::online::{OnlineConfig, OnlineReport, OnlineTrainer};
use crate::hdc::train::{label_windows, train_from_frames, Trainer};
use crate::lbp::LbpFrontend;

/// Grace period after the annotated offset during which an alarm still
/// counts as a detection (s).
pub const DETECT_GRACE_S: f64 = 10.0;

/// Stream a record as labelled LBP frames.
///
/// Returns a lazy iterator — one frame is produced per pull and nothing
/// is materialized, so the tuning / training / evaluation / density
/// passes each cost one LBP state machine instead of a full-record
/// `Vec<(Frame, bool)>` per pass.
pub fn record_frames(record: &Record) -> impl Iterator<Item = (Frame, bool)> + '_ {
    let mut fe = LbpFrontend::new();
    (0..record.num_samples()).map(move |t| (fe.push(&record.sample_array(t)), record.is_ictal(t)))
}

/// One-shot training on a record (the patient's first seizure), yielding
/// a version-1 [`ModelBundle`] — the persistent artifact the serving
/// layers, the CLI (`repro train --save`) and the registry consume.
pub fn train_on_record(
    encoder: &mut dyn Encoder,
    record: &Record,
    cfg: &ClassifierConfig,
) -> ModelBundle {
    train_from_frames(encoder, record_frames(record), cfg)
}

/// Window queries per [`Classifier::search_batch`] flush in
/// [`run_on_record`] — bounds the query buffer while still amortising
/// the AM hold across many windows.
const SEARCH_MICRO_BATCH: usize = 64;

/// Run a trained classifier over a record, collecting one prediction per
/// window. Same streaming pass as every other consumer of
/// [`record_frames`], but windows are scored in micro-batches through
/// [`Classifier::search_batch`] (bit-exact with per-window search).
pub fn run_on_record(clf: &mut Classifier, record: &Record) -> Vec<WindowPrediction> {
    fn flush(clf: &Classifier, queries: &mut Vec<Hv>, preds: &mut Vec<WindowPrediction>) {
        let base = preds.len();
        for (k, r) in clf.search_batch(queries).into_iter().enumerate() {
            preds.push(WindowPrediction {
                idx: base + k,
                is_ictal: r.is_ictal(),
                margin: r.margin(),
            });
        }
        queries.clear();
    }

    clf.reset();
    let mut preds = Vec::new();
    let mut queries = Vec::with_capacity(SEARCH_MICRO_BATCH);
    for (codes, _) in record_frames(record) {
        if let Some(q) = clf.encoder.push_frame(&codes) {
            queries.push(q);
            if queries.len() == SEARCH_MICRO_BATCH {
                flush(clf, &mut queries, &mut preds);
            }
        }
    }
    flush(clf, &mut queries, &mut preds);
    preds
}

/// Derive the temporal threshold realising a *maximum HV density after
/// thinning* hyperparameter (Fig. 4's x-axis): feed the training record
/// through the encoder and take, over its windows, the largest
/// per-window minimal threshold — the smallest hardware threshold that
/// keeps every training window at or below `max_density`.
pub fn tune_temporal_threshold(
    variant: Variant,
    cfg: &ClassifierConfig,
    record: &Record,
    max_density: f64,
) -> u16 {
    tune_temporal_thresholds(variant, cfg, record, &[max_density])[0]
}

/// Single-pass multi-density tuning: one encode of the training record
/// yields the threshold for *every* candidate density. Each window's
/// accumulator is histogrammed once
/// ([`crate::hdc::temporal::count_histogram`]) and all densities'
/// per-window minimal thresholds are derived from that histogram —
/// bit-exact with calling [`tune_temporal_threshold`] once per density,
/// at one encode pass instead of D (the `repro fig4` sweep shape).
pub fn tune_temporal_thresholds(
    variant: Variant,
    cfg: &ClassifierConfig,
    record: &Record,
    max_densities: &[f64],
) -> Vec<u16> {
    assert!(variant.is_sparse(), "density tuning applies to sparse HDC");
    let mut enc = SparseEncoder::new(variant, cfg.clone());
    let mut best = vec![1u16; max_densities.len()];
    let mut inspect = |acc: &crate::hdc::temporal::TemporalAccumulator| {
        let hist = crate::hdc::temporal::count_histogram(&acc.counts());
        for (b, &d) in best.iter_mut().zip(max_densities) {
            *b = (*b).max(crate::hdc::temporal::threshold_for_max_density_hist(&hist, d));
        }
    };
    for (codes, _) in record_frames(record) {
        enc.push_frame_inspect(&codes, &mut inspect);
    }
    best
}

/// Outcome of the one-shot protocol on one patient.
#[derive(Clone, Debug)]
pub struct PatientEval {
    pub patient_id: u32,
    pub summary: EvalSummary,
    /// The temporal threshold actually deployed.
    pub temporal_threshold: u16,
    /// Mean query density observed on the test records (diagnostic; the
    /// paper's 20–30% band at threshold 130).
    pub mean_query_density: f64,
}

/// Run the full one-shot protocol for one patient and one design point:
/// optionally tune the temporal threshold for a max-density target, train
/// on record 0, evaluate on records 1.. .
pub fn evaluate_patient(
    variant: Variant,
    base_cfg: &ClassifierConfig,
    patient: &SynthPatient,
    max_density: Option<f64>,
    policy: AlarmPolicy,
) -> PatientEval {
    let mut cfg = base_cfg.clone();
    if let (Some(d), true) = (max_density, variant.is_sparse()) {
        cfg.temporal_threshold = tune_temporal_threshold(variant, &cfg, patient.train_record(), d);
    }

    // Train.
    let mut encoder = crate::hdc::classifier::make_encoder(variant, cfg.clone());
    let bundle = train_on_record(encoder.as_mut(), patient.train_record(), &cfg);
    let mut clf = Classifier::from_encoder(encoder, bundle.am);

    // Evaluate.
    let mut summary = EvalSummary::default();
    for rec in patient.test_records() {
        let preds = run_on_record(&mut clf, rec);
        let outcome = evaluate_record(rec, &preds, policy, DETECT_GRACE_S);
        summary.add(&outcome);
    }
    // Query-density diagnostic on the first test record (cheap extra pass).
    let mean_query_density = if let Some(rec) = patient.test_records().first() {
        measure_query_density(variant, &cfg, rec)
    } else {
        f64::NAN
    };

    PatientEval {
        patient_id: patient.profile.id,
        summary,
        temporal_threshold: cfg.temporal_threshold,
        mean_query_density,
    }
}

/// Mean query-HV density over a record for a given configuration.
pub fn measure_query_density(variant: Variant, cfg: &ClassifierConfig, record: &Record) -> f64 {
    let mut enc = crate::hdc::classifier::make_encoder(variant, cfg.clone());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (codes, _) in record_frames(record) {
        if let Some(q) = enc.push_frame(&codes) {
            acc += q.density();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        acc / n as f64
    }
}

/// Train with an explicit trainer (exposed for tests that need the
/// intermediate planes). Window labelling is
/// [`label_windows`](crate::hdc::train::label_windows) — the same rule
/// as every other training path.
pub fn trainer_for_record(
    encoder: &mut dyn Encoder,
    record: &Record,
    train_density: f64,
) -> Trainer {
    let mut trainer = Trainer::new(train_density);
    label_windows(encoder, record_frames(record), |q, ictal| {
        trainer.add_window(&q, ictal)
    });
    trainer
}

/// Encode a record into an [`OnlineTrainer`]: the same streaming pass and
/// majority window-labelling as one-shot training
/// ([`label_windows`](crate::hdc::train::label_windows)), but the
/// labelled window queries are retained for the retraining epochs.
pub fn online_trainer_for_record(
    variant: Variant,
    cfg: &ClassifierConfig,
    record: &Record,
) -> OnlineTrainer {
    let mut encoder = SparseEncoder::new(variant, cfg.clone());
    let mut trainer = OnlineTrainer::new(variant, cfg.train_density);
    label_windows(&mut encoder, record_frames(record), |q, ictal| {
        trainer.absorb(q, ictal)
    });
    trainer
}

/// Knobs of a bundle-level retrain ([`retrain_bundle`]).
#[derive(Clone, Debug)]
pub struct RetrainOptions {
    /// Upper bound on retraining epochs.
    pub max_epochs: usize,
    /// Full Pale-style update (add to correct, subtract from wrong).
    pub subtract: bool,
    /// Re-tune the temporal threshold for this max query density before
    /// encoding (the Fig. 4 hyperparameter, derived through the
    /// [`crate::hdc::temporal::count_histogram`] path). `None` keeps the
    /// bundle's threshold.
    pub max_density: Option<f64>,
}

impl Default for RetrainOptions {
    fn default() -> Self {
        RetrainOptions {
            max_epochs: 8,
            subtract: true,
            max_density: None,
        }
    }
}

/// Derive the next version of a model bundle by iterative online
/// retraining on `record` (typically the same training seizure, or a
/// newly annotated one). The input bundle is untouched — the result
/// carries `version + 1` and lineage provenance, ready for
/// [`crate::coordinator::registry::ModelRegistry::publish`]; in-flight
/// inference on the old version is unaffected.
///
/// When the bundle carries format-2 counter planes *and* the effective
/// encoder config is unchanged (no threshold re-tune), the retrain
/// **resumes incrementally** from the persisted planes
/// ([`OnlineTrainer::from_counters`]): the record supplies only the
/// labelled window queries for the epoch loop. For a one-shot bundle
/// this is bit-identical to re-seeding from the record (pinned by
/// `tests/retrain_scheduler.rs`); for a bundle that already went through
/// epochs it continues from the post-epoch planes instead of discarding
/// them. A re-tuned threshold changes the encoding, which invalidates the
/// stored planes — that path falls back to from-record seeding.
pub fn retrain_bundle(
    bundle: &ModelBundle,
    record: &Record,
    opts: &RetrainOptions,
) -> (ModelBundle, OnlineReport) {
    let mut cfg = bundle.config.clone();
    if let Some(d) = opts.max_density {
        cfg.temporal_threshold = tune_temporal_threshold(bundle.variant, &cfg, record, d);
    }
    let (mut trainer, incremental) = match &bundle.counters {
        Some(planes) if cfg == bundle.config && bundle.variant.is_sparse() => {
            let mut trainer =
                OnlineTrainer::from_counters(bundle.variant, cfg.train_density, planes);
            let mut encoder = SparseEncoder::new(bundle.variant, cfg.clone());
            label_windows(&mut encoder, record_frames(record), |q, ictal| {
                trainer.attach(q, ictal)
            });
            (trainer, true)
        }
        _ => (online_trainer_for_record(bundle.variant, &cfg, record), false),
    };
    let (am, report) = trainer.run(&OnlineConfig {
        max_epochs: opts.max_epochs,
        subtract: opts.subtract,
    });
    let windows = trainer.windows_per_class();
    let counters = Some(trainer.counters());
    let next = ModelBundle {
        version: bundle.next_version(),
        variant: bundle.variant,
        config: cfg,
        am,
        provenance: Provenance {
            patient_id: bundle.provenance.patient_id,
            epochs: report.epochs.len() as u32,
            parent_version: bundle.version,
            train_windows: [windows[0] as u64, windows[1] as u64],
            note: format!(
                "online retrain ({}): training-window errors {} -> {} over {} epoch(s)",
                if incremental { "resumed from counter planes" } else { "seeded from record" },
                report.initial_errors,
                report.best_errors,
                report.epochs.len()
            ),
        },
        counters,
    };
    (next, report)
}

/// Like [`retrain_bundle`], but the training material is a set of
/// labelled *serving* windows captured by the feedback loop
/// ([`crate::coordinator::session::Session`]'s bounded retention ring)
/// instead of a retained record: each entry is one prediction window's
/// frame-major LBP codes (`FRAMES_PER_PREDICTION * CHANNELS` bytes) plus
/// its ground-truth label. Each window is encoded independently — encoder
/// state reset at the window boundary, exactly how the serving engine
/// scores it — so the retrain optimises the same queries the model is
/// judged on. Counter-plane resumption applies as in [`retrain_bundle`];
/// `opts.max_density` is ignored (a threshold re-tune needs a raw record,
/// and re-tuning would invalidate the stored codes anyway).
pub fn retrain_bundle_from_windows(
    bundle: &ModelBundle,
    windows: &[(Vec<u8>, bool)],
    opts: &RetrainOptions,
) -> (ModelBundle, OnlineReport) {
    let cfg = bundle.config.clone();
    let mut encoder = SparseEncoder::new(bundle.variant, cfg.clone());
    let mut queries: Vec<(Hv, bool)> = Vec::with_capacity(windows.len());
    for (codes, ictal) in windows {
        encoder.reset();
        let mut query = None;
        for chunk in codes.chunks_exact(crate::params::CHANNELS) {
            let mut frame: Frame = [0u8; crate::params::CHANNELS];
            frame.copy_from_slice(chunk);
            query = encoder.push_frame(&frame).or(query);
        }
        if let Some(q) = query {
            queries.push((q, *ictal));
        }
    }
    let (mut trainer, incremental) = match &bundle.counters {
        Some(planes) if bundle.variant.is_sparse() => {
            let mut trainer =
                OnlineTrainer::from_counters(bundle.variant, cfg.train_density, planes);
            for (q, ictal) in queries {
                trainer.attach(q, ictal);
            }
            (trainer, true)
        }
        _ => {
            let mut trainer = OnlineTrainer::new(bundle.variant, cfg.train_density);
            for (q, ictal) in queries {
                trainer.absorb(q, ictal);
            }
            (trainer, false)
        }
    };
    let (am, report) = trainer.run(&OnlineConfig {
        max_epochs: opts.max_epochs,
        subtract: opts.subtract,
    });
    let per_class = trainer.windows_per_class();
    let counters = Some(trainer.counters());
    let next = ModelBundle {
        version: bundle.next_version(),
        variant: bundle.variant,
        config: cfg,
        am,
        provenance: Provenance {
            patient_id: bundle.provenance.patient_id,
            epochs: report.epochs.len() as u32,
            parent_version: bundle.version,
            train_windows: [per_class[0] as u64, per_class[1] as u64],
            note: format!(
                "feedback retrain ({}) on {} serving window(s): \
                 training-window errors {} -> {} over {} epoch(s)",
                if incremental { "resumed from counter planes" } else { "seeded from scratch" },
                windows.len(),
                report.initial_errors,
                report.best_errors,
                report.epochs.len()
            ),
        },
        counters,
    };
    (next, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn test_patient() -> SynthPatient {
        let cfg = SynthConfig {
            records_per_patient: 3,
            pre_s: 12.0,
            ictal_s: 8.0,
            post_s: 4.0,
            ..Default::default()
        };
        SynthPatient::generate(&cfg, 11)
    }

    #[test]
    fn one_shot_detects_on_synthetic_patient() {
        let patient = test_patient();
        let eval = evaluate_patient(
            Variant::Optimized,
            &ClassifierConfig::optimized(),
            &patient,
            None,
            AlarmPolicy::default(),
        );
        assert_eq!(eval.summary.seizures, 2);
        assert!(
            eval.summary.detection_accuracy() > 0.4,
            "detected {}/{} seizures",
            eval.summary.detected,
            eval.summary.seizures
        );
        if eval.summary.detected > 0 {
            let d = eval.summary.mean_delay_s();
            assert!(d >= 0.0 && d < 20.0, "delay {d}");
        }
    }

    #[test]
    fn dense_baseline_also_detects() {
        let patient = test_patient();
        let eval = evaluate_patient(
            Variant::DenseBaseline,
            &ClassifierConfig::default(),
            &patient,
            None,
            AlarmPolicy::default(),
        );
        assert!(eval.summary.detection_accuracy() > 0.4);
    }

    #[test]
    fn tuned_threshold_caps_density() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        for max_d in [0.1, 0.3] {
            let t =
                tune_temporal_threshold(Variant::Optimized, &cfg, patient.train_record(), max_d);
            let mut tuned = cfg.clone();
            tuned.temporal_threshold = t;
            let d = measure_query_density(Variant::Optimized, &tuned, patient.train_record());
            assert!(
                d <= max_d + 0.02,
                "max_d {max_d}: measured {d} at threshold {t}"
            );
        }
    }

    #[test]
    fn lower_max_density_needs_higher_threshold() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        let t_low = tune_temporal_threshold(Variant::Optimized, &cfg, patient.train_record(), 0.05);
        let t_high = tune_temporal_threshold(Variant::Optimized, &cfg, patient.train_record(), 0.4);
        assert!(t_low >= t_high, "t(0.05)={t_low} vs t(0.4)={t_high}");
    }

    #[test]
    fn single_pass_tuning_matches_per_density_passes() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        let densities = [0.05, 0.15, 0.25, 0.4, 0.5];
        let one_pass =
            tune_temporal_thresholds(Variant::Optimized, &cfg, patient.train_record(), &densities);
        for (&d, &t) in densities.iter().zip(&one_pass) {
            assert_eq!(
                t,
                tune_temporal_threshold(Variant::Optimized, &cfg, patient.train_record(), d),
                "density {d}"
            );
        }
    }

    #[test]
    fn retrain_bundle_bumps_version_and_never_degrades() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        let mut enc = crate::hdc::classifier::make_encoder(Variant::Optimized, cfg.clone());
        let bundle = train_on_record(enc.as_mut(), patient.train_record(), &cfg);
        assert_eq!(bundle.version, 1);

        let (next, report) = retrain_bundle(&bundle, patient.train_record(), &Default::default());
        assert_eq!(next.version, 2);
        assert_eq!(next.provenance.parent_version, 1);
        assert_eq!(next.variant, bundle.variant);
        assert!(report.best_errors <= report.initial_errors);

        // The retrained AM's training-window error really is what the
        // report claims (and therefore <= one-shot's), measured with a
        // fresh encode pass.
        let trainer =
            online_trainer_for_record(Variant::Optimized, &cfg, patient.train_record());
        assert_eq!(trainer.errors(&next.am), report.best_errors);
        assert_eq!(trainer.errors(&bundle.am), report.initial_errors);
    }

    #[test]
    fn retrain_from_windows_matches_window_semantics_and_bumps_version() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        let mut enc = crate::hdc::classifier::make_encoder(Variant::Optimized, cfg.clone());
        let bundle = train_on_record(enc.as_mut(), patient.train_record(), &cfg);

        // Slice the record into the same frame-major per-window code
        // buffers a serving session retains, with majority labels —
        // the feedback ring's exact shape.
        let frames: Vec<(Frame, bool)> = record_frames(patient.train_record()).collect();
        let per_window = crate::params::FRAMES_PER_PREDICTION;
        let windows: Vec<(Vec<u8>, bool)> = frames
            .chunks_exact(per_window)
            .map(|w| {
                let codes: Vec<u8> = w.iter().flat_map(|(f, _)| f.iter().copied()).collect();
                let ictal = w.iter().filter(|(_, i)| *i).count() * 2 > per_window;
                (codes, ictal)
            })
            .collect();
        assert!(!windows.is_empty());

        let (next, report) = retrain_bundle_from_windows(&bundle, &windows, &Default::default());
        assert_eq!(next.version, 2);
        assert_eq!(next.provenance.parent_version, 1);
        assert!(report.best_errors <= report.initial_errors);
        assert!(next.counters.is_some(), "feedback retrain persists planes");
        // Incremental resume attaches the feedback windows to the epoch
        // loop without re-counting them into the planes, so the window
        // census carries over from the base bundle unchanged.
        assert_eq!(next.provenance.train_windows, bundle.provenance.train_windows);
        assert!(next.provenance.note.contains("feedback retrain"), "{}", next.provenance.note);
    }

    #[test]
    fn retrain_can_re_tune_the_temporal_threshold() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        let mut enc = crate::hdc::classifier::make_encoder(Variant::Optimized, cfg.clone());
        let bundle = train_on_record(enc.as_mut(), patient.train_record(), &cfg);
        let opts = RetrainOptions {
            max_density: Some(0.05),
            ..Default::default()
        };
        let (next, _) = retrain_bundle(&bundle, patient.train_record(), &opts);
        let expect =
            tune_temporal_threshold(Variant::Optimized, &cfg, patient.train_record(), 0.05);
        assert_eq!(next.config.temporal_threshold, expect);
    }

    #[test]
    fn predictions_cover_record() {
        let patient = test_patient();
        let cfg = ClassifierConfig::optimized();
        let mut enc = crate::hdc::classifier::make_encoder(Variant::Optimized, cfg.clone());
        let bundle = train_on_record(enc.as_mut(), patient.train_record(), &cfg);
        let mut clf = Classifier::from_encoder(enc, bundle.am);
        let rec = &patient.records[1];
        let preds = run_on_record(&mut clf, rec);
        let expected = rec.num_samples() / crate::params::FRAMES_PER_PREDICTION;
        assert_eq!(preds.len(), expected);
        // indices contiguous from 0
        for (i, p) in preds.iter().enumerate() {
            assert_eq!(p.idx, i);
        }
    }
}
