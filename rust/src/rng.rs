//! Deterministic pseudo-random number generation shared across layers.
//!
//! The item memory of an HDC accelerator is "randomly generated at design
//! time" (paper §II-A); for the reproduction every layer — the Rust golden
//! model, the Pallas kernels / JAX graphs, and the HLO artifacts executed
//! through PJRT — must generate *the same* item memory. We therefore pin an
//! exact, trivially portable algorithm: **SplitMix64** (Steele et al. 2014),
//! with domain separation by chained remixing. `python/compile/hdc_params.py`
//! reimplements these few lines on top of `numpy.uint64`.
//!
//! `Xoshiro256**` (seeded via SplitMix64) is used for bulk data generation
//! (synthetic iEEG, test inputs) where cross-language bit-equality is not
//! required but determinism is.

/// The SplitMix64 finalizer: a strong 64-bit mixing function.
#[inline]
pub fn splitmix64_mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Domain-separated chained hash: `mix(mix(mix(seed) ^ a) ^ b) ...`.
///
/// Chaining (rather than XOR-combining) the words avoids structured
/// collisions between index tuples such as `(2, 0)` and `(0, 2)`.
#[inline]
pub fn hash_chain(seed: u64, words: &[u64]) -> u64 {
    let mut h = splitmix64_mix(seed);
    for &w in words {
        h = splitmix64_mix(h ^ w);
    }
    h
}

/// A SplitMix64 sequence generator (stateful).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast bulk PRNG for synthetic data and tests.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; synthetic-data generation is not on the hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference values from the public SplitMix64 reference stream for
        // seed 1234567 (first three outputs).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        let c = sm.next_u64();
        assert_ne!(a, b);
        assert_ne!(b, c);
        // Determinism: same seed, same stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
        assert_eq!(c, sm2.next_u64());
    }

    #[test]
    fn mix_known_value() {
        // Pin the exact mixing function so the Python mirror can assert the
        // same vector (see python/tests/test_params.py).
        assert_eq!(splitmix64_mix(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64_mix(1), 0x910A_2DEC_8902_5CC1);
    }

    #[test]
    fn hash_chain_order_sensitive() {
        let h1 = hash_chain(42, &[2, 0]);
        let h2 = hash_chain(42, &[0, 2]);
        assert_ne!(h1, h2);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Xoshiro256::new(7);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = rng.next_below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256::new(99);
        let n = 20_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.next_gaussian();
            sum += g;
            sum2 += g * g;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::new(3);
        for _ in 0..1000 {
            let f = rng.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
