//! Engine host: a dedicated worker thread that owns a PJRT executable.
//!
//! PJRT objects wrap raw pointers and are neither `Send` nor `Sync`, so
//! the host *constructs* the runtime inside its thread and communicates
//! over bounded channels — which doubles as the coordinator's
//! backpressure boundary (a full queue blocks the producing session, the
//! streaming analogue of the accelerator's fixed 256-cycle cadence).

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::params::CHANNELS;

use super::{EngineKind, Runtime, WindowOutput};

/// One prediction-window job.
pub struct Job {
    /// Opaque tag the submitter uses to route the reply (session id, ...).
    pub tag: u64,
    /// Window sequence number within the tag.
    pub seq: u64,
    /// Frame-major `[frames * CHANNELS]` LBP codes.
    pub codes: Vec<u8>,
    /// AM plane, shared across jobs of one session.
    pub am: Arc<Vec<i32>>,
    pub threshold: i32,
    pub submitted: Instant,
}

/// A completed job.
pub struct Completion {
    pub tag: u64,
    pub seq: u64,
    pub output: crate::Result<WindowOutput>,
    pub submitted: Instant,
    pub finished: Instant,
}

impl Completion {
    pub fn latency_s(&self) -> f64 {
        (self.finished - self.submitted).as_secs_f64()
    }
}

/// Handle to the engine worker thread.
pub struct EngineHost {
    tx: SyncSender<Job>,
    pub completions: Receiver<Completion>,
    handle: Option<JoinHandle<()>>,
}

impl EngineHost {
    /// Spawn a worker owning a freshly-compiled engine for `kind`.
    ///
    /// `queue_depth` bounds the in-flight jobs (backpressure). Compile
    /// errors surface through the returned channel's first receive.
    pub fn spawn(
        artifacts_dir: PathBuf,
        kind: EngineKind,
        queue_depth: usize,
    ) -> crate::Result<EngineHost> {
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let (done_tx, done_rx) = sync_channel::<Completion>(queue_depth.max(1) * 2);
        // Report engine construction success/failure synchronously.
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<()>>(1);

        let handle = std::thread::Builder::new()
            .name(format!("engine-{kind:?}"))
            .spawn(move || {
                let engine = match Runtime::new(&artifacts_dir).and_then(|rt| match kind {
                    EngineKind::SparseWindow => rt.load_sparse(),
                    EngineKind::DenseWindow => rt.load_dense(),
                }) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    debug_assert_eq!(job.codes.len() % CHANNELS, 0);
                    let output = engine.run(&job.codes, &job.am, job.threshold);
                    let completion = Completion {
                        tag: job.tag,
                        seq: job.seq,
                        output,
                        submitted: job.submitted,
                        finished: Instant::now(),
                    };
                    if done_tx.send(completion).is_err() {
                        break; // consumer gone
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;

        Ok(EngineHost {
            tx,
            completions: done_rx,
            handle: Some(handle),
        })
    }

    /// Blocking submit (backpressure: waits while the queue is full).
    pub fn submit(&self, job: Job) -> crate::Result<()> {
        self.tx
            .send(job)
            .map_err(|_| anyhow::anyhow!("engine worker has shut down"))
    }

    /// Non-blocking submit; `Err(job)` when the queue is full.
    pub fn try_submit(&self, job: Job) -> Result<(), Job> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        // Close the job queue, then join the worker.
        let (dead_tx, _) = sync_channel::<Job>(1);
        let tx = std::mem::replace(&mut self.tx, dead_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
