//! Engine host: a dedicated worker thread that owns a window engine.
//!
//! One [`EngineHost`] serves one engine — native golden model or, with the
//! `pjrt` feature, a PJRT executable. The engine is *constructed inside*
//! the worker thread (PJRT objects wrap raw pointers and are neither
//! `Send` nor `Sync`; the native engine simply follows the same
//! discipline) and communicates over bounded channels — which doubles as
//! the coordinator's backpressure boundary (a full queue blocks the
//! producing session, the streaming analogue of the accelerator's fixed
//! 256-cycle cadence).
//!
//! ## Batching and coalescing
//!
//! A [`Job`] owns a window *range*: `thresholds.len()` consecutive
//! windows of one session, executed through the engine's `run_batch`.
//! Before executing, the worker drains whatever is already queued and
//! **coalesces consecutive jobs that share an AM** (`Arc` identity) into
//! one `run_batch` call, amortising the AM hold across every queued
//! window. Coalescing never reorders: jobs are grouped in arrival order
//! only, and each job gets its own [`Completion`] (original `tag`/`seq`),
//! delivered in submission order. If a coalesced call fails, the group is
//! re-run job by job so the error lands on the offending job alone.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::err;
use crate::hdc::am::AmPlane;
use crate::hdc::classifier::ClassifierConfig;

use super::native::{NativeWindowEngine, WINDOW_CODES};
use super::{EngineKind, WindowOutput};

/// Which engine the worker thread should construct.
///
/// The spec (unlike the engine itself) is `Send`, so the host can ship it
/// into the worker and surface construction errors synchronously from
/// [`EngineHost::spawn`].
pub enum EngineSpec {
    /// Bit-accurate golden model — always available, no artifacts.
    Native { cfg: ClassifierConfig },
    /// AOT HLO artifacts through the PJRT client (`--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt { artifacts_dir: std::path::PathBuf },
}

/// The engine actually owned by the worker thread.
enum Executor {
    Native(NativeWindowEngine),
    #[cfg(feature = "pjrt")]
    Pjrt(super::pjrt::WindowEngine),
}

impl Executor {
    fn build(spec: EngineSpec, kind: EngineKind) -> crate::Result<Executor> {
        match spec {
            EngineSpec::Native { cfg } => Ok(Executor::Native(NativeWindowEngine::new(kind, cfg))),
            #[cfg(feature = "pjrt")]
            EngineSpec::Pjrt { artifacts_dir } => {
                let rt = super::pjrt::Runtime::new(&artifacts_dir)?;
                let engine = match kind {
                    EngineKind::SparseWindow => rt.load_sparse()?,
                    EngineKind::DenseWindow => rt.load_dense()?,
                };
                Ok(Executor::Pjrt(engine))
            }
        }
    }

    fn run_batch(
        &mut self,
        codes: &[u8],
        am: &AmPlane,
        thresholds: &[i32],
    ) -> crate::Result<Vec<WindowOutput>> {
        match self {
            Executor::Native(engine) => engine.run_batch(codes, am, thresholds),
            #[cfg(feature = "pjrt")]
            Executor::Pjrt(engine) => engine.run_batch(codes, am.i32s(), thresholds),
        }
    }
}

/// Execute a run of AM-sharing jobs, returning one result per job in
/// input order.
///
/// The fast path concatenates the jobs into a single `run_batch` call and
/// splits the outputs back per job. It is taken only when every job's
/// shape is self-consistent and the batched call succeeds; otherwise each
/// job runs on its own so an error is attributed to the job that caused
/// it (the per-job results are bit-exact either way — `run_batch` is
/// pinned against serial execution at every batch size).
fn run_coalesced(engine: &mut Executor, group: &[Job]) -> Vec<crate::Result<Vec<WindowOutput>>> {
    let shapes_ok = group
        .iter()
        .all(|job| job.codes.len() == job.windows() * WINDOW_CODES);
    if group.len() > 1 && shapes_ok {
        let codes: Vec<u8> = group.iter().flat_map(|job| job.codes.iter().copied()).collect();
        let thresholds: Vec<i32> = group
            .iter()
            .flat_map(|job| job.thresholds.iter().copied())
            .collect();
        if let Ok(mut outputs) = engine.run_batch(&codes, &group[0].am, &thresholds) {
            let mut per_job = Vec::with_capacity(group.len());
            for job in group {
                let rest = outputs.split_off(job.windows());
                per_job.push(Ok(std::mem::replace(&mut outputs, rest)));
            }
            return per_job;
        }
    }
    group
        .iter()
        .map(|job| engine.run_batch(&job.codes, &job.am, &job.thresholds))
        .collect()
}

/// One prediction job: a range of `thresholds.len()` consecutive windows
/// of one session (N=1 is the degenerate case, not the design center).
pub struct Job {
    /// Opaque tag the submitter uses to route the reply (session id, ...).
    pub tag: u64,
    /// Sequence number of the job's *first* window within the tag;
    /// window `k` of the batch is `seq + k`.
    pub seq: u64,
    /// Frame-major LBP codes of all windows, concatenated
    /// (`thresholds.len() * FRAMES_PER_PREDICTION * CHANNELS`).
    pub codes: Vec<u8>,
    /// AM shared across jobs of one session (`Arc` identity is the
    /// worker's coalescing key; the decode happens at most once).
    pub am: Arc<AmPlane>,
    /// One temporal thinning threshold per window — the batch size.
    pub thresholds: Vec<i32>,
    /// Model version the windows are scored against — opaque to the
    /// worker, echoed in the [`Completion`] so wire-level consumers can
    /// label predictions truthfully (0 = unversioned).
    pub version: u64,
    pub submitted: Instant,
}

impl Job {
    /// Windows in this job's range.
    pub fn windows(&self) -> usize {
        self.thresholds.len()
    }

    /// A job carrying a single window (the N=1 degenerate case).
    pub fn single(tag: u64, seq: u64, codes: Vec<u8>, am: Arc<AmPlane>, threshold: i32) -> Job {
        Job {
            tag,
            seq,
            codes,
            am,
            thresholds: vec![threshold],
            version: 0,
            submitted: Instant::now(),
        }
    }
}

/// A completed job: one [`WindowOutput`] per window of the job's range,
/// in window order.
pub struct Completion {
    pub tag: u64,
    pub seq: u64,
    /// Windows the job carried (so failures account for every window).
    pub windows: usize,
    /// The job's model-version label, echoed back.
    pub version: u64,
    pub outputs: crate::Result<Vec<WindowOutput>>,
    pub submitted: Instant,
    pub finished: Instant,
}

impl Completion {
    pub fn latency_s(&self) -> f64 {
        (self.finished - self.submitted).as_secs_f64()
    }
}

/// Handle to the engine worker thread.
pub struct EngineHost {
    tx: SyncSender<Job>,
    pub completions: Receiver<Completion>,
    handle: Option<JoinHandle<()>>,
}

impl EngineHost {
    /// Spawn a worker owning a freshly-constructed engine for `kind`.
    ///
    /// `queue_depth` bounds the in-flight jobs (backpressure).
    /// Construction errors (missing/corrupt artifacts, stub PJRT, …)
    /// surface synchronously from this call.
    pub fn spawn(
        spec: EngineSpec,
        kind: EngineKind,
        queue_depth: usize,
    ) -> crate::Result<EngineHost> {
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let (done_tx, done_rx) = sync_channel::<Completion>(queue_depth.max(1) * 2);
        // Report engine construction success/failure synchronously.
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<()>>(1);

        let handle = std::thread::Builder::new()
            .name(format!("engine-{kind:?}"))
            .spawn(move || {
                let mut engine = match Executor::build(spec, kind) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                'serve: while let Ok(first) = rx.recv() {
                    // Drain whatever is already queued (never waits), then
                    // execute arrival-order runs of AM-sharing jobs as one
                    // run_batch call each.
                    let mut jobs = vec![first];
                    while let Ok(job) = rx.try_recv() {
                        jobs.push(job);
                    }
                    let mut start = 0;
                    while start < jobs.len() {
                        let mut end = start + 1;
                        while end < jobs.len() && Arc::ptr_eq(&jobs[start].am, &jobs[end].am) {
                            end += 1;
                        }
                        let group = &jobs[start..end];
                        let results = run_coalesced(&mut engine, group);
                        let finished = Instant::now();
                        for (job, outputs) in group.iter().zip(results) {
                            let completion = Completion {
                                tag: job.tag,
                                seq: job.seq,
                                windows: job.windows(),
                                version: job.version,
                                outputs,
                                submitted: job.submitted,
                                finished,
                            };
                            if done_tx.send(completion).is_err() {
                                break 'serve; // consumer gone
                            }
                        }
                        start = end;
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| err!("engine thread died during startup"))??;

        Ok(EngineHost {
            tx,
            completions: done_rx,
            handle: Some(handle),
        })
    }

    /// Blocking submit (backpressure: waits while the queue is full).
    pub fn submit(&self, job: Job) -> crate::Result<()> {
        self.tx
            .send(job)
            .map_err(|_| err!("engine worker has shut down"))
    }

    /// Non-blocking submit; `Err(job)` when the queue is full.
    pub fn try_submit(&self, job: Job) -> Result<(), Job> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }

    /// A cloneable submission handle for multi-producer setups (one per
    /// wire connection actor). Senders share the host's bounded queue —
    /// backpressure is global — and completions still arrive on the
    /// host's single `completions` receiver in submission order per
    /// sender. Dropping every sender does *not* stop the worker; the
    /// host's own queue handle keeps it alive until the host drops.
    pub fn sender(&self) -> JobSender {
        JobSender {
            tx: self.tx.clone(),
        }
    }
}

/// Cloneable job-submission handle ([`EngineHost::sender`]).
#[derive(Clone)]
pub struct JobSender {
    tx: SyncSender<Job>,
}

impl JobSender {
    /// Blocking submit (backpressure: waits while the queue is full).
    pub fn submit(&self, job: Job) -> crate::Result<()> {
        self.tx
            .send(job)
            .map_err(|_| err!("engine worker has shut down"))
    }

    /// Non-blocking submit; `Err(job)` when the queue is full.
    pub fn try_submit(&self, job: Job) -> Result<(), Job> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        // Close the job queue AND detach the completions receiver before
        // joining: a worker blocked on a full completions channel (the
        // consumer stopped draining) only observes shutdown through the
        // receiver going away — joining with it still alive would
        // deadlock. Undelivered completions are discarded.
        let (dead_tx, _) = sync_channel::<Job>(1);
        drop(std::mem::replace(&mut self.tx, dead_tx));
        let (_dead_done_tx, dead_done_rx) = sync_channel::<Completion>(1);
        drop(std::mem::replace(&mut self.completions, dead_done_rx));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::hdc::hv::Hv;
    use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION, LBP_CODES};
    use crate::rng::Xoshiro256;

    fn zero_am() -> Arc<AmPlane> {
        Arc::new(AmPlane::from_memory(&AssociativeMemory::new(Hv::zero(), Hv::zero())))
    }

    fn job_on(am: &Arc<AmPlane>, seq: u64, codes: Vec<u8>) -> Job {
        Job::single(1, seq, codes, am.clone(), 130)
    }

    fn spawn_native(queue_depth: usize) -> EngineHost {
        EngineHost::spawn(
            EngineSpec::Native {
                cfg: ClassifierConfig::optimized(),
            },
            EngineKind::SparseWindow,
            queue_depth,
        )
        .unwrap()
    }

    fn random_window(rng: &mut Xoshiro256) -> Vec<u8> {
        (0..FRAMES_PER_PREDICTION * CHANNELS)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect()
    }

    #[test]
    fn native_host_round_trip() {
        let host = spawn_native(2);
        let mut rng = Xoshiro256::new(1);
        let am = zero_am();
        host.submit(job_on(&am, 7, random_window(&mut rng))).unwrap();
        let done = host.completions.recv().unwrap();
        assert_eq!(done.seq, 7);
        assert_eq!(done.windows, 1);
        let outs = done.outputs.unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].query.len(), DIM);
        assert!(done.latency_s() >= 0.0);
    }

    #[test]
    fn malformed_job_surfaces_error_not_panic() {
        let host = spawn_native(2);
        let am = zero_am();
        // Wrong length: the worker must report the error through the
        // completion, then keep serving subsequent jobs.
        host.submit(job_on(&am, 0, vec![0u8; CHANNELS])).unwrap();
        let bad = host.completions.recv().unwrap();
        assert!(bad.outputs.is_err());
        assert_eq!(bad.windows, 1);

        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        host.submit(job_on(&am, 1, codes)).unwrap();
        let good = host.completions.recv().unwrap();
        assert!(good.outputs.is_ok(), "worker must survive a bad job");
    }

    #[test]
    fn try_submit_reports_full_queue() {
        let host = spawn_native(1);
        // Saturate: with depth 1 and a busy worker, eventually try_submit
        // must hand a job back instead of blocking.
        let am = zero_am();
        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        let mut handed_back = false;
        for seq in 0..64 {
            if host.try_submit(job_on(&am, seq, codes.clone())).is_err() {
                handed_back = true;
                break;
            }
        }
        assert!(handed_back, "bounded queue must exert backpressure");
        // Drain whatever completed so Drop joins cleanly.
        while host.completions.try_recv().is_ok() {}
    }

    #[test]
    fn coalescing_preserves_tags_seqs_and_order() {
        // Two sessions interleaved, more jobs than the queue depth, mixed
        // batch sizes: completions must come back in submission order with
        // the original tag/seq, and every output must equal a fresh serial
        // run of the same window.
        let mut rng = Xoshiro256::new(0xC0A1);
        let am_a = Arc::new(AmPlane::from_memory(&AssociativeMemory::new(
            Hv::random(&mut rng, 0.3),
            Hv::random(&mut rng, 0.3),
        )));
        let am_b = Arc::new(AmPlane::from_memory(&AssociativeMemory::new(
            Hv::random(&mut rng, 0.3),
            Hv::random(&mut rng, 0.3),
        )));

        struct Sent {
            tag: u64,
            seq: u64,
            codes: Vec<u8>,
            thresholds: Vec<i32>,
            am: Arc<AmPlane>,
        }
        let mut sent = Vec::new();
        let mut seqs = [0u64, 0u64];
        for i in 0..12u64 {
            // Runs of 3 same-AM jobs so arrival-order coalescing has
            // actual material (alternating AMs would never group).
            let (tag, am) = if (i / 3) % 2 == 0 { (1, &am_a) } else { (2, &am_b) };
            let windows = 1 + (i as usize % 3);
            let codes: Vec<u8> = (0..windows).flat_map(|_| random_window(&mut rng)).collect();
            let thresholds: Vec<i32> = (0..windows).map(|w| 90 + 20 * w as i32).collect();
            sent.push(Sent {
                tag,
                seq: seqs[tag as usize - 1],
                codes,
                thresholds,
                am: am.clone(),
            });
            seqs[tag as usize - 1] += windows as u64;
        }

        let host = spawn_native(4);
        let mut completions = Vec::new();
        for s in &sent {
            host.submit(Job {
                tag: s.tag,
                seq: s.seq,
                codes: s.codes.clone(),
                am: s.am.clone(),
                thresholds: s.thresholds.clone(),
                version: 3,
                submitted: Instant::now(),
            })
            .unwrap();
        }
        for _ in 0..sent.len() {
            completions.push(host.completions.recv().unwrap());
        }

        let mut serial =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        for (s, c) in sent.iter().zip(&completions) {
            assert_eq!((c.tag, c.seq), (s.tag, s.seq), "submission order kept");
            assert_eq!(c.windows, s.thresholds.len());
            assert_eq!(c.version, 3, "version label echoed through coalescing");
            let outs = c.outputs.as_ref().unwrap();
            assert_eq!(outs.len(), s.thresholds.len());
            for (w, &t) in s.thresholds.iter().enumerate() {
                let expect = serial
                    .run(&s.codes[w * WINDOW_CODES..(w + 1) * WINDOW_CODES], s.am.i32s(), t)
                    .unwrap();
                assert_eq!(outs[w].scores, expect.scores);
                assert_eq!(outs[w].query, expect.query);
            }
        }
    }

    #[test]
    fn coalesced_error_lands_on_offending_job_only() {
        let mut rng = Xoshiro256::new(0xE44);
        let am = zero_am();
        let host = spawn_native(8);
        // good, bad (truncated codes), good — all sharing one AM so they
        // are coalescing candidates whenever they queue up together.
        host.submit(job_on(&am, 0, random_window(&mut rng))).unwrap();
        host.submit(job_on(&am, 1, vec![0u8; 7])).unwrap();
        host.submit(job_on(&am, 2, random_window(&mut rng))).unwrap();
        let a = host.completions.recv().unwrap();
        let b = host.completions.recv().unwrap();
        let c = host.completions.recv().unwrap();
        assert!(a.outputs.is_ok(), "seq 0 must succeed");
        assert!(b.outputs.is_err(), "seq 1 carries the shape error");
        assert!(c.outputs.is_ok(), "seq 2 must succeed");
        assert_eq!((a.seq, b.seq, c.seq), (0, 1, 2));
    }

    #[test]
    fn mid_queue_model_swap_never_mixes_planes() {
        // The registry hot-swap contract at the engine level: when a
        // session's jobs switch from model v1's plane to v2's mid-queue,
        // completions stay in submission order and every window is scored
        // against exactly the plane its job carried — the Arc-identity
        // coalescing key makes mixing versions inside one run_batch call
        // impossible.
        let mut rng = Xoshiro256::new(0x5A47);
        let v1 = Arc::new(AmPlane::from_memory(&AssociativeMemory::new(
            Hv::random(&mut rng, 0.3),
            Hv::random(&mut rng, 0.3),
        )));
        let v2 = Arc::new(AmPlane::from_memory(&AssociativeMemory::new(
            Hv::random(&mut rng, 0.3),
            Hv::random(&mut rng, 0.3),
        )));
        let windows: Vec<Vec<u8>> = (0..8).map(|_| random_window(&mut rng)).collect();

        let host = spawn_native(8);
        for (seq, codes) in windows.iter().enumerate() {
            let am = if seq < 4 { &v1 } else { &v2 };
            host.submit(job_on(am, seq as u64, codes.clone())).unwrap();
        }
        let mut serial =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        for seq in 0..8usize {
            let c = host.completions.recv().unwrap();
            assert_eq!(c.seq, seq as u64, "submission order preserved across the swap");
            let am = if seq < 4 { &v1 } else { &v2 };
            let expect = serial.run(&windows[seq], am.i32s(), 130).unwrap();
            let outs = c.outputs.unwrap();
            assert_eq!(outs[0].scores, expect.scores, "seq {seq} scored on the wrong plane");
            assert_eq!(outs[0].query, expect.query);
        }
    }

    #[test]
    fn shared_am_plane_decodes_at_most_once_across_jobs() {
        // The ISSUE regression guard: jobs sharing one `Arc<AmPlane>` must
        // reuse the decoded plane (the old path re-decoded per call).
        let mut rng = Xoshiro256::new(0xA51);
        let raw: Vec<i32> = AssociativeMemory::new(
            Hv::random(&mut rng, 0.3),
            Hv::random(&mut rng, 0.3),
        )
        .to_i32s();
        let am = Arc::new(AmPlane::from_i32s(&raw).unwrap());
        assert_eq!(am.decode_count(), 0);
        let host = spawn_native(4);
        for seq in 0..6 {
            host.submit(job_on(&am, seq, random_window(&mut rng))).unwrap();
        }
        for _ in 0..6 {
            assert!(host.completions.recv().unwrap().outputs.is_ok());
        }
        // The completion channel recv synchronises with the worker's
        // sends, so the counter read is ordered after every decode.
        assert_eq!(am.decode_count(), 1, "decode must happen exactly once");
    }

    #[test]
    fn cloned_senders_feed_one_worker() {
        // The wire server's multi-producer shape: N actor threads each
        // own a JobSender clone; every job completes on the host's
        // single completions receiver.
        let host = spawn_native(8);
        let am = zero_am();
        let mut rng = Xoshiro256::new(0x5E4D);
        let windows: Vec<Vec<u8>> = (0..6).map(|_| random_window(&mut rng)).collect();
        let handles: Vec<_> = windows
            .iter()
            .enumerate()
            .map(|(i, codes)| {
                let sender = host.sender();
                let am = am.clone();
                let codes = codes.clone();
                std::thread::spawn(move || {
                    let mut job = Job::single(i as u64, 0, codes, am, 130);
                    job.version = 7;
                    sender.submit(job).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut tags = Vec::new();
        for _ in 0..windows.len() {
            let c = host.completions.recv().unwrap();
            assert!(c.outputs.is_ok());
            assert_eq!(c.version, 7);
            tags.push(c.tag);
        }
        tags.sort_unstable();
        assert_eq!(tags, vec![0, 1, 2, 3, 4, 5]);
    }
}
