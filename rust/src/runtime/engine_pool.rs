//! Engine host: a dedicated worker thread that owns a window engine.
//!
//! One [`EngineHost`] serves one engine — native golden model or, with the
//! `pjrt` feature, a PJRT executable. The engine is *constructed inside*
//! the worker thread (PJRT objects wrap raw pointers and are neither
//! `Send` nor `Sync`; the native engine simply follows the same
//! discipline) and communicates over bounded channels — which doubles as
//! the coordinator's backpressure boundary (a full queue blocks the
//! producing session, the streaming analogue of the accelerator's fixed
//! 256-cycle cadence).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::err;
use crate::hdc::classifier::ClassifierConfig;

use super::native::NativeWindowEngine;
use super::{EngineKind, WindowOutput};

/// Which engine the worker thread should construct.
///
/// The spec (unlike the engine itself) is `Send`, so the host can ship it
/// into the worker and surface construction errors synchronously from
/// [`EngineHost::spawn`].
pub enum EngineSpec {
    /// Bit-accurate golden model — always available, no artifacts.
    Native { cfg: ClassifierConfig },
    /// AOT HLO artifacts through the PJRT client (`--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt { artifacts_dir: std::path::PathBuf },
}

/// The engine actually owned by the worker thread.
enum Executor {
    Native(NativeWindowEngine),
    #[cfg(feature = "pjrt")]
    Pjrt(super::pjrt::WindowEngine),
}

impl Executor {
    fn build(spec: EngineSpec, kind: EngineKind) -> crate::Result<Executor> {
        match spec {
            EngineSpec::Native { cfg } => Ok(Executor::Native(NativeWindowEngine::new(kind, cfg))),
            #[cfg(feature = "pjrt")]
            EngineSpec::Pjrt { artifacts_dir } => {
                let rt = super::pjrt::Runtime::new(&artifacts_dir)?;
                let engine = match kind {
                    EngineKind::SparseWindow => rt.load_sparse()?,
                    EngineKind::DenseWindow => rt.load_dense()?,
                };
                Ok(Executor::Pjrt(engine))
            }
        }
    }

    fn run(&mut self, codes: &[u8], am: &[i32], threshold: i32) -> crate::Result<WindowOutput> {
        match self {
            Executor::Native(engine) => engine.run(codes, am, threshold),
            #[cfg(feature = "pjrt")]
            Executor::Pjrt(engine) => engine.run(codes, am, threshold),
        }
    }
}

/// One prediction-window job.
pub struct Job {
    /// Opaque tag the submitter uses to route the reply (session id, ...).
    pub tag: u64,
    /// Window sequence number within the tag.
    pub seq: u64,
    /// Frame-major `[frames * CHANNELS]` LBP codes.
    pub codes: Vec<u8>,
    /// AM plane, shared across jobs of one session.
    pub am: Arc<Vec<i32>>,
    pub threshold: i32,
    pub submitted: Instant,
}

/// A completed job.
pub struct Completion {
    pub tag: u64,
    pub seq: u64,
    pub output: crate::Result<WindowOutput>,
    pub submitted: Instant,
    pub finished: Instant,
}

impl Completion {
    pub fn latency_s(&self) -> f64 {
        (self.finished - self.submitted).as_secs_f64()
    }
}

/// Handle to the engine worker thread.
pub struct EngineHost {
    tx: SyncSender<Job>,
    pub completions: Receiver<Completion>,
    handle: Option<JoinHandle<()>>,
}

impl EngineHost {
    /// Spawn a worker owning a freshly-constructed engine for `kind`.
    ///
    /// `queue_depth` bounds the in-flight jobs (backpressure).
    /// Construction errors (missing/corrupt artifacts, stub PJRT, …)
    /// surface synchronously from this call.
    pub fn spawn(
        spec: EngineSpec,
        kind: EngineKind,
        queue_depth: usize,
    ) -> crate::Result<EngineHost> {
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let (done_tx, done_rx) = sync_channel::<Completion>(queue_depth.max(1) * 2);
        // Report engine construction success/failure synchronously.
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<()>>(1);

        let handle = std::thread::Builder::new()
            .name(format!("engine-{kind:?}"))
            .spawn(move || {
                let mut engine = match Executor::build(spec, kind) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let output = engine.run(&job.codes, &job.am, job.threshold);
                    let completion = Completion {
                        tag: job.tag,
                        seq: job.seq,
                        output,
                        submitted: job.submitted,
                        finished: Instant::now(),
                    };
                    if done_tx.send(completion).is_err() {
                        break; // consumer gone
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| err!("engine thread died during startup"))??;

        Ok(EngineHost {
            tx,
            completions: done_rx,
            handle: Some(handle),
        })
    }

    /// Blocking submit (backpressure: waits while the queue is full).
    pub fn submit(&self, job: Job) -> crate::Result<()> {
        self.tx
            .send(job)
            .map_err(|_| err!("engine worker has shut down"))
    }

    /// Non-blocking submit; `Err(job)` when the queue is full.
    pub fn try_submit(&self, job: Job) -> Result<(), Job> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        // Close the job queue AND detach the completions receiver before
        // joining: a worker blocked on a full completions channel (the
        // consumer stopped draining) only observes shutdown through the
        // receiver going away — joining with it still alive would
        // deadlock. Undelivered completions are discarded.
        let (dead_tx, _) = sync_channel::<Job>(1);
        drop(std::mem::replace(&mut self.tx, dead_tx));
        let (_dead_done_tx, dead_done_rx) = sync_channel::<Completion>(1);
        drop(std::mem::replace(&mut self.completions, dead_done_rx));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION, LBP_CODES, NUM_CLASSES};
    use crate::rng::Xoshiro256;

    fn job(seq: u64, codes: Vec<u8>) -> Job {
        Job {
            tag: 1,
            seq,
            codes,
            am: Arc::new(vec![0i32; NUM_CLASSES * DIM]),
            threshold: 130,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn native_host_round_trip() {
        let host = EngineHost::spawn(
            EngineSpec::Native {
                cfg: ClassifierConfig::optimized(),
            },
            EngineKind::SparseWindow,
            2,
        )
        .unwrap();
        let mut rng = Xoshiro256::new(1);
        let codes: Vec<u8> = (0..FRAMES_PER_PREDICTION * CHANNELS)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect();
        host.submit(job(7, codes)).unwrap();
        let done = host.completions.recv().unwrap();
        assert_eq!(done.seq, 7);
        let out = done.output.unwrap();
        assert_eq!(out.query.len(), DIM);
        assert!(done.latency_s() >= 0.0);
    }

    #[test]
    fn malformed_job_surfaces_error_not_panic() {
        let host = EngineHost::spawn(
            EngineSpec::Native {
                cfg: ClassifierConfig::optimized(),
            },
            EngineKind::SparseWindow,
            2,
        )
        .unwrap();
        // Wrong length: the worker must report the error through the
        // completion, then keep serving subsequent jobs.
        host.submit(job(0, vec![0u8; CHANNELS])).unwrap();
        let bad = host.completions.recv().unwrap();
        assert!(bad.output.is_err());

        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        host.submit(job(1, codes)).unwrap();
        let good = host.completions.recv().unwrap();
        assert!(good.output.is_ok(), "worker must survive a bad job");
    }

    #[test]
    fn try_submit_reports_full_queue() {
        let host = EngineHost::spawn(
            EngineSpec::Native {
                cfg: ClassifierConfig::optimized(),
            },
            EngineKind::SparseWindow,
            1,
        )
        .unwrap();
        // Saturate: with depth 1 and a busy worker, eventually try_submit
        // must hand a job back instead of blocking.
        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        let mut handed_back = false;
        for seq in 0..64 {
            if host.try_submit(job(seq, codes.clone())).is_err() {
                handed_back = true;
                break;
            }
        }
        assert!(handed_back, "bounded queue must exert backpressure");
        // Drain whatever completed so Drop joins cleanly.
        while host.completions.try_recv().is_ok() {}
    }
}
