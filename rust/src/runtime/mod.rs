//! Window-engine runtime: the execution layer behind the coordinator.
//!
//! Two engines implement the same batch-first contract behind the
//! [`engine_pool`] worker (`Job`/`Completion` channels):
//! `run_batch(codes /* N windows */, am, thresholds /* len N */) →
//! Vec<`[`WindowOutput`]`>`, with the single-window
//! `(codes, am, threshold)` `run` as the N=1 degenerate case:
//!
//! * [`native`] — the bit-accurate golden model from [`crate::hdc`];
//!   always compiled, needs **no artifacts** and no external crates. This
//!   is what the default build serves with.
//! * [`pjrt`] *(cargo feature `pjrt`)* — loads the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them through the
//!   `xla` PJRT client, i.e. the full Rust + JAX + Pallas stack on the
//!   request path.
//!
//! `artifacts/` (and `make artifacts` / `python/compile/aot.py`) are only
//! needed when building with `--features pjrt`; without the feature the
//! PJRT symbols do not exist and `Backend::Pjrt` degrades to a clear
//! runtime error in [`crate::coordinator::server`].
//!
//! [`Manifest`] (the artifact metadata parser + cross-language item-memory
//! digest check) is always compiled — it needs nothing beyond
//! [`crate::config`] and is unit-tested offline.

use std::path::Path;

use crate::config::ConfigFile;
use crate::ensure;
use crate::error::Context;
use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION, NUM_CLASSES};

pub mod engine_pool;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{Runtime, WindowEngine};

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub frames: usize,
    pub channels: usize,
    pub dim: usize,
    pub num_classes: usize,
    pub im_seed: u64,
    pub im_digest: u64,
    pub sparse_window: String,
    pub dense_window: String,
}

fn parse_hex_or_dec(s: &str) -> crate::Result<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        Ok(u64::from_str_radix(hex, 16)?)
    } else {
        Ok(s.parse()?)
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.txt");
        let file = ConfigFile::load(&path)?;
        let get = |k: &str| -> crate::Result<&str> {
            file.get(k)
                .with_context(|| format!("manifest missing key {k}"))
        };
        Ok(Manifest {
            frames: get("frames")?.parse()?,
            channels: get("channels")?.parse()?,
            dim: get("dim")?.parse()?,
            num_classes: get("num_classes")?.parse()?,
            im_seed: parse_hex_or_dec(get("im_seed")?)?,
            im_digest: parse_hex_or_dec(get("im_digest")?)?,
            sparse_window: get("sparse_window")?.to_string(),
            dense_window: get("dense_window")?.to_string(),
        })
    }

    /// Check the artifact was built for this binary's architecture
    /// constants and item-memory generator.
    pub fn validate(&self) -> crate::Result<()> {
        ensure!(self.channels == CHANNELS, "manifest channels {}", self.channels);
        ensure!(self.dim == DIM, "manifest dim {}", self.dim);
        ensure!(self.num_classes == NUM_CLASSES, "manifest classes {}", self.num_classes);
        ensure!(
            self.frames == FRAMES_PER_PREDICTION,
            "manifest frames {}",
            self.frames
        );
        let rust_digest = crate::hdc::im::ItemMemory::generate(self.im_seed).digest();
        ensure!(
            rust_digest == self.im_digest,
            "item-memory digest mismatch: rust {rust_digest:#018x} vs artifact {:#018x} — \
             rebuild artifacts (`make artifacts`)",
            self.im_digest
        );
        Ok(())
    }
}

/// Which compiled model a window engine wraps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// (codes, am, threshold) → (scores, query)
    SparseWindow,
    /// (codes, am) → (scores, query)
    DenseWindow,
}

/// Result of one prediction-window execution.
#[derive(Clone, Debug)]
pub struct WindowOutput {
    pub scores: [i32; NUM_CLASSES],
    pub query: Vec<i32>,
}

impl WindowOutput {
    pub fn is_ictal(&self) -> bool {
        self.scores[crate::params::CLASS_ICTAL] > self.scores[crate::params::CLASS_INTERICTAL]
    }

    pub fn margin(&self) -> i64 {
        self.scores[crate::params::CLASS_ICTAL] as i64
            - self.scores[crate::params::CLASS_INTERICTAL] as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "\
# comment
frames = 256
channels = 64
dim = 1024
segments = 8
num_classes = 2
im_seed = 0x5eed1ee600000001
im_digest = 0xf7cdf969f2b33a13
sparse_window = sparse_window.hlo.txt
dense_window = dense_window.hlo.txt
";
        let dir = std::env::temp_dir().join(format!("hdc_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.frames, 256);
        assert_eq!(m.im_seed, crate::params::IM_SEED);
        m.validate().expect("digest must match the rust generator");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_digest_mismatch_rejected() {
        let text = "\
frames = 256
channels = 64
dim = 1024
num_classes = 2
im_seed = 0x5eed1ee600000001
im_digest = 0xdeadbeefdeadbeef
sparse_window = s.hlo.txt
dense_window = d.hlo.txt
";
        let dir = std::env::temp_dir().join(format!("hdc_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn window_output_decision_helpers() {
        let out = WindowOutput {
            scores: [10, 25],
            query: vec![0; DIM],
        };
        assert!(out.is_ictal());
        assert_eq!(out.margin(), 15);
        let tie = WindowOutput {
            scores: [25, 25],
            query: vec![0; DIM],
        };
        assert!(!tie.is_ictal(), "ties break toward interictal");
    }
}
